"""Deterministic, step-indexed synthetic data pipeline.

Fault-tolerance contract: ``batch_for_step(step)`` is a pure function of
(seed, step), so a restart from checkpoint step N reproduces the exact
byte-identical stream from step N+1 — no data-loader state to persist.

The token stream is a mixture of (a) a Zipf-like unigram draw and (b) short
deterministic motifs (so the model has learnable structure and the loss
visibly falls during the example runs).  Host-side numpy generation with
double-buffered prefetch; arrays are placed with the dp sharding.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_alpha: float = 1.2
    motif_period: int = 17


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        v = cfg.vocab_size
        # Zipf-ish unigram distribution over a clipped vocab
        ranks = np.arange(1, min(v, 4096) + 1, dtype=np.float64)
        probs = 1.0 / ranks ** data_cfg.zipf_alpha
        self._probs = probs / probs.sum()
        self._vocab = len(self._probs)

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data_cfg.seed, step]))
        b, s = self.shape.global_batch, self.shape.seq_len
        cfg = self.cfg
        tokens = rng.choice(self._vocab, size=(b, s), p=self._probs)
        # deterministic motif: position-dependent token every `period`
        period = self.data_cfg.motif_period
        pos = np.arange(s)
        motif_mask = (pos % period) == 0
        tokens[:, motif_mask] = (pos[motif_mask] // period) % 97 + 2
        tokens = tokens.astype(np.int32)

        if cfg.is_encdec:
            frames = rng.standard_normal(
                (b, s, cfg.d_model)).astype(np.float32) * 0.02
            return {"frames": frames, "tokens": tokens, "labels": tokens}
        if cfg.frontend == "vision":
            p = cfg.frontend_tokens
            tokens = tokens[:, : s - p]
            pe = rng.standard_normal(
                (b, p, cfg.d_model)).astype(np.float32) * 0.02
            return {"tokens": tokens, "patch_embeds": pe, "labels": tokens}
        return {"tokens": tokens, "labels": tokens}

    def place(self, batch: Dict[str, np.ndarray], ctx: ShardingCtx,
              model=None):
        if not ctx.enabled:
            import jax.numpy as jnp
            out = {}
            for k, v in batch.items():
                dt = jnp.bfloat16 if v.dtype == np.float32 else v.dtype
                out[k] = jnp.asarray(v, dtype=dt)
            return out
        out = {}
        for k, v in batch.items():
            axes = ("batch",) + (None,) * (v.ndim - 1)
            sh = ctx.sharding(axes, v.shape)
            arr = v.astype(np.float32) if v.dtype == np.float32 else v
            out[k] = jax.device_put(arr, sh)
        return out


class Prefetcher:
    """Double-buffered background prefetch of batch_for_step."""

    def __init__(self, source: SyntheticLM, ctx: ShardingCtx,
                 start_step: int = 0, depth: int = 2):
        self.source = source
        self.ctx = ctx
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_for_step(step)
            placed = self.source.place(batch, self.ctx)
            while not self._stop.is_set():
                try:
                    self.q.put((step, placed), timeout=1.0)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
