"""Train step: microbatched gradient accumulation + AdamW + optional
gradient compression.

Memory discipline for the large dense archs (DESIGN.md §4):
  * params fp32 master, FSDP+TP sharded; cast to bf16 once per step
    (hoisted out of the microbatch scan by XLA)
  * grads accumulated fp32 at param sharding (XLA reduce-scatters instead
    of all-reducing, because grad sharding == param sharding)
  * per-layer remat inside the model: saved activations = layer inputs of
    the current microbatch only
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.sharding import ShardingCtx
from repro.train import grad_compress
from repro.train.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    error_fb: Optional[Any]          # grad-compression error feedback


def init_state(model: Model, key, optimizer: AdamW,
               compress: bool = False) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      error_fb=(grad_compress.init_error_state(params)
                                if compress else None))


def _split_microbatches(batch: Dict[str, Any], n_micro: int,
                        ctx: ShardingCtx):
    """[GB, ...] -> [n_micro, GB/n_micro, ...] with microbatch dim
    replicated and the batch dim re-constrained onto dp."""
    def split(x):
        gb = x.shape[0]
        assert gb % n_micro == 0, (gb, n_micro)
        xm = x.reshape(n_micro, gb // n_micro, *x.shape[1:])
        if ctx.enabled:
            spec = ctx.spec((None, "batch") + (None,) * (x.ndim - 1),
                            xm.shape)
            xm = jax.lax.with_sharding_constraint(
                xm, jax.sharding.NamedSharding(ctx.mesh, spec))
        return xm
    return jax.tree.map(split, batch)


def make_train_step(model: Model, optimizer: AdamW, ctx: ShardingCtx,
                    num_microbatches: int = 1, compress: bool = False,
                    constrain_grads: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    constrain_grads: re-constrain each microbatch's gradients to the
    parameter sharding at the point of production, so XLA lowers the
    cross-data-parallel reduction as reduce-scatter instead of a
    full-tensor all-reduce (§Perf hillclimb: 16x less DP collective
    volume on the FSDP axis).
    """
    grad_shardings = None
    if constrain_grads and ctx.enabled:
        grad_shardings = model.param_shardings(ctx)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g, grads, grad_shardings)

    def loss_fn(params, microbatch):
        loss, metrics = model.loss(params, microbatch, ctx)
        return loss, metrics

    def train_step(state: TrainState, batch: Dict[str, Any]):
        params = state.params

        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = _constrain(grads)
        else:
            micro = _split_microbatches(batch, num_microbatches, ctx)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                acc, loss_sum = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                grads = _constrain(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_sum + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                mb_step, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
            metrics = {}

        error_fb = state.error_fb
        if compress and error_fb is not None:
            grads, error_fb = grad_compress.compress_tree(grads, error_fb)

        new_params, opt_state, opt_metrics = optimizer.update(
            grads, state.opt, params)
        out_metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, opt_state, error_fb), out_metrics

    return train_step


def state_specs(model: Model, ctx: ShardingCtx, compress: bool = False):
    """PartitionSpec pytree for TrainState (for jit in/out shardings)."""
    p = model.param_specs(ctx)
    from jax.sharding import PartitionSpec as P
    return TrainState(
        params=p,
        opt=AdamWState(step=P(), mu=jax.tree.map(lambda s: s, p),
                       nu=jax.tree.map(lambda s: s, p)),
        error_fb=jax.tree.map(lambda s: s, p) if compress else None,
    )


def state_shardings(model: Model, ctx: ShardingCtx, compress: bool = False):
    from jax.sharding import NamedSharding
    specs = state_specs(model, ctx, compress)
    if not ctx.enabled:
        return None
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))
