from repro.train.optimizer import AdamW, AdamWState, cosine_schedule, constant_schedule, global_norm
from repro.train.train_step import TrainState, init_state, make_train_step, state_specs, state_shardings
from repro.train.data import DataConfig, SyntheticLM, Prefetcher
from repro.train import grad_compress
