"""Gradient compression with error feedback (cross-pod DCN optimization).

int8 per-tensor-scaled quantization.  The quantize->(all-reduce)->dequantize
transform is convergent under error feedback: the residual e is carried in
the optimizer-side state and re-added before the next quantization
(1-bit-Adam / EF-SGD family).

Two entry points:
  * ``compress_tree`` / paired state — drop-in transform on the grad pytree
    inside train_step (what crosses the pod axis in a real deployment is
    the int8 payload; the dry-run's collective-bytes accounting uses this
    to size the cross-pod all-reduce).
  * ``compressed_psum`` — explicit shard_map demonstration of an int8
    all-reduce over a mesh axis, used by the tests to show numerics.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_tree(grads, error_state):
    """Quantize-dequantize each gradient leaf with error feedback.

    Returns (decompressed grads, new error state).  The quantized payload
    is what would transit the DCN; numerically this function is the
    round-trip the receiving side sees.
    """
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, error_state)
    deq = jax.tree.map(lambda cg: _dequantize(*_quantize(cg)), corrected)
    err = jax.tree.map(lambda cg, dg: cg - dg, corrected, deq)
    return deq, err


def compression_ratio() -> float:
    """Payload bytes ratio vs fp32 all-reduce (int8 + one fp32 scale)."""
    return 0.25


def compressed_psum(x, axis_name: str):
    """int8 all-reduce over a mesh axis (call inside shard_map):
    quantize locally, sum int32 payloads, dequantize with the max scale."""
    q, scale = _quantize(x)
    # consistent scale across the axis so the sum is well-defined
    scale_max = jax.lax.pmax(scale, axis_name)
    q2 = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max
