"""AdamW with fp32 master weights, global-norm clipping, and LR schedules.

All optimizer state mirrors the parameter sharding (FSDP over 'data',
TP-natural dims over 'model'), so the update is purely element-wise and
communication-free — gradients arrive already reduce-scattered by XLA
because grad sharding == param sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array              # int32 scalar
    mu: Any                      # first moment, like params
    nu: Any                      # second moment, like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, dict]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        if self.grad_clip_norm is not None:
            scale = jnp.minimum(1.0, self.grad_clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self.learning_rate(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            u = mh / (jnp.sqrt(vh) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant_schedule(lr_value: float):
    return lambda step: jnp.full((), lr_value, jnp.float32)
