"""Version-tolerant aliases for JAX APIs that moved between releases.

Everything in the repo that touches an API whose home changed across JAX
versions imports it from here, so a version bump is a one-file change:

  * ``tree_flatten_with_path`` / ``tree_map_with_path`` — exposed as
    ``jax.tree.*_with_path`` only in newer releases; older ones (e.g. the
    pinned 0.4.37) carry them under ``jax.tree_util`` only.
  * ``shard_map`` — top-level ``jax.shard_map`` in newer releases; under
    ``jax.experimental.shard_map`` before, with ``check_rep`` instead of
    the newer ``check_vma`` keyword.

Importing this module also enables ``jax_threefry_partitionable``.  With
the legacy (non-partitionable) threefry that 0.4.x defaults to, jitting an
RNG-consuming program with sharded ``out_shardings`` lets XLA partition
the counter stream differently per layout, so ``init`` under a (4, 2) mesh
draws DIFFERENT parameter values than the same key on one device (observed
0.09 max abs diff on an embedding table).  Partitionable threefry makes
random draws layout-invariant — sharded-vs-single-device training then
agrees to float-reassociation noise, which is what the elastic-checkpoint
and distributed-training tests require.
"""
from __future__ import annotations

import jax

# Layout-invariant RNG (see module docstring).  Must be set before any
# random bits are drawn under a sharded jit.
jax.config.update("jax_threefry_partitionable", True)

try:
    tree_flatten_with_path = jax.tree.flatten_with_path
    tree_map_with_path = jax.tree.map_with_path
except AttributeError:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path
    tree_map_with_path = jax.tree_util.tree_map_with_path

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        """Newer-style signature mapped onto the experimental API
        (``check_vma`` was called ``check_rep`` there)."""
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
