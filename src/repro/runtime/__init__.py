from repro.runtime.fault import DriverConfig, RunReport, SimulatedFailure, run
from repro.runtime.straggler import StragglerMonitor, StragglerEvent
