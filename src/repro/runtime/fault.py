"""Fault-tolerant training driver: checkpoint/restart, failure injection,
heartbeats.

The driver owns the outer loop:
  * periodic async checkpoints (every ``ckpt_every`` steps)
  * a heartbeat file touched every step (external watchdogs restart the
    job when it goes stale — the 1000-node deployment contract)
  * simulated failures (``fail_at_steps``) raise mid-step; the driver
    restores the latest committed checkpoint and replays — the
    deterministic step-indexed data pipeline makes the replay exact
  * bounded restarts (``max_restarts``)
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.checkpoint import ckpt


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    heartbeat_path: Optional[str] = None
    fail_at_steps: Sequence[int] = ()
    max_restarts: int = 3
    async_ckpt: bool = True


@dataclasses.dataclass
class RunReport:
    steps_run: int = 0
    restarts: int = 0
    restored_steps: List[int] = dataclasses.field(default_factory=list)
    losses: List[float] = dataclasses.field(default_factory=list)


def run(train_step: Callable, state, batch_for_step: Callable,
        cfg: DriverConfig, state_shardings=None,
        on_step: Optional[Callable[[int, Dict], None]] = None) -> RunReport:
    """Drive training with checkpoint/restart.

    train_step(state, batch) -> (state, metrics);
    batch_for_step(step) -> placed batch.
    """
    report = RunReport()
    fail_pending = set(cfg.fail_at_steps)
    step = 0
    restarts = 0

    # resume if a checkpoint exists
    last = ckpt.latest_step(cfg.ckpt_dir)
    if last is not None:
        state, _ = ckpt.restore(cfg.ckpt_dir, target=jax.eval_shape(
            lambda: state), shardings=state_shardings)
        step = last + 1
        report.restored_steps.append(last)

    while step < cfg.total_steps:
        try:
            if step in fail_pending:
                fail_pending.discard(step)
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = batch_for_step(step)
            state, metrics = train_step(state, batch)
            if cfg.heartbeat_path:
                with open(cfg.heartbeat_path, "w") as f:
                    f.write(f"{step} {time.time()}\n")
            if on_step is not None:
                on_step(step, metrics)
            if "loss" in metrics:
                report.losses.append(float(metrics["loss"]))
            if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
                ckpt.save(state, step, cfg.ckpt_dir,
                          asynchronous=cfg.async_ckpt)
            report.steps_run += 1
            step += 1
        except SimulatedFailure:
            restarts += 1
            report.restarts = restarts
            if restarts > cfg.max_restarts:
                raise
            ckpt.wait()
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is None:
                step = 0     # restart from scratch
                continue
            state, _ = ckpt.restore(cfg.ckpt_dir, target=jax.eval_shape(
                lambda: state), shardings=state_shardings)
            report.restored_steps.append(last)
            step = last + 1
    ckpt.wait()
    return report
