"""Straggler mitigation: per-step latency monitoring + mitigation hooks.

At multi-pod scale the dominant availability hazards are slow hosts (NIC
degradation, thermal throttle) rather than hard failures.  The monitor
keeps an EWMA + robust deviation of step times; a step slower than
``threshold``x the EWMA flags a straggler event.  Mitigation is pluggable:
the default action logs and (after ``evict_after`` consecutive events)
requests a remap — in a real deployment that triggers the elastic
restart path onto the healthy device set (checkpoint -> remap -> resume);
here it is observable through the report and tested with synthetic
latency injection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    ewma_s: float


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0
    alpha: float = 0.1
    evict_after: int = 3
    on_remap: Optional[Callable[[int], None]] = None

    ewma: Optional[float] = None
    consecutive: int = 0
    events: List[StragglerEvent] = dataclasses.field(default_factory=list)
    remaps: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        if self.ewma is None:
            self.ewma = duration_s
            return False
        flagged = duration_s > self.threshold * self.ewma
        if flagged:
            self.events.append(StragglerEvent(step, duration_s, self.ewma))
            self.consecutive += 1
            if self.consecutive >= self.evict_after:
                self.remaps.append(step)
                self.consecutive = 0
                if self.on_remap is not None:
                    self.on_remap(step)
        else:
            self.consecutive = 0
            # only fold healthy steps into the baseline
            self.ewma = (1 - self.alpha) * self.ewma \
                + self.alpha * duration_s
        return flagged

    def timed(self, fn):
        """Wrap a step function with timing + observation; the wrapped
        function's first argument is the step index."""
        import jax

        def wrapper(step, *a, **kw):
            t0 = time.perf_counter()
            out = fn(*a, **kw)
            jax.block_until_ready(out)
            self.observe(step, time.perf_counter() - t0)
            return out
        return wrapper
