"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests/benches must keep seeing 1 device.

Topology contract (DESIGN.md §4):
    single pod : (16, 16)    axes ("data", "model")      — 256 chips, ICI
    multi-pod  : (2, 16, 16) axes ("pod", "data", "model") — 512 chips,
                 pods linked by DCN; only gradient all-reduce crosses pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess sets device count)."""
    return jax.make_mesh((data, model), ("data", "model"))
