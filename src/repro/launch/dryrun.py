import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# initialization.  This module is the ONLY place the 512 placeholder
# devices exist — smoke tests and benchmarks see the real single device.

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from typing import Any, Dict, Optional   # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402

from repro.configs import arch_ids, get, SHAPES, applicable, \
    microbatches_for                                          # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models import build, from_mesh                     # noqa: E402
from repro.models.sharding import ShardingCtx                 # noqa: E402
from repro.roofline import analysis                           # noqa: E402
from repro.train.optimizer import AdamW, constant_schedule    # noqa: E402
from repro.train.train_step import (                          # noqa: E402
    init_state, make_train_step, state_shardings)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _tree_device_bytes(avals, shardings) -> int:
    """Per-device bytes of a sharded pytree of ShapeDtypeStructs."""
    total = 0
    for aval, sh in zip(jax.tree.leaves(avals), jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None
            or isinstance(x, jax.sharding.Sharding))):
        n = int(np.prod(aval.shape)) * aval.dtype.itemsize
        if sh is not None:
            n //= sh.num_devices // _replication(sh, aval.shape)
        total += n
    return total


def _replication(sharding, shape) -> int:
    spec = sharding.spec
    mesh = sharding.mesh
    used = set()
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(ax)
    rep = 1
    for name in mesh.axis_names:
        if name not in used:
            rep *= mesh.shape[name]
    return rep


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               sequence_parallel: bool = False,
               num_microbatches: Optional[int] = None,
               remat: Optional[bool] = None,
               donate: bool = True,
               baseline: bool = False,
               cfg_overrides: Optional[Dict[str, Any]] = None):
    """Build + lower one (arch × shape × mesh) cell.  Returns
    (lowered, ctx, meta).

    baseline=True reproduces the pre-hillclimb configuration (q-seq
    attention sharding, no gradient sharding constraints).
    cfg_overrides: dataclasses.replace overrides (e.g. ssm_chunk)."""
    cfg = get(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise ValueError(f"skip {arch}/{shape_name}: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = from_mesh(mesh, sequence_parallel=sequence_parallel,
                    force_seq_attn=baseline)
    model = build(cfg)
    dp = ctx.dp_size()

    in_specs = model.input_specs(shape)
    in_shards = model.input_shardings(shape, ctx, in_specs)
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(np.prod(list(mesh.shape.values()))),
        "params": model.param_count(),
        "active_params": cfg.active_param_count(),
    }

    if shape.kind == "train":
        n_micro = (num_microbatches if num_microbatches is not None
                   else microbatches_for(cfg, shape, dp))
        meta["num_microbatches"] = n_micro
        opt = AdamW(learning_rate=constant_schedule(1e-4))
        step_fn = make_train_step(model, opt, ctx,
                                  num_microbatches=n_micro,
                                  constrain_grads=not baseline)
        state_sds = jax.eval_shape(
            lambda k: init_state(model, k, opt), jax.random.PRNGKey(0))
        st_shards = state_shardings(model, ctx)
        fn = jax.jit(step_fn,
                     in_shardings=(st_shards, in_shards),
                     out_shardings=(st_shards, None),
                     donate_argnums=(0,) if donate else ())
        lowered = fn.lower(state_sds, in_specs)
        meta["state_bytes_per_chip"] = _tree_device_bytes(
            jax.tree.leaves(state_sds), jax.tree.leaves(
                st_shards, is_leaf=lambda x: x is None or isinstance(
                    x, jax.sharding.Sharding)))
        # model flops: 6 N D per token (fwd+bwd), D = global tokens
        tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = 6.0 * cfg.active_param_count() * tokens
        return lowered, ctx, meta

    params_sds = model.abstract_params()
    p_shards = model.param_shardings(ctx)

    if shape.kind == "prefill":
        def prefill_fn(params, inputs):
            return model.prefill(params, inputs, ctx)
        fn = jax.jit(prefill_fn, in_shardings=(p_shards, in_shards))
        lowered = fn.lower(params_sds, in_specs)
        tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = 2.0 * cfg.active_param_count() * tokens
        return lowered, ctx, meta

    # decode
    cache_sds = in_specs["caches"]
    cache_shards = in_shards["caches"]

    def decode_fn(params, tokens, caches, positions):
        return model.decode_step(params, tokens, caches, positions, ctx)

    fn = jax.jit(decode_fn,
                 in_shardings=(p_shards, in_shards["tokens"], cache_shards,
                               in_shards["positions"]),
                 out_shardings=None,
                 donate_argnums=(2,) if donate else ())
    lowered = fn.lower(params_sds, in_specs["tokens"], cache_sds,
                       in_specs["positions"])
    meta["model_flops"] = 2.0 * cfg.active_param_count() \
        * shape.global_batch
    meta["cache_bytes_per_chip"] = _tree_device_bytes(
        jax.tree.leaves(cache_sds), jax.tree.leaves(
            cache_shards, is_leaf=lambda x: x is None or isinstance(
                x, jax.sharding.Sharding)))
    return lowered, ctx, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             hlo_out: Optional[str] = None, **kw) -> Dict[str, Any]:
    t0 = time.time()
    lowered, ctx, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                    **kw)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:           # pragma: no cover
        mem, mem_info = None, {"error": str(e)}

    hlo = compiled.as_text()
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)
    report = analysis.analyze(
        arch, shape_name, meta["mesh"], meta["chips"], cost, hlo,
        meta["model_flops"],
        peak_memory_bytes=float(mem_info.get("temp_size_in_bytes", 0)))
    bridge = analysis.memsys_bridge(report)

    result = {
        **meta,
        "lower_s": t_lower, "compile_s": t_compile,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "roofline": report.to_json(),
        "memsys_bridge": bridge,
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(f"== {arch} × {shape_name} × {meta['mesh']} "
              f"({meta['chips']} chips) ==")
        print(f"   lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"   memory_analysis: {mem_info}")
        print(f"   cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        r = report
        print(f"   roofline: compute={r.compute_s*1e3:.2f}ms "
              f"memory={r.memory_s*1e3:.2f}ms "
              f"collective={r.collective_s*1e3:.2f}ms "
              f"-> dominant={r.dominant} "
              f"useful_flops={r.useful_flops_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{meta['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape) cell")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in arch_ids():
            cfg = get(arch)
            for shape_name, shape in SHAPES.items():
                ok, why = applicable(cfg, shape)
                if ok:
                    cells.append((arch, shape_name))
                else:
                    print(f"SKIP {arch} × {shape_name}: {why}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    results = []
    for arch, shape_name in cells:
        try:
            results.append(run_cell(
                arch, shape_name, multi_pod=args.multi_pod,
                out_dir=args.out,
                num_microbatches=args.microbatches,
                sequence_parallel=args.sequence_parallel,
                remat=False if args.no_remat else None))
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape_name))
    if results:
        # one batched [configs x catalog x mix-grid x shoreline] evaluation
        # over every compiled cell: each workload's design-space frontier
        reports = {
            f"{r['arch']}__{r['shape']}__{r['mesh']}":
                analysis.RooflineReport(**r["roofline"])
            for r in results}
        ds = analysis.bridge_design_space(reports)
        if args.all:
            # persist the aggregate only for full sweeps — a later
            # single-cell refresh must not clobber the all-cells space.
            # The artifact carries the joint (mix x backlog x shoreline)
            # analytic-vs-simulated frontier alongside the per-workload
            # bridge, so downstream consumers see where the cycle-level
            # simulation overrules the closed forms.
            from repro.core.space import DesignSpace, joint_frontier
            ds["joint_frontier"] = joint_frontier()
            # the serving-trace frontier rides along: which memory
            # approach wins at which (model, QPS) point, from synthetic
            # serving traces evaluated through the trace axis
            ds["serving_frontier"] = DesignSpace.serving_frontier()
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, analysis.DESIGN_SPACE_JSON),
                      "w") as f:
                json.dump(ds, f, indent=1)
        for name, w in ds["workloads"].items():
            print(f"frontier {name}: best={w['best']} ({w['mix']}) "
                  f"shoreline_sensitive={w['shoreline_sensitive']}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells")


if __name__ == "__main__":
    main()
