"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs the fault-tolerant driver (checkpoint/restart, heartbeats, straggler
monitor, deterministic data) on whatever devices exist — reduced configs
on one CPU device for local runs, or the production mesh on a real
cluster (--mesh data,model).
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (demo)")
    ap.add_argument("--mesh", default=None,
                    help="data,model mesh shape, e.g. 4,2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get
    from repro.configs.shapes import ShapeSpec
    from repro.models import ShardingCtx, build, from_mesh
    from repro.runtime import DriverConfig, StragglerMonitor, run
    from repro.train import (
        AdamW, SyntheticLM, cosine_schedule, init_state, make_train_step,
        state_shardings,
    )

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    print(f"arch={cfg.name} params={model.param_count():,}")

    if args.mesh:
        d, m = (int(v) for v in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        ctx = from_mesh(mesh)
    else:
        ctx = ShardingCtx()

    opt = AdamW(learning_rate=cosine_schedule(args.lr, warmup=10,
                                              total=args.steps))
    state = init_state(model, jax.random.PRNGKey(args.seed), opt,
                       compress=args.compress_grads)
    st_sh = state_shardings(model, ctx, compress=args.compress_grads)
    step_fn = jax.jit(make_train_step(model, opt, ctx,
                                      num_microbatches=args.microbatches,
                                      compress=args.compress_grads),
                      in_shardings=(st_sh, None) if ctx.enabled else None,
                      out_shardings=(st_sh, None) if ctx.enabled else None)

    shape = ShapeSpec("cli", args.seq_len, args.global_batch, "train")
    src = SyntheticLM(cfg, shape)
    mon = StragglerMonitor()

    import time
    t_last = [time.perf_counter()]

    def on_step(step, metrics):
        now = time.perf_counter()
        mon.observe(step, now - t_last[0])
        t_last[0] = now
        if step % 10 == 0 or step < 3:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")

    dcfg = DriverConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat"),
        fail_at_steps=tuple(args.fail_at))
    os.makedirs(args.ckpt_dir, exist_ok=True)
    report = run(step_fn, state, lambda s: src.place(src.batch_for_step(s),
                                                     ctx),
                 dcfg, state_shardings=st_sh, on_step=on_step)
    print(f"done: steps={report.steps_run} restarts={report.restarts} "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"straggler_events={len(mon.events)}")


if __name__ == "__main__":
    main()
