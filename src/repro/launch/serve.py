"""Serving launcher: batched continuous-batching engine on a model.

``python -m repro.launch.serve --arch smollm-360m --reduced --requests 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get
    from repro.models import ShardingCtx, build
    from repro.serve import Request, ServingEngine

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    ctx = ShardingCtx()
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"serving {cfg.name}: params={model.param_count():,} "
          f"slots={args.batch_slots}")

    eng = ServingEngine(model, params, ctx, batch_slots=args.batch_slots,
                        max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               plen).astype(np.int32),
                           max_new_tokens=args.max_new_tokens))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    for r in done[: min(4, len(done))]:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} "
              f"generated={r.generated[:8]}...")
    print(f"done: {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
