"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1:2 attn:recurrent.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1, i.e. MQA)
d_ff=7680 vocab=256000.  Block pattern (rec, rec, attn) repeating; local
attention window 2048; RG-LRU width = d_model with block-diagonal gates
(num heads = attention heads).  Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention="local",
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_heads=10,
    mlp_gated=True,          # GeGLU
    scan_layers=False,       # heterogeneous pattern -> python loop (26L ok)
    sub_quadratic=True,
)
