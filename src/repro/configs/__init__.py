from repro.configs.base import ModelConfig
from repro.configs.registry import all_configs, arch_ids, get
from repro.configs.shapes import (
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
    ShapeSpec, applicable, microbatches_for,
)
