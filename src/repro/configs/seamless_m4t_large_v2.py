"""seamless-m4t-large-v2 — enc-dec multimodal (audio) transformer backbone.

[arXiv:2308.11596; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  Encoder-decoder; the audio frontend (w2v-BERT conformer) is
a STUB — input_specs() provides precomputed frame embeddings (DESIGN.md §5).
24L is interpreted as 24 encoder + 24 decoder layers (the published text
stacks are 24/24).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    is_encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,          # full MHA (GQA kv=16 == heads)
    d_ff=8192,
    vocab_size=256206,
    mlp_gated=False,          # classic transformer FFN (GELU)
    frontend="audio",
    frontend_tokens=0,        # encoder consumes frames directly
    sub_quadratic=False,
)
