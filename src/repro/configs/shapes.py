"""The assigned input-shape set (seq_len x global_batch) and applicability.

  train_4k     seq_len=4096    global_batch=256   (training;   train_step)
  prefill_32k  seq_len=32768   global_batch=32    (inference;  prefill_step)
  decode_32k   seq_len=32768   global_batch=128   (inference;  decode_step,
                               one new token against a KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     (long-context decode; only
                               for sub-quadratic archs: SSM / hybrid)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a shape applies to an architecture; (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k KV decode needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def microbatches_for(cfg: ModelConfig, shape: ShapeSpec,
                     dp_shards: int) -> int:
    """Default gradient-accumulation factor for train shapes.

    Sized so one microbatch's saved activations stay ~O(100 MB)/chip for
    the large dense archs; tuned further in the perf pass.
    """
    if shape.kind != "train":
        return 1
    per_shard = shape.global_batch // dp_shards
    if cfg.d_model >= 8000 or cfg.vocab_size >= 150_000:
        return min(per_shard, 16)
    if cfg.d_model >= 4000:
        return min(per_shard, 8)
    return min(per_shard, 4)
