"""mamba2-2.7b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  64L d_model=2560 (attn-free) d_ff=0
vocab=50280, ssm_state=128.  expand=2 -> d_inner=5120; head_dim=64 ->
80 SSD heads; conv width 4.  Sub-quadratic -> runs long_500k with O(1)
recurrent state (no KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
    conv_width=4,
    sub_quadratic=True,
)
