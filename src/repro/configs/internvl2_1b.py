"""internvl2-1b — VLM: InternViT frontend + Qwen2-0.5B-class LM backbone.

[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The vision frontend (InternViT) is a STUB — input_specs()
provides precomputed patch embeddings prepended to the text sequence
(DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,            # qwen2-style
    frontend="vision",
    frontend_tokens=256,      # one 448x448 tile -> 256 patch tokens
    sub_quadratic=False,
)
