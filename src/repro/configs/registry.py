"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "smollm-360m": "repro.configs.smollm_360m",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "internvl2-1b": "repro.configs.internvl2_1b",
}


def arch_ids() -> List[str]:
    return list(_ARCH_MODULES)


def get(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {arch_ids()}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in arch_ids()}
