"""starcoder2-15b — dense code LM, GQA + RoPE.

[arXiv:2402.19173; hf]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.  Plain (non-gated) GELU MLP per the StarCoder2 arch; we model
full attention (the optional 4k sliding window is not modeled — DESIGN.md
§6.8).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,
    qkv_bias=True,            # starcoder2 uses bias
    sub_quadratic=False,
)
