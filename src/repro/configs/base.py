"""Model configuration schema for every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # attention
    attention: str = "full"        # full | local | none
    window: int = 0                # local-attention window
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mlp_gated: bool = True         # SwiGLU vs plain GELU MLP

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_groups: int = 1
    conv_width: int = 4

    # hybrid (RecurrentGemma): repeating block pattern
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_heads: int = 0                    # block-diagonal gate heads

    # encoder-decoder
    encoder_layers: int = 0
    is_encdec: bool = False

    # modality frontend (stub): precomputed embeddings are the input
    frontend: str = "none"         # none | audio | vision
    frontend_tokens: int = 0       # prefix length contributed by frontend

    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scan_layers: bool = True       # lax.scan over homogeneous layers
    remat: bool = True
    sub_quadratic: bool = False    # supports the long_500k shape

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    # -- derived sizes --------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-friendly multiple (256) so the
        embedding/unembedding tables and logits shard over the model axis
        regardless of tokenizer size; padded logit columns are masked to
        -inf (§Perf hillclimb: unpadded vocabs replicate the CE chain)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind ('attn' | 'rec' | 'ssm' | 'moe')."""
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        if self.is_moe:
            return ("moe",) * self.num_layers
        return ("attn",) * self.num_layers

    def homogeneous(self) -> bool:
        kinds = self.layer_kinds()
        return all(k == kinds[0] for k in kinds)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6 N D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        counts = {"attn": 0, "moe": 0, "rec": 0, "ssm": 0}
        for kind in self.layer_kinds():
            counts[kind] += 1
        h, k, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn_p = d * (h + 2 * k) * hd + h * hd * d
        mlp_p = d * f * (3 if self.mlp_gated else 2)
        counts_total = 0
        counts_total += counts["attn"] * (attn_p + mlp_p + 2 * d)
        if counts["moe"]:
            e = self.num_experts
            moe_mlp = e * d * f * (3 if self.mlp_gated else 2) + d * e
            counts_total += counts["moe"] * (attn_p + moe_mlp + 2 * d)
        if counts["rec"]:
            lru = d  # lru width == d_model
            blk = lru * lru // max(self.lru_heads, 1)
            rec_p = 2 * d * lru + lru * d + 2 * blk + 3 * lru + lru * self.conv_width
            counts_total += counts["rec"] * (rec_p + mlp_p + 2 * d)
        if counts["ssm"]:
            di, st, g, nh = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            in_p = d * (2 * di + 2 * g * st + nh)
            ssm_p = in_p + di * d + (di + 2 * g * st) * self.conv_width + 3 * nh + di
            counts_total += counts["ssm"] * (ssm_p + 2 * d)
        enc = 0
        if self.is_encdec:
            # encoder stack + decoder cross-attention
            enc = self.encoder_layers * (attn_p + mlp_p + 2 * d)
            enc += self.num_layers * (attn_p + d)       # cross attn + norm
        return emb + counts_total + enc + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        d, f, e = self.d_model, self.d_ff, self.num_experts
        moe_layers = sum(1 for kk in self.layer_kinds() if kk == "moe")
        expert_p = d * f * (3 if self.mlp_gated else 2)
        inactive = moe_layers * (e - self.experts_per_token) * expert_p
        return full - inactive

    # -- reduced config for CPU smoke tests -----------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config: 2-3 layers, small widths, small vocab."""
        n_layers = len(self.block_pattern) if self.block_pattern else 2
        n_layers = max(n_layers, 2)
        kv = min(self.num_kv_heads, 2)
        heads = max(4, kv * 2)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            window=min(self.window, 32) if self.window else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.experts_per_token else 0),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=8 if self.ssm_state else self.ssm_chunk,
            lru_heads=min(self.lru_heads, 2) if self.lru_heads else 0,
            encoder_layers=2 if self.is_encdec else 0,
            frontend_tokens=(8 if self.frontend != "none" else 0),
        )
