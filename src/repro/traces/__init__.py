"""repro.traces — serving-trace traffic: time-varying memory load.

The subsystem that closes the loop between the LLM serving stack and the
flit simulators.  Per-tick traffic is recorded from live
``ServingEngine`` runs (:class:`TraceRecorder`) or replayed synthetically
from config shapes alone (:func:`synthetic_serving_trace` — no weights,
the tier-1 path), compiled into :class:`TrafficTrace` phase sequences,
and evaluated through the design space's ``trace`` axis, where the
simulators carry queue/credit state across phase boundaries.
:func:`serving_frontier` is the headline report: the winning memory
approach per (model, QPS) point.

See ``src/repro/traces/README.md`` for the phase format, the arrival
processes, and the state-carry semantics.
"""
from repro.traces.arrival import (bursty_arrivals, diurnal_arrivals,
                                  diurnal_rate, poisson_arrivals,
                                  rate_from_users)
from repro.traces.frontier import (DEFAULT_MODELS, DEFAULT_QPS,
                                   serving_frontier)
from repro.traces.model_traffic import ModelTrafficSpec
from repro.traces.recorder import TraceRecorder
from repro.traces.synthetic import synthetic_serving_trace
from repro.traces.trace import (MIN_BACKLOG, TrafficTrace, pad_traces)

__all__ = [
    "MIN_BACKLOG",
    "DEFAULT_MODELS",
    "DEFAULT_QPS",
    "ModelTrafficSpec",
    "TraceRecorder",
    "TrafficTrace",
    "bursty_arrivals",
    "diurnal_arrivals",
    "diurnal_rate",
    "pad_traces",
    "poisson_arrivals",
    "rate_from_users",
    "serving_frontier",
    "synthetic_serving_trace",
]
