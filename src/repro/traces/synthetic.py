"""Synthetic serving traces: the no-weights tier-1 fallback.

Replays a continuous-batching serving engine (fixed decode slots, FIFO
admission — the same lifecycle as ``repro.serve.ServingEngine``) as a
pure-numpy queueing simulation over a model's
:class:`~repro.traces.model_traffic.ModelTrafficSpec`, then compiles the
per-tick byte/backlog records into a :class:`TrafficTrace`.  No model is
built and no weights exist, so CI and tier-1 tests can sweep full-size
architectures (the byte model needs only config shapes).

One tick is one decode step for every active slot.  Arrivals come from
:mod:`repro.traces.arrival`; queue depth plus active sequences is the
recorded backlog, which is what makes the compiled trace QPS-sensitive:
past the service rate the queue (and the simulated flit backlog) grows,
and prefill admissions pull the read fraction down from the decode
stream's read-heavy steady state.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.traces.arrival import (bursty_arrivals, diurnal_arrivals,
                                  poisson_arrivals)
from repro.traces.model_traffic import ModelTrafficSpec
from repro.traces.trace import TrafficTrace

ARRIVALS = {
    "poisson": poisson_arrivals,
    "diurnal": diurnal_arrivals,
    "bursty": bursty_arrivals,
}


def synthetic_serving_trace(spec: ModelTrafficSpec, *, qps: float,
                            n_ticks: int = 384, n_phases: int = 6,
                            batch_slots: int = 32, prompt_len: int = 512,
                            decode_len: int = 128,
                            arrival: str = "diurnal", seed: int = 0,
                            name: Optional[str] = None) -> TrafficTrace:
    """Generate a phase-compiled trace for ``spec`` under ``qps``
    requests per tick.

    The queueing replay admits arrivals into ``batch_slots`` decode
    slots (prompt/decode lengths jittered around ``prompt_len`` /
    ``decode_len``), prices every prefill and decode step through the
    spec's byte model, and records per-tick read/write bytes plus the
    outstanding-request backlog.  ``arrival`` picks the process:
    ``"poisson"`` (stationary), ``"diurnal"`` (day/night swing) or
    ``"bursty"`` (flash crowds).
    """
    if arrival not in ARRIVALS:
        raise ValueError(f"unknown arrival process {arrival!r}; choose "
                         f"from {sorted(ARRIVALS)}")
    if qps < 0:
        raise ValueError(f"qps must be >= 0, got {qps}")
    n_ticks = int(n_ticks)
    arrivals = ARRIVALS[arrival](qps, n_ticks, seed=seed)
    rng = np.random.default_rng(seed + 1)

    queue: deque = deque()          # pending prompt lengths
    positions = np.zeros(batch_slots, np.int64)      # context per slot
    remaining = np.zeros(batch_slots, np.int64)      # decode tokens left
    active = np.zeros(batch_slots, bool)

    read_b = np.zeros(n_ticks, np.float64)
    write_b = np.zeros(n_ticks, np.float64)
    backlog = np.zeros(n_ticks, np.float64)

    def jitter(mean: int) -> int:
        return max(int(rng.integers(max(mean // 2, 1),
                                    mean + mean // 2 + 1)), 1)

    for t in range(n_ticks):
        for _ in range(int(arrivals[t])):
            queue.append(jitter(prompt_len))
        # admit into free slots; prefill is the write burst
        for slot in np.flatnonzero(~active):
            if not queue:
                break
            plen = queue.popleft()
            r, w = spec.prefill_bytes(plen)
            read_b[t] += r
            write_b[t] += w
            positions[slot] = plen
            remaining[slot] = jitter(decode_len)
            active[slot] = True
        # decode one token for every active slot
        slots = np.flatnonzero(active)
        for slot in slots:
            r, w = spec.decode_bytes(int(positions[slot]))
            read_b[t] += r
            write_b[t] += w
            positions[slot] += 1
            remaining[slot] -= 1
            if remaining[slot] <= 0:
                active[slot] = False
        if slots.size:
            # weights stream once per tick, amortized over the batch
            read_b[t] += spec.weight_stream_bytes
        backlog[t] = len(queue) + slots.size

    label = name if name is not None else \
        f"{spec.name}@qps{qps:g}-{arrival}"
    return TrafficTrace.from_ticks(label, read_b, write_b, backlog,
                                   n_phases=n_phases)
