"""Phase-compiled traffic traces: the ``trace`` axis value type.

A :class:`TrafficTrace` is a short sequence of traffic *phases*, each a
``(duration, read_fraction, backlog)`` triple:

* ``duration`` — how long the phase lasted, in engine ticks (used as the
  aggregation weight; the simulators sample every phase for the same
  static cycle count so one executable serves every trace of a given
  phase count).
* ``read_fraction`` — the phase's byte-weighted read share in ``[0, 1]``
  (lowered to the simulators' ``x:y`` mix as ``100*rf : 100-100*rf``).
* ``backlog`` — mean outstanding requests during the phase (> 0), the
  symmetric simulators' queue-pressure knob.

Traces are compiled from per-tick records (:meth:`TrafficTrace.from_ticks`
— what the serving recorder and the synthetic generator both emit) and
evaluated by the flit simulators in trace-scan mode: phases run back to
back and the queue/credit state is CARRIED across phase boundaries, so
the backlog transient at a prefill-burst -> decode-stream edge is
simulated rather than reset (see ``flitsim.simulate_trace_grid``).

This module is numpy + stdlib only (the jax pytree registration is
optional) so tier-1 trace tests need no model weights.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

#: floor for compiled phase backlogs: a drained engine still has the
#: probe request in flight, and the flit cores need backlog > 0
MIN_BACKLOG = 1.0


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """A named sequence of (duration, read_fraction, backlog) phases."""

    name: str
    durations: Tuple[float, ...]
    read_fractions: Tuple[float, ...]
    backlogs: Tuple[float, ...]

    def __post_init__(self):
        n = len(self.durations)
        if n < 1:
            raise ValueError(f"trace {self.name!r} needs >= 1 phase")
        if len(self.read_fractions) != n or len(self.backlogs) != n:
            raise ValueError(
                f"trace {self.name!r}: phase arrays disagree on length "
                f"({n} durations, {len(self.read_fractions)} read "
                f"fractions, {len(self.backlogs)} backlogs)")
        object.__setattr__(self, "durations",
                           tuple(float(d) for d in self.durations))
        object.__setattr__(self, "read_fractions",
                           tuple(float(r) for r in self.read_fractions))
        object.__setattr__(self, "backlogs",
                           tuple(float(b) for b in self.backlogs))
        if any(d < 0.0 for d in self.durations) or \
                not sum(self.durations) > 0.0:
            raise ValueError(f"trace {self.name!r}: durations must be "
                             f">= 0 with a positive sum, got "
                             f"{self.durations}")
        for r in self.read_fractions:
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"trace {self.name!r}: read fraction {r} "
                                 "outside [0, 1]")
        for b in self.backlogs:
            if not b > 0.0:
                raise ValueError(f"trace {self.name!r}: backlog {b} must "
                                 "be > 0")

    @property
    def n_phases(self) -> int:
        return len(self.durations)

    def padded(self, n: int) -> "TrafficTrace":
        """Extend to ``n`` phases by repeating the last phase with zero
        duration — zero-weight padding changes no aggregate, so traces of
        different lengths can share one axis (and one executable)."""
        if n < self.n_phases:
            raise ValueError(f"cannot pad trace {self.name!r} of "
                             f"{self.n_phases} phases down to {n}")
        if n == self.n_phases:
            return self
        pad = n - self.n_phases
        return TrafficTrace(
            name=self.name,
            durations=self.durations + (0.0,) * pad,
            read_fractions=(self.read_fractions
                            + (self.read_fractions[-1],) * pad),
            backlogs=self.backlogs + (self.backlogs[-1],) * pad)

    @classmethod
    def steady(cls, name: str, read_fraction: float,
               backlog: float) -> "TrafficTrace":
        """Single-phase trace — bit-identical under the trace engine to
        the equivalent static (mix, backlog) cell."""
        return cls(name=name, durations=(1.0,),
                   read_fractions=(float(read_fraction),),
                   backlogs=(float(backlog),))

    @classmethod
    def from_ticks(cls, name: str, read_bytes: Sequence[float],
                   write_bytes: Sequence[float],
                   backlogs: Sequence[float],
                   n_phases: int = 8) -> "TrafficTrace":
        """Compile per-tick byte/backlog records into ``n_phases``
        contiguous phases (fewer if the record is shorter).

        Each phase covers an equal slice of ticks; its read fraction is
        the slice's byte-weighted read share (idle slices inherit the
        whole record's share) and its backlog is the slice mean, floored
        at :data:`MIN_BACKLOG`.
        """
        r = np.asarray(read_bytes, np.float64).reshape(-1)
        w = np.asarray(write_bytes, np.float64).reshape(-1)
        b = np.asarray(backlogs, np.float64).reshape(-1)
        if not (r.size == w.size == b.size) or r.size == 0:
            raise ValueError(
                f"trace {name!r}: per-tick records disagree on length "
                f"({r.size} read, {w.size} write, {b.size} backlog)")
        if n_phases < 1:
            raise ValueError(f"n_phases must be >= 1, got {n_phases}")
        n_phases = min(int(n_phases), r.size)
        tot_r, tot_w = float(r.sum()), float(w.sum())
        if tot_r + tot_w <= 0.0:
            raise ValueError(f"trace {name!r}: no bytes recorded")
        global_rf = tot_r / (tot_r + tot_w)
        durs, rfs, bls = [], [], []
        for rs, ws, bs in zip(np.array_split(r, n_phases),
                              np.array_split(w, n_phases),
                              np.array_split(b, n_phases)):
            seg = float(rs.sum() + ws.sum())
            durs.append(float(rs.size))
            rfs.append(float(rs.sum()) / seg if seg > 0.0 else global_rf)
            bls.append(max(float(bs.mean()), MIN_BACKLOG))
        return cls(name=name, durations=tuple(durs),
                   read_fractions=tuple(rfs), backlogs=tuple(bls))


def pad_traces(traces: Sequence[TrafficTrace]) -> Tuple[TrafficTrace, ...]:
    """Pad a collection to a common phase count (the max) so they can
    share one ``trace`` axis and one compiled executable."""
    if not traces:
        raise ValueError("need at least one trace")
    n = max(t.n_phases for t in traces)
    return tuple(t.padded(n) for t in traces)


def _register_pytree() -> None:
    """Register :class:`TrafficTrace` as a jax pytree (name static, phase
    tuples as leaves) — optional, so this module stays importable without
    jax."""
    try:
        import jax
    except Exception:       # pragma: no cover - jax is a repo-wide dep
        return
    jax.tree_util.register_pytree_node(
        TrafficTrace,
        lambda t: ((t.durations, t.read_fractions, t.backlogs), t.name),
        lambda name, kids: TrafficTrace(
            name=name, durations=kids[0], read_fractions=kids[1],
            backlogs=kids[2]))


_register_pytree()
