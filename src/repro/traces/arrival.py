"""Request arrival processes for serving-trace generation.

All generators return an integer array of request arrivals per engine
tick, deterministic in ``seed`` (numpy ``default_rng``).  Rates are in
requests per tick; aggregate user populations fold into the rate —
superposing millions of independent per-user request streams is again
Poisson (:func:`rate_from_users`), so "N concurrent users" is one rate
scalar, not N simulated actors.

Three processes cover the regimes the serving frontier sweeps:

* :func:`poisson_arrivals` — stationary load (the M/./. baseline).
* :func:`diurnal_arrivals` — a sinusoidal day/night rate swing
  (``peak_ratio`` peak:trough) modulating the Poisson draw, so one trace
  carries both the loaded and the drained regime.
* :func:`bursty_arrivals` — a two-state (quiet/burst) Markov-modulated
  Poisson process: flash-crowd spikes of ``burst_factor`` x the base
  rate with geometric burst lengths.
"""
from __future__ import annotations

import numpy as np


def rate_from_users(users: float, requests_per_user_per_tick: float
                    ) -> float:
    """Aggregate request rate of ``users`` independent users — the
    superposition of per-user Poisson streams is Poisson at the summed
    rate, which is how traces model millions of concurrent users."""
    if users < 0 or requests_per_user_per_tick < 0:
        raise ValueError("users and per-user rate must be >= 0")
    return float(users) * float(requests_per_user_per_tick)


def poisson_arrivals(rate: float, n_ticks: int, seed: int = 0
                     ) -> np.ndarray:
    """Stationary Poisson arrivals: ``rate`` requests per tick."""
    if rate < 0:
        raise ValueError(f"rate must be >= 0, got {rate}")
    rng = np.random.default_rng(seed)
    return rng.poisson(rate, int(n_ticks)).astype(np.int64)


def diurnal_rate(base_rate: float, n_ticks: int, peak_ratio: float = 4.0,
                 period: int = 0) -> np.ndarray:
    """Sinusoidal rate profile with mean ``base_rate`` and peak:trough
    ratio ``peak_ratio`` (``period`` ticks per cycle; 0 -> one full cycle
    over the record)."""
    if peak_ratio < 1.0:
        raise ValueError(f"peak_ratio must be >= 1, got {peak_ratio}")
    period = int(period) if period else int(n_ticks)
    t = np.arange(int(n_ticks), dtype=np.float64)
    # mean 1, swing a: peak (1+a) / trough (1-a) == peak_ratio
    a = (peak_ratio - 1.0) / (peak_ratio + 1.0)
    return base_rate * (1.0 + a * np.sin(2.0 * np.pi * t / period))


def diurnal_arrivals(base_rate: float, n_ticks: int,
                     peak_ratio: float = 4.0, period: int = 0,
                     seed: int = 0) -> np.ndarray:
    """Poisson arrivals under the :func:`diurnal_rate` profile."""
    rng = np.random.default_rng(seed)
    return rng.poisson(diurnal_rate(base_rate, n_ticks, peak_ratio,
                                    period)).astype(np.int64)


def bursty_arrivals(base_rate: float, n_ticks: int,
                    burst_factor: float = 8.0, burst_prob: float = 0.05,
                    mean_burst_len: float = 16.0, seed: int = 0
                    ) -> np.ndarray:
    """Markov-modulated Poisson arrivals: a quiet state at ``base_rate``
    and a burst state at ``burst_factor * base_rate``, entered with
    per-tick probability ``burst_prob`` and left with probability
    ``1 / mean_burst_len``."""
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    if not 0.0 <= burst_prob <= 1.0:
        raise ValueError(f"burst_prob must be in [0, 1], got {burst_prob}")
    if mean_burst_len < 1.0:
        raise ValueError(f"mean_burst_len must be >= 1, got "
                         f"{mean_burst_len}")
    rng = np.random.default_rng(seed)
    n = int(n_ticks)
    rates = np.empty(n, np.float64)
    in_burst = False
    for t in range(n):
        if in_burst:
            if rng.random() < 1.0 / mean_burst_len:
                in_burst = False
        elif rng.random() < burst_prob:
            in_burst = True
        rates[t] = base_rate * (burst_factor if in_burst else 1.0)
    return rng.poisson(rates).astype(np.int64)
