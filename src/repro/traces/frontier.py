"""Per-model serving frontier: which memory approach wins at which QPS.

For every (model, QPS) point a synthetic serving trace is generated
(:func:`~repro.traces.synthetic.synthetic_serving_trace` — config shapes
only, no weights), the whole batch is evaluated through the ``trace``
axis in ONE design-space evaluation per engine family, and the winning
flit-simulated protocol (duration-weighted ``trace_bandwidth_gbs`` on
the target PHY) is mapped to its catalog memory approach.  The report is
the ``serving_frontier`` section of ``design_space.json``; its winner
labels are gated by the CI summary golden.

QPS sensitivity is the point: low-QPS traces sit at drained backlogs and
decode-heavy read fractions, high-QPS traces saturate the queue and mix
in prefill write bursts, so the winning approach can flip along the QPS
axis — a frontier the static-mix sections cannot express.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.traces.model_traffic import ModelTrafficSpec
from repro.traces.synthetic import synthetic_serving_trace

#: model configs the committed artifact sweeps: a dense decoder, a MoE
#: (expert-shuffle bytes), and an SSM (context-independent state reads)
DEFAULT_MODELS: Tuple[str, ...] = ("smollm-360m", "olmoe-1b-7b",
                                   "mamba2-2.7b")
#: requests per engine tick — drained, at-capacity, and saturated
#: regimes (the default batch has 32 slots serving ~128-token decodes,
#: so its service rate is 0.25 req/tick: 0.05 drains to a shallow queue
#: where the asymmetric approaches win, 1.0 and 4.0 pile up backlog
#: where the optimized symmetric protocol takes over)
DEFAULT_QPS: Tuple[float, ...] = (0.05, 1.0, 4.0)


def serving_frontier(models: Sequence[str] = DEFAULT_MODELS,
                     qps_points: Sequence[float] = DEFAULT_QPS, *,
                     phy: Any = None,
                     protocols: Optional[Sequence[str]] = None,
                     n_phases: int = 6, n_ticks: int = 384,
                     batch_slots: int = 32, arrival: str = "diurnal",
                     seed: int = 0, sim=None) -> Dict[str, Any]:
    """Build the per-(model, QPS) serving-frontier report.

    ``phy`` defaults to the paper's UCIe-A 32G point; ``sim`` is the
    trace engine's :class:`~repro.core.space.SimConfig` (fixed trace-scan
    core by default).  Winner labels are catalog approach keys
    (``A:lpddr6-asym`` ...), the vocabulary the summary golden gates.
    """
    from repro.core import UCIE_A_32G_55U, flitsim
    from repro.core.selector import approach_key_for
    from repro.core.space import DesignSpace, axis

    if phy is None:
        phy = UCIE_A_32G_55U
    traces = [
        synthetic_serving_trace(
            ModelTrafficSpec.from_name(m), qps=q, n_ticks=n_ticks,
            n_phases=n_phases, batch_slots=batch_slots, arrival=arrival,
            seed=seed, name=f"{m}@q{q:g}")
        for m in models for q in qps_points]

    before = flitsim.compile_cache_stats()
    axes = [axis("trace", traces)]
    if protocols is not None:
        axes.append(axis("protocol", protocols))
    res = DesignSpace(axes, phy=phy, sim=sim).evaluate(
        metrics=("trace_efficiency", "trace_bandwidth_gbs"))
    after = flitsim.compile_cache_stats()

    bw = res["trace_bandwidth_gbs"]             # [protocol, trace]
    best = bw.argbest("protocol")               # [trace]
    best_gbs = bw.best("protocol")
    names = list(bw.coord("trace"))

    winner: Dict[str, Dict[str, str]] = {}
    proto: Dict[str, Dict[str, str]] = {}
    gbs: Dict[str, Dict[str, float]] = {}
    for i, m in enumerate(models):
        winner[m], proto[m], gbs[m] = {}, {}, {}
        for j, q in enumerate(qps_points):
            k = str(best.values[i * len(qps_points) + j])
            qkey = f"{q:g}"
            proto[m][qkey] = k
            winner[m][qkey] = approach_key_for(k)
            gbs[m][qkey] = float(
                best_gbs.values[i * len(qps_points) + j])

    tele = {fam: info for fam, info in flitsim.last_run_info().items()
            if info.get("mode") == "trace"}
    return {
        "models": list(models),
        "qps_points": [float(q) for q in qps_points],
        "phy": phy.name,
        "arrival": arrival,
        "n_ticks": int(n_ticks),
        "n_phases": int(max(t.n_phases for t in traces)),
        "protocols": list(bw.coord("protocol")),
        "trace_names": names,
        "winner_by_model_qps": winner,
        "protocol_by_model_qps": proto,
        "winner_gbs_by_model_qps": gbs,
        "qps_sensitive": {
            m: len(set(winner[m].values())) > 1 for m in models},
        "traces": {
            t.name: {"durations": list(t.durations),
                     "read_fractions": list(t.read_fractions),
                     "backlogs": list(t.backlogs)}
            for t in traces},
        "telemetry": tele,
        "compiles": after.misses - before.misses,
    }
