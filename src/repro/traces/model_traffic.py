"""First-order memory-traffic model of one decode/prefill step.

:class:`ModelTrafficSpec` reduces a :class:`repro.configs.ModelConfig` to
the per-token byte flows the serving recorder and the synthetic trace
generator both price:

* KV cache — attention (and MoE-attention) layers write
  ``2 * kv_heads * head_dim`` values per token and read the whole
  per-sequence cache back every decode step (reads grow with context).
* Recurrent state — SSM / recurrent layers read + write a
  context-independent state per token instead.
* MoE expert shuffle — dispatch + combine move each token's activations
  to/from its routed experts (``2 * d_model * experts_per_token``),
  priced half read / half write.
* Weight streaming — active parameters are read once per engine tick
  (amortized across the decode batch), the dominant read flow at small
  batch.

The numbers are first-order by design: the trace axis only consumes the
per-phase *read fraction* and *backlog* these flows imply, not absolute
bandwidth, so layout/replication constants cancel.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelTrafficSpec:
    """Per-token byte costs of a model, derived from its config shapes."""

    name: str
    dtype_bytes: int = 2
    #: KV bytes written per generated/prefilled token (all attn layers)
    kv_write_bytes_per_token: float = 0.0
    #: recurrent-state bytes read AND written per token (SSM/rec layers)
    state_bytes_per_token: float = 0.0
    #: MoE dispatch+combine bytes per token (half read, half write)
    moe_shuffle_bytes_per_token: float = 0.0
    #: active parameters streamed (read) once per engine tick
    weight_stream_bytes: float = 0.0

    @classmethod
    def from_config(cls, cfg) -> "ModelTrafficSpec":
        """Price a :class:`repro.configs.ModelConfig` (full or reduced)."""
        dtype_bytes = 2
        kinds = list(cfg.layer_kinds())
        n_attn = sum(1 for k in kinds if k in ("attn", "moe"))
        n_moe = sum(1 for k in kinds if k == "moe")
        n_ssm = sum(1 for k in kinds if k == "ssm")
        n_rec = sum(1 for k in kinds if k == "rec")
        kv = (n_attn * 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes)
        state = 0.0
        if n_ssm:
            state += n_ssm * 2.0 * cfg.d_inner * cfg.ssm_state * dtype_bytes
        if n_rec:
            state += n_rec * 2.0 * cfg.d_model * dtype_bytes
        moe = (2.0 * n_moe * cfg.d_model * cfg.experts_per_token
               * dtype_bytes) if n_moe else 0.0
        return cls(name=cfg.name, dtype_bytes=dtype_bytes,
                   kv_write_bytes_per_token=float(kv),
                   state_bytes_per_token=float(state),
                   moe_shuffle_bytes_per_token=float(moe),
                   weight_stream_bytes=float(cfg.active_param_count()
                                             * dtype_bytes))

    @classmethod
    def from_name(cls, arch_id: str) -> "ModelTrafficSpec":
        """Price a registered architecture by id — config shapes only, no
        model weights (the tier-1 synthetic-trace path)."""
        from repro.configs import get
        return cls.from_config(get(arch_id))

    # -- per-event byte flows (read_bytes, write_bytes) -------------------

    def decode_bytes(self, context_len: int) -> Tuple[float, float]:
        """One decode step of one sequence at ``context_len``: read the
        KV cache back, write one token's KV, cycle the recurrent state,
        shuffle the token through its experts."""
        ctx = max(int(context_len), 0)
        reads = (ctx * self.kv_write_bytes_per_token
                 + self.state_bytes_per_token / 2.0
                 + self.moe_shuffle_bytes_per_token / 2.0)
        writes = (self.kv_write_bytes_per_token
                  + self.state_bytes_per_token / 2.0
                  + self.moe_shuffle_bytes_per_token / 2.0)
        return reads, writes

    def prefill_bytes(self, prompt_len: int) -> Tuple[float, float]:
        """One prompt prefill: fill ``prompt_len`` tokens of KV (the
        write burst the decode stream never shows), read each filled
        entry back once (causal attention over the prompt, flash-style
        single pass), and shuffle every prompt token through the
        experts."""
        n = max(int(prompt_len), 0)
        reads = n * (self.kv_write_bytes_per_token
                     + self.state_bytes_per_token / 2.0
                     + self.moe_shuffle_bytes_per_token / 2.0)
        writes = n * (self.kv_write_bytes_per_token
                      + self.state_bytes_per_token / 2.0
                      + self.moe_shuffle_bytes_per_token / 2.0)
        return reads, writes
