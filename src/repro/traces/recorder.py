"""Phase-resolved traffic recording from live ``ServingEngine`` runs.

A :class:`TraceRecorder` is handed to ``ServingEngine(recorder=...)``;
the engine reports every prefill, every decode batch, and every tick
boundary, and the recorder prices the events through the model's
:class:`~repro.traces.model_traffic.ModelTrafficSpec` into per-tick
read/write bytes and outstanding-request backlog.  ``trace()`` compiles
the record into a :class:`TrafficTrace` for the ``trace`` axis.

The recorder observes token counts and context lengths only — it never
touches parameters or caches, so recording adds no device work to the
serving hot path.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.traces.model_traffic import ModelTrafficSpec
from repro.traces.trace import TrafficTrace


class TraceRecorder:
    """Accumulates one serving run's per-tick memory-traffic record."""

    def __init__(self, spec: ModelTrafficSpec):
        self.spec = spec
        self._read: List[float] = []
        self._write: List[float] = []
        self._backlog: List[float] = []
        self._tick_read = 0.0
        self._tick_write = 0.0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self.prefill_tokens_per_tick: List[int] = []
        self.decode_tokens_per_tick: List[int] = []

    @classmethod
    def for_model(cls, cfg) -> "TraceRecorder":
        """Recorder priced for a :class:`repro.configs.ModelConfig`."""
        return cls(ModelTrafficSpec.from_config(cfg))

    # -- engine callbacks -------------------------------------------------

    def on_prefill(self, prompt_len: int) -> None:
        """One request's prompt was prefilled into a slot this tick."""
        r, w = self.spec.prefill_bytes(prompt_len)
        self._tick_read += r
        self._tick_write += w
        self._prefill_tokens += int(prompt_len)

    def on_decode(self, context_lens: Sequence[int]) -> None:
        """One decode step ran for the given per-slot context lengths."""
        for ctx in context_lens:
            r, w = self.spec.decode_bytes(int(ctx))
            self._tick_read += r
            self._tick_write += w
        if len(context_lens):
            self._tick_read += self.spec.weight_stream_bytes
        self._decode_tokens += len(context_lens)

    def on_tick(self, queue_depth: int, active: int) -> None:
        """Close the tick: record its bytes and outstanding requests."""
        self._read.append(self._tick_read)
        self._write.append(self._tick_write)
        self._backlog.append(float(queue_depth + active))
        self.prefill_tokens_per_tick.append(self._prefill_tokens)
        self.decode_tokens_per_tick.append(self._decode_tokens)
        self._tick_read = self._tick_write = 0.0
        self._prefill_tokens = self._decode_tokens = 0

    # -- compilation ------------------------------------------------------

    @property
    def n_ticks(self) -> int:
        return len(self._read)

    def trace(self, n_phases: int = 8,
              name: Optional[str] = None) -> TrafficTrace:
        """Compile the recorded ticks into a phase trace."""
        if not self._read:
            raise ValueError("no ticks recorded; run the engine with "
                             "this recorder first")
        return TrafficTrace.from_ticks(
            name if name is not None else f"{self.spec.name}-recorded",
            self._read, self._write, self._backlog, n_phases=n_phases)
