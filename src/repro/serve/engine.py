"""Batched serving engine with continuous batching.

Fixed-slot decode batch: requests queue up, free slots are prefilled (one
request at a time — prefill and decode are separate compiled programs, as
on a real serving stack), and every engine tick decodes one token for all
active slots.  Completed sequences (EOS or max tokens) free their slot.

Per-slot absolute positions let sequences of different lengths share one
decode batch (the decode path takes positions [B, 1]).  KV caches live
packed per slot in one [*, B, max_len, ...] buffer set.

An optional ``recorder`` (``repro.traces.TraceRecorder``) observes every
prefill, decode batch, and tick boundary, turning a serving run into a
phase-resolved memory-traffic trace for the design space's ``trace``
axis.  The hooks see token counts and context lengths only, so recording
adds no device work to the serving hot path.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.models.sharding import ShardingCtx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [T] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, ctx: ShardingCtx,
                 batch_slots: int = 4, max_len: int = 256,
                 greedy: bool = True, recorder: Any = None):
        self.model = model
        self.params = params
        self.ctx = ctx
        self.b = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.recorder = recorder
        cfg = model.cfg

        self.caches = model.init_decode_caches(batch_slots, max_len)
        self.positions = np.zeros((batch_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.last_token = np.zeros((batch_slots,), np.int32)
        self.queue: deque = deque()
        self.finished: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, ctx))

    # -- request lifecycle ------------------------------------------------
    def submit(self, req: Request):
        # a prompt at max_len - 1 leaves no room for even one decoded
        # token; past max_len the prefill would overflow the packed KV
        # slot and silently corrupt whatever sequence shares the buffer
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"does not fit the engine's max_len={self.max_len} KV "
                f"slots (need prompt length < max_len); truncate the "
                f"prompt or build the engine with a larger max_len")
        self.queue.append(req)

    def _prefill_into_slot(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, caches = self.model.prefill(
            self.params, {"tokens": prompt}, self.ctx,
            pad_cache_to=self.max_len)
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        # splice this request's caches into the batch buffers
        def splice(batch_c, one_c):
            # batch dim is axis 1 for stacked caches [L, B, ...], else 0
            axis = 1 if batch_c.ndim == one_c.ndim and batch_c.ndim >= 2 \
                and batch_c.shape[0] == one_c.shape[0] else 0
            idx = [slice(None)] * batch_c.ndim
            idx[axis] = slice(slot, slot + 1)
            return batch_c.at[tuple(idx)].set(one_c)
        self.caches = jax.tree.map(splice, self.caches, caches)
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = tok
        if self.recorder is not None:
            self.recorder.on_prefill(len(req.prompt))

    def _free_slot(self, slot: int):
        """Release a slot and reset its scalar state — stale positions /
        last_token must never leak into the next request admitted here."""
        self.active[slot] = None
        self.positions[slot] = 0
        self.last_token[slot] = 0

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    # -- engine tick --------------------------------------------------------
    def step(self) -> int:
        """Admit + decode one token for all active slots.  Returns the
        number of active sequences processed."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._prefill_into_slot(slot, self.queue.popleft())

        active_idx = [i for i, r in enumerate(self.active) if r is not None]
        if not active_idx:
            if self.recorder is not None:
                self.recorder.on_tick(len(self.queue), 0)
            return 0
        if self.recorder is not None:
            self.recorder.on_decode([int(self.positions[i])
                                     for i in active_idx])

        tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
        positions = jnp.asarray(self.positions, jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, tokens,
                                           self.caches, positions)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))

        for i in active_idx:
            req = self.active[i]
            self.positions[i] += 1
            tok = int(next_tokens[i])
            req.generated.append(tok)
            self.last_token[i] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.generated) >= req.max_new_tokens
                    or self.positions[i] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self._free_slot(i)
        if self.recorder is not None:
            self.recorder.on_tick(
                len(self.queue),
                sum(r is not None for r in self.active))
        return len(active_idx)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.active)):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        return self.finished
