"""repro-lint engine: source loading, suppression parsing, reporting.

The static pass is **stdlib-only** (``ast`` + ``re``): the CI lint job
runs it on a bare Python with no JAX installed, before the test matrix
spends any compute.  Only :mod:`repro.lint.runtime` (the runtime
sanitizer) imports ``jax``, and nothing here imports that module.

Suppression syntax
------------------

``# repro-lint: disable=RL003`` on a line suppresses findings of that
check on the annotated line and the line directly below it (so the
directive can trail the offending statement or sit on its own line
above).  ``# repro-lint: disable-file=RL002`` anywhere in a file
suppresses the check for the whole file.  Several IDs may be
comma-separated.  Suppressed findings still appear in the JSON report
with ``"suppressed": true`` — they are audited, not hidden.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import time
from pathlib import Path
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?P<scope>-file)?\s*=\s*"
    r"(?P<ids>RL\d{3}(?:\s*,\s*RL\d{3})*)")

#: directories never descended into
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".venv",
              "node_modules", "build", "dist"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding, stable across runs (sorted by path, line, id)."""

    check: str          # e.g. "RL001"
    path: str           # root-relative posix path
    line: int           # 1-based
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.check}{tag}: {self.message}"


@dataclasses.dataclass
class Source:
    """A parsed source file plus its suppression directives."""

    path: Path
    rel: str
    text: str
    tree: ast.Module
    file_suppressions: FrozenSet[str]
    line_suppressions: Dict[int, FrozenSet[str]]

    def suppresses(self, check: str, line: int) -> bool:
        if check in self.file_suppressions:
            return True
        for ln in (line, line - 1):
            if check in self.line_suppressions.get(ln, frozenset()):
                return True
        return False


def _parse_suppressions(text: str):
    file_level: set = set()
    per_line: Dict[int, FrozenSet[str]] = {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        ids = frozenset(s.strip() for s in m.group("ids").split(","))
        if m.group("scope"):
            file_level |= ids
        else:
            per_line[lineno] = per_line.get(lineno, frozenset()) | ids
    return frozenset(file_level), per_line


def load_sources(root: Path) -> List[Source]:
    """Parse every ``*.py`` under ``root`` (or ``root`` itself, if it is
    a file) into :class:`Source` records, sorted by path."""
    root = Path(root).resolve()
    if root.is_file():
        paths = [root]
        base = root.parent
    else:
        paths = sorted(p for p in root.rglob("*.py")
                       if not any(part in _SKIP_DIRS or part.startswith(".")
                                  for part in p.relative_to(root).parts))
        base = root
    out: List[Source] = []
    for p in paths:
        text = p.read_text()
        try:
            tree = ast.parse(text, filename=str(p))
        except SyntaxError as e:
            raise LintError(f"{p}: cannot parse: {e}") from e
        file_sup, line_sup = _parse_suppressions(text)
        out.append(Source(path=p, rel=p.relative_to(base).as_posix(),
                          text=text, tree=tree,
                          file_suppressions=file_sup,
                          line_suppressions=line_sup))
    return out


class LintError(RuntimeError):
    """Internal linter failure (unparseable input, bad check id)."""


@dataclasses.dataclass
class LintReport:
    root: str
    checks: Tuple[str, ...]
    files: int
    findings: List[Finding]
    elapsed_s: float

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed]

    def to_json(self) -> dict:
        return {
            "root": self.root,
            "checks": list(self.checks),
            "files": self.files,
            "elapsed_s": round(self.elapsed_s, 4),
            "counts": {"total": len(self.findings),
                       "unsuppressed": len(self.unsuppressed),
                       "suppressed": len(self.suppressed)},
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }


def default_root() -> Path:
    """The repo's ``src/`` tree (this package lives at ``src/repro/lint``),
    independent of the caller's working directory."""
    return Path(__file__).resolve().parents[2]


def run_lint(root=None, select: Optional[Iterable[str]] = None) -> LintReport:
    """Run the selected checks (default: all) over ``root`` (default:
    the repo's ``src/`` tree) and return a :class:`LintReport`."""
    from repro.lint import checks as checks_mod

    root = Path(root) if root is not None else default_root()
    wanted = tuple(select) if select is not None \
        else tuple(checks_mod.CHECKS)
    unknown = [c for c in wanted if c not in checks_mod.CHECKS]
    if unknown:
        raise LintError(f"unknown check ids {sorted(unknown)}; choose "
                        f"from {sorted(checks_mod.CHECKS)}")
    t0 = time.perf_counter()
    sources = load_sources(root)
    by_rel = {s.rel: s for s in sources}
    findings: List[Finding] = []
    for check_id in wanted:
        _, fn = checks_mod.CHECKS[check_id]
        for f in fn(sources):
            src = by_rel.get(f.path)
            if src is not None and src.suppresses(f.check, f.line):
                f = dataclasses.replace(f, suppressed=True)
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.check, f.message))
    return LintReport(root=str(root), checks=wanted, files=len(sources),
                      findings=findings, elapsed_s=time.perf_counter() - t0)
