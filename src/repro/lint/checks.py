"""The five repro-lint checks (RL001–RL005).

Each check is a pure function ``(sources) -> Iterable[Finding]`` over the
parsed AST of the whole tree; suppression filtering happens in the
engine.  The checks encode the repo's own normative invariants (the
prose contracts in ``kernels/flit_sim/README.md`` and the PR 5/6
incident history — see ``src/repro/lint/README.md`` for the catalogue):

RL001  cache-key integrity      every numerics-affecting config field
                                participates in the compile-cache key
RL002  kernel/ref parity        kernel.py shares ref.py compute bodies
                                and keeps the rows-leading layout
RL003  float-encoded-int bounds constants/horizons feeding f32 counters
                                stay <= 2**24
RL004  traced control flow      no Python branching / host syncs /
                                stray numpy on traced values
RL005  registry consistency     *_FIELDS registries track the dataclass
                                fields they claim to cover, sorted
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.lint.engine import Finding, Source

MAX_EXACT_F32_INT = 2 ** 24

# ---------------------------------------------------------------- helpers


def _is_dataclass_decorator(dec: ast.expr) -> bool:
    if isinstance(dec, ast.Call):
        dec = dec.func
    if isinstance(dec, ast.Name):
        return dec.id == "dataclass"
    if isinstance(dec, ast.Attribute):
        return dec.attr == "dataclass"
    return False


def _dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    """(name, lineno) of every dataclass field declared on ``cls``
    (annotated assignments, skipping ClassVar and private names)."""
    out = []
    for node in cls.body:
        if not isinstance(node, ast.AnnAssign) or \
                not isinstance(node.target, ast.Name):
            continue
        if node.target.id.startswith("_"):
            continue
        ann = ast.dump(node.annotation)
        if "ClassVar" in ann:
            continue
        out.append((node.target.id, node.lineno))
    return out


def _dataclasses_in(tree: ast.Module) -> List[ast.ClassDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.ClassDef)
            and any(_is_dataclass_decorator(d) for d in n.decorator_list)]


def _int_value(node: ast.expr) -> Optional[int]:
    """Constant-fold an integer expression (literals and +,-,*,//,%,**,
    <<); None when the value is not a compile-time int."""
    if isinstance(node, ast.Constant):
        v = node.value
        return v if isinstance(v, int) and not isinstance(v, bool) else None
    if isinstance(node, ast.UnaryOp) and \
            isinstance(node.op, (ast.USub, ast.UAdd)):
        v = _int_value(node.operand)
        if v is None:
            return None
        return -v if isinstance(node.op, ast.USub) else v
    if isinstance(node, ast.BinOp):
        lhs, rhs = _int_value(node.left), _int_value(node.right)
        if lhs is None or rhs is None:
            return None
        ops = {ast.Add: lambda a, b: a + b, ast.Sub: lambda a, b: a - b,
               ast.Mult: lambda a, b: a * b,
               ast.FloorDiv: lambda a, b: a // b if b else None,
               ast.Mod: lambda a, b: a % b if b else None,
               ast.Pow: lambda a, b: a ** b if b >= 0 else None,
               ast.LShift: lambda a, b: a << b if 0 <= b < 64 else None}
        fn = ops.get(type(node.op))
        return fn(lhs, rhs) if fn else None
    return None


def _callee_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _caps_int_consts(tree: ast.Module):
    """Module-level ``ALL_CAPS = <int>`` assignments -> (name, value,
    lineno)."""
    for node in tree.body:
        targets: Sequence[ast.expr]
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        v = _int_value(value)
        if v is None:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id.isupper():
                yield t.id, v, node.lineno


# ------------------------------------------------- RL001 cache-key integrity


def check_rl001(sources: List[Source]) -> Iterable[Finding]:
    """Cache-key integrity.

    (a) Every field of a dataclass that exposes a ``key()`` method (the
        compile-cache key protocol, e.g. ``SimConfig``) must be read by
        ``key()``: a numerics-affecting field outside the key silently
        reuses a stale compiled executable for different numerics — the
        exact PR 5 (``mode/chunk/tol``) and PR 6 (``engine``) incidents.
    (b) A positional row reconstruction ``Cls(*[rows[i] for i in
        range(N)])`` (the kernel-side pytree unpacking in
        ``kernels/flit_sim/ref.py``) must use exactly as many rows as
        ``Cls`` has dataclass fields, or the row-stacked operands and
        the pytree drift apart.
    """
    findings: List[Finding] = []
    field_counts: Dict[str, Tuple[int, str]] = {}
    for src in sources:
        for cls in _dataclasses_in(src.tree):
            fields = _dataclass_fields(cls)
            field_counts[cls.name] = (len(fields), src.rel)
            key_fn = next((n for n in cls.body
                           if isinstance(n, ast.FunctionDef)
                           and n.name == "key"), None)
            if key_fn is None or not fields:
                continue
            used = {n.attr for n in ast.walk(key_fn)
                    if isinstance(n, ast.Attribute)
                    and isinstance(n.value, ast.Name)
                    and n.value.id == "self"}
            for fname, fline in fields:
                if fname not in used:
                    findings.append(Finding(
                        "RL001", src.rel, fline,
                        f"{cls.name}.{fname} never participates in "
                        f"{cls.name}.key(): the field can change numerics "
                        f"without changing the compile-cache key, so a "
                        f"stale executable would be reused"))
    for src in sources:
        for call in ast.walk(src.tree):
            n = _reconstruction_arity(call)
            if n is None:
                continue
            cls_name = call.func.id  # type: ignore[union-attr]
            if cls_name not in field_counts:
                continue
            n_fields, decl_rel = field_counts[cls_name]
            if n != n_fields:
                findings.append(Finding(
                    "RL001", src.rel, call.lineno,
                    f"rebuilds {cls_name} from {n} positional rows but the "
                    f"dataclass ({decl_rel}) declares {n_fields} fields — "
                    f"the row-stacked operand layout and the pytree are "
                    f"out of sync"))
    return findings


def _reconstruction_arity(node: ast.AST) -> Optional[int]:
    """Arity N of a ``Cls(*[seq[i] for i in range(N)])`` call."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and len(node.args) == 1
            and isinstance(node.args[0], ast.Starred)):
        return None
    comp = node.args[0].value
    if not isinstance(comp, (ast.ListComp, ast.GeneratorExp)) or \
            len(comp.generators) != 1:
        return None
    it = comp.generators[0].iter
    if isinstance(it, ast.Call) and _callee_name(it.func) == "range" \
            and len(it.args) == 1:
        return _int_value(it.args[0])
    return None


# --------------------------------------------------- RL002 kernel/ref parity


def check_rl002(sources: List[Source]) -> Iterable[Finding]:
    """Kernel/ref parity for every sibling ``kernel.py`` / ``ref.py``
    pair: the kernel must import from its reference module (shared
    compute bodies, the PR 6 contract), must not re-define a function
    ref already defines (re-implementation drift), and ``pl.BlockSpec``
    block shapes must keep the ``*_ROWS`` dimension leading (operands
    are row-stacked with cells last, so cells land on TPU lanes)."""
    findings: List[Finding] = []
    by_dir: Dict[str, Dict[str, Source]] = {}
    for src in sources:
        parts = src.rel.rsplit("/", 1)
        d, name = (parts[0], parts[1]) if len(parts) == 2 else ("", parts[0])
        by_dir.setdefault(d, {})[name] = src
    for d, files in sorted(by_dir.items()):
        kernel, ref = files.get("kernel.py"), files.get("ref.py")
        if kernel is None or ref is None:
            continue
        if not _imports_sibling_ref(kernel.tree):
            findings.append(Finding(
                "RL002", kernel.rel, 1,
                "kernel.py never imports from its sibling ref.py — compute "
                "bodies and layout constants must be shared with the "
                "reference implementation, not re-implemented"))
        ref_defs = {n.name: n.lineno for n in ref.tree.body
                    if isinstance(n, ast.FunctionDef)}
        for n in kernel.tree.body:
            if isinstance(n, ast.FunctionDef) and n.name in ref_defs:
                findings.append(Finding(
                    "RL002", kernel.rel, n.lineno,
                    f"re-defines '{n.name}' (ref.py:{ref_defs[n.name]}) "
                    f"instead of importing the ref body — the two copies "
                    f"will drift"))
        rows_names = {name for name, _, _ in _caps_int_consts(ref.tree)
                      if name.endswith("_ROWS")}
        if rows_names:
            findings.extend(_blockspec_rows_last(kernel, rows_names))
    return findings


def _imports_sibling_ref(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "ref" or mod.endswith(".ref"):
                return True
            if node.level > 0 and any(a.name == "ref" for a in node.names):
                return True
        elif isinstance(node, ast.Import):
            if any(a.name.endswith(".ref") for a in node.names):
                return True
    return False


def _blockspec_rows_last(kernel: Source, rows_names: Set[str]):
    for call in ast.walk(kernel.tree):
        if not (isinstance(call, ast.Call)
                and _callee_name(call.func) == "BlockSpec"):
            continue
        shape = call.args[0] if call.args else None
        if not isinstance(shape, ast.Tuple) or len(shape.elts) < 2:
            continue
        for elt in shape.elts[1:]:
            if isinstance(elt, ast.Name) and elt.id in rows_names:
                yield Finding(
                    "RL002", kernel.rel, call.lineno,
                    f"BlockSpec block shape puts the row dimension "
                    f"({elt.id}) after the cell dimension — operands are "
                    f"row-stacked with cells LAST (rows must be the "
                    f"leading block dim so cells land on lanes)")


# --------------------------------------------- RL003 float-encoded-int bounds


#: keyword/positional defaults that flow into f32-encoded cycle counters
#: in the simulation engines
_HORIZON_PARAMS = {"n_flits", "n_accesses", "n_lines", "n_cycles",
                   "n_steps", "max_cycles", "horizon", "chunk"}


def check_rl003(sources: List[Source]) -> Iterable[Finding]:
    """Float-encoded-int bounds: the Pallas cores carry cycle counters,
    periods and histogram bins as f32 lanes, exact only up to 2**24.
    Flags (a) module-level ALL_CAPS integer constants above the bound in
    kernel-scope files (under ``kernels/`` or importing pallas), and
    (b) horizon-like parameter defaults above the bound anywhere."""
    findings: List[Finding] = []
    for src in sources:
        kernelish = "kernels/" in src.rel or _imports_pallas(src.tree)
        if kernelish:
            for name, value, lineno in _caps_int_consts(src.tree):
                if value > MAX_EXACT_F32_INT:
                    findings.append(Finding(
                        "RL003", src.rel, lineno,
                        f"{name} = {value} exceeds 2**24 = "
                        f"{MAX_EXACT_F32_INT}: f32-encoded counters lose "
                        f"integer exactness above that bound"))
        for fn in ast.walk(src.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for pname, default, lineno in _defaults_of(fn):
                if pname in _HORIZON_PARAMS:
                    v = _int_value(default)
                    if v is not None and v > MAX_EXACT_F32_INT:
                        findings.append(Finding(
                            "RL003", src.rel, lineno,
                            f"default {pname}={v} in {fn.name}() exceeds "
                            f"2**24 = {MAX_EXACT_F32_INT}: horizons feed "
                            f"f32-encoded cycle counters which lose "
                            f"exactness above that bound"))
    return findings


def _imports_pallas(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if "pallas" in (node.module or "") or \
                    any("pallas" in a.name for a in node.names):
                return True
        if isinstance(node, ast.Import) and \
                any("pallas" in a.name for a in node.names):
            return True
    return False


def _defaults_of(fn):
    args = fn.args
    positional = list(args.posonlyargs) + list(args.args)
    for arg, default in zip(positional[len(positional)
                                       - len(args.defaults):],
                            args.defaults):
        yield arg.arg, default, arg.lineno
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            yield arg.arg, default, arg.lineno


# ---------------------------------------------- RL004 traced control flow


#: callables whose listed positional-arg indices receive traced bodies
_TRACED_ENTRY = {"pallas_call": (0,), "scan": (0,), "while_loop": (0, 1),
                 "fori_loop": (2,), "cond": (1, 2),
                 "associative_scan": (0,)}

#: attribute accesses that are static at trace time — reading them off a
#: traced value is safe, so taint does not flow through them
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}

#: FIFO mutators through which in-flight taint enters a container
_QUEUE_PUSH = {"append", "appendleft", "extend"}


def check_rl004(sources: List[Source]) -> Iterable[Finding]:
    """Traced-control-flow / sync-point detector.

    (a) A *traced scope* is a function passed (directly or through
    ``functools.partial``) to ``pl.pallas_call`` or to
    ``lax.scan/while_loop/fori_loop/cond/associative_scan``.  Inside
    such scopes the positional parameters are traced values; Python
    ``if``/``while`` on them, ``bool()``/``int()``/``float()``/
    ``.item()``/``.tolist()`` of them, and ``numpy`` calls on them
    either crash at trace time or silently bake one trace's value into
    the compiled program.  Keyword-only parameters are static (the
    ``functools.partial`` convention for grid constants) and stay
    exempt, as do ``.shape``/``.dtype`` reads.

    (b) A *streaming dispatch loop* is a Python ``for`` loop that calls
    a ``cached_program(...)`` executable.  Values returned by the
    executable (and anything pulled back out of a FIFO they were pushed
    into) are *in-flight device values*: a host sync on one —
    ``np.asarray(...)`` / ``.block_until_ready()`` /
    ``jax.device_get(...)`` — blocks the host until that dispatch
    completes, serializing the marshal/device overlap the async
    double-buffered engine exists to provide.  The one legitimate sync
    is the bounded-FIFO retire path, which carries an audited
    ``# repro-lint: disable=RL004`` directive."""
    findings: List[Finding] = []
    for src in sources:
        np_aliases = _numpy_aliases(src.tree)
        seen_sync: Set[int] = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.FunctionDef):
                findings.extend(_dispatch_sync_findings(
                    src, node, np_aliases, seen_sync))
        defs: Dict[str, List[ast.FunctionDef]] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.FunctionDef):
                defs.setdefault(node.name, []).append(node)
        aliases: Dict[str, str] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                root = _body_arg_name(node.value)
                if root:
                    aliases[node.targets[0].id] = root
        traced_names: Set[str] = set()
        for call in ast.walk(src.tree):
            if not isinstance(call, ast.Call):
                continue
            indices = _TRACED_ENTRY.get(_callee_name(call.func) or "")
            if not indices:
                continue
            for idx in indices:
                if idx < len(call.args):
                    name = _body_arg_name(call.args[idx])
                    if name:
                        # chase `body = functools.partial(_kernel, ...)`
                        # style aliases (bounded, cycle-safe)
                        for _ in range(8):
                            if name not in aliases or \
                                    aliases[name] == name:
                                break
                            name = aliases[name]
                        traced_names.add(name)
        seen: Set[int] = set()
        for name in sorted(traced_names):
            for fn in defs.get(name, []):
                if id(fn) in seen:
                    continue
                seen.add(id(fn))
                findings.extend(_lint_traced_fn(src, fn, np_aliases))
    return findings


def _body_arg_name(arg: ast.expr) -> Optional[str]:
    if isinstance(arg, ast.Name):
        return arg.id
    if isinstance(arg, ast.Call) and _callee_name(arg.func) == "partial" \
            and arg.args and isinstance(arg.args[0], ast.Name):
        return arg.args[0].id
    return None


def _numpy_aliases(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy" or a.name.startswith("numpy."):
                    out.add((a.asname or a.name).split(".")[0])
    return out


def _tainted(node: ast.AST, taint: Set[str]) -> bool:
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Name):
        return node.id in taint
    return any(_tainted(child, taint)
               for child in ast.iter_child_nodes(node))


def _target_names(target: ast.expr) -> Iterable[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _target_names(elt)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)


def _lint_traced_fn(src: Source, fn: ast.FunctionDef,
                    np_aliases: Set[str]) -> Iterable[Finding]:
    args = fn.args
    taint = {a.arg for a in list(args.posonlyargs) + list(args.args)}
    taint.discard("self")
    # propagate through simple assignments to a fixed point
    for _ in range(16):
        grew = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _tainted(node.value, taint):
                for t in node.targets:
                    for name in _target_names(t):
                        grew |= name not in taint
                        taint.add(name)
            elif isinstance(node, ast.AugAssign) and \
                    _tainted(node.value, taint):
                for name in _target_names(node.target):
                    grew |= name not in taint
                    taint.add(name)
        if not grew:
            break
    where = f"traced scope {fn.name}() ({src.rel}:{fn.lineno})"
    for node in ast.walk(fn):
        if isinstance(node, ast.If) and _tainted(node.test, taint):
            yield Finding("RL004", src.rel, node.lineno,
                          f"Python `if` on a traced value inside {where} — "
                          f"use jnp.where / lax.cond / lax.select")
        elif isinstance(node, ast.While) and _tainted(node.test, taint):
            yield Finding("RL004", src.rel, node.lineno,
                          f"Python `while` on a traced value inside {where} "
                          f"— use lax.while_loop")
        elif isinstance(node, ast.Call):
            cname = _callee_name(node.func)
            if isinstance(node.func, ast.Name) and \
                    cname in ("bool", "int", "float") and \
                    any(_tainted(a, taint) for a in node.args):
                yield Finding("RL004", src.rel, node.lineno,
                              f"host sync: {cname}() on a traced value "
                              f"inside {where} — forces a blocking "
                              f"device readback at trace time")
            elif isinstance(node.func, ast.Attribute) and \
                    cname in ("item", "tolist") and \
                    _tainted(node.func.value, taint):
                yield Finding("RL004", src.rel, node.lineno,
                              f"host sync: .{cname}() on a traced value "
                              f"inside {where}")
            elif isinstance(node.func, ast.Attribute) and \
                    _attr_root(node.func) in np_aliases and \
                    any(_tainted(a, taint) for a in node.args):
                yield Finding("RL004", src.rel, node.lineno,
                              f"stray numpy call on a traced value inside "
                              f"{where} — numpy executes on the host at "
                              f"trace time and bakes in one trace's value")


def _attr_root(node: ast.Attribute) -> Optional[str]:
    value = node.value
    while isinstance(value, ast.Attribute):
        value = value.value
    return value.id if isinstance(value, ast.Name) else None


def _dispatch_sync_findings(src: Source, fn: ast.FunctionDef,
                            np_aliases: Set[str],
                            seen: Set[int]) -> Iterable[Finding]:
    """RL004(b): host syncs on in-flight device values inside a function
    that drives a streaming dispatch loop (see :func:`check_rl004`)."""
    progs = {node.targets[0].id for node in ast.walk(fn)
             if isinstance(node, ast.Assign) and len(node.targets) == 1
             and isinstance(node.targets[0], ast.Name)
             and isinstance(node.value, ast.Call)
             and _callee_name(node.value.func) == "cached_program"}
    if not progs:
        return

    def calls_prog(tree: ast.AST) -> bool:
        return any(isinstance(c, ast.Call) and isinstance(c.func, ast.Name)
                   and c.func.id in progs for c in ast.walk(tree))

    if not any(isinstance(n, ast.For) and calls_prog(n)
               for n in ast.walk(fn)):
        return
    # in-flight taint: program results, plus any FIFO they are pushed
    # into and everything unpacked back out of it (fixed point)
    taint = set(progs)
    for _ in range(16):
        grew = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _tainted(node.value, taint):
                for t in node.targets:
                    for name in _target_names(t):
                        grew |= name not in taint
                        taint.add(name)
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _QUEUE_PUSH and \
                    isinstance(node.func.value, ast.Name) and \
                    any(_tainted(a, taint) for a in node.args):
                name = node.func.value.id
                grew |= name not in taint
                taint.add(name)
        if not grew:
            break
    where = f"{fn.name}() ({src.rel}:{fn.lineno})"
    for stmt in ast.walk(fn):
        if not isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                                 ast.Expr, ast.Return)):
            continue
        for call in ast.walk(stmt):
            desc = _sync_call_desc(call, np_aliases, taint)
            if desc and stmt.lineno not in seen:
                seen.add(stmt.lineno)
                yield Finding(
                    "RL004", src.rel, stmt.lineno,
                    f"host sync: {desc} on an in-flight device value of "
                    f"the streaming dispatch loop in {where} — blocking "
                    f"inside the loop serializes host marshalling against "
                    f"device execution; retire through the bounded FIFO "
                    f"(the audited retire path carries a suppression)")
                break


def _sync_call_desc(node: ast.AST, np_aliases: Set[str],
                    taint: Set[str]) -> Optional[str]:
    """Describe ``node`` when it is a host-sync call on a tainted value."""
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)):
        return None
    attr = node.func.attr
    if attr == "block_until_ready" and _tainted(node.func.value, taint):
        return ".block_until_ready()"
    if attr == "device_get" and any(_tainted(a, taint) for a in node.args):
        return "jax.device_get()"
    if attr in ("asarray", "array") and \
            _attr_root(node.func) in np_aliases and \
            any(_tainted(a, taint) for a in node.args):
        return f"np.{attr}()"
    return None


# ---------------------------------------------- RL005 registry consistency


def check_rl005(sources: List[Source]) -> Iterable[Finding]:
    """Registry consistency: module-level ``*_FIELDS`` registries (e.g.
    ``PERTURBABLE_FIELDS`` / ``PERTURBABLE_PHY_FIELDS``) must name real
    fields of the dataclasses defined in the same module, stay sorted
    and duplicate-free (deterministic unknown-field errors / goldens),
    and — when derived — be computed from ``dataclasses.fields(...)``
    through ``sorted(...)`` so they track the dataclass automatically."""
    findings: List[Finding] = []
    for src in sources:
        cls_fields: Set[str] = set()
        for cls in _dataclasses_in(src.tree):
            cls_fields |= {name for name, _ in _dataclass_fields(cls)}
        for node in src.tree.body:
            name, value = _fields_registry(node)
            if name is None:
                continue
            entries = _str_tuple(value)
            if entries is not None:
                if cls_fields:
                    for e in entries:
                        if e not in cls_fields:
                            findings.append(Finding(
                                "RL005", src.rel, node.lineno,
                                f"{name} entry '{e}' is not a field of any "
                                f"dataclass in this module — the registry "
                                f"drifted from the dataclass it covers"))
                if list(entries) != sorted(entries):
                    findings.append(Finding(
                        "RL005", src.rel, node.lineno,
                        f"{name} is not sorted — unknown-field error "
                        f"messages and lint goldens become "
                        f"nondeterministic"))
                if len(set(entries)) != len(entries):
                    findings.append(Finding(
                        "RL005", src.rel, node.lineno,
                        f"{name} contains duplicate entries"))
            else:
                has_sorted = any(isinstance(n, ast.Call)
                                 and _callee_name(n.func) == "sorted"
                                 for n in ast.walk(value))
                has_fields = any(isinstance(n, ast.Call)
                                 and _callee_name(n.func) == "fields"
                                 for n in ast.walk(value))
                if not (has_sorted and has_fields):
                    findings.append(Finding(
                        "RL005", src.rel, node.lineno,
                        f"{name} should be derived from "
                        f"dataclasses.fields(...) wrapped in sorted(...) "
                        f"so it tracks the dataclass and stays "
                        f"deterministic"))
    return findings


def _fields_registry(node: ast.stmt):
    if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
            isinstance(node.targets[0], ast.Name):
        name = node.targets[0].id
        value = node.value
    elif isinstance(node, ast.AnnAssign) and \
            isinstance(node.target, ast.Name) and node.value is not None:
        name = node.target.id
        value = node.value
    else:
        return None, None
    if name.isupper() and name.endswith("_FIELDS"):
        return name, value
    return None, None


def _str_tuple(value: ast.expr) -> Optional[List[str]]:
    if isinstance(value, ast.Call) and \
            _callee_name(value.func) in ("tuple", "list") and \
            len(value.args) == 1:
        value = value.args[0]
    if not isinstance(value, (ast.Tuple, ast.List)):
        return None
    out = []
    for elt in value.elts:
        if not (isinstance(elt, ast.Constant) and isinstance(elt.value, str)):
            return None
        out.append(elt.value)
    return out


#: check registry: id -> (human title, implementation)
CHECKS = {
    "RL001": ("cache-key integrity", check_rl001),
    "RL002": ("kernel/ref parity", check_rl002),
    "RL003": ("float-encoded-int bounds", check_rl003),
    "RL004": ("traced control flow / sync points", check_rl004),
    "RL005": ("registry consistency", check_rl005),
}
