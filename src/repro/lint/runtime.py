"""Runtime tracer-safety sanitizer: retrace counting + transfer guards.

The static checks in :mod:`repro.lint.checks` prove properties of the
source; this module enforces the complementary *runtime* claims — "this
warm section triggers zero recompiles" and "this section moves no data
across the host/device boundary" — so the compile-once acceptance tests
(``tests/test_design_space.py``, ``tests/test_adaptive_sim.py``) verify
no-retrace directly rather than only inferring it from cache counters.

Retrace detection listens to JAX's own compile logging
(``jax_log_compiles``): every trace+compile emits log records from the
``jax.*`` loggers ("Compiling ...", "Finished tracing + transforming
..."), and a fully warm path emits none — the C++ jit fast path never
re-enters Python.  This is version-robust (the flag and messages are
stable across the repo's 0.4.37 floor and latest) and catches *any*
compile in the section, including internal jits the shared
``cached_program`` cache never sees.

This is the only :mod:`repro.lint` module that imports JAX; keep it out
of the static pass so the CI lint job runs on a bare interpreter.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import re
from typing import Iterator, List, Optional

import jax

__all__ = ["CompileLog", "RetraceError", "count_compiles", "no_retrace"]

#: one compile produces one or more of these records; a warm path
#: produces none.  ``count`` therefore means "compile log events", an
#: upper bound on compiles that is exactly zero iff no retrace happened.
_COMPILE_EVENT_RE = re.compile(
    r"Compiling |Finished tracing \+ transforming|Finished XLA compilation")


class RetraceError(AssertionError):
    """A section declared retrace-free compiled something."""


@dataclasses.dataclass
class CompileLog:
    """Compile log events captured inside a :func:`count_compiles`
    section."""

    events: List[str]

    @property
    def count(self) -> int:
        return len(self.events)


class _CaptureHandler(logging.Handler):
    def __init__(self, sink: List[str]):
        super().__init__(level=logging.DEBUG)
        self._sink = sink

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:      # a malformed record must not kill the test
            return
        if _COMPILE_EVENT_RE.search(msg):
            self._sink.append(msg)


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileLog]:
    """Capture JAX compile log events for the duration of the block.

    Temporarily enables ``jax_log_compiles`` and attaches a handler to
    the ``jax`` logger (all ``jax._src.*`` loggers propagate through
    it); both are restored on exit.
    """
    log = CompileLog(events=[])
    handler = _CaptureHandler(log.events)
    jax_logger = logging.getLogger("jax")
    prev = bool(getattr(jax.config, "jax_log_compiles", False))
    jax.config.update("jax_log_compiles", True)
    jax_logger.addHandler(handler)
    try:
        yield log
    finally:
        jax_logger.removeHandler(handler)
        jax.config.update("jax_log_compiles", prev)


@contextlib.contextmanager
def no_retrace(max_compiles: int = 0,
               transfer: Optional[str] = None) -> Iterator[CompileLog]:
    """Assert that the block performs at most ``max_compiles`` compile
    events (default: a fully warm, zero-retrace section).

    ``transfer`` optionally arms ``jax.transfer_guard`` for the block
    ("allow" / "log" / "disallow" / the explicit variants), so a section
    can additionally assert it moves no data across the host/device
    boundary.  Raises :class:`RetraceError` on violation, annotated with
    the first captured compile events.
    """
    guard = jax.transfer_guard(transfer) if transfer is not None \
        else contextlib.nullcontext()
    with guard, count_compiles() as log:
        yield log
    if log.count > max_compiles:
        head = "\n  ".join(log.events[:8])
        raise RetraceError(
            f"{log.count} compile event(s) inside a "
            f"no_retrace(max_compiles={max_compiles}) section — a warm "
            f"path retraced.  First events:\n  {head}")
