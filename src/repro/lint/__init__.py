"""repro-lint: the repo's own static invariant checker.

``python -m repro.lint`` (or ``tools/lint.py``) runs five AST checks
(RL001–RL005) over ``src/`` — cache-key integrity, kernel/ref parity,
float-encoded-int bounds, traced control flow, registry consistency —
and exits non-zero on any unsuppressed finding.  See
``src/repro/lint/README.md`` for the check catalogue and the
``# repro-lint: disable=RLxxx`` suppression syntax.

The static pass is stdlib-only; the runtime tracer-safety sanitizer
(retrace counting + ``jax.transfer_guard`` wiring) lives in
:mod:`repro.lint.runtime` and is the only part that imports JAX.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.lint.engine import (
    Finding, LintError, LintReport, default_root, load_sources, run_lint,
)

__all__ = ["Finding", "LintError", "LintReport", "default_root",
           "load_sources", "run_lint", "main"]


def main(argv: Optional[List[str]] = None) -> int:
    from repro.lint.checks import CHECKS

    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST invariant checks RL001-RL005 over the repo's "
                    "own source tree")
    ap.add_argument("root", nargs="?", default=None,
                    help="file or tree to lint (default: the repo's src/)")
    ap.add_argument("--json", action="store_true",
                    help="emit the full JSON report on stdout")
    ap.add_argument("--output", metavar="PATH", default=None,
                    help="also write the JSON report to PATH (the CI "
                         "artifact)")
    ap.add_argument("--select", metavar="IDS", default=None,
                    help="comma-separated check ids to run (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list the check catalogue and exit")
    args = ap.parse_args(argv)

    if args.list:
        for check_id, (title, fn) in CHECKS.items():
            print(f"{check_id}  {title}")
        return 0
    select = [s.strip() for s in args.select.split(",")] \
        if args.select else None
    try:
        report = run_lint(args.root, select=select)
    except LintError as e:
        print(f"repro-lint: error: {e}", file=sys.stderr)
        return 2
    payload = report.to_json()
    if args.output:
        with open(args.output, "w") as f:
            json.dump(payload, f, indent=1)
    if args.json:
        json.dump(payload, sys.stdout, indent=1)
        print()
    else:
        for finding in report.findings:
            print(finding.format())
        n, m = len(report.unsuppressed), len(report.suppressed)
        print(f"repro-lint: checked {report.files} files "
              f"({', '.join(report.checks)}) in {report.elapsed_s:.2f}s — "
              f"{n} finding(s), {m} suppressed")
    return 1 if report.unsuppressed else 0
