"""Elastic scaling: restore any checkpoint onto any mesh.

The checkpoint format is mesh-agnostic (shards carry global indices), so
elasticity is: build the new mesh, derive fresh shardings from the model's
logical-axis schema, and restore with re-placement.  This module adds the
driver-level helpers: pick a mesh for the devices that are actually
healthy, and produce the (state_shardings, restore) pair in one call.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.models.model import Model
from repro.models.sharding import ShardingCtx, from_mesh


def mesh_for_devices(n_devices: int, model_axis: int = 1):
    """Largest (data, model) mesh that fits n_devices (model fixed)."""
    data = n_devices // model_axis
    devs = np.array(jax.devices()[: data * model_axis]).reshape(
        data, model_axis)
    return jax.sharding.Mesh(devs, ("data", "model"))


def restore_elastic(directory: str, model: Model, ctx: ShardingCtx,
                    make_state_specs, step: Optional[int] = None):
    """Restore a TrainState saved under ANY mesh onto ctx.mesh.

    make_state_specs: fn(model, ctx) -> pytree of PartitionSpec (e.g.
    repro.train.train_step.state_specs).
    """
    from jax.sharding import NamedSharding

    specs = make_state_specs(model, ctx)
    shardings = (jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        if ctx.enabled else None)
    target = jax.tree.map(lambda s: s, specs,
                          is_leaf=lambda x: isinstance(
                              x, jax.sharding.PartitionSpec))
    # build abstract target with shapes from a fresh eval_shape of init
    return ckpt.restore(directory, target=_abstract_state(model, ctx),
                        step=step, shardings=shardings)


def _abstract_state(model: Model, ctx: ShardingCtx):
    import jax.numpy as jnp
    from repro.train.optimizer import AdamW, constant_schedule
    from repro.train.train_step import TrainState, init_state
    opt = AdamW(learning_rate=constant_schedule(1e-3))
    return jax.eval_shape(
        lambda k: init_state(model, k, opt), jax.random.PRNGKey(0))
