from repro.checkpoint import ckpt, elastic
