"""Sharded, async, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_<N>/
        manifest.json            — treedef paths, shapes, dtypes, specs,
                                   mesh shape/axis names, step
        <leaf-path>.shard<i>.npy — one file per addressable shard
        _COMMITTED               — written last; restore ignores
                                   uncommitted (crashed) checkpoints

Each process writes only its addressable shards (single-process on CPU
writes all of them).  Restore is *elastic*: shards are reassembled into
full host arrays by their index metadata and re-placed with any target
sharding/mesh — restoring a (4,2)-mesh checkpoint onto (2,2) or (1,1)
works by construction (tested in tests/test_checkpoint.py).

Async mode: device->host copies happen synchronously (cheap), file writes
happen on a background thread; ``wait()`` joins before the next save.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.compat import tree_flatten_with_path

_MANIFEST = "manifest.json"
_COMMITTED = "_COMMITTED"

# shared holder for the async writer thread (save() joins the previous
# write; wait() joins the outstanding one)
_WRITER = {"thread": None}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """bfloat16 isn't a native numpy dtype — persist as a uint16 view."""
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(state, step: int, directory: str, asynchronous: bool = False,
         _thread_holder: Dict = _WRITER):
    """Save a pytree of (possibly sharded) jax arrays."""
    prev = _thread_holder.get("thread")
    if prev is not None:
        prev.join()

    stepdir = os.path.join(directory, f"step_{step:08d}")
    tmpdir = stepdir + ".tmp"
    if os.path.exists(tmpdir):
        shutil.rmtree(tmpdir)
    os.makedirs(tmpdir, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    writes: List[Tuple[str, np.ndarray]] = []
    for name, leaf in _leaf_paths(state):
        arr = jax.numpy.asarray(leaf) if not isinstance(
            leaf, (np.ndarray, jax.Array)) else leaf
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shards": []}
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for i, sh in enumerate(arr.addressable_shards):
                if sh.replica_id != 0:
                    continue
                idx = [[s.start, s.stop] if isinstance(s, slice)
                       and s.start is not None
                       else None for s in sh.index]
                fname = f"{name.replace('/', '__')}.shard{i}.npy"
                entry["shards"].append({"file": fname, "index": idx})
                writes.append((os.path.join(tmpdir, fname),
                               _to_savable(np.asarray(sh.data))))
        else:
            fname = f"{name.replace('/', '__')}.shard0.npy"
            entry["shards"].append({"file": fname, "index": None})
            writes.append((os.path.join(tmpdir, fname),
                           _to_savable(np.asarray(arr))))
        manifest["leaves"][name] = entry

    def _write():
        for path, data in writes:
            np.save(path, data)
        with open(os.path.join(tmpdir, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmpdir, _COMMITTED), "w") as f:
            f.write("ok")
        if os.path.exists(stepdir):
            shutil.rmtree(stepdir)
        os.rename(tmpdir, stepdir)

    if asynchronous:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _thread_holder["thread"] = t
    else:
        _write()
        _thread_holder["thread"] = None
    return stepdir


def wait(_thread_holder: Dict = _WRITER):
    t = _thread_holder.get("thread")
    if t is not None:
        t.join()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _COMMITTED)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def _assemble(entry: Dict, stepdir: str) -> np.ndarray:
    shape = tuple(entry["shape"])
    dtype = np.dtype(entry["dtype"]) if entry["dtype"] != "bfloat16" else \
        jax.numpy.bfloat16
    shards = entry["shards"]
    if len(shards) == 1 and shards[0]["index"] is None:
        return _from_saved(np.load(os.path.join(stepdir, shards[0]["file"])),
                           entry["dtype"])
    out = np.zeros(shape, dtype=dtype)
    for sh in shards:
        data = _from_saved(np.load(os.path.join(stepdir, sh["file"])),
                           entry["dtype"])
        idx = tuple(slice(*s) if s is not None else slice(None)
                    for s in sh["index"])
        out[idx] = data
    return out


def restore(directory: str, target, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings for elastic re-placement on the current mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    stepdir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(stepdir, _MANIFEST)) as f:
        manifest = json.load(f)

    names = [n for n, _ in _leaf_paths(target)]
    leaves_t, treedef = jax.tree.flatten(target)
    shard_list = (jax.tree.leaves(shardings,
                                  is_leaf=lambda x: x is None
                                  or isinstance(x, jax.sharding.Sharding))
                  if shardings is not None else [None] * len(leaves_t))
    out = []
    for name, tgt, shd in zip(names, leaves_t, shard_list):
        entry = manifest["leaves"][name]
        arr = _assemble(entry, stepdir)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step
