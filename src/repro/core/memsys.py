"""MemorySystem — compose a protocol mapping with a UCIe PHY (or a bus
baseline) into a deployable on-package memory model.

This is the object the roofline bridge consumes: given a workload's traffic
mix it answers "what data bandwidth, pJ/b and latency does this memory
system deliver, for a given shoreline budget?".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import latency as latency_mod
from repro.core.protocols import (
    ALL_APPROACHES, BASELINES, BidirectionalBusMemory, MemoryProtocol,
)
from repro.core.ucie import UCIE_A_32G_55U, UCIE_S_32G, UCIePhy


@dataclasses.dataclass(frozen=True)
class MemorySystem:
    name: str
    protocol: MemoryProtocol
    phy: Optional[UCIePhy] = None          # None for bus baselines
    latency_ns: float = 3.0
    #: relative $/bit of the DRAM behind the interface (LPDDR=1, HBM=7.5)
    relative_bit_cost: float = 1.0

    def _is_bus(self) -> bool:
        return isinstance(self.protocol, BidirectionalBusMemory)

    def bw_eff(self, x, y):
        return self.protocol.bw_eff(x, y)

    def linear_density(self, x, y):
        return self.protocol.bw_density_linear(x, y, self.phy)

    def areal_density(self, x, y):
        return self.protocol.bw_density_areal(x, y, self.phy)

    def pj_per_bit(self, x, y):
        return self.protocol.power_pj_per_bit(x, y, self.phy)

    def bandwidth_gbs(self, x, y, shoreline_mm: float):
        """Deliverable cache-line GB/s for a shoreline budget."""
        return self.linear_density(x, y) * shoreline_mm

    def power_w(self, x, y, shoreline_mm: float):
        """Interconnect power (W) at full utilization of the shoreline."""
        gbs = self.bandwidth_gbs(x, y, shoreline_mm)
        return gbs * 8.0 * self.pj_per_bit(x, y) / 1000.0   # GB/s * pJ/b -> W


def standard_catalog() -> Dict[str, MemorySystem]:
    """Every (approach x packaging) the paper evaluates + the baselines."""
    cat: Dict[str, MemorySystem] = {}
    lat = latency_mod.MEASURED_FRONTEND_LATENCY_NS
    for key, proto in ALL_APPROACHES.items():
        for phy, tag in ((UCIE_A_32G_55U, "UCIe-A"), (UCIE_S_32G, "UCIe-S")):
            bit_cost = 7.5 if "hbm" in key else 1.0
            cat[f"{key}/{tag}"] = MemorySystem(
                name=f"{proto.name}/{tag}",
                protocol=proto, phy=phy,
                latency_ns=lat["UCIe-Memory"],
                relative_bit_cost=bit_cost,
            )
    for bname, bus in BASELINES.items():
        cat[bname] = MemorySystem(
            name=bus.name, protocol=bus, phy=None,
            latency_ns=lat.get(bname, 6.0),
            relative_bit_cost=7.5 if "HBM" in bname else 1.0,
        )
    return cat
