"""MemorySystem — compose a protocol mapping with a UCIe PHY (or a bus
baseline) into a deployable on-package memory model.

This is the object the roofline bridge consumes: given a workload's traffic
mix it answers "what data bandwidth, pJ/b and latency does this memory
system deliver, for a given shoreline budget?".

Batched evaluation: :func:`run_catalog_program` stacks every system's
closed-form metrics into ``[S, ...]`` arrays produced by a single compiled
(and memoized) program — this is the analytic engine the axes-first
:class:`repro.core.space.DesignSpace` lowers onto.  Executables live in the
SHARED design-space compile cache (:mod:`repro.core.space`), keyed on
(catalog, grid shapes): any front-end — ``_catalog_grid_impl``,
``bridge_design_space``, or a ``DesignSpace`` evaluation — that requests an
identically-shaped grid runs the warm executable.  ``_catalog_grid_impl`` and
:func:`approach_grid` remain as compatibility wrappers returning the legacy
stacked dataclasses.

The PHY is an axis, not a key suffix: :func:`run_catalog_phys_program` /
:func:`run_approach_phys_program` stack (phy x system) pairs into the SAME
cache families, which is what ``axis("phy", [...])`` lowers onto —
:func:`approach_catalog_items` provides the PHY-less per-approach
templates, and :func:`perturbed_catalog_items` folds ``catalog_param``
perturbations (``UCIePhy.perturbed``) into the stack.

Relation to the flit-simulation ``sim=`` config: the analytic programs
here are closed forms (no cycle loop), so
:class:`repro.core.space.SimConfig` does not change their numerics — only
the flit-simulated metrics (``sim_efficiency`` / ``sim_bandwidth_gbs``)
riding next to them in a joint ``DesignSpace`` evaluation switch between
fixed-horizon and convergence-adaptive execution.  The PHY axis does feed
the simulators through ``sim_bandwidth_gbs`` (simulated efficiency x
``UCIePhy.raw_bandwidth_gbs``), which is how the simulation-corrected
frontier sweeps 32G/48G generations like the closed forms do.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import latency as latency_mod
from repro.core import space as space_mod
from repro.core.space import CacheStats, cached_program
from repro.core.protocols import (
    ALL_APPROACHES, BASELINES, BidirectionalBusMemory, MemoryProtocol,
)
from repro.core.ucie import UCIE_A_32G_55U, UCIE_S_32G, UCIePhy


@dataclasses.dataclass(frozen=True)
class MemorySystem:
    name: str
    protocol: MemoryProtocol
    phy: Optional[UCIePhy] = None          # None for bus baselines
    latency_ns: float = 3.0
    #: relative $/bit of the DRAM behind the interface (LPDDR=1, HBM=7.5)
    relative_bit_cost: float = 1.0

    def _is_bus(self) -> bool:
        return isinstance(self.protocol, BidirectionalBusMemory)

    def bw_eff(self, x, y):
        return self.protocol.bw_eff(x, y)

    def linear_density(self, x, y):
        return self.protocol.bw_density_linear(x, y, self.phy)

    def areal_density(self, x, y):
        return self.protocol.bw_density_areal(x, y, self.phy)

    def pj_per_bit(self, x, y):
        return self.protocol.power_pj_per_bit(x, y, self.phy)

    def bandwidth_gbs(self, x, y, shoreline_mm: float):
        """Deliverable cache-line GB/s for a shoreline budget."""
        return self.linear_density(x, y) * shoreline_mm

    def power_w(self, x, y, shoreline_mm: float):
        """Interconnect power (W) at full utilization of the shoreline."""
        gbs = self.bandwidth_gbs(x, y, shoreline_mm)
        return gbs * 8.0 * self.pj_per_bit(x, y) / 1000.0   # GB/s * pJ/b -> W


def standard_catalog() -> Dict[str, MemorySystem]:
    """Every (approach x packaging) the paper evaluates + the baselines."""
    cat: Dict[str, MemorySystem] = {}
    lat = latency_mod.MEASURED_FRONTEND_LATENCY_NS
    for key, proto in ALL_APPROACHES.items():
        for phy, tag in ((UCIE_A_32G_55U, "UCIe-A"), (UCIE_S_32G, "UCIe-S")):
            bit_cost = 7.5 if "hbm" in key else 1.0
            cat[f"{key}/{tag}"] = MemorySystem(
                name=f"{proto.name}/{tag}",
                protocol=proto, phy=phy,
                latency_ns=lat["UCIe-Memory"],
                relative_bit_cost=bit_cost,
            )
    for bname, bus in BASELINES.items():
        cat[bname] = MemorySystem(
            name=bus.name, protocol=bus, phy=None,
            latency_ns=lat.get(bname, 6.0),
            relative_bit_cost=7.5 if "HBM" in bname else 1.0,
        )
    return cat


@functools.lru_cache(maxsize=1)
def default_catalog_items() -> Tuple[Tuple[str, MemorySystem], ...]:
    """The standard catalog as a hashable, cached tuple of items — the key
    the batched-grid compile cache is built on."""
    return tuple(standard_catalog().items())


@functools.lru_cache(maxsize=1)
def approach_catalog_items() -> Tuple[Tuple[str, MemorySystem], ...]:
    """Per-approach :class:`MemorySystem` templates WITHOUT a baked PHY.

    This is the catalog a ``phy`` axis stacks: the axes-first API pairs
    each template with every PHY on the axis
    (:func:`phy_stacked_items`), so the PHY is a queryable dimension of
    the result instead of a ``/UCIe-A`` key suffix.  Bus baselines are
    excluded — they do not attach over a UCIe PHY.
    """
    lat = latency_mod.MEASURED_FRONTEND_LATENCY_NS
    return tuple(
        (key, MemorySystem(
            name=proto.name, protocol=proto, phy=None,
            latency_ns=lat["UCIe-Memory"],
            relative_bit_cost=7.5 if "hbm" in key else 1.0))
        for key, proto in ALL_APPROACHES.items())


def phy_stacked_items(items: Tuple[Tuple[str, MemorySystem], ...],
                      phys) -> Tuple[Tuple[str, MemorySystem], ...]:
    """Flatten (phy x system) into one stacked catalog: PHY-major order,
    so program outputs reshape to ``[F, S, ...]``."""
    return tuple(
        (f"{key}@{phy.name}", dataclasses.replace(ms, phy=phy,
                                                  name=f"{ms.name}/{phy.name}"))
        for phy in phys for key, ms in items)


def perturbed_catalog_items(items: Tuple[Tuple[str, MemorySystem], ...],
                            perturbations
                            ) -> Tuple[Tuple[str, MemorySystem], ...]:
    """Flatten (catalog_param x system) into one stacked catalog.

    Each multiplicative ``{field: scale}`` perturbation is applied to every
    system's PHY (``UCIePhy.perturbed``); systems without a PHY (bus
    baselines) pass through unperturbed — mirroring how an asymmetric flit
    protocol ignores a symmetric-only ``protocol_param`` field.
    Perturbation-major order: program outputs reshape to ``[Q, S, ...]``.
    """
    out = []
    for pert in perturbations:
        for key, ms in items:
            if ms.phy is not None and pert:
                ms = dataclasses.replace(ms, phy=ms.phy.perturbed(pert))
            out.append((key, ms))
    return tuple(out)


# -- batched grid evaluation --------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CatalogGrid:
    """Stacked per-system metrics over a traffic-mix grid.

    Metric arrays are ``[S, *mix_shape]`` where ``S`` follows ``keys``;
    ``latency_ns`` / ``relative_bit_cost`` are per-system ``[S]`` scalars.
    """

    keys: Tuple[str, ...]
    bandwidth_gbs: jnp.ndarray
    pj_per_bit: jnp.ndarray
    power_w: jnp.ndarray
    gbs_per_watt: jnp.ndarray
    latency_ns: jnp.ndarray
    relative_bit_cost: jnp.ndarray


#: legacy alias — the shared-cache counters use one stats type now
GridCacheStats = CacheStats


def grid_cache_stats() -> CacheStats:
    """This module's slice of the SHARED design-space compile cache
    (families ``memsys.*``): one miss == one trace+compile of a stacked
    program (new catalog or new grid shape); hits run warm."""
    return space_mod.cache_stats(space_mod.MEMSYS_FAMILIES)


def clear_grid_cache() -> None:
    """Drop the memoized grid programs and reset the hit/miss counters."""
    space_mod.clear_cache(space_mod.MEMSYS_FAMILIES)


def run_catalog_program(items: Tuple[Tuple[str, MemorySystem], ...],
                        x, y, shoreline_mm):
    """Evaluate the stacked catalog program on (x, y, shoreline) arrays.

    The engine entry point ``DesignSpace`` lowers onto.  Returns
    ``(bandwidth_gbs, pj_per_bit, power_w, gbs_per_watt)``, each
    ``[S, *broadcast(x, y, shoreline)]``.  Compiled once per (catalog,
    grid-shape) into the shared design-space cache.
    """
    items = tuple(items)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sl = jnp.asarray(shoreline_mm, jnp.float32)
    systems = [ms for _, ms in items]

    def fn(x, y, sl):
        bw = jnp.stack([ms.bandwidth_gbs(x, y, sl) for ms in systems])
        pjb = jnp.stack([jnp.broadcast_to(ms.pj_per_bit(x, y), bw.shape[1:])
                         for ms in systems])
        pw = bw * 8.0 * pjb / 1000.0        # GB/s * pJ/b -> W
        gpw = jnp.where(pw > 0, bw / pw, jnp.inf)
        return bw, pjb, pw, gpw

    prog = cached_program("memsys.catalog",
                          (items, x.shape, y.shape, sl.shape),
                          fn, (x, y, sl))
    return prog(x, y, sl)


def _catalog_grid_impl(x, y, shoreline_mm=8.0,
                       catalog: Optional[Dict[str, MemorySystem]] = None,
                       ) -> CatalogGrid:
    """Engine body of the retired ``catalog_grid`` front-end —
    internal callers (``selector.rank``, the roofline bridge) use this
    directly, warning-free."""
    items = (default_catalog_items() if catalog is None
             else tuple(catalog.items()))
    bw, pjb, pw, gpw = run_catalog_program(items, x, y, shoreline_mm)
    return CatalogGrid(
        keys=tuple(k for k, _ in items),
        bandwidth_gbs=bw, pj_per_bit=pjb, power_w=pw, gbs_per_watt=gpw,
        latency_ns=jnp.asarray([ms.latency_ns for _, ms in items],
                               jnp.float32),
        relative_bit_cost=jnp.asarray(
            [ms.relative_bit_cost for _, ms in items], jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class ApproachGrid:
    """Stacked ``[S, *mix_shape]`` density/power metrics for ALL_APPROACHES
    on a given PHY (the Figs 10-12 sweeps)."""

    keys: Tuple[str, ...]
    linear: jnp.ndarray
    areal: jnp.ndarray
    pj_per_bit: jnp.ndarray


def run_catalog_phys_program(items: Tuple[Tuple[str, MemorySystem], ...],
                             phys, x, y, shoreline_mm):
    """PHY-stacked variant of :func:`run_catalog_program`.

    ``items`` are PHY-less templates (:func:`approach_catalog_items`);
    every (phy, system) pair is flattened into ONE stacked catalog program
    (same ``memsys.catalog`` cache family — the full ``[phy x configs x
    mix x shoreline]`` space still compiles once), then reshaped to
    ``(bandwidth_gbs, pj_per_bit, power_w, gbs_per_watt)``, each
    ``[F, S, *grid]``.
    """
    phys = tuple(phys)
    items = tuple(items)
    flat = phy_stacked_items(items, phys)
    bw, pjb, pw, gpw = run_catalog_program(flat, x, y, shoreline_mm)
    lead = (len(phys), len(items))
    return tuple(a.reshape(lead + a.shape[1:]) for a in (bw, pjb, pw, gpw))


def run_approach_phys_program(phys, x, y):
    """PHY-stacked approach-density program on (x, y); shared-cache
    memoized (``memsys.approach`` family — one compile per (phys,
    grid-shape)).

    Returns ``(linear, areal, pj_per_bit)``, each ``[F, A, *x.shape]``.
    """
    phys = tuple(phys)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    protos = tuple(ALL_APPROACHES.values())

    def fn(x, y):
        lin = jnp.stack([
            jnp.stack([p.bw_density_linear(x, y, phy) for p in protos])
            for phy in phys])
        areal = jnp.stack([
            jnp.stack([p.bw_density_areal(x, y, phy) for p in protos])
            for phy in phys])
        pjb = jnp.stack([
            jnp.stack([jnp.broadcast_to(p.power_pj_per_bit(x, y, phy),
                                        lin.shape[2:]) for p in protos])
            for phy in phys])
        return lin, areal, pjb

    prog = cached_program("memsys.approach", (phys, x.shape, y.shape),
                          fn, (x, y))
    return prog(x, y)


def run_approach_program(phy: UCIePhy, x, y):
    """Stacked approach-density program on (x, y); shared-cache memoized.

    Single-PHY wrapper over :func:`run_approach_phys_program` — the same
    executable serves ``approach_grid``, ``DesignSpace(phy=...)`` and a
    one-entry ``phy`` axis.  Returns ``(linear, areal, pj_per_bit)``, each
    ``[A, *x.shape]``.
    """
    lin, areal, pjb = run_approach_phys_program((phy,), x, y)
    return lin[0], areal[0], pjb[0]


def approach_grid(phy: UCIePhy, x, y) -> ApproachGrid:
    """All approaches' bandwidth-density and pJ/b over a mix grid, stacked
    and computed in one compiled call per (phy, grid-shape) — a
    compatibility wrapper over :func:`run_approach_program`."""
    lin, areal, pjb = run_approach_program(phy, x, y)
    return ApproachGrid(keys=tuple(ALL_APPROACHES), linear=lin, areal=areal,
                        pj_per_bit=pjb)
