"""MemorySystem — compose a protocol mapping with a UCIe PHY (or a bus
baseline) into a deployable on-package memory model.

This is the object the roofline bridge consumes: given a workload's traffic
mix it answers "what data bandwidth, pJ/b and latency does this memory
system deliver, for a given shoreline budget?".

Batched evaluation: :func:`catalog_grid` and :func:`approach_grid` stack
every system's closed-form metrics into ``[S, ...]`` arrays produced by a
single jitted (and memoized) program, so a dense traffic-mix grid over the
whole catalog costs one compiled call instead of a per-system Python loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import latency as latency_mod
from repro.core.protocols import (
    ALL_APPROACHES, BASELINES, BidirectionalBusMemory, MemoryProtocol,
)
from repro.core.ucie import UCIE_A_32G_55U, UCIE_S_32G, UCIePhy


@dataclasses.dataclass(frozen=True)
class MemorySystem:
    name: str
    protocol: MemoryProtocol
    phy: Optional[UCIePhy] = None          # None for bus baselines
    latency_ns: float = 3.0
    #: relative $/bit of the DRAM behind the interface (LPDDR=1, HBM=7.5)
    relative_bit_cost: float = 1.0

    def _is_bus(self) -> bool:
        return isinstance(self.protocol, BidirectionalBusMemory)

    def bw_eff(self, x, y):
        return self.protocol.bw_eff(x, y)

    def linear_density(self, x, y):
        return self.protocol.bw_density_linear(x, y, self.phy)

    def areal_density(self, x, y):
        return self.protocol.bw_density_areal(x, y, self.phy)

    def pj_per_bit(self, x, y):
        return self.protocol.power_pj_per_bit(x, y, self.phy)

    def bandwidth_gbs(self, x, y, shoreline_mm: float):
        """Deliverable cache-line GB/s for a shoreline budget."""
        return self.linear_density(x, y) * shoreline_mm

    def power_w(self, x, y, shoreline_mm: float):
        """Interconnect power (W) at full utilization of the shoreline."""
        gbs = self.bandwidth_gbs(x, y, shoreline_mm)
        return gbs * 8.0 * self.pj_per_bit(x, y) / 1000.0   # GB/s * pJ/b -> W


def standard_catalog() -> Dict[str, MemorySystem]:
    """Every (approach x packaging) the paper evaluates + the baselines."""
    cat: Dict[str, MemorySystem] = {}
    lat = latency_mod.MEASURED_FRONTEND_LATENCY_NS
    for key, proto in ALL_APPROACHES.items():
        for phy, tag in ((UCIE_A_32G_55U, "UCIe-A"), (UCIE_S_32G, "UCIe-S")):
            bit_cost = 7.5 if "hbm" in key else 1.0
            cat[f"{key}/{tag}"] = MemorySystem(
                name=f"{proto.name}/{tag}",
                protocol=proto, phy=phy,
                latency_ns=lat["UCIe-Memory"],
                relative_bit_cost=bit_cost,
            )
    for bname, bus in BASELINES.items():
        cat[bname] = MemorySystem(
            name=bus.name, protocol=bus, phy=None,
            latency_ns=lat.get(bname, 6.0),
            relative_bit_cost=7.5 if "HBM" in bname else 1.0,
        )
    return cat


@functools.lru_cache(maxsize=1)
def default_catalog_items() -> Tuple[Tuple[str, MemorySystem], ...]:
    """The standard catalog as a hashable, cached tuple of items — the key
    the batched-grid compile cache is built on."""
    return tuple(standard_catalog().items())


# -- batched grid evaluation --------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CatalogGrid:
    """Stacked per-system metrics over a traffic-mix grid.

    Metric arrays are ``[S, *mix_shape]`` where ``S`` follows ``keys``;
    ``latency_ns`` / ``relative_bit_cost`` are per-system ``[S]`` scalars.
    """

    keys: Tuple[str, ...]
    bandwidth_gbs: jnp.ndarray
    pj_per_bit: jnp.ndarray
    power_w: jnp.ndarray
    gbs_per_watt: jnp.ndarray
    latency_ns: jnp.ndarray
    relative_bit_cost: jnp.ndarray


@dataclasses.dataclass
class GridCacheStats:
    """Catalog-grid compile counters: one miss == one trace+compile of the
    stacked program (new catalog or new grid shape); hits run warm."""

    hits: int = 0
    misses: int = 0


_GRID_STATS = GridCacheStats()


def grid_cache_stats() -> GridCacheStats:
    """Snapshot of the batched catalog-grid compile counters."""
    return dataclasses.replace(_GRID_STATS)


def clear_grid_cache() -> None:
    """Drop the memoized grid programs and reset the hit/miss counters."""
    _catalog_grid_fn.cache_clear()
    _approach_grid_fn.cache_clear()
    _GRID_STATS.hits = 0
    _GRID_STATS.misses = 0


@functools.lru_cache(maxsize=8)
def _catalog_grid_fn(items: Tuple[Tuple[str, MemorySystem], ...]):
    systems = [ms for _, ms in items]

    def fn(x, y, shoreline_mm):
        # body runs only while jax traces — i.e. once per compile
        _GRID_STATS.misses += 1
        bw = jnp.stack([ms.bandwidth_gbs(x, y, shoreline_mm)
                        for ms in systems])
        pjb = jnp.stack([jnp.broadcast_to(ms.pj_per_bit(x, y), bw.shape[1:])
                         for ms in systems])
        pw = bw * 8.0 * pjb / 1000.0        # GB/s * pJ/b -> W
        gpw = jnp.where(pw > 0, bw / pw, jnp.inf)
        return bw, pjb, pw, gpw

    return jax.jit(fn)


def catalog_grid(x, y, shoreline_mm=8.0,
                 catalog: Optional[Dict[str, MemorySystem]] = None,
                 ) -> CatalogGrid:
    """Evaluate every catalog system over a mix grid in one compiled call.

    ``x`` / ``y`` may be scalars or arrays of any (matching) shape, and
    ``shoreline_mm`` a scalar or an array broadcastable against them (e.g.
    ``x``/``y`` of shape ``[R, 1]`` with shorelines ``[L]`` gives metric
    grids ``[S, R, L]``).  The jitted stacked program is memoized per
    catalog, so repeated grids of the same shape reuse the warm executable
    (``grid_cache_stats()`` exposes hit/miss counters).
    """
    items = (default_catalog_items() if catalog is None
             else tuple(catalog.items()))
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    before = _GRID_STATS.misses
    bw, pjb, pw, gpw = _catalog_grid_fn(items)(
        x, y, jnp.asarray(shoreline_mm, jnp.float32))
    if _GRID_STATS.misses == before:
        _GRID_STATS.hits += 1
    return CatalogGrid(
        keys=tuple(k for k, _ in items),
        bandwidth_gbs=bw, pj_per_bit=pjb, power_w=pw, gbs_per_watt=gpw,
        latency_ns=jnp.asarray([ms.latency_ns for _, ms in items],
                               jnp.float32),
        relative_bit_cost=jnp.asarray(
            [ms.relative_bit_cost for _, ms in items], jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class ApproachGrid:
    """Stacked ``[S, *mix_shape]`` density/power metrics for ALL_APPROACHES
    on a given PHY (the Figs 10-12 sweeps)."""

    keys: Tuple[str, ...]
    linear: jnp.ndarray
    areal: jnp.ndarray
    pj_per_bit: jnp.ndarray


@functools.lru_cache(maxsize=8)
def _approach_grid_fn(phy: UCIePhy):
    protos = tuple(ALL_APPROACHES.values())

    def fn(x, y):
        lin = jnp.stack([p.bw_density_linear(x, y, phy) for p in protos])
        areal = jnp.stack([p.bw_density_areal(x, y, phy) for p in protos])
        pjb = jnp.stack([jnp.broadcast_to(p.power_pj_per_bit(x, y, phy),
                                          lin.shape[1:]) for p in protos])
        return lin, areal, pjb

    return jax.jit(fn)


def approach_grid(phy: UCIePhy, x, y) -> ApproachGrid:
    """All approaches' bandwidth-density and pJ/b over a mix grid, stacked
    and computed in one compiled call per (phy, grid-shape)."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    lin, areal, pjb = _approach_grid_fn(phy)(x, y)
    return ApproachGrid(keys=tuple(ALL_APPROACHES), linear=lin, areal=areal,
                        pj_per_bit=pjb)
