"""Tiled / sharded streaming evaluation for 10^6–10^8-cell design spaces.

The materialized engines (:meth:`repro.core.space.DesignSpace.evaluate`)
return whole per-cell metric tensors — fine up to ~10^6 cells, impossible
for the joint [phy x protocol_param x backlog x mix] spaces the ROADMAP
targets.  This module is the other execution mode behind the SAME axes:
``evaluate(..., stream=StreamConfig(...))`` flattens the cell space along
a configurable axis order, cuts it into per-device chunks, and pushes
every chunk through ONE executable (shared shape-keyed compile cache,
families ``stream.*``) that is ``shard_map``-ped across devices via the
:mod:`repro.compat` shim.  Frontier / argbest / feasibility resolve as
RUNNING on-device reductions:

* per-cell winner codes (one small int per cell — the only per-cell
  output that ever exists),
* per-label win counts and best metric values (``lax.psum`` /
  ``lax.pmax`` across the device mesh, accumulated across dispatches
  host-side).

Bit-identity contract: the streamed winner labels are bit-identical to
the materialized ``argbest`` on every grid — the chunk programs vmap the
EXACT scalar cell functions of the fixed-horizon cores
(:func:`repro.core.flitsim._symmetric_cells_grid` /
``_asymmetric_cells_grid``) and the closed-form
:class:`~repro.core.memsys.MemorySystem` methods, f32 arithmetic is
IEEE-deterministic, and ``jnp.argmax`` shares numpy's first-max
tie-break.  Constraint thresholds are compared through
:func:`_le_threshold_f32` / :func:`_ge_threshold_f32` so the f32 on-device
comparison admits exactly the cells the f64 host comparison admits.

Simulated metrics stream under the FIXED engine only (the adaptive cores'
early-exit schedule depends on batch shape, which would break the
bit-identity contract across chunk sizes); control cost via
``DesignSpace(n_flits=..., n_accesses=...)`` instead.

Async double-buffered dispatch: the per-dispatch loop marshals chunk
``t+1``'s cell indices (pure numpy — ``_chunk_ids`` plus the
mix/backlog/perturbation gathers) while up to ``StreamConfig.prefetch``
earlier chunks are still in flight on the device, and blocks only when
the in-flight window is full.  Results retire strictly FIFO, so the
running host-side folds (winner-code scatter, count sums, best maxima)
execute in EXACTLY the order of the sequential loop — ``prefetch=1``
reduces to the sequential schedule, and every depth produces
bit-identical ``StreamResult`` contents.  The FIFO retire is the one
audited host sync of the loop (see the RL004 suppressions); per-run
dispatch/overlap telemetry lands in
``flitsim.last_run_info()["stream.*"]``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro import compat
from repro.core import space as space_mod

__all__ = ["StreamResult", "stream_evaluate"]

#: streamable flit-simulated metrics (reduce dim: ``protocol``)
STREAM_SIM_METRICS: Tuple[str, ...] = ("sim_efficiency",
                                       "sim_bandwidth_gbs")

_MESHES: Dict[int, Any] = {}


def _mesh(devices: int):
    """Memoized 1-d ``("chunks",)`` device mesh of the leading devices."""
    cached = _MESHES.get(devices)
    if cached is not None:
        return cached
    avail = jax.local_device_count()
    if devices > avail:
        raise ValueError(
            f"StreamConfig(devices={devices}) exceeds the {avail} local "
            f"device(s); on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices} before "
            "importing jax to emulate a wider mesh")
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:devices]), ("chunks",))
    _MESHES[devices] = mesh
    return mesh


def _le_threshold_f32(t: float) -> np.float32:
    """Largest f32 ``t32`` with ``v <= t32  <=>  v <= t`` for every f32
    ``v`` — keeps the on-device f32 constraint comparison admitting
    exactly the cells the materialized f64 comparison admits."""
    t32 = np.float32(t)
    if np.float64(t32) > np.float64(t):
        t32 = np.nextafter(t32, np.float32(-np.inf))
    return t32


def _ge_threshold_f32(t: float) -> np.float32:
    """Smallest f32 ``t32`` with ``v >= t32  <=>  v >= t`` (see
    :func:`_le_threshold_f32`)."""
    t32 = np.float32(t)
    if np.float64(t32) < np.float64(t):
        t32 = np.nextafter(t32, np.float32(np.inf))
    return t32


def _cell_order(dims_all: Sequence[str], present: Sequence[bool],
                axis_order) -> Tuple[int, ...]:
    """Permutation of cell-dim positions realizing ``axis_order``.

    ``axis_order`` must be a permutation of the PRESENT cell axes; absent
    (size-1 placeholder) dims are appended at the end — they carry one
    index, so their position never changes the enumeration.
    """
    if axis_order is None:
        return tuple(range(len(dims_all)))
    avail = [d for d, p in zip(dims_all, present) if p]
    if sorted(axis_order) != sorted(avail):
        raise ValueError(
            f"StreamConfig.axis_order must be a permutation of the "
            f"space's cell axes {avail}, got {list(axis_order)}")
    order = [dims_all.index(d) for d in axis_order]
    order += [i for i, p in enumerate(present) if not p]
    return tuple(order)


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Reduced output of one streaming evaluation.

    ``winners`` is the ONLY per-cell artifact: a
    :class:`~repro.core.space.SpaceArray` of winner labels whose dims and
    coords are bit-identical to the materialized
    ``evaluate()[metric].argbest(reduce_dim, mode)`` (cells where the
    constraints admit nothing read ``"(none)"``).  ``win_counts`` /
    ``best_by_label`` are the running on-device reductions (win counts sum
    to ``n_cells``; bests are NaN for labels the constraints never
    admit).  ``peak_cells_per_chunk`` is the asserted memory budget: the
    maximum number of joint cells resident per device per dispatch.
    """

    metric: str
    reduce_dim: str                 # "protocol" | "system"
    mode: str                       # "max" | "min"
    labels: Tuple[str, ...]
    winners: Any                    # SpaceArray of winner labels
    win_counts: Dict[str, int]
    best_by_label: Dict[str, float]
    n_cells: int                    # total joint cells reduced
    n_stream_cells: int             # streamed (chunked) cell-space size
    n_dispatches: int
    chunk_cells: int                # streamed cells per device per dispatch
    peak_cells_per_chunk: int       # peak joint cells per device
    devices: int
    compiles: int                   # stream.* cache misses this evaluation

    def frontier(self) -> Any:
        """The winner-label array (argbest alias, mirroring
        :meth:`repro.core.space.SpaceResult.frontier`)."""
        return self.winners


def _dispatch_plan(n_cells: int, stream, shape_perm):
    """(devices, chunk, step, dispatches) for a flat cell space."""
    devices = (int(stream.devices) if stream.devices is not None
               else jax.local_device_count())
    mesh = _mesh(devices)
    chunk = max(1, min(int(stream.chunk_cells),
                       -(-n_cells // devices)))
    step = devices * chunk
    return mesh, devices, chunk, step, -(-n_cells // step)


def _chunk_ids(lo: int, step: int, n_cells: int):
    """Global cell ids + validity for dispatch window [lo, lo+step);
    the tail pads by repeating the last live cell."""
    live = min(step, n_cells - lo)
    ids = np.arange(lo, lo + step, dtype=np.int64)
    if live < step:
        ids[live:] = ids[live - 1]
    valid = np.zeros(step, np.int32)
    valid[:live] = 1
    return ids, valid, live


def _winner_array(codes: np.ndarray, shape_perm, order, full, labels_ext):
    """Reduced winner codes -> a SpaceArray bit-identical to the
    materialized argbest: reshape in dispatch order, transpose back to
    canonical order, gather labels, drop absent (size-1) dims."""
    trail = codes.shape[1:]         # broadcast dims appended after cells
    grid = codes.reshape(shape_perm + trail)
    inv = tuple(int(i) for i in np.argsort(np.asarray(order)))
    grid = np.transpose(grid, inv + tuple(len(order) + i
                                          for i in range(len(trail))))
    lab = labels_ext[grid.astype(np.int64)]
    if trail:                       # [cells..., F] -> [pert, F, rest...]
        lab = np.moveaxis(lab, -1, 1)
    for axpos in reversed(range(len(full))):
        if not full[axpos][1]:
            lab = np.take(lab, 0, axis=axpos)
    dims = tuple(n for n, p, _ in full if p)
    coords = tuple(c for _, p, c in full if p)
    return space_mod.SpaceArray(dims, coords,
                                np.asarray(lab, dtype=object))


# =========================================================================
# Simulated metrics (stream.sim family)
# =========================================================================


def _stream_sim(space, metric: str, sim, stream) -> StreamResult:
    from repro.core import flitsim
    if sim.mode != "fixed":
        raise ValueError(
            "streaming evaluation runs the fixed-horizon cores only (the "
            "adaptive early-exit schedule depends on batch shape, which "
            "would break chunk-size invariance); got "
            f"SimConfig(mode={sim.mode!r}).  Control cost via "
            "DesignSpace(n_flits=..., n_accesses=...) instead")
    if stream.mode not in (None, "max"):
        raise ValueError("simulated streaming frontiers maximize "
                         f"efficiency; got StreamConfig(mode="
                         f"{stream.mode!r})")
    keys = space._sim_protocols()
    x, y, mix_dims = space._mix_arrays()
    mix_shape = x.shape
    xf = np.asarray(x, np.float32).reshape(-1)
    yf = np.asarray(y, np.float32).reshape(-1)
    if np.any(xf < 0) or np.any(yf < 0) or np.any(xf + yf <= 0):
        raise ValueError("invalid traffic mix in the lowered grid")
    bl_ax = space.axes.get("backlog")
    backlogs = np.asarray(bl_ax.values if bl_ax is not None
                          else [space.default_backlog], np.float32)
    pert_ax = space.axes.get("protocol_param")
    perts = ([dict(p) for _, p in pert_ax.values]
             if pert_ax is not None else [{}])
    sym_keys = [k for k in keys if k in flitsim.SYMMETRIC_PARAMS]
    asym_keys = [k for k in keys if k in flitsim.ASYMMETRIC_PARAMS]
    # perturbation validation — mirror of flitsim.simulate_grid
    active_fields: set = set()
    if sym_keys:
        active_fields |= {f.name for f in dataclasses.fields(
            flitsim.SymmetricFlitParams)}
    if asym_keys:
        active_fields |= {f.name for f in dataclasses.fields(
            flitsim.AsymmetricLaneParams)}
    for p in perts:
        flitsim.check_perturbation(p)
        if p and not set(p) & active_fields:
            raise ValueError(
                f"perturbation {p} applies to no parameter of the "
                f"selected protocols {keys}; applicable fields: "
                f"{sorted(active_fields)}")

    phy_ax = space.axes.get("phy")
    if metric == "sim_bandwidth_gbs":
        if phy_ax is not None:
            phys = list(phy_ax.values)
            has_phy_dim = True
        elif space.phy is not None:
            phys = [space.phy]
            has_phy_dim = False
        else:
            raise ValueError(
                "the 'sim_bandwidth_gbs' metric threads the PHY's raw "
                "link bandwidth into the simulated efficiency — add a "
                "'phy' axis or pass DesignSpace(phy=...)")
        raw = np.asarray([p.raw_bandwidth_gbs for p in phys], np.float32)
        phy_names: Tuple[str, ...] = tuple(p.name for p in phys)
    else:
        phys, has_phy_dim, phy_names = None, False, ("-",)
        raw = np.ones(1, np.float32)
    n_phys = raw.shape[0]

    # -- flat cell space: [protocol_param x backlog x mix...] ------------
    dims_all = ["protocol_param", "backlog"] + list(mix_dims)
    sizes = [len(perts), backlogs.shape[0]]
    present = [pert_ax is not None, bl_ax is not None]
    if mix_dims:
        sizes += list(mix_shape)
        present += [True] * len(mix_dims)
    order = _cell_order(dims_all, present, stream.axis_order)
    shape_perm = tuple(sizes[i] for i in order)
    n_cells = int(np.prod(shape_perm))
    mesh, devices, chunk, step, n_dispatch = _dispatch_plan(
        n_cells, stream, shape_perm)

    # perturbation-major parameter stacks (row = q * P_fam + key index —
    # exactly simulate_grid's layout), gathered host-side per chunk
    p_sym, p_asym = len(sym_keys), len(asym_keys)
    sym_host = jax.tree_util.tree_map(np.asarray, flitsim.
                                      SymmetricFlitParams.stack(
                                          [flitsim.SYMMETRIC_PARAMS[k]
                                           .perturbed(p)
                                           for p in perts
                                           for k in sym_keys]))
    asym_host = jax.tree_util.tree_map(np.asarray, flitsim.
                                       AsymmetricLaneParams.stack(
                                           [flitsim.ASYMMETRIC_PARAMS[k]
                                            .perturbed(p)
                                            for p in perts
                                            for k in asym_keys]))
    col_src = [("sym", sym_keys.index(k)) if k in flitsim.SYMMETRIC_PARAMS
               else ("asym", asym_keys.index(k)) for k in keys]
    n_protocols = len(keys)
    n_flits, n_accesses = int(space.n_flits), int(space.n_accesses)
    spec_c, spec_r = PartitionSpec("chunks"), PartitionSpec()

    def chunk_fn(sym_cells, sxs, sys_, sbs, asym_cells, axs, ays, raw_in,
                 valid):
        def body(sym_cells, sxs, sys_, sbs, asym_cells, axs, ays, raw_in,
                 valid):
            eff_by = {}
            if p_sym:
                eff_by["sym"] = flitsim._symmetric_cells_grid(
                    sym_cells, sxs, sys_, sbs,
                    n_flits=n_flits).reshape(chunk, p_sym)
            if p_asym:
                eff_by["asym"] = flitsim._asymmetric_cells_grid(
                    asym_cells, axs, ays,
                    n_accesses=n_accesses).reshape(chunk, p_asym)
            eff = jnp.stack([eff_by[fam][:, i] for fam, i in col_src],
                            axis=1)                         # [C, P]
            m = eff[:, None, :] * raw_in[None, :, None]     # [C, F, P]
            codes = jnp.argmax(m, axis=2).astype(jnp.int32)
            ok = (valid > 0)[:, None, None]
            onehot = codes[..., None] == jnp.arange(n_protocols,
                                                    dtype=jnp.int32)
            counts = jnp.sum((onehot & ok).astype(jnp.int32),
                             axis=0)                        # [F, P]
            best = jnp.max(jnp.where(ok, m, -jnp.inf),
                           axis=(0, 1))                     # [P]
            counts = jax.lax.psum(counts, "chunks")
            best = jax.lax.pmax(best, "chunks")
            return codes, counts, best

        sharded = compat.shard_map(
            body, mesh=mesh,
            in_specs=(spec_c, spec_c, spec_c, spec_c,
                      spec_c, spec_c, spec_c, spec_r, spec_c),
            out_specs=(spec_c, spec_r, spec_r))
        return sharded(sym_cells, sxs, sys_, sbs, asym_cells, axs, ays,
                       raw_in, valid)

    key = ("sim", keys, chunk, devices, n_phys, n_flits, n_accesses,
           sim.key())
    misses0 = _stream_misses()
    codes_out = np.empty((n_cells, n_phys), np.int16)
    counts_total = np.zeros((n_phys, n_protocols), np.int64)
    best_total = np.full((n_protocols,), -np.inf, np.float64)
    a_sym = np.arange(p_sym, dtype=np.int64)
    a_asym = np.arange(p_asym, dtype=np.int64)
    prog = None
    prefetch = int(stream.prefetch)
    t0 = time.perf_counter()
    marshal_s = overlap_s = 0.0
    inflight: Any = collections.deque()    # FIFO of (lo, live, results)

    def retire():
        # the ONE audited host sync of the dispatch loop: the OLDEST
        # in-flight chunk blocks here, so folds run in sequential order
        lo, live, (codes, counts, best) = inflight.popleft()
        # repro-lint: disable=RL004  (audited FIFO retire sync)
        codes_np, counts_np, best_np = (np.asarray(codes),
                                        np.asarray(counts),
                                        np.asarray(best))
        codes_out[lo:lo + live] = codes_np[:live]
        counts_total[...] += counts_np.astype(np.int64)
        np.maximum(best_total, best_np.astype(np.float64),
                   out=best_total)

    for t in range(n_dispatch):
        m0 = time.perf_counter()
        lo = t * step
        ids, valid, live = _chunk_ids(lo, step, n_cells)
        multi = np.unravel_index(ids, shape_perm)
        by_dim = {dims_all[order[j]]: multi[j]
                  for j in range(len(order))}
        q_idx = by_dim["protocol_param"]
        b_idx = by_dim["backlog"]
        if mix_dims:
            m_idx = np.ravel_multi_index(
                tuple(by_dim[d] for d in mix_dims), mix_shape)
        else:
            m_idx = np.zeros(step, np.int64)
        rows_sym = (q_idx[:, None] * p_sym + a_sym).reshape(-1)
        rows_asym = (q_idx[:, None] * p_asym + a_asym).reshape(-1)
        args = (
            jax.tree_util.tree_map(lambda l: l[rows_sym], sym_host),
            np.repeat(xf[m_idx], p_sym), np.repeat(yf[m_idx], p_sym),
            np.repeat(backlogs[b_idx], p_sym),
            jax.tree_util.tree_map(lambda l: l[rows_asym], asym_host),
            np.repeat(xf[m_idx], p_asym), np.repeat(yf[m_idx], p_asym),
            raw, valid)
        dm = time.perf_counter() - m0
        marshal_s += dm
        if inflight:                # marshalled while a chunk was in flight
            overlap_s += dm
        if prog is None:
            prog = space_mod.cached_program("stream.sim", key, chunk_fn,
                                            args)
        inflight.append((lo, live, prog(*args)))
        while len(inflight) >= prefetch:
            retire()
    while inflight:
        retire()
    flitsim._record_stream(
        "stream.sim", dispatches=n_dispatch, prefetch=prefetch,
        pad_cells=n_dispatch * step - n_cells,
        overlap_frac=overlap_s / marshal_s if marshal_s else 0.0,
        cells=n_cells, elapsed_s=time.perf_counter() - t0,
        marshal_s=marshal_s)

    pert_labels = (tuple(pert_ax.labels) if pert_ax is not None
                   else ("baseline",))
    bl_labels = (tuple(bl_ax.labels) if bl_ax is not None
                 else (space.default_backlog,))
    full = [("protocol_param", pert_ax is not None, pert_labels),
            ("phy", has_phy_dim, phy_names),
            ("backlog", bl_ax is not None, bl_labels)]
    full += [(d, True, tuple(space.axes[d].labels)) for d in mix_dims]
    winners = _winner_array(codes_out, shape_perm, order, full,
                            np.asarray(keys, dtype=object))
    per_label = counts_total.sum(axis=0)
    return StreamResult(
        metric=metric, reduce_dim="protocol", mode="max", labels=keys,
        winners=winners,
        win_counts={k: int(per_label[i]) for i, k in enumerate(keys)},
        best_by_label={k: float(best_total[i])
                       for i, k in enumerate(keys)},
        n_cells=n_cells * n_phys, n_stream_cells=n_cells,
        n_dispatches=n_dispatch, chunk_cells=chunk,
        peak_cells_per_chunk=chunk * n_phys, devices=devices,
        compiles=_stream_misses() - misses0)


# =========================================================================
# Analytic catalog metrics (stream.catalog family)
# =========================================================================


def _knee_admissibility(space, items, cons, sim):
    """``[S, K]`` backlog-knee admissibility + the cell dim ``K`` indexes
    (``None`` = broadcast) — mirror of ``SpaceResult._knee_mask``."""
    from repro.core import flitsim
    from repro.core import selector as selector_mod
    keys = [k for k, _ in items]
    simkeys = [selector_mod.sim_key_for(k) for k in keys]
    budget = cons.max_backlog_knee
    if budget is None:
        return np.ones((len(keys), 1), bool), None
    cfg = space.axes.get("workload_config")
    mix_ax = space.axes.mix_axis()
    if cfg is not None:
        mixes = [(w.x, w.y) for _, w in cfg.values]
        dim = "workload_config"
    elif mix_ax is not None and space_mod.OWN_MIX not in mix_ax.values:
        if mix_ax.name == "read_fraction":
            mixes = [(100.0 * r, 100.0 - 100.0 * r)
                     for r in mix_ax.values]
        else:
            mixes = list(mix_ax.values)
        dim = mix_ax.name
    else:
        knees = selector_mod._default_knees()
        sub = np.asarray([sk is None or knees[sk] <= budget
                          for sk in simkeys], bool)
        return sub[:, None], None
    per = flitsim.backlog_knees(mixes=mixes, per_mix=True, sim=sim)
    sub = np.ones((len(keys), len(mixes)), bool)
    for i, sk in enumerate(simkeys):
        if sk is not None:
            sub[i] = per[sk] <= budget
    return sub, dim


def _stream_catalog(space, metric: str, sim, stream) -> StreamResult:
    from repro.core import memsys
    from repro.core import selector as selector_mod
    if (space.axes.get("catalog_param") is not None
            or space.axes.get("phy") is not None
            or space.phy is not None):
        raise ValueError(
            "streaming analytic evaluation covers the (workload_config, "
            "mix/read_fraction, shoreline_mm) cell axes over the default "
            "or custom catalog; catalog_param / phy axes run through the "
            "materialized evaluate() path")
    items = (memsys.default_catalog_items() if space.catalog is None
             else tuple(space.catalog.items()))
    keys = tuple(k for k, _ in items)
    systems = tuple(ms for _, ms in items)
    n_systems = len(items)
    mode = stream.mode if stream.mode is not None else (
        "min" if metric in ("pj_per_bit", "power_w") else "max")
    x, y, mix_dims = space._mix_arrays()
    mix_shape = x.shape
    xf = np.asarray(x, np.float32).reshape(-1)
    yf = np.asarray(y, np.float32).reshape(-1)
    sl_ax = space.axes.get("shoreline_mm")
    sls = np.asarray(sl_ax.values if sl_ax is not None
                     else [space.default_shoreline_mm], np.float32)

    dims_all = list(mix_dims) + ["shoreline_mm"]
    sizes = (list(mix_shape) if mix_dims else []) + [sls.shape[0]]
    present = [True] * len(mix_dims) + [sl_ax is not None]
    if not mix_dims:
        dims_all, sizes, present = (["shoreline_mm"], [sls.shape[0]],
                                    [sl_ax is not None])
    order = _cell_order(dims_all, present, stream.axis_order)
    shape_perm = tuple(sizes[i] for i in order)
    n_cells = int(np.prod(shape_perm))
    mesh, devices, chunk, step, n_dispatch = _dispatch_plan(
        n_cells, stream, shape_perm)

    cons = stream.constraints
    if cons is None:
        static = np.ones(n_systems, bool)
        knee_adm, knee_dim = np.ones((n_systems, 1), bool), None
        thr = np.asarray([np.inf, -np.inf], np.float32)
    else:
        static = np.asarray(selector_mod.system_mask(
            items, dataclasses.replace(cons, max_backlog_knee=None)),
            bool)
        knee_adm, knee_dim = _knee_admissibility(space, items, cons, sim)
        thr = np.asarray(
            [_le_threshold_f32(cons.max_power_w)
             if cons.max_power_w is not None else np.float32(np.inf),
             _ge_threshold_f32(cons.required_bandwidth_gbs)
             if cons.required_bandwidth_gbs is not None
             else np.float32(-np.inf)], np.float32)

    spec_c, spec_r = PartitionSpec("chunks"), PartitionSpec()
    is_max = mode == "max"
    fill = np.float32(-np.inf if is_max else np.inf)

    def chunk_fn(xs, ys, sls_c, adm, thr_in, valid):
        def body(xs, ys, sls_c, adm, thr_in, valid):
            bw = jnp.stack([ms.bandwidth_gbs(xs, ys, sls_c)
                            for ms in systems])             # [S, C]
            pjb = jnp.stack([jnp.broadcast_to(ms.pj_per_bit(xs, ys),
                                              bw.shape[1:])
                             for ms in systems])
            pw = bw * 8.0 * pjb / 1000.0        # GB/s * pJ/b -> W
            gpw = jnp.where(pw > 0, bw / pw, jnp.inf)
            vals = {"bandwidth_gbs": bw, "pj_per_bit": pjb,
                    "power_w": pw, "gbs_per_watt": gpw}[metric]
            ok = (adm.T > 0) & (pw <= thr_in[0]) & (bw >= thr_in[1])
            masked = jnp.where(ok, vals, fill)
            codes = (jnp.argmax if is_max else jnp.argmin)(
                masked, axis=0).astype(jnp.int32)           # [C]
            any_ok = jnp.any(ok, axis=0)
            codes = jnp.where(any_ok, codes, -1)
            vcell = valid > 0
            onehot = codes[:, None] == jnp.arange(n_systems,
                                                  dtype=jnp.int32)
            counts = jnp.sum((onehot & vcell[:, None]).astype(jnp.int32),
                             axis=0)                        # [S]
            none_ct = jnp.sum((vcell & ~any_ok).astype(jnp.int32))
            best = (jnp.max if is_max else jnp.min)(
                jnp.where(ok & vcell[None, :], vals, fill), axis=1)
            counts = jax.lax.psum(counts, "chunks")
            none_ct = jax.lax.psum(none_ct, "chunks")
            best = (jax.lax.pmax if is_max else jax.lax.pmin)(
                best, "chunks")
            return codes, counts, best, none_ct

        sharded = compat.shard_map(
            body, mesh=mesh,
            in_specs=(spec_c, spec_c, spec_c, spec_c, spec_r, spec_c),
            out_specs=(spec_c, spec_r, spec_r, spec_r))
        return sharded(xs, ys, sls_c, adm, thr_in, valid)

    key = ("catalog", items, chunk, devices, metric, mode,
           stream.key()[-1])           # constraint STRUCTURE is static
    misses0 = _stream_misses()
    codes_out = np.empty(n_cells, np.int16)
    counts_total = np.zeros(n_systems, np.int64)
    none_total = np.zeros((), np.int64)
    best_total = np.full(n_systems, -np.inf if is_max else np.inf,
                         np.float64)
    prog = None
    prefetch = int(stream.prefetch)
    t0 = time.perf_counter()
    marshal_s = overlap_s = 0.0
    inflight: Any = collections.deque()    # FIFO of (lo, live, results)
    acc = np.maximum if is_max else np.minimum

    def retire():
        # the ONE audited host sync of the dispatch loop: the OLDEST
        # in-flight chunk blocks here, so folds run in sequential order
        lo, live, (codes, counts, best, none_ct) = inflight.popleft()
        # repro-lint: disable=RL004  (audited FIFO retire sync)
        codes_np, counts_np, best_np, none_np = (
            np.asarray(codes), np.asarray(counts), np.asarray(best),
            np.asarray(none_ct))
        codes_out[lo:lo + live] = codes_np[:live]
        counts_total[...] += counts_np.astype(np.int64)
        none_total[...] += np.int64(none_np)
        acc(best_total, best_np.astype(np.float64), out=best_total)

    for t in range(n_dispatch):
        m0 = time.perf_counter()
        lo = t * step
        ids, valid, live = _chunk_ids(lo, step, n_cells)
        multi = np.unravel_index(ids, shape_perm)
        by_dim = {dims_all[order[j]]: multi[j]
                  for j in range(len(order))}
        l_idx = by_dim["shoreline_mm"]
        if mix_dims:
            m_idx = np.ravel_multi_index(
                tuple(by_dim[d] for d in mix_dims), mix_shape)
        else:
            m_idx = np.zeros(step, np.int64)
        k_idx = by_dim[knee_dim] if knee_dim is not None else \
            np.zeros(step, np.int64)
        adm = (static[None, :]
               & knee_adm[:, k_idx].T).astype(np.int32)     # [step, S]
        args = (xf[m_idx], yf[m_idx], sls[l_idx], adm, thr, valid)
        dm = time.perf_counter() - m0
        marshal_s += dm
        if inflight:                # marshalled while a chunk was in flight
            overlap_s += dm
        if prog is None:
            prog = space_mod.cached_program("stream.catalog", key,
                                            chunk_fn, args)
        inflight.append((lo, live, prog(*args)))
        while len(inflight) >= prefetch:
            retire()
    while inflight:
        retire()
    from repro.core import flitsim
    flitsim._record_stream(
        "stream.catalog", dispatches=n_dispatch, prefetch=prefetch,
        pad_cells=n_dispatch * step - n_cells,
        overlap_frac=overlap_s / marshal_s if marshal_s else 0.0,
        cells=n_cells, elapsed_s=time.perf_counter() - t0,
        marshal_s=marshal_s)

    full = [(d, True, tuple(space.axes[d].labels)) for d in mix_dims]
    sl_labels = (tuple(sl_ax.labels) if sl_ax is not None
                 else (space.default_shoreline_mm,))
    full += [("shoreline_mm", sl_ax is not None, sl_labels)]
    winners = _winner_array(codes_out, shape_perm, order, full,
                            np.asarray(keys + ("(none)",), dtype=object))
    win_counts = {k: int(counts_total[i]) for i, k in enumerate(keys)}
    if cons is not None:
        win_counts["(none)"] = int(none_total)
    fill64 = np.float64(fill)
    return StreamResult(
        metric=metric, reduce_dim="system", mode=mode, labels=keys,
        winners=winners, win_counts=win_counts,
        best_by_label={k: (float(best_total[i])
                           if best_total[i] != fill64 else float("nan"))
                       for i, k in enumerate(keys)},
        n_cells=n_cells, n_stream_cells=n_cells,
        n_dispatches=n_dispatch, chunk_cells=chunk,
        peak_cells_per_chunk=chunk, devices=devices,
        compiles=_stream_misses() - misses0)


def _stream_misses() -> int:
    return space_mod.cache_stats(space_mod.STREAM_FAMILIES).misses


def stream_evaluate(space, metrics, sim, stream) -> StreamResult:
    """Dispatch one streamed metric reduction (the ``stream=`` path of
    :meth:`repro.core.space.DesignSpace.evaluate`)."""
    if metrics is None:
        raise ValueError(
            "streaming evaluation reduces exactly ONE metric per call; "
            "pass metrics=('sim_efficiency',) (or another single metric) "
            "explicitly")
    if isinstance(metrics, str):
        metric = metrics
    else:
        wanted = tuple(metrics)
        if len(wanted) != 1:
            raise ValueError(
                "streaming evaluation reduces exactly ONE metric per "
                f"call, got {wanted}; run one stream per metric "
                "(executables are cached per chunk shape, so repeats "
                "reuse the warm program)")
        metric = wanted[0]
    sim = sim if sim is not None else space_mod.FIXED_SIM
    for name in ("trace", "k", "ucie_line_ui", "device_line_ui"):
        if space.axes.get(name) is not None:
            raise ValueError(
                f"streaming evaluation does not cover the {name!r} axis "
                "yet; use the materialized evaluate() path")
    if metric in STREAM_SIM_METRICS:
        if stream.constraints is not None:
            raise ValueError(
                "StreamConfig.constraints stream through the analytic "
                "metrics only; the simulated frontier mirrors the "
                "materialized unconstrained argbest")
        return _stream_sim(space, metric, sim, stream)
    if metric in space_mod.ANALYTIC_METRICS:
        return _stream_catalog(space, metric, sim, stream)
    raise ValueError(
        f"metric {metric!r} is not streamable; choose from "
        f"{STREAM_SIM_METRICS + space_mod.ANALYTIC_METRICS}")
