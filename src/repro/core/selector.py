"""Best-approach selection — the paper's conclusion, automated.

Given a traffic mix (typically derived from a compiled workload's HLO byte
counts), rank the catalog of memory systems on bandwidth density / power /
latency / cost, under optional constraints (shoreline budget, packaging,
power cap).  §IV.C's conclusion — "CXL.Mem with optimization on symmetric
UCIe offers the best power-efficient performance" — falls out of this
ranking, and the tests assert it does.

Ranking consumes the batched catalog grid (:func:`repro.core.memsys.
_catalog_grid_impl` — the shared design-space engine in
:mod:`repro.core.space`): every system's metrics come from one stacked,
compiled call, and ``_rank_grid_impl`` extends the same program to dense mix
grids — the best system for hundreds of (x, y) points resolves in a single
compiled evaluation instead of a per-point Python loop.  The masking /
argbest core is :func:`grid_ranking`; its static per-system admissibility
(:func:`system_mask`) is the same core the axes-first
:meth:`repro.core.space.SpaceResult.feasible` mask builds on, so
constraint masking composes with arbitrary axes (``frontier(...,
where=mask)``), not just this module's grid layout —
``bridge_design_space`` consumes the feasible/``where=`` path directly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import space as space_mod
from repro.core.memsys import (
    CatalogGrid, MemorySystem, _catalog_grid_impl, default_catalog_items,
)
from repro.core.traffic import TrafficMix


@dataclasses.dataclass(frozen=True)
class SelectionConstraints:
    shoreline_mm: float = 8.0              # available die edge for memory I/O
    packaging: Optional[str] = None        # "UCIe-A" | "UCIe-S" | None (any)
    max_power_w: Optional[float] = None
    max_relative_bit_cost: Optional[float] = None
    required_bandwidth_gbs: Optional[float] = None
    #: queue-depth budget: exclude flit-simulated protocols whose
    #: efficiency knee (:func:`repro.core.flitsim.backlog_knees`) needs a
    #: deeper request backlog than this.  Bus baselines have no flit
    #: simulator and are unaffected.
    max_backlog_knee: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RankedSystem:
    key: str
    name: str
    bandwidth_gbs: float
    pj_per_bit: float
    power_w: float
    latency_ns: float
    relative_bit_cost: float
    #: bandwidth per watt — the paper's central figure of merit
    gbs_per_watt: float


_OBJECTIVES = ("bandwidth", "power", "gbs_per_watt", "latency")


def _catalog_items(catalog: Optional[Dict[str, MemorySystem]]):
    return default_catalog_items() if catalog is None \
        else tuple(catalog.items())


#: catalog approach prefix -> flit-simulator family key (for the knee
#: constraint and the analytic-vs-simulated frontier).  A2 (native LPDDR6
#: mapping) shares approach A's asymmetric lane-group simulator; bus
#: baselines have no simulator entry.
CATALOG_SIM_KEYS = {
    "A:lpddr6-asym": "lpddr6_asym",
    "A2:lpddr6-native": "lpddr6_asym",
    "B:hbm-asym": "hbm_asym",
    "C:chi-sym": "chi",
    "D:cxl-mem": "cxl_unopt",
    "E:cxl-mem-opt": "cxl_opt",
}


def sim_key_for(catalog_key: str) -> Optional[str]:
    """Flit-simulator key backing a catalog system key, or ``None`` for
    bus baselines (which have no cycle-level simulator)."""
    return CATALOG_SIM_KEYS.get(catalog_key.split("/")[0])


#: flit-simulator key -> canonical catalog approach prefix (the inverse of
#: :data:`CATALOG_SIM_KEYS`; ``lpddr6_asym`` resolves to approach A, its
#: primary mapping — A2 shares the same lane-group simulator)
SIM_APPROACH_KEYS = {
    "lpddr6_asym": "A:lpddr6-asym",
    "hbm_asym": "B:hbm-asym",
    "chi": "C:chi-sym",
    "cxl_unopt": "D:cxl-mem",
    "cxl_opt": "E:cxl-mem-opt",
}


def approach_key_for(sim_key: str) -> str:
    """Catalog approach prefix for a flit-simulator protocol key — how the
    sim-phy frontier labels simulated winners in catalog vocabulary."""
    try:
        return SIM_APPROACH_KEYS[sim_key]
    except KeyError:
        raise KeyError(f"no catalog approach backs simulator key "
                       f"{sim_key!r}; choose from "
                       f"{sorted(SIM_APPROACH_KEYS)}") from None


@functools.lru_cache(maxsize=1)
def _default_knees() -> Dict[str, float]:
    """Memoized default-grid backlog knees — deterministic constants, so
    ranking many mixes under a knee budget runs the sweep once."""
    from repro.core import flitsim
    return flitsim.backlog_knees()


def system_mask(items, constraints: SelectionConstraints) -> np.ndarray:
    """Per-system admissibility that doesn't depend on the mix point:
    packaging, relative bit cost, and the backlog-knee budget (canonical
    envelope).

    A packaging constraint names a UCIe package variant, so it admits only
    systems actually attached over that package: bus baselines (``ms.phy is
    None``) are excluded, not waved through.

    This is the shared static core behind :func:`rank`,
    :func:`grid_ranking` AND the axes-first
    :meth:`repro.core.space.SpaceResult.feasible` mask (which refines the
    knee budget per workload/mix before composing with arbitrary axes).
    """
    mask = np.ones(len(items), dtype=bool)
    knees = None
    if constraints.max_backlog_knee is not None:
        knees = _default_knees()
    for i, (key, ms) in enumerate(items):
        if constraints.packaging:
            if ms.phy is None or constraints.packaging not in key:
                mask[i] = False
        if (constraints.max_relative_bit_cost is not None
                and ms.relative_bit_cost > constraints.max_relative_bit_cost):
            mask[i] = False
        if knees is not None:
            sim = sim_key_for(key)
            if sim is not None and knees[sim] > constraints.max_backlog_knee:
                mask[i] = False
    return mask


def _score(grid: CatalogGrid, objective: str) -> jnp.ndarray:
    """Lower-is-better score array, broadcast to the metric grid shape."""
    if objective not in _OBJECTIVES:
        raise KeyError(objective)
    if objective == "bandwidth":
        return -grid.bandwidth_gbs
    if objective == "power":
        return grid.pj_per_bit
    if objective == "gbs_per_watt":
        return -grid.gbs_per_watt
    lat = grid.latency_ns.reshape(
        (len(grid.keys),) + (1,) * (grid.bandwidth_gbs.ndim - 1))
    return jnp.broadcast_to(lat, grid.bandwidth_gbs.shape)


def rank(mix: TrafficMix,
         constraints: SelectionConstraints = SelectionConstraints(),
         catalog: Optional[Dict[str, MemorySystem]] = None,
         objective: str = "bandwidth") -> List[RankedSystem]:
    """Rank all memory systems for a traffic mix.

    objective: "bandwidth" | "power" (pJ/b) | "gbs_per_watt" | "latency".
    """
    items = _catalog_items(catalog)
    grid = _catalog_grid_impl(mix.x, mix.y, constraints.shoreline_mm,
                              dict(items))
    if objective not in _OBJECTIVES:
        raise KeyError(objective)
    bw = np.asarray(grid.bandwidth_gbs, dtype=np.float64)
    pjb = np.asarray(grid.pj_per_bit, dtype=np.float64)
    pw = np.asarray(grid.power_w, dtype=np.float64)
    static_ok = system_mask(items, constraints)
    out: List[RankedSystem] = []
    for i, (key, ms) in enumerate(items):
        if not static_ok[i]:
            continue
        if (constraints.max_power_w is not None
                and pw[i] > constraints.max_power_w):
            continue
        if (constraints.required_bandwidth_gbs is not None
                and bw[i] < constraints.required_bandwidth_gbs):
            continue
        out.append(RankedSystem(
            key=key, name=ms.name, bandwidth_gbs=float(bw[i]),
            pj_per_bit=float(pjb[i]), power_w=float(pw[i]),
            latency_ns=ms.latency_ns,
            relative_bit_cost=ms.relative_bit_cost,
            gbs_per_watt=float(bw[i] / pw[i]) if pw[i] > 0 else float("inf"),
        ))
    keyfn = {
        "bandwidth": lambda r: -r.bandwidth_gbs,
        "power": lambda r: r.pj_per_bit,
        "gbs_per_watt": lambda r: -r.gbs_per_watt,
        "latency": lambda r: r.latency_ns,
    }[objective]
    return sorted(out, key=keyfn)


def best(mix: TrafficMix, **kw) -> RankedSystem:
    ranked = rank(mix, **kw)
    if not ranked:
        raise ValueError("no memory system satisfies the constraints")
    return ranked[0]


@dataclasses.dataclass(frozen=True)
class GridRanking:
    """Per-point best system over a dense mix grid.

    ``best_index`` indexes ``keys`` per grid point; ``valid`` marks which
    systems satisfied the constraints at each point; ``grid`` carries the
    full stacked metrics for downstream plotting/analysis.
    """

    keys: Tuple[str, ...]
    best_index: jnp.ndarray            # [*mix_shape] int32; -1 where no
                                       # system satisfies the constraints
    score: jnp.ndarray                 # [S, *mix_shape] lower-is-better
    valid: jnp.ndarray                 # [S, *mix_shape] bool
    grid: CatalogGrid

    def best_keys(self) -> np.ndarray:
        """Best-system key per grid point (numpy object array); points with
        no admissible system read ``"(none)"``."""
        idx = np.asarray(self.best_index)
        flat = np.atleast_1d(idx)
        out = np.asarray(self.keys, dtype=object)[np.maximum(flat, 0)]
        out[flat < 0] = "(none)"
        return out.reshape(idx.shape)


def grid_ranking(items, grid: CatalogGrid,
                 constraints: SelectionConstraints = SelectionConstraints(),
                 objective: str = "bandwidth",
                 valid_mask=None) -> GridRanking:
    """Mask + argbest core over an already-evaluated :class:`CatalogGrid`.

    ``valid_mask`` (optional, broadcastable against ``[S, *mix_shape]``)
    adds point-dependent admissibility on top of the constraint masks.
    New code should prefer the axes-first path —
    ``SpaceResult.feasible(constraints)`` composed through ``frontier(...,
    where=mask)`` — which derives the same masks (including per-workload
    backlog-knee budgets) from named axes instead of positional grids;
    the design-space bridge now consumes that path.
    """
    score = _score(grid, objective)
    valid = jnp.asarray(system_mask(items, constraints)).reshape(
        (len(items),) + (1,) * (score.ndim - 1))
    valid = jnp.broadcast_to(valid, score.shape)
    if valid_mask is not None:
        valid = valid & jnp.broadcast_to(jnp.asarray(valid_mask, bool),
                                         score.shape)
    if constraints.max_power_w is not None:
        valid = valid & (grid.power_w <= constraints.max_power_w)
    if constraints.required_bandwidth_gbs is not None:
        valid = valid & (grid.bandwidth_gbs
                         >= constraints.required_bandwidth_gbs)
    masked = jnp.where(valid, score, jnp.inf)
    # argmin over an all-inf column would silently report system 0; mark
    # points with no admissible system as -1 (best() raises in that case).
    best_index = jnp.where(jnp.any(valid, axis=0),
                           jnp.argmin(masked, axis=0), -1)
    return GridRanking(keys=grid.keys, best_index=best_index,
                       score=masked, valid=valid, grid=grid)


def _rank_grid_impl(x, y,
                    constraints: SelectionConstraints = SelectionConstraints(),
                    catalog: Optional[Dict[str, MemorySystem]] = None,
                    objective: str = "bandwidth",
                    shoreline_mm=None,
                    valid_mask=None) -> GridRanking:
    """Rank the whole catalog over a dense mix grid in one compiled call:
    one :func:`repro.core.memsys._catalog_grid_impl` evaluation (shared
    design-space engine) followed by :func:`grid_ranking`.  The
    composition engine behind the axes-first path — prefer
    ``res = DesignSpace([axis("read_fraction", ...)]).evaluate()`` then
    ``res.frontier("bandwidth_gbs", where=res.feasible(constraints))``.

    ``x`` / ``y`` are arrays of matching shape (e.g. from ``mix_grid``);
    returns the per-point argbest plus the full masked score grid.

    ``shoreline_mm`` (default: ``constraints.shoreline_mm``) may itself be
    an array broadcastable against ``x`` — pass ``x``/``y`` of shape
    ``[R, 1]`` and shorelines of shape ``[L]`` for a 2-D (read-fraction x
    shoreline) trade-off map whose metrics come out ``[S, R, L]``, still
    from a single compiled evaluation.  ``valid_mask`` adds point-dependent
    admissibility (see :func:`grid_ranking`).
    """
    items = _catalog_items(catalog)
    if shoreline_mm is None:
        shoreline_mm = constraints.shoreline_mm
    grid = _catalog_grid_impl(x, y, shoreline_mm, dict(items))
    return grid_ranking(items, grid, constraints, objective,
                        valid_mask=valid_mask)
