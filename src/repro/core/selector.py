"""Best-approach selection — the paper's conclusion, automated.

Given a traffic mix (typically derived from a compiled workload's HLO byte
counts), rank the catalog of memory systems on bandwidth density / power /
latency / cost, under optional constraints (shoreline budget, packaging,
power cap).  §IV.C's conclusion — "CXL.Mem with optimization on symmetric
UCIe offers the best power-efficient performance" — falls out of this
ranking, and the tests assert it does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core.memsys import MemorySystem, standard_catalog
from repro.core.traffic import TrafficMix


@dataclasses.dataclass(frozen=True)
class SelectionConstraints:
    shoreline_mm: float = 8.0              # available die edge for memory I/O
    packaging: Optional[str] = None        # "UCIe-A" | "UCIe-S" | None (any)
    max_power_w: Optional[float] = None
    max_relative_bit_cost: Optional[float] = None
    required_bandwidth_gbs: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RankedSystem:
    key: str
    name: str
    bandwidth_gbs: float
    pj_per_bit: float
    power_w: float
    latency_ns: float
    relative_bit_cost: float
    #: bandwidth per watt — the paper's central figure of merit
    gbs_per_watt: float


def rank(mix: TrafficMix,
         constraints: SelectionConstraints = SelectionConstraints(),
         catalog: Optional[Dict[str, MemorySystem]] = None,
         objective: str = "bandwidth") -> List[RankedSystem]:
    """Rank all memory systems for a traffic mix.

    objective: "bandwidth" | "power" (pJ/b) | "gbs_per_watt" | "latency".
    """
    catalog = catalog if catalog is not None else standard_catalog()
    out: List[RankedSystem] = []
    for key, ms in catalog.items():
        if constraints.packaging and ms.phy is not None:
            if constraints.packaging not in key:
                continue
        bw = float(ms.bandwidth_gbs(mix.x, mix.y, constraints.shoreline_mm))
        pjb = float(ms.pj_per_bit(mix.x, mix.y))
        pw = bw * 8.0 * pjb / 1000.0
        if constraints.max_power_w is not None and pw > constraints.max_power_w:
            continue
        if (constraints.max_relative_bit_cost is not None
                and ms.relative_bit_cost > constraints.max_relative_bit_cost):
            continue
        if (constraints.required_bandwidth_gbs is not None
                and bw < constraints.required_bandwidth_gbs):
            continue
        out.append(RankedSystem(
            key=key, name=ms.name, bandwidth_gbs=bw, pj_per_bit=pjb,
            power_w=pw, latency_ns=ms.latency_ns,
            relative_bit_cost=ms.relative_bit_cost,
            gbs_per_watt=bw / pw if pw > 0 else float("inf"),
        ))
    keyfn = {
        "bandwidth": lambda r: -r.bandwidth_gbs,
        "power": lambda r: r.pj_per_bit,
        "gbs_per_watt": lambda r: -r.gbs_per_watt,
        "latency": lambda r: r.latency_ns,
    }[objective]
    return sorted(out, key=keyfn)


def best(mix: TrafficMix, **kw) -> RankedSystem:
    ranked = rank(mix, **kw)
    if not ranked:
        raise ValueError("no memory system satisfies the constraints")
    return ranked[0]
