"""Micro-architecture latency model — Fig 9 of the paper (§IV.A).

UCIe-Memory round-trip pipeline at a 2 GHz logic clock (32 GT/s link,
internal clock = forwarded clock / 16):

    analog PHY TX .......... 0.5 ns        } 1 ns round-trip
    analog PHY RX .......... 0.5 ns        }
    logical PHY (FDI<->bump, (de)scramble single ex-or level, CRC 5 gate
    levels, mux/demux, drift FIFO) ... 2 ns round-trip *including* analog
    flit pack .............. 0.5 ns (1 cycle @ 2 GHz, half counted each way)
    flit unpack ............ 0.5 ns

    => 3 ns round-trip from the memory protocol layer.

Measured silicon equivalents for the incumbent front-ends: LPDDR5 7.5 ns,
HBM3 6 ns (LPDDR6 / HBM4 expected similar).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class PipelineStage:
    name: str
    cycles: float            # logic-clock cycles, round-trip contribution


@dataclasses.dataclass(frozen=True)
class UCIeMemoryLatency:
    """Round-trip interconnect latency of UCIe-Memory (protocol layer)."""

    logic_clock_ghz: float = 2.0
    # Fig 9 decomposition (round-trip cycles at the logic clock).
    stages: Tuple[PipelineStage, ...] = (
        PipelineStage("analog-phy-tx+rx", 2.0),       # 0.5 ns x2
        PipelineStage("logical-phy(fdi<->bump)", 2.0),  # remainder of the 2ns RT
        PipelineStage("flit-pack+unpack", 2.0),       # 1 cycle each way
    )

    @property
    def roundtrip_ns(self) -> float:
        return sum(s.cycles for s in self.stages) / self.logic_clock_ghz

    def breakdown_ns(self) -> Dict[str, float]:
        return {s.name: s.cycles / self.logic_clock_ghz for s in self.stages}

    def at_data_rate(self, gtps: float) -> "UCIeMemoryLatency":
        """Other data rates keep the 1/16 internal-clock ratio (§IV.A)."""
        return dataclasses.replace(self, logic_clock_ghz=gtps / 16.0)


#: Measured silicon equivalents (paper §IV.A).
MEASURED_FRONTEND_LATENCY_NS = {
    "UCIe-Memory": UCIeMemoryLatency().roundtrip_ns,   # 3.0
    "LPDDR5": 7.5,
    "LPDDR6": 7.5,   # "similar results expected in LPDDR6"
    "HBM3": 6.0,
    "HBM4": 6.0,     # "... and HBM4 respectively"
}


def latency_speedup() -> Dict[str, float]:
    """Paper headline: 'lower latency (up to 3x)' vs incumbents."""
    u = MEASURED_FRONTEND_LATENCY_NS["UCIe-Memory"]
    return {k: v / u for k, v in MEASURED_FRONTEND_LATENCY_NS.items()
            if k != "UCIe-Memory"}
