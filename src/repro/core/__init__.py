"""repro.core — the paper's models behind one axes-first design-space API.

The primary contribution: analytical models of on-package memory over UCIe
(approaches A-E), incumbent-bus baselines, latency/power/cost models, and a
flit-level discrete-event simulator that validates the closed forms.

The design-space surface is AXES-FIRST (:mod:`repro.core.space`): declare
named axes — ``phy``, ``read_fraction`` / ``mix``, ``backlog``,
``shoreline_mm``, ``workload_config``, ``protocol``, ``protocol_param``,
``catalog_param``, and the pipelining axes ``k`` / ``ucie_line_ui`` /
``device_line_ui`` — and a :class:`DesignSpace` lowers any combination
onto the batched engines through ONE shared shape-keyed compile cache,
returning a named-axis :class:`SpaceResult` with ``sel()`` /
``frontier()`` / ``argbest()`` queries and a first-class
``feasible(constraints)`` mask composable via ``where=``:

    from repro.core import DesignSpace, SelectionConstraints, axis
    from repro.core import UCIE_A_32G_55U, UCIE_S_32G, UCIE_A_48G_45U
    res = DesignSpace([
        axis("phy", [UCIE_A_32G_55U, UCIE_S_32G, UCIE_A_48G_45U]),
        axis("read_fraction", [0.0, 0.5, 1.0]),
        axis("shoreline_mm", [4.0, 8.0]),
    ]).evaluate()
    res["bandwidth_gbs"].argbest("system")      # frontier labels
    mask = res.feasible(SelectionConstraints(max_relative_bit_cost=2.0))
    res.frontier("bandwidth_gbs", where=mask)   # feasible-set winners

Flit-simulated metrics run under a :class:`repro.core.space.SimConfig`
(``sim=`` on ``DesignSpace`` and every legacy wrapper): :data:`FIXED_SIM`
(default, bit-identical fixed horizon) or :data:`ADAPTIVE_SIM`
(convergence-adaptive chunked cores with batched early exit — the
benchmarks/explorer default; <= tol-scale deviation, several-x fewer
sequential cycles).

Legacy front-ends (``flitsim.sweep*``, ``memsys.catalog_grid`` /
``approach_grid``, ``selector.rank_grid``,
``analysis.bridge_design_space``) are thin compatibility wrappers over the
same engines and cache — identical numerics, shared warm executables.
:func:`joint_frontier` is the first capability only the unified API can
express: the (mix x backlog x shoreline) frontier marking where the flit
simulation and the closed forms disagree about the best memory system.
"""
from repro.core.ucie import (
    UCIePhy, Packaging, UCIE_S_32G, UCIE_A_32G_55U, UCIE_A_32G_45U,
    UCIE_S_48G_110U, UCIE_A_48G_45U, PERTURBABLE_PHY_FIELDS,
    IDLE_POWER_FRACTION, table1,
)
from repro.core.traffic import TrafficMix, PAPER_MIXES, mix_grid, mixes_named
from repro.core.protocols import (
    MemoryProtocol, APPROACH_A, APPROACH_A_NATIVE, APPROACH_B, APPROACH_C,
    APPROACH_D, APPROACH_E, ALL_APPROACHES, BASELINES,
    LPDDR5, LPDDR6, HBM3, HBM4,
)
from repro.core.latency import (
    UCIeMemoryLatency, MEASURED_FRONTEND_LATENCY_NS, latency_speedup,
)
from repro.core.space import (
    ADAPTIVE_SIM, Axis, AxisSet, DesignSpace, FIXED_SIM, OWN_MIX,
    SimConfig, SpaceArray, SpaceResult, axis, cache_stats, clear_cache,
    joint_frontier, regimes,
)
from repro.core.memsys import (
    CatalogGrid, MemorySystem, catalog_grid, grid_cache_stats,
    standard_catalog,
)
from repro.core.selector import (
    GridRanking, RankedSystem, SelectionConstraints, best, rank, rank_grid,
)
from repro.core import cost, flitsim, space
