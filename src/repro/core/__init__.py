# The paper's primary contribution: analytical models of on-package memory
# over UCIe (approaches A-E), incumbent-bus baselines, latency/power/cost
# models, and a flit-level discrete-event simulator that validates the
# closed forms.
from repro.core.ucie import (
    UCIePhy, Packaging, UCIE_S_32G, UCIE_A_32G_55U, UCIE_A_32G_45U,
    IDLE_POWER_FRACTION, table1,
)
from repro.core.traffic import TrafficMix, PAPER_MIXES, mix_grid, mixes_named
from repro.core.protocols import (
    MemoryProtocol, APPROACH_A, APPROACH_A_NATIVE, APPROACH_B, APPROACH_C,
    APPROACH_D, APPROACH_E, ALL_APPROACHES, BASELINES,
    LPDDR5, LPDDR6, HBM3, HBM4,
)
from repro.core.latency import (
    UCIeMemoryLatency, MEASURED_FRONTEND_LATENCY_NS, latency_speedup,
)
from repro.core.memsys import (
    CatalogGrid, MemorySystem, catalog_grid, grid_cache_stats,
    standard_catalog,
)
from repro.core.selector import (
    GridRanking, RankedSystem, SelectionConstraints, best, rank, rank_grid,
)
from repro.core import cost, flitsim
