"""repro.core — the paper's models behind one axes-first design-space API.

The primary contribution: analytical models of on-package memory over UCIe
(approaches A-E), incumbent-bus baselines, latency/power/cost models, and a
flit-level discrete-event simulator that validates the closed forms.

The design-space surface is AXES-FIRST (:mod:`repro.core.space`): declare
named axes — ``phy``, ``read_fraction`` / ``mix``, ``backlog``,
``shoreline_mm``, ``workload_config``, ``protocol``, ``protocol_param``,
``catalog_param``, and the pipelining axes ``k`` / ``ucie_line_ui`` /
``device_line_ui`` — and a :class:`DesignSpace` lowers any combination
onto the batched engines through ONE shared shape-keyed compile cache,
returning a named-axis :class:`SpaceResult` with ``sel()`` /
``frontier()`` / ``argbest()`` queries and a first-class
``feasible(constraints)`` mask composable via ``where=``:

    from repro.core import DesignSpace, SelectionConstraints, axis
    from repro.core import UCIE_A_32G_55U, UCIE_S_32G, UCIE_A_48G_45U
    res = DesignSpace([
        axis("phy", [UCIE_A_32G_55U, UCIE_S_32G, UCIE_A_48G_45U]),
        axis("read_fraction", [0.0, 0.5, 1.0]),
        axis("shoreline_mm", [4.0, 8.0]),
    ]).evaluate()
    res["bandwidth_gbs"].argbest("system")      # frontier labels
    mask = res.feasible(SelectionConstraints(max_relative_bit_cost=2.0))
    res.frontier("bandwidth_gbs", where=mask)   # feasible-set winners

Flit-simulated metrics run under a :class:`repro.core.space.SimConfig`
(``sim=`` on ``DesignSpace`` and every legacy wrapper).  Migration table
— pick the row matching what you need; every row shares the same compile
cache and the same report numerics:

    ==================  =========================================  =======
    config              engine / guarantee                         use for
    ==================  =========================================  =======
    ``FIXED_SIM``       full-horizon XLA scan; bit-identical to    goldens,
    (default)           the seed goldens                           CI gates
    ``ADAPTIVE_SIM``    chunked XLA cores, batched early exit +    CPU
                        period-exact asymmetric detector;          sweeps
                        <= ``tol`` deviation, several-x fewer
                        sequential cycles
    ``PALLAS_SIM``      same adaptive schedule through the fused   TPU,
    ``SimConfig(        :mod:`repro.kernels.flit_sim` kernels —    dense
    engine="pallas")``  ONE launch per chunk, state on-chip;       grids
                        interpret-mode (traced to XLA) off-TPU
    ``SimConfig(        trace-scan cores for the ``trace`` axis:   serving
    trace_cycles=C)``   C cycles per phase, state carried across   traces
                        phase boundaries; ``None`` = full horizon
                        per phase (single phase bit-identical to
                        the static cell)
    ``StreamConfig(     STREAMING shards: chunk the cell space,    10^6 -
    chunk_cells=...,    one cached executable per chunk shape      10^8
    devices=N)`` via    (``STREAM_FAMILIES``), ``shard_map`` the   cell
    ``evaluate(...,     chunk batch across N devices, reduce       joint
    stream=cfg)``       frontier/argbest/feasibility on-device —   spaces
                        per-cell tensors never materialize; winner
                        labels bit-identical to the materialized
                        engine (``FIXED_SIM`` cores)
    ==================  =========================================  =======

Streaming keeps peak memory at ``chunk_cells x stacked-protocol rows``
regardless of space size: each dispatch carries running argmax codes,
per-label win counts, and the running best value; constraints stream
through the same reduction (``StreamConfig(constraints=...)``, with
``"(none)"`` cells counted).  See ``docs/streaming.md`` for chunking
semantics and the reduction contracts.

The five frontier builders (``SpaceResult.frontier``,
:func:`joint_frontier` — which now folds the PHY-absolute
``sim_bandwidth_gbs`` subsection — the explorer's phy / sim-phy
frontier reports, and :meth:`DesignSpace.serving_frontier`) converge on
ONE report API: :meth:`DesignSpace.report` /
:func:`repro.core.report.build_report` resolve a
:class:`~repro.core.report.ReportSpec` into typed
:class:`~repro.core.report.FrontierReport` sections (``"frontier"``,
``"joint"``, ``"phy"``, ``"sim_phy"``, ``"serving"``) whose payloads
are byte-identical to the legacy ``design_space.json`` sections.

Time-varying serving traffic rides the ``trace`` axis
(:mod:`repro.traces`): a :class:`~repro.traces.trace.TrafficTrace` is a
sequence of (duration, read_fraction, backlog) phases — recorded live
from :class:`repro.serve.engine.ServingEngine` via
:class:`~repro.traces.recorder.TraceRecorder`, or synthesized from model
config shapes alone (no weights) by
:func:`~repro.traces.synthetic.synthetic_serving_trace`.  Trace cells
run through dedicated trace-scan simulator cores that CARRY queue and
credit state across phase boundaries (a warm phase 2 differs from a cold
steady-state run — that is the point), report duration-weighted
``trace_efficiency`` / per-phase ``trace_phase_efficiency`` /
PHY-absolute ``trace_bandwidth_gbs``, and share the same shape-keyed
compile cache (trace VALUES are traced, so same-shaped trace sets reuse
warm executables).  A single-phase trace is bit-identical to the static
(mix, backlog) cell.  :meth:`DesignSpace.serving_frontier` maps the
winning protocol per (model, QPS) point to its catalog approach — the
``serving_frontier`` section of the CI design-space artifact.

``flitsim.last_run_info()`` reports per-family telemetry for the last
adaptive run: ``engine``, ``launches``, ``cycles_run``, ``elapsed_s``,
``cycles_per_sec_per_cell``, and the detected-period histogram when the
asymmetric periodic detector closed the run.  Trace-scan runs report
under ``<family>.trace`` with ``phases``, ``cycles_per_phase``, and
``state_carry_depth`` instead.

The positional legacy front-ends (``flitsim.sweep`` /
``sweep_pipelining``, ``memsys.catalog_grid``, ``selector.rank_grid``)
were RETIRED in PR 10 after warning since PR 9; the migration table in
:mod:`repro.core.space` maps each retired idiom to its axes-first
replacement, and the engines live on as the private ``_*_impl``
functions the unified API lowers onto (identical numerics, shared warm
executables).

``flitsim.last_run_info()["stream.sim" / "stream.catalog"]`` reports the
streaming engine's async dispatch telemetry — ``dispatches``,
``prefetch`` (bounded in-flight depth), ``pad_cells``, and
``overlap_frac`` (marshal time overlapped with in-flight device work).
:func:`joint_frontier` is the first capability only the unified API can
express: the (mix x backlog x shoreline) frontier marking where the flit
simulation and the closed forms disagree about the best memory system.
"""
from repro.core.ucie import (
    UCIePhy, Packaging, UCIE_S_32G, UCIE_A_32G_55U, UCIE_A_32G_45U,
    UCIE_S_48G_110U, UCIE_A_48G_45U, PERTURBABLE_PHY_FIELDS,
    IDLE_POWER_FRACTION, table1,
)
from repro.core.traffic import TrafficMix, PAPER_MIXES, mix_grid, mixes_named
from repro.core.protocols import (
    MemoryProtocol, APPROACH_A, APPROACH_A_NATIVE, APPROACH_B, APPROACH_C,
    APPROACH_D, APPROACH_E, ALL_APPROACHES, BASELINES,
    LPDDR5, LPDDR6, HBM3, HBM4,
)
from repro.core.latency import (
    UCIeMemoryLatency, MEASURED_FRONTEND_LATENCY_NS, latency_speedup,
)
from repro.core.space import (
    ADAPTIVE_SIM, Axis, AxisSet, DesignSpace, FIXED_SIM, OWN_MIX,
    PALLAS_SIM, STREAM_FAMILIES, SimConfig, SpaceArray, SpaceResult,
    StreamConfig, axis, cache_stats, clear_cache, joint_frontier, regimes,
)
from repro.core.report import FrontierReport, ReportSpec, build_report
from repro.core.streaming import StreamResult
from repro.core.memsys import (
    CatalogGrid, MemorySystem, grid_cache_stats, standard_catalog,
)
from repro.core.selector import (
    GridRanking, RankedSystem, SelectionConstraints, best, rank,
)
from repro.core import cost, flitsim, space
