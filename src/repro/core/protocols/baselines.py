"""Existing on-package memory baselines — native LPDDR5/6 and HBM3/4 buses.

Modeled *optimistically*, exactly as the paper does (§IV.B): no penalty for
bus turn-around, peak data bandwidth for any traffic mix, bump-limited.
These are upper bounds for the incumbents — the comparisons in Figs 10-12
are therefore conservative for the UCIe approaches.

Published constants:
  LPDDR5  : 128 DQ @ 9.6 GT/s, bump map 5.8 mm x 1.75 mm, 2.8 pJ/b
            -> 26.5 GB/s/mm shoreline, 15.1 GB/s/mm^2
  LPDDR6  : same pin density assumed for 192 DQ @ 12.8 GT/s, 2.8 pJ/b
            -> 35.3 GB/s/mm, 20.2 GB/s/mm^2 (frequency-scaled)
  HBM4    : 2048 DQ @ 6.4 GT/s, 8 mm x 2.5 mm, 0.9 pJ/b (HBM3-measured)
            -> 204.8 GB/s/mm, 81.9 GB/s/mm^2
  HBM3    : 1024 DQ @ 6.4 GT/s over the same footprint (for latency/cost refs)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class BidirectionalBusMemory(MemoryProtocol):
    """Optimistic incumbent model: bw_eff == 1, full power while active."""

    name: str = "bus"
    dq_width: int = 0
    data_rate_gtps: float = 0.0
    edge_mm: float = 1.0
    depth_mm: float = 1.0
    pj_per_bit: float = 0.0

    @property
    def peak_bandwidth_gbs(self) -> float:
        return self.dq_width * self.data_rate_gtps / 8.0

    @property
    def linear_density_gbs_mm(self) -> float:
        return self.peak_bandwidth_gbs / self.edge_mm

    @property
    def areal_density_gbs_mm2(self) -> float:
        return self.peak_bandwidth_gbs / (self.edge_mm * self.depth_mm)

    def bw_eff(self, x, y):
        # Optimistic: bidirectional bus delivers peak for any mix.
        return jnp.ones_like(_as_f32(x) + _as_f32(y))

    def p_data(self, x, y):
        return jnp.ones_like(_as_f32(x) + _as_f32(y))

    # density helpers that don't need a UCIe PHY
    def bw_density_linear(self, x, y, phy=None):
        return self.bw_eff(x, y) * self.linear_density_gbs_mm

    def bw_density_areal(self, x, y, phy=None):
        return self.bw_eff(x, y) * self.areal_density_gbs_mm2

    def power_pj_per_bit(self, x, y, phy=None):
        return self.pj_per_bit / self.p_data(x, y)


LPDDR5 = BidirectionalBusMemory(
    name="LPDDR5(native)", dq_width=128, data_rate_gtps=9.6,
    edge_mm=5.8, depth_mm=1.75, pj_per_bit=2.8,
)

LPDDR6 = BidirectionalBusMemory(
    name="LPDDR6(native)", dq_width=192, data_rate_gtps=12.8,
    # paper assumes the same linear and areal density as LPDDR5, scaled by
    # frequency: reproduce by scaling the footprint with the width ratio.
    edge_mm=5.8 * (192 / 128), depth_mm=1.75, pj_per_bit=2.8,
)

HBM3 = BidirectionalBusMemory(
    name="HBM3(native)", dq_width=1024, data_rate_gtps=6.4,
    edge_mm=8.0, depth_mm=2.5, pj_per_bit=0.9,
)

HBM4 = BidirectionalBusMemory(
    name="HBM4(native)", dq_width=2048, data_rate_gtps=6.4,
    edge_mm=8.0, depth_mm=2.5, pj_per_bit=0.9,
)
