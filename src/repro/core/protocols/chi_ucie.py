"""Approach C — ARM CHI (C2C) on Symmetric UCIe.

Format-X 256 B container: twelve 20 B granules + 16 B Link/Protocol headers
(CRC, FEC, Credits).  The paper gives no closed form; DESIGN.md §6.2
documents our model, built to encode the paper's stated reason CHI loses to
CXL: "its granules are 20B (vs 16B for CXL) and there are less granules
available for memory traffic".

Model (Write-Push assumed, as in the paper):

  * capacity fraction = 240/256 = 15/16 (12 granules of the 256 B container)
  * a 64 B line needs 4 granules, each carrying 16 B of payload in a 20 B
    granule -> payload efficiency 16/20 = 4/5
  * requests: 1 per granule; responses: 2 per granule

    G_S2M = x + 5y ;  G_M2S = (x+y)/2 + 4x
    BW_eff = (15/16) * (4/5) * 4(x+y) / (2*G_max)

(equivalently: 512(x+y) data bits over 2*G_max granules of 160 bits each,
scaled by the 16/15 container overhead).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class CHIOnUCIe(MemoryProtocol):
    name: str = "CHI-on-UCIe(sym)"
    asymmetric: bool = False

    granules_per_flit: int = 12
    granule_bytes: int = 20
    payload_bytes_per_granule: int = 16
    data_granules_per_line: int = 4
    requests_per_granule: float = 1.0
    responses_per_granule: float = 2.0

    @property
    def capacity_fraction(self) -> float:
        return (self.granules_per_flit * self.granule_bytes) / 256.0   # 15/16

    @property
    def payload_efficiency(self) -> float:
        return self.payload_bytes_per_granule / self.granule_bytes     # 4/5

    def granules_s2m(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (x + y) / self.requests_per_granule + self.data_granules_per_line * y

    def granules_m2s(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (x + y) / self.responses_per_granule + self.data_granules_per_line * x

    def granules_max(self, x, y):
        return jnp.maximum(self.granules_s2m(x, y), self.granules_m2s(x, y))

    def bw_eff(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (self.capacity_fraction * self.payload_efficiency
                * 4.0 * (x + y) / (2.0 * self.granules_max(x, y)))

    def p_data(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        p = self.p_idle
        s2m = self.granules_s2m(x, y)
        m2s = self.granules_m2s(x, y)
        gmax = self.granules_max(x, y)
        denom = s2m + m2s + (2.0 * gmax - s2m - m2s) * p
        return (self.capacity_fraction * self.payload_efficiency
                * 4.0 * (x + y) / denom)
