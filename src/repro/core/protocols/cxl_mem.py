"""Approach D — CXL.Mem on Symmetric UCIe (unoptimized flit).

256 B latency-optimized flit = 1 H-slot + 14 G-slots usable + 16 B of
Flit-Hdr/CRC/Credit overhead -> 15/16 slot fraction carries traffic
(the paper's eq (14) factor).  Command layout (Table 2, "Unopt"):

    SoC->Mem request : 74 bits  -> 1 request per 16 B slot
    Mem->SoC response: 26 bits  -> 2 responses per slot

The memory controller resides in the logic die, so every access also gets
a response header in the Mem->SoC direction.  A 64 B cache line = 4 slots.

    Slots_S2M = x + 5y                      (eq 11: x read reqs + y*(1 req + 4 data))
    Slots_M2S = (x+y)/2 + 4x = (9x+y)/2     (eq 12)
    BW_eff    = (15/16) * 4(x+y) / (2*max)  (eq 14)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class CXLMemOnUCIe(MemoryProtocol):
    name: str = "CXL.Mem-on-UCIe(sym)"
    asymmetric: bool = False

    slot_fraction: float = 15.0 / 16.0   # 1 of 16 slots lost to Hdr/CRC/Credit
    data_slots_per_line: int = 4         # 64 B / 16 B
    requests_per_slot: float = 1.0       # 74-bit request
    responses_per_slot: float = 2.0      # 26-bit response

    def slots_s2m(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (x + y) / self.requests_per_slot + self.data_slots_per_line * y

    def slots_m2s(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (x + y) / self.responses_per_slot + self.data_slots_per_line * x

    def slots_max(self, x, y):
        return jnp.maximum(self.slots_s2m(x, y), self.slots_m2s(x, y))

    def bw_eff(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return (self.slot_fraction * 4.0 * (x + y)
                / (2.0 * self.slots_max(x, y)))            # eq (14)

    def p_data(self, x, y):
        """eq (16): active slots at full power, idle slot-times at p."""
        x, y = _as_f32(x), _as_f32(y)
        p = self.p_idle
        s2m = self.slots_s2m(x, y)
        m2s = self.slots_m2s(x, y)
        smax = self.slots_max(x, y)
        denom = s2m + m2s + (2.0 * smax - s2m - m2s) * p
        return self.slot_fraction * 4.0 * (x + y) / denom
