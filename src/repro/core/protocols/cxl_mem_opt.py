"""Approach E — CXL.Mem with optimization on Symmetric UCIe.

256 B flit = 15 G-slots (16 B) + 1 HS-slot (10 B, headers only) + 2 B HDR
+ 2 B Credit + 2 B CRC (trailing header; protocol-ID parked from previous
flit).  Optimized commands (Table 2, "Opt"):

    request  : 62 bits -> 1 per HS-slot (2-per-G-slot possible, not modeled,
               matching the paper's performance analysis)
    response : 16 bits -> 4 per slot

Per 15 G-slots of payload there is 1 HS-slot of free header capacity:

    Slots_S2M = (16/15)*4y + max((x+y)   - 4y/15, 0)    (eq 17)
    Slots_M2S = (16/15)*4x + max((x+y)/4 - 4x/15, 0)    (eq 18)
    BW_eff    = 4(x+y) / (2*Slots_max)                  (eq 20; no 15/16 loss)

The (16/15) factor accounts the HS-slot time that rides along with every
15 G-slots; the max() term adds G-slots when headers overflow the free HS
capacity.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class CXLMemOptOnUCIe(MemoryProtocol):
    name: str = "CXL.Mem-opt-on-UCIe(sym)"
    asymmetric: bool = False

    g_slots_per_flit: int = 15
    data_slots_per_line: int = 4
    requests_per_hs: float = 1.0
    responses_per_slot: float = 4.0

    def slots_s2m(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        data = self.data_slots_per_line * y                  # 4y
        hdr_need = (x + y) / self.requests_per_hs
        hs_free = data / self.g_slots_per_flit               # 4y/15
        return (16.0 / 15.0) * data + jnp.maximum(hdr_need - hs_free, 0.0)

    def slots_m2s(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        data = self.data_slots_per_line * x                  # 4x
        hdr_need = (x + y) / self.responses_per_slot
        hs_free = data / self.g_slots_per_flit               # 4x/15
        return (16.0 / 15.0) * data + jnp.maximum(hdr_need - hs_free, 0.0)

    def slots_max(self, x, y):
        return jnp.maximum(self.slots_s2m(x, y), self.slots_m2s(x, y))

    def bw_eff(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        return 4.0 * (x + y) / (2.0 * self.slots_max(x, y))  # eq (20)

    def p_data(self, x, y):
        """eq (22): like eq (16) but no slot lost to CRC/FEC/Hdr/Credit."""
        x, y = _as_f32(x), _as_f32(y)
        p = self.p_idle
        s2m = self.slots_s2m(x, y)
        m2s = self.slots_m2s(x, y)
        smax = self.slots_max(x, y)
        denom = s2m + m2s + (2.0 * smax - s2m - m2s) * p
        return 4.0 * (x + y) / denom
