"""The paper's five protocol mappings (A-E) + incumbent bus baselines."""
from repro.core.protocols.base import MemoryProtocol
from repro.core.protocols.lpddr6_ucie import LPDDR6OnUCIe, LPDDR6NativeUCIe
from repro.core.protocols.hbm_ucie import HBMOnUCIe
from repro.core.protocols.chi_ucie import CHIOnUCIe
from repro.core.protocols.cxl_mem import CXLMemOnUCIe
from repro.core.protocols.cxl_mem_opt import CXLMemOptOnUCIe
from repro.core.protocols.baselines import (
    BidirectionalBusMemory, LPDDR5, LPDDR6, HBM3, HBM4,
)

#: The paper's approaches, instantiated (A, B, C, D, E).
APPROACH_A = LPDDR6OnUCIe()
APPROACH_A_NATIVE = LPDDR6NativeUCIe()
APPROACH_B = HBMOnUCIe()
APPROACH_C = CHIOnUCIe()
APPROACH_D = CXLMemOnUCIe()
APPROACH_E = CXLMemOptOnUCIe()

ALL_APPROACHES = {
    "A:lpddr6-asym": APPROACH_A,
    "A2:lpddr6-native": APPROACH_A_NATIVE,
    "B:hbm-asym": APPROACH_B,
    "C:chi-sym": APPROACH_C,
    "D:cxl-mem": APPROACH_D,
    "E:cxl-mem-opt": APPROACH_E,
}

BASELINES = {
    "LPDDR5": LPDDR5,
    "LPDDR6": LPDDR6,
    "HBM3": HBM3,
    "HBM4": HBM4,
}
