"""Protocol-mapping interface shared by approaches A-E and the bus baselines.

Every protocol model is a pair of pure functions over the traffic mix
(x reads : y writes of 64 B lines):

  * ``bw_eff(x, y)``   — fraction of the PHY's raw (bump-limited) bandwidth
    that carries cache-line *data* (CRC/ECC/header/credit/command/address are
    overhead, matching the LPDDR/HBM DQ-only efficiency methodology §IV.B).
  * ``p_data(x, y)``   — data-power ratio: data bits over power-weighted
    bit-slots, with idle lane groups burning ``p`` (=0.15) of peak power.

Both accept scalars or jnp arrays (vectorized mix sweeps).  Derived metrics:

  * bandwidth density (linear / areal)   = bw_eff * PHY published density
  * realizable power efficiency (pJ/b)   = PHY pJ/b / p_data
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.ucie import IDLE_POWER_FRACTION, UCIePhy


@dataclasses.dataclass(frozen=True)
class MemoryProtocol:
    """Base class; subclasses override ``bw_eff`` and ``p_data``."""

    name: str = "base"
    #: idle-lane power fraction (paper: p = 0.15)
    p_idle: float = IDLE_POWER_FRACTION
    #: True when each direction has independently-sized lane groups that can
    #: be gated separately (asymmetric UCIe); symmetric links gate all-or-none
    #: per direction.  Informational — the math lives in each subclass.
    asymmetric: bool = False

    # -- overridables --------------------------------------------------------
    def bw_eff(self, x, y):
        raise NotImplementedError

    def p_data(self, x, y):
        raise NotImplementedError

    # -- derived metrics -----------------------------------------------------
    def bw_density_linear(self, x, y, phy: UCIePhy):
        """GB/s per mm of die shoreline for mix xRyW."""
        return self.bw_eff(x, y) * phy.linear_density_gbs_mm

    def bw_density_areal(self, x, y, phy: UCIePhy):
        """GB/s per mm^2 for mix xRyW."""
        return self.bw_eff(x, y) * phy.areal_density_gbs_mm2

    def power_pj_per_bit(self, x, y, phy: UCIePhy):
        """Realizable pJ per *data* bit for mix xRyW (eq 10 / 17 / 23)."""
        return phy.power_pj_per_bit / self.p_data(x, y)

    def effective_bandwidth_gbs(self, x, y, phy: UCIePhy,
                                shoreline_mm: Optional[float] = None):
        """Deliverable data GB/s for a given shoreline budget (or one block)."""
        if shoreline_mm is None:
            return self.bw_eff(x, y) * phy.raw_bandwidth_gbs
        return self.bw_density_linear(x, y, phy) * shoreline_mm


def _as_f32(v):
    return jnp.asarray(v, dtype=jnp.float32)
