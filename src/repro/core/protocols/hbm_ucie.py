"""Approach B — HBM3/4 protocol mapped on Asymmetric UCIe.

The paper uses a 138-lane UCIe module (2:1 read:write bandwidth ratio) and
omits the equations "due to page limits"; we derive them with the same
method as Approach A (see DESIGN.md §6.1).  Lane accounting from Fig 5b:

    SoC->Logic : 24 cmd + 36 DRAM data + 4 write-mask + 1 CRC = 65 (data) / 69
    Logic->SoC : 72 DRAM data + 1 CRC                         = 73 (data) / 77

("Total (Data)" 65 + 73 = 138 counted lanes; clock/track/valid excluded.)

Cache-line transfer times from Fig 5b: 16 UI SoC->Logic (writes over 36
lanes: 576/36), 8 UI Logic->SoC (reads over 72 lanes: 576/72), i.e.

    t_xRyW = max(8x, 16y)

Commands are serialized over the 24 command lanes; per access we charge 96
command bits (ACT + RD/WR, mirroring eq (6)'s LPDDR6 value) -> 4 UI/access.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class HBMOnUCIe(MemoryProtocol):
    name: str = "HBM3/4-on-UCIe(asym)"
    asymmetric: bool = True

    total_lanes: int = 138
    read_lanes: int = 72            # Logic->SoC data
    write_lanes: int = 36           # SoC->Logic data
    wmask_lanes: int = 4
    cmd_lanes: int = 24
    cmd_bits_per_access: int = 96
    access_bits: int = 576          # 512 + ECC/meta, as in Approach A

    def read_ui(self, x):
        return _as_f32(x) * self.access_bits / self.read_lanes     # 8x

    def write_ui(self, y):
        return _as_f32(y) * self.access_bits / self.write_lanes    # 16y

    def t_xryw(self, x, y):
        return jnp.maximum(self.read_ui(x), self.write_ui(y))

    def bw_eff(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        t = self.t_xryw(x, y)
        return (x + y) * 512.0 / (self.total_lanes * t)

    def p_data(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        p = self.p_idle
        t = self.t_xryw(x, y)
        w_ui = self.write_ui(y)
        r_ui = self.read_ui(x)
        dq_wmask = self.write_lanes + self.wmask_lanes          # 40
        p_s2m_dq = dq_wmask * (w_ui + (t - w_ui) * p)
        cmd_bits = self.cmd_bits_per_access * (x + y)
        p_s2m_cmd = cmd_bits + (self.cmd_lanes * t - cmd_bits) * p
        cmd_ui = cmd_bits / self.cmd_lanes                      # 4(x+y)
        p_s2m_crc = jnp.maximum(w_ui, cmd_ui) * (1 - p) + t * p
        m2s_lanes = self.read_lanes + 1                         # 73
        p_m2s = m2s_lanes * (r_ui * (1 - p) + t * p)
        total = p_s2m_dq + p_s2m_cmd + p_s2m_crc + p_m2s
        return 512.0 * (x + y) / total
