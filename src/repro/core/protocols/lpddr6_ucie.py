"""Approach A — LPDDR6 protocol mapped on Asymmetric (Enhanced) UCIe.

Implements eqs (1)-(10) of the paper exactly, for the 74-lane module
(double-stacked Fig 4 module): per direction,

    SoC->Mem : 24 data + 2 write-mask + 8 CA + 2 CS (=10 cmd) + 1 CRC = 37
    Mem->SoC : 36 data                                 + 1 CRC       = 37

Transfer granularity is 288 bits (256 data + 32 meta/ECC) per half cache
line with the x12 device arrangement, i.e. 576 bits per 64 B access:

    reads :  576 / 36 lanes = 16 UI each        (eq 1)
    writes:  576 / 24 lanes = 24 UI each        (eq 1)
    t_xRyW = max(16x, 24y) = 8*max(2x, 3y)      (eq 2)

The memory controller resides in the SoC; requests carry no responses.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols.base import MemoryProtocol, _as_f32


@dataclasses.dataclass(frozen=True)
class LPDDR6OnUCIe(MemoryProtocol):
    name: str = "LPDDR6-on-UCIe(asym)"
    asymmetric: bool = True

    total_lanes: int = 74          # counted data lanes, both directions
    read_lanes: int = 36           # Mem->SoC data
    write_lanes: int = 24          # SoC->Mem data
    wmask_lanes: int = 2
    cmd_lanes: int = 10            # 8 CA + 2 CS
    cmd_bits_per_access: int = 96  # eq (6)
    access_bits: int = 576         # 512 data + 64 meta/ECC (2x 288b beats)

    # -- timing ---------------------------------------------------------------
    def read_ui(self, x):
        return _as_f32(x) * self.access_bits / self.read_lanes      # 16x

    def write_ui(self, y):
        return _as_f32(y) * self.access_bits / self.write_lanes     # 24y

    def t_xryw(self, x, y):
        """eq (2): link is full duplex — reads and writes stream concurrently."""
        return jnp.maximum(self.read_ui(x), self.write_ui(y))

    # -- eq (3): bandwidth efficiency -----------------------------------------
    def bw_eff(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        t = self.t_xryw(x, y)
        return (x + y) * 512.0 / (self.total_lanes * t)

    # -- eqs (5)-(9): data-power ratio ------------------------------------------
    def p_data(self, x, y):
        x, y = _as_f32(x), _as_f32(y)
        p = self.p_idle
        t = self.t_xryw(x, y)
        w_ui = self.write_ui(y)            # 24y
        r_ui = self.read_ui(x)             # 16x
        dq_wmask = self.write_lanes + self.wmask_lanes        # 26
        # eq (5): write data + mask lanes active for 24y UI, else idle
        p_s2m_dq = dq_wmask * (w_ui + (t - w_ui) * p)
        # eq (6): command lanes carry 96 bits per access
        cmd_bits = self.cmd_bits_per_access * (x + y)
        p_s2m_cmd = cmd_bits + (self.cmd_lanes * t - cmd_bits) * p
        # eq (7): S2M CRC lane active while write data or commands flow
        cmd_ui = cmd_bits / self.cmd_lanes                    # 9.6(x+y)
        p_s2m_crc = jnp.maximum(w_ui, cmd_ui) * (1 - p) + t * p
        # eq (8): Mem->SoC — 36 data + 1 CRC active for 16x UI
        m2s_lanes = self.read_lanes + 1                       # 37
        p_m2s = m2s_lanes * (r_ui * (1 - p) + t * p)
        total = p_s2m_dq + p_s2m_cmd + p_s2m_crc + p_m2s
        return 512.0 * (x + y) / total                        # eq (9)


@dataclasses.dataclass(frozen=True)
class LPDDR6NativeUCIe(LPDDR6OnUCIe):
    """Fig 4b variant: LPDDR6 die with native UCIe PHY (single module).

    Module is 43-45 data lanes optimized 2:1 read:write (24 read data,
    12 write data per x12 device pair, 4 cmd).  Same equations with the
    single-module lane counts from Fig 4d.
    """

    name: str = "LPDDR6-native-UCIe(asym)"
    total_lanes: int = 43          # 18 S2M + 25 M2S (Fig 4d data totals)
    read_lanes: int = 24
    write_lanes: int = 12
    wmask_lanes: int = 1
    cmd_lanes: int = 4
    cmd_bits_per_access: int = 48  # half of the double-stacked module
