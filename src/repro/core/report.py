"""ONE frontier/report API over every engine and execution mode.

Five frontier builders accreted across PRs 3-8: ``SpaceResult.frontier``,
``joint_frontier``, the explorer's ``phy_frontier_report`` /
``sim_phy_frontier_report``, and ``DesignSpace.serving_frontier``.  They
now converge here: :func:`build_report` (the engine behind
:meth:`repro.core.space.DesignSpace.report`) resolves a
:class:`ReportSpec` into typed :class:`FrontierReport` sections whose
payloads are byte-identical to the legacy ``design_space.json`` sections
— the explorer functions are thin wrappers over this module, and the
summary golden pins the winner labels of every section.

Sections:

* ``"frontier"`` — the calling space's own winner map
  (``argbest``-reduced, optionally constraint-masked, optionally through
  the STREAMING engine via a ``stream=StreamConfig`` option — the path
  that scales one section to 10^6–10^8 cells).
* ``"joint"`` — :func:`repro.core.space.joint_frontier`: the
  (mix x backlog x shoreline) analytic-vs-simulated disagreement map,
  which since the streaming PR also carries the folded
  ``sim_bandwidth_gbs`` PHY-absolute subsection.
* ``"phy"`` — the PHY-stacked analytic frontier (UCIe-A/S, 32G + 48G).
* ``"sim_phy"`` — its cycle-level counterpart (simulated efficiency x
  raw PHY bandwidth, per queue depth).
* ``"serving"`` — the per-(model, QPS) serving-trace winner map.

Every section accepts keyword options via ``ReportSpec.options`` (keyed
by section name); ``verbose=True`` reproduces the explorer's progress
prints byte-for-byte (the explorer wrappers pass it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

__all__ = ["FrontierReport", "ReportSpec", "build_report"]

#: sections that need no DesignSpace instance (they build their own)
STANDALONE_SECTIONS: Tuple[str, ...] = ("joint", "phy", "sim_phy",
                                        "serving")


@dataclasses.dataclass(frozen=True)
class ReportSpec:
    """What to report: which sections, under which execution config.

    ``options`` maps section name -> keyword options for that section's
    builder (e.g. ``{"phy": {"n_fracs": 41}}``; the ``"frontier"``
    section accepts ``metric`` / ``dim`` / ``mode`` / ``constraints`` /
    ``stream``).  ``sim`` is the default :class:`~repro.core.space.
    SimConfig` for simulated sections (a per-section ``sim`` option
    wins).  ``verbose`` reproduces the explorer's progress prints.
    """

    sections: Tuple[str, ...] = STANDALONE_SECTIONS
    sim: Optional[Any] = None
    options: Mapping[str, Mapping[str, Any]] = dataclasses.field(
        default_factory=dict)
    verbose: bool = False

    def __post_init__(self):
        object.__setattr__(self, "sections",
                           tuple(str(s) for s in self.sections))


@dataclasses.dataclass(frozen=True)
class FrontierReport:
    """One typed report section: the JSON-able payload (byte-identical
    to the legacy ``design_space.json`` section of the same name) plus
    its identity."""

    section: str
    payload: Dict[str, Any]

    def __getitem__(self, key: str) -> Any:
        return self.payload[key]

    def __contains__(self, key: str) -> bool:
        return key in self.payload


def build_report(spec: Optional[ReportSpec] = None, *,
                 space=None) -> Dict[str, FrontierReport]:
    """Resolve ``spec`` into ``{section: FrontierReport}``.

    ``space`` is the :class:`~repro.core.space.DesignSpace` the
    ``"frontier"`` section reduces (required for that section only;
    :meth:`DesignSpace.report` passes itself).
    """
    spec = spec if spec is not None else ReportSpec()
    builders = {"frontier": _frontier_section, "joint": _joint_section,
                "phy": _phy_section, "sim_phy": _sim_phy_section,
                "serving": _serving_section}
    unknown = [s for s in spec.sections if s not in builders]
    if unknown:
        raise ValueError(f"unknown report sections {unknown}; choose "
                         f"from {sorted(builders)}")
    out: Dict[str, FrontierReport] = {}
    for section in spec.sections:
        if section == "frontier" and space is None:
            raise ValueError(
                "the 'frontier' section reduces a DesignSpace instance; "
                "call space.report(spec) (or pass build_report(spec, "
                "space=...)) instead of the standalone form")
        opts = dict(spec.options.get(section, {}))
        if section in ("joint", "sim_phy", "frontier") \
                and spec.sim is not None:
            opts.setdefault("sim", spec.sim)
        payload = builders[section](space, spec.verbose, **opts)
        out[section] = FrontierReport(section=section, payload=payload)
    return out


# =========================================================================
# sections
# =========================================================================


def _frontier_section(space, verbose, *, metric: str = "bandwidth_gbs",
                      dim: str = "system", mode: str = "max",
                      constraints=None, sim=None, stream=None
                      ) -> Dict[str, Any]:
    """The calling space's own winner map — materialized
    (``SpaceResult.frontier``) or streamed (``StreamConfig``), one
    payload schema for both."""
    if stream is not None:
        res = space.evaluate(metrics=(metric,), sim=sim, stream=stream)
        winners = res.winners
        extra = {"engine": "streaming", "win_counts": res.win_counts,
                 "n_cells": res.n_cells,
                 "peak_cells_per_chunk": res.peak_cells_per_chunk,
                 "devices": res.devices, "compiles": res.compiles}
        mode = res.mode
    else:
        metrics = [metric]
        if constraints is not None:
            # point-dependent constraints read these arrays
            if constraints.max_power_w is not None:
                metrics.append("power_w")
            if constraints.required_bandwidth_gbs is not None:
                metrics.append("bandwidth_gbs")
        res = space.evaluate(metrics=tuple(dict.fromkeys(metrics)),
                             sim=sim)
        where = res.feasible(constraints) if constraints is not None \
            else None
        winners = res.frontier(metric, dim, mode, where=where)
        extra = {"engine": "materialized"}
    payload = {"metric": metric, "dim": dim, "mode": mode,
               "dims": list(winners.dims),
               "coords": [[str(c) for c in coord]
                          for coord in winners.coords],
               "winners": np.asarray(winners.values, dtype=object)
               .tolist(), **extra}
    if verbose:
        print(f"frontier: {metric} argbest({dim!r}, {mode!r}) over dims "
              f"{payload['dims']} [{extra['engine']}]")
    return payload


def _joint_section(space, verbose, **opts) -> Dict[str, Any]:
    from repro.core.space import joint_frontier
    t0 = time.perf_counter()
    jf = joint_frontier(**opts)
    dt = time.perf_counter() - t0
    if verbose:
        n_jf = (len(jf["read_fractions"]) * len(jf["backlogs"])
                * len(jf["shorelines"]))
        print(f"analytic-vs-simulated frontier: {n_jf} joint "
              f"(mix x backlog x shoreline) points in {dt:.2f}s; winners "
              f"disagree on {jf['disagreement_fraction']:.0%} of the "
              f"space")
    return jf


def _phy_section(space, verbose, *, n_fracs: int = 21,
                 shorelines=(4.0, 8.0, 16.0)) -> Dict[str, Any]:
    """First-class ``phy`` axis: the catalog across UCIe-A/UCIe-S at 32G
    plus the forward-looking 48G (UCIe 2.0 scaling) points, in ONE
    PHY-stacked evaluation."""
    from repro.core import (
        UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G, UCIE_S_48G_110U,
    )
    from repro.core.memsys import grid_cache_stats
    from repro.core.space import DesignSpace, axis, regimes

    phys = [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U, UCIE_A_48G_45U]
    fracs = np.linspace(0.0, 1.0, n_fracs)
    before = grid_cache_stats()
    t0 = time.perf_counter()
    res = DesignSpace([
        axis("phy", phys),
        axis("read_fraction", fracs),
        axis("shoreline_mm", shorelines),
    ]).evaluate(metrics=("bandwidth_gbs", "gbs_per_watt"))
    dt = time.perf_counter() - t0
    after = grid_cache_stats()
    bw = res["bandwidth_gbs"]          # [S, F, M, L]
    if verbose:
        n_pts = int(np.prod(bw.shape))
        print(f"phy axis: {len(phys)} PHYs x {len(bw.coord('system'))} "
              f"approaches x {n_fracs} mixes x {len(shorelines)} "
              f"shorelines = {n_pts} points in {dt:.2f}s "
              f"[{after.misses - before.misses} compiles]")
    report = {"phys": [p.name for p in phys],
              "read_fractions": fracs.tolist(),
              "shorelines": [float(s) for s in shorelines],
              "best_approach_by_phy": {}, "regimes_by_phy": {}}
    for p in phys:
        front = res.frontier("bandwidth_gbs").sel(phy=p.name,
                                                  shoreline_mm=8.0)
        regs = regimes(front.values.tolist(), fracs)
        report["regimes_by_phy"][p.name] = [
            {"read_fraction_lo": lo, "read_fraction_hi": hi,
             "best": str(lab)} for lo, hi, lab in regs]
        at70 = front.values[int(round(0.7 * (n_fracs - 1)))]
        report["best_approach_by_phy"][p.name] = str(at70)
        if verbose:
            peak = float(bw.sel(phy=p.name,
                                shoreline_mm=8.0).values.max())
            print(f"    {p.name:18s} best@70R30W {at70:24s} "
                  f"peak {peak:6.0f} GB/s @ 8 mm")
    # §V scaling check surfaced in the artifact: at the SAME bump pitch
    # (both UCIe-S points are 110um) 48G carries exactly 48/32 = 1.5x the
    # bandwidth at identical pJ/b.  (The advanced 48G point above stacks
    # a further 55/45 pitch gain on top, hence its larger peak.)
    g32 = float(bw.sel(phy=UCIE_S_32G.name).values.max())
    g48 = float(bw.sel(phy=UCIE_S_48G_110U.name).values.max())
    report["bw_gain_48g_vs_32g_same_pitch"] = g48 / g32
    if verbose:
        print(f"    48G vs 32G same-pitch bandwidth gain: "
              f"x{g48 / g32:.2f} at constant pJ/b")
    return report


def _sim_phy_section(space, verbose, *, n_fracs: int = 21,
                     backlogs=(2.0, 64.0), sim=None) -> Dict[str, Any]:
    """Simulation-corrected PHY-absolute frontier: the flit simulators'
    data efficiency threaded onto each PHY generation's raw link
    bandwidth — the cycle-level counterpart of the ``phy`` section, and
    the first one that can disagree with it per queue depth."""
    from repro.core import (
        ADAPTIVE_SIM, UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G,
        UCIE_S_48G_110U, flitsim,
    )
    from repro.core.selector import approach_key_for
    from repro.core.space import DesignSpace, axis, regimes

    sim = sim if sim is not None else ADAPTIVE_SIM
    phys = [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U, UCIE_A_48G_45U]
    fracs = np.linspace(0.0, 1.0, n_fracs)
    before = flitsim.compile_cache_stats()
    t0 = time.perf_counter()
    res = DesignSpace([
        axis("phy", phys),
        axis("read_fraction", fracs),
        axis("backlog", backlogs),
    ], sim=sim).evaluate(
        metrics=("sim_efficiency", "sim_bandwidth_gbs"))
    dt = time.perf_counter() - t0
    after = flitsim.compile_cache_stats()
    bw = res["sim_bandwidth_gbs"]      # [protocol, phy, backlog, mix]
    info = flitsim.last_run_info()
    cycles = {fam.split(".")[1]: info[fam]["cycles_run"] for fam in info
              if info[fam].get("mode") == "adaptive"}
    if verbose:
        print(f"sim-phy frontier: {len(bw.coord('protocol'))} protocols "
              f"x {len(phys)} PHYs x {len(backlogs)} backlogs x "
              f"{n_fracs} read fractions = {int(np.prod(bw.shape))} "
              f"points in {dt:.2f}s "
              f"[{after.misses - before.misses} compiles; adaptive "
              f"cycles {cycles}]")
    report = {"phys": [p.name for p in phys],
              "backlogs": [float(b) for b in backlogs],
              "read_fractions": fracs.tolist(),
              "adaptive_cycles": cycles,
              "peak_sim_gbs_by_phy": {},
              "best_protocol_by_phy": {},
              "regimes_by_phy_backlog": {}}
    for p in phys:
        regs_by_bl = {}
        for b in backlogs:
            front = bw.sel(phy=p.name, backlog=b).argbest("protocol")
            regs_by_bl[f"{b:g}"] = [
                {"read_fraction_lo": lo, "read_fraction_hi": hi,
                 "best": str(lab),
                 "approach": approach_key_for(str(lab))}
                for lo, hi, lab in regimes(front.values.tolist(), fracs)]
        report["regimes_by_phy_backlog"][p.name] = regs_by_bl
        deep = bw.sel(phy=p.name, backlog=backlogs[-1])
        at70 = deep.argbest("protocol").values[
            int(round(0.7 * (n_fracs - 1)))]
        report["best_protocol_by_phy"][p.name] = str(at70)
        peak = float(deep.values.max())
        report["peak_sim_gbs_by_phy"][p.name] = peak
        if verbose:
            print(f"    {p.name:18s} best@70R30W {str(at70):12s} "
                  f"peak {peak:5.0f} GB/s (raw link, simulated)")
    # the shallow-queue disagreement the closed forms cannot see: winners
    # at backlog 2 vs saturation
    shallow = {p.name: [r["best"]
                        for r in report["regimes_by_phy_backlog"][p.name]
                        [f"{backlogs[0]:g}"]] for p in phys}
    deep_w = {p.name: [r["best"]
                       for r in report["regimes_by_phy_backlog"][p.name]
                       [f"{backlogs[-1]:g}"]] for p in phys}
    report["shallow_queue_disagrees"] = {
        name: shallow[name] != deep_w[name] for name in shallow}
    return report


def _serving_section(space, verbose, *, models=None, qps_points=None,
                     **kwargs) -> Dict[str, Any]:
    from repro.core.space import DesignSpace
    rep = DesignSpace.serving_frontier(models, qps_points, **kwargs)
    if verbose:
        print(f"serving frontier: {len(rep['models'])} models x "
              f"{len(rep['qps_points'])} QPS points x "
              f"{len(rep['protocols'])} protocols on {rep['phy']}")
    return rep
