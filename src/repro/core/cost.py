"""Relative cost model — the paper's fourth axis ("low cost").

The paper argues cost qualitatively from two cited facts:
  * HBM is 5-10x more expensive per bit than LPDDR (refs 9-11);
  * advanced (2.5D) packaging costs more than standard (2D) packaging,
    and wire-bonded LPDDR stacks are cheaper than TSV HBM stacks.

We encode these as a parameterized relative-cost calculator so the
benchmark can rank full memory systems ($/GB and $/(GB/s)) under the
same assumptions the paper states.  All numbers are *relative* to
LPDDR-bit-cost = 1.0; absolute dollars are out of scope (and of the
paper's).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostParams:
    lpddr_bit_cost: float = 1.0
    hbm_bit_cost: float = 7.5          # middle of the cited 5-10x range
    # packaging adders, relative units per mm^2 of interconnect footprint
    standard_pkg_cost_mm2: float = 1.0  # organic substrate (UCIe-S, LPDDR)
    advanced_pkg_cost_mm2: float = 2.5  # silicon bridge/interposer (UCIe-A, HBM)
    # die adders
    logic_die_cost: float = 0.5        # per stack: buffer/controller die
    tsv_stack_premium: float = 1.5     # HBM TSV stacking premium (per stack)
    wirebond_stack_premium: float = 0.2  # LPDDR wire-bonded stack (per stack)


@dataclasses.dataclass(frozen=True)
class MemorySystemCost:
    name: str
    dram_kind: str                 # "lpddr" | "hbm"
    packaging: str                 # "standard" | "advanced"
    uses_logic_die: bool
    stacked_tsv: bool
    footprint_mm2: float           # interconnect footprint per stack
    capacity_gb: float = 16.0
    bandwidth_gbs: float = 256.0

    def relative_cost(self, p: CostParams = CostParams()) -> float:
        bit = p.lpddr_bit_cost if self.dram_kind == "lpddr" else p.hbm_bit_cost
        cost = bit * self.capacity_gb
        cost += (p.standard_pkg_cost_mm2 if self.packaging == "standard"
                 else p.advanced_pkg_cost_mm2) * self.footprint_mm2
        if self.uses_logic_die:
            cost += p.logic_die_cost
        cost += p.tsv_stack_premium if self.stacked_tsv else p.wirebond_stack_premium
        return cost

    def cost_per_gb(self, p: CostParams = CostParams()) -> float:
        return self.relative_cost(p) / self.capacity_gb

    def cost_per_gbs(self, p: CostParams = CostParams()) -> float:
        return self.relative_cost(p) / self.bandwidth_gbs


def reference_systems() -> list:
    """The paper's comparison set, at equal 16 GB capacity per stack."""
    return [
        MemorySystemCost("HBM4(native)", "hbm", "advanced",
                         uses_logic_die=True, stacked_tsv=True,
                         footprint_mm2=8.0 * 2.5, bandwidth_gbs=1638.4),
        MemorySystemCost("LPDDR6(native)", "lpddr", "standard",
                         uses_logic_die=False, stacked_tsv=False,
                         footprint_mm2=8.7 * 1.75, bandwidth_gbs=307.2),
        MemorySystemCost("UCIe-A+HBM-stack(B)", "hbm", "advanced",
                         uses_logic_die=True, stacked_tsv=True,
                         footprint_mm2=0.7776 * 1.585, bandwidth_gbs=512.0),
        MemorySystemCost("UCIe-A+LPDDR6-wirebond(E)", "lpddr", "advanced",
                         uses_logic_die=True, stacked_tsv=False,
                         footprint_mm2=0.7776 * 1.585, bandwidth_gbs=512.0),
        MemorySystemCost("UCIe-S+LPDDR6-wirebond(E)", "lpddr", "standard",
                         uses_logic_die=True, stacked_tsv=False,
                         footprint_mm2=1.143 * 1.54, bandwidth_gbs=256.0),
        MemorySystemCost("UCIe-S+LPDDR6-native(A)", "lpddr", "standard",
                         uses_logic_die=False, stacked_tsv=False,
                         footprint_mm2=1.143 * 1.54, bandwidth_gbs=256.0),
    ]
