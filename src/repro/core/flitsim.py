"""Flit-level discrete-event link simulator — batched, jit-cached sweep engine.

Validates the paper's closed-form bandwidth-efficiency expressions with a
cycle-level simulation of slot scheduling — the executable counterpart of
the Appendix (Fig 13) timing analysis.  Three simulator families:

  * symmetric   — slot/granule scheduler for approaches C/D/E (256 B flits
    per direction per step; greedy packing per the paper: "pack as many
    headers as possible into an H-slot and leave as many G-slots for data").
  * asymmetric  — lane-group/UI scheduler for approaches A/B.
  * pipelining  — Fig 13: k LPDDR6 devices time-multiplexed behind the
    logic die; utilization -> 100% at k=4.

The memory is modeled with zero processing latency: steady-state throughput
(what the closed forms predict) is latency-independent; queue feedback —
headers stealing data slots and vice versa — emerges naturally and is
exactly what the analytic max() terms capture.

Batched API
-----------
``SymmetricFlitParams`` and ``AsymmetricLaneParams`` are registered pytrees,
so parameter *stacks* (one leading axis per protocol, optionally folded with
a perturbation axis) flow straight through ``jax.vmap``.  One jitted
``lax.scan`` evaluates an entire ``[P protocols, B backlogs, M mixes]`` grid
in a single compiled program.  :func:`simulate_grid` is the engine entry
point the axes-first :class:`repro.core.space.DesignSpace` lowers onto; the
legacy front-ends are thin wrappers over it:

    res = flitsim.sweep()                       # 5 protocols x 5 mixes
    res = flitsim.sweep(mixes=grid, backlogs=[16, 64, 128])
    res.efficiency                              # [P, B, M] (or [P, M])
    flitsim.sweep_perturbed([{}, {"credit_lines": 0.5}])   # sensitivity

``sweep_pipelining`` batches the Fig-13 model over device counts — and,
when ``ucie_line_ui`` / ``device_line_ui`` are sequences, over the full
``[k x ucie_line_ui x device_line_ui]`` joint grid (faster DRAM generations
behind the logic die).  Compiled executables are memoized in the SHARED
design-space cache (:mod:`repro.core.space`) keyed on (family, grid shape,
static lengths) — a second identically-shaped sweep from ANY front-end
(``sweep``, a ``DesignSpace`` evaluation, a scalar ``simulate_*`` call)
reuses the warm executable with zero retracing.  ``compile_cache_stats()``
exposes this module's slice of the shared counters; the scalar entry points
``simulate_symmetric`` / ``simulate_asymmetric`` /
``simulate_lpddr6_pipelining`` are thin wrappers over a ``[1, 1, 1]`` grid,
so they share the same cache and numerics bit-for-bit with ``sweep()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space as space_mod
from repro.core.space import CacheStats, cached_program
from repro.core.protocols.chi_ucie import CHIOnUCIe
from repro.core.protocols.cxl_mem import CXLMemOnUCIe
from repro.core.protocols.cxl_mem_opt import CXLMemOptOnUCIe
from repro.core.protocols.hbm_ucie import HBMOnUCIe
from repro.core.protocols.lpddr6_ucie import LPDDR6OnUCIe


def _f32(v) -> jnp.ndarray:
    return jnp.asarray(v, dtype=jnp.float32)


def _check_mix(x: float, y: float) -> None:
    """Reject degenerate mixes loudly (the traced cores would emit NaN)."""
    if x < 0 or y < 0 or x + y <= 0:
        raise ValueError(f"invalid traffic mix x={x} y={y}: need x, y >= 0 "
                         "and x + y > 0")

def _register_params_pytree(cls):
    """Register a frozen params dataclass as a pytree (all fields leaves).

    Lets a *stack* of parameter sets (every field a ``[P]`` array) pass
    through ``jax.vmap`` / ``jax.jit`` like any other array pytree.
    """
    names = tuple(f.name for f in dataclasses.fields(cls))
    jax.tree_util.register_pytree_node(
        cls,
        lambda p: (tuple(getattr(p, n) for n in names), None),
        lambda _, children: cls(*children),
    )
    return cls


def apply_perturbation(obj, pert: Mapping[str, float]):
    """Multiplicatively scale the named fields of a frozen dataclass.

    The shared perturbation core behind every sensitivity axis: the flit
    simulators' ``protocol_param`` (scaling :class:`SymmetricFlitParams` /
    :class:`AsymmetricLaneParams` stacks) and the analytic catalog's
    ``catalog_param`` (scaling :class:`repro.core.ucie.UCIePhy` pJ/b and
    density fields).  Fields ``obj`` doesn't have are ignored — validate
    applicability upstream (:func:`check_perturbation` for flit params,
    ``UCIePhy.perturbed`` for catalog params).
    """
    fields = {f.name for f in dataclasses.fields(type(obj))}
    rep = {k: float(getattr(obj, k)) * float(s)
           for k, s in pert.items() if k in fields}
    return dataclasses.replace(obj, **rep) if rep else obj


class _Stackable:
    """Mixin: stack N parameter sets into one pytree of ``[N]`` f32 arrays."""

    @classmethod
    def stack(cls, params: Sequence["_Stackable"]):
        names = [f.name for f in dataclasses.fields(cls)]
        return cls(*[_f32([getattr(p, n) for p in params]) for n in names])

    def perturbed(self, pert: Mapping[str, float]) -> "_Stackable":
        """Scale the named fields multiplicatively (fields this family
        doesn't have are ignored — validated upstream)."""
        return apply_perturbation(self, pert)


@_register_params_pytree
@dataclasses.dataclass(frozen=True)
class SymmetricFlitParams(_Stackable):
    """Slot geometry for a symmetric flit protocol."""

    g_slots: Any                 # payload-capable slots per flit
    h_slots: Any                 # header-only slots per flit
    reqs_per_h: Any              # requests fitting the header slot
    resps_per_h: Any
    reqs_per_g: Any              # requests per payload slot (header overflow)
    resps_per_g: Any
    data_slots_per_line: Any     # slots per 64 B line
    slot_bits: Any               # payload slot size in bits
    flit_bits: Any = 2048        # 256 B
    #: in-flight read-return credit, in flits' worth of payload slots —
    #: the credit limit is ``credit_lines * g_slots`` slots (default 8
    #: flits, the pre-perturbation constant)
    credit_lines: Any = 8.0

    @classmethod
    def cxl_unopt(cls) -> "SymmetricFlitParams":
        # 1 H + 14 G usable; 16 B slots; 1 req / 2 resp per slot.
        return cls(g_slots=14, h_slots=1, reqs_per_h=1, resps_per_h=2,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def cxl_opt(cls) -> "SymmetricFlitParams":
        # 15 G + 1 HS (10 B, headers only); 1 req / 4 resp per slot.
        return cls(g_slots=15, h_slots=1, reqs_per_h=1, resps_per_h=4,
                   reqs_per_g=1, resps_per_g=4, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def chi(cls) -> "SymmetricFlitParams":
        # 12 granules of 20 B, no dedicated header slot; 16 B payload/granule.
        return cls(g_slots=12, h_slots=0, reqs_per_h=0, resps_per_h=0,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=160)   # granule is 20 B on the wire


@_register_params_pytree
@dataclasses.dataclass(frozen=True)
class AsymmetricLaneParams(_Stackable):
    """Lane-group geometry for the asymmetric mappings (A/B)."""

    total_lanes: Any
    read_lanes: Any
    write_lanes: Any
    cmd_lanes: Any
    cmd_bits_per_access: Any
    access_bits: Any = 576

    @classmethod
    def lpddr6(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=74, read_lanes=36, write_lanes=24,
                   cmd_lanes=10, cmd_bits_per_access=96)

    @classmethod
    def hbm(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=138, read_lanes=72, write_lanes=36,
                   cmd_lanes=24, cmd_bits_per_access=96)


#: every flit-simulator parameter field a perturbation may scale
PERTURBABLE_FIELDS: Tuple[str, ...] = tuple(sorted(
    {f.name for f in dataclasses.fields(SymmetricFlitParams)}
    | {f.name for f in dataclasses.fields(AsymmetricLaneParams)}))


def check_perturbation(pert: Mapping[str, float]) -> None:
    """Reject ``{field: scale}`` perturbations naming unknown flit-simulator
    parameter fields (catalog perturbations are validated by
    ``UCIePhy.perturbed`` against its own field set)."""
    unknown = [k for k in pert if k not in PERTURBABLE_FIELDS]
    if unknown:
        raise ValueError(f"unknown perturbation fields {unknown}; choose "
                         f"from {PERTURBABLE_FIELDS}")


#: backwards-compatible alias (pre-shared-helper name)
_check_perturbation = check_perturbation


# -- simulator cores (traced params; static lengths only) ---------------------


def _symmetric_efficiency(p: SymmetricFlitParams, x, y, backlog,
                          n_flits: int):
    """Saturation data efficiency of a symmetric full-duplex link.

    Data bits delivered (both directions, 512 b per line) over raw link
    capacity — directly comparable to the analytic ``bw_eff``.  Headers
    have priority; data fills the remaining G-slots.  Read requests are
    gated by credit-based flow control on the read-data return path (as
    CXL's credit mechanism does).
    """
    x, y, backlog = _f32(x), _f32(y), _f32(backlog)
    tot = x + y
    xr = x / tot
    yr = y / tot
    dpl = p.data_slots_per_line
    rdata_limit = p.credit_lines * p.g_slots  # in-flight read credit (slots)
    hdr_cap = p.reqs_per_h * p.h_slots + p.reqs_per_g * p.g_slots
    resp_cap = p.resps_per_h * p.h_slots + p.resps_per_g * p.g_slots
    reqs_per_g = jnp.maximum(_f32(p.reqs_per_g), 1e-9)
    resps_per_g = jnp.maximum(_f32(p.resps_per_g), 1e-9)

    def step(carry, _):
        (rq, wq, wdata, rdata, resp, cr, cw, data_slots, warm_slots,
         warm) = carry
        # -- generate traffic to hold the request backlog at `backlog` ------
        deficit = jnp.maximum(backlog - (rq + wq), 0.0)
        cr2 = cr + deficit * xr
        cw2 = cw + deficit * yr
        gen_r = jnp.floor(cr2)
        gen_w = jnp.floor(cw2)
        cr2, cw2 = cr2 - gen_r, cw2 - gen_w
        rq = rq + gen_r
        wq = wq + gen_w

        # -- SoC -> Mem flit: headers first (H then G), data fills the rest -
        # Both request kinds are credit-gated by their data path: reads by
        # the in-flight read-return credit, writes by the write buffer.
        credit_r = jnp.maximum(rdata_limit - rdata, 0.0) / dpl
        credit_w = jnp.maximum(rdata_limit - wdata, 0.0) / dpl
        rq_elig = jnp.minimum(rq, credit_r)
        wq_elig = jnp.minimum(wq, credit_w)
        sent_req = jnp.minimum(rq_elig + wq_elig, hdr_cap)
        tot_q = jnp.maximum(rq_elig + wq_elig, 1e-9)
        sent_r = sent_req * rq_elig / tot_q
        sent_w = sent_req * wq_elig / tot_q
        g_hdr = (jnp.maximum(sent_req - p.reqs_per_h * p.h_slots, 0.0)
                 / reqs_per_g)
        d_s2m = jnp.minimum(wdata, p.g_slots - g_hdr)
        rq, wq = rq - sent_r, wq - sent_w
        wdata = wdata + sent_w * dpl - d_s2m   # data follows its request
        # a sent read instantly enqueues 4 data slots + 1 response (M2S);
        # a sent write enqueues 1 completion response
        rdata = rdata + sent_r * dpl
        resp = resp + sent_r + sent_w

        # -- Mem -> SoC flit: responses first, read data fills the rest -----
        sent_resp = jnp.minimum(resp, resp_cap)
        g_resp = (jnp.maximum(sent_resp - p.resps_per_h * p.h_slots, 0.0)
                  / resps_per_g)
        d_m2s = jnp.minimum(rdata, p.g_slots - g_resp)
        resp = resp - sent_resp
        rdata = rdata - d_m2s

        new_data = d_s2m + d_m2s
        # warm-up: skip the first quarter of the run when accumulating
        warm = warm + 1
        is_warm = (warm > n_flits // 4).astype(jnp.float32)
        data_slots = data_slots + new_data * is_warm
        warm_slots = warm_slots + is_warm
        return (rq, wq, wdata, rdata, resp, cr2, cw2, data_slots,
                warm_slots, warm), None

    init = tuple(jnp.zeros((), jnp.float32) for _ in range(9)) + (
        jnp.zeros((), jnp.int32),)
    (_, _, _, _, _, _, _, data_slots, warm_slots, _), _ = jax.lax.scan(
        step, init, None, length=n_flits)
    # data bits delivered over both-direction capacity during warm window
    data_bits = data_slots * 128.0           # 16 B of payload per data slot
    cap_bits = 2.0 * warm_slots * _f32(p.flit_bits)
    return data_bits / cap_bits


def _asymmetric_efficiency(p: AsymmetricLaneParams, x, y, n_accesses: int):
    """Lane-occupancy simulation: issue n accesses in x:y ratio, measure
    512*n/(total_lanes*T) — comparable to eq (3)."""
    x, y = _f32(x), _f32(y)
    xr = x / (x + y)
    r_ui = _f32(p.access_bits) / p.read_lanes
    w_ui = _f32(p.access_bits) / p.write_lanes
    c_ui = _f32(p.cmd_bits_per_access) / p.cmd_lanes

    def step(carry, _):
        t_read, t_write, t_cmd, credit = carry
        credit = credit + xr
        is_read = credit >= 1.0
        credit = jnp.where(is_read, credit - 1.0, credit)
        t_read = t_read + jnp.where(is_read, r_ui, 0.0)
        t_write = t_write + jnp.where(is_read, 0.0, w_ui)
        t_cmd = t_cmd + c_ui
        return (t_read, t_write, t_cmd, credit), None

    init = (jnp.zeros((), jnp.float32),) * 4
    (t_r, t_w, t_c, _), _ = jax.lax.scan(step, init, None, length=n_accesses)
    t_total = jnp.maximum(jnp.maximum(t_r, t_w), t_c)
    return 512.0 * n_accesses / (p.total_lanes * t_total)


def _pipelining_utilization(k, ucie_line_ui, device_line_ui,
                            max_k: int, n_lines: int):
    """Appendix Fig 13: k x12 LPDDR6 devices time-multiplexed behind the
    logic die.  The UCIe link moves a 64 B line in ``ucie_line_ui`` UI; each
    device sources a line every ``device_line_ui`` UI.  Returns link data
    utilization — 1.0 at k = 4.

    Commands are pipelined (ACT/RD interleaved at 8-bit granularity, Fig 13)
    so the command bus never limits: we model device ready-times only.
    The device ready-time table is padded to ``max_k`` so one executable
    serves every batched ``k`` (entries past k are never addressed).
    """
    k = jnp.asarray(k, jnp.int32)
    ucie_line_ui = _f32(ucie_line_ui)
    device_line_ui = _f32(device_line_ui)

    def step(carry, _):
        dev_ready, link_free, idx = carry
        dev = idx % k
        start = jnp.maximum(dev_ready[dev], link_free)
        finish = start + ucie_line_ui
        dev_ready = dev_ready.at[dev].set(start + device_line_ui)
        return (dev_ready, finish, idx + 1), None

    init = (jnp.zeros((max_k,), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32))
    (_, last_finish, _), _ = jax.lax.scan(step, init, None, length=n_lines)
    return n_lines * ucie_line_ui / last_finish


# -- batched grid programs ----------------------------------------------------


def _symmetric_grid(pstack, x, y, backlogs, *, n_flits: int):
    """[P params] x [B backlogs] x [M mixes] -> efficiency [P, B, M]."""
    point = lambda p, b, xx, yy: _symmetric_efficiency(p, xx, yy, b, n_flits)
    over_m = jax.vmap(point, in_axes=(None, None, 0, 0))
    over_bm = jax.vmap(over_m, in_axes=(None, 0, None, None))
    over_pbm = jax.vmap(over_bm, in_axes=(0, None, None, None))
    return over_pbm(pstack, backlogs, x, y)


def _asymmetric_grid(pstack, x, y, *, n_accesses: int):
    """[P params] x [M mixes] -> efficiency [P, M] (backlog-independent)."""
    point = lambda p, xx, yy: _asymmetric_efficiency(p, xx, yy, n_accesses)
    over_m = jax.vmap(point, in_axes=(None, 0, 0))
    return jax.vmap(over_m, in_axes=(0, None, None))(pstack, x, y)


def _pipelining_grid(ks, ucie_line_uis, device_line_uis, *, max_k: int,
                     n_lines: int):
    """[K device-counts] x [U link-UIs] x [D device-UIs] -> utilization
    [K, U, D] — the joint faster-DRAM-generations sweep."""
    point = lambda k, u, d: _pipelining_utilization(k, u, d, max_k, n_lines)
    over_d = jax.vmap(point, in_axes=(None, None, 0))
    over_ud = jax.vmap(over_d, in_axes=(None, 0, None))
    over_kud = jax.vmap(over_ud, in_axes=(0, None, None))
    return over_kud(ks, ucie_line_uis, device_line_uis)


# -- shared compile cache (repro.core.space) ---------------------------------


def compile_cache_stats() -> CacheStats:
    """This module's slice of the SHARED design-space compile cache
    (families ``flitsim.*``): hits / misses, one miss == one compile."""
    return space_mod.cache_stats(space_mod.FLITSIM_FAMILIES)


def clear_compile_cache() -> None:
    """Drop this module's cached executables and reset its counters."""
    space_mod.clear_cache(space_mod.FLITSIM_FAMILIES)


def _run_symmetric(pstack, x, y, backlogs, n_flits: int):
    fn = cached_program(
        "flitsim.symmetric",
        (pstack.g_slots.shape[0], backlogs.shape[0], x.shape[0], n_flits),
        functools.partial(_symmetric_grid, n_flits=n_flits),
        (pstack, x, y, backlogs))
    return fn(pstack, x, y, backlogs)


def _run_asymmetric(pstack, x, y, n_accesses: int):
    fn = cached_program(
        "flitsim.asymmetric",
        (pstack.total_lanes.shape[0], x.shape[0], n_accesses),
        functools.partial(_asymmetric_grid, n_accesses=n_accesses),
        (pstack, x, y))
    return fn(pstack, x, y)


def _run_pipelining(ks, ucie_line_uis, device_line_uis, max_k: int,
                    n_lines: int):
    fn = cached_program(
        "flitsim.pipelining",
        (ks.shape[0], ucie_line_uis.shape[0], device_line_uis.shape[0],
         max_k, n_lines),
        functools.partial(_pipelining_grid, max_k=max_k, n_lines=n_lines),
        (ks, ucie_line_uis, device_line_uis))
    return fn(ks, ucie_line_uis, device_line_uis)


# -- engine entry point (what DesignSpace lowers onto) ------------------------


def simulate_grid(protocols: Sequence[str], x, y, backlogs, *,
                  perturbations: Optional[Sequence[Mapping[str, float]]]
                  = None,
                  n_flits: int = 2048,
                  n_accesses: int = 4096) -> jnp.ndarray:
    """Evaluate the full ``[Q perturbations, P protocols, B backlogs,
    M mixes]`` grid, one compiled call per simulator family.

    ``x`` / ``y`` are flat ``[M]`` mix arrays; ``backlogs`` is ``[B]``
    (symmetric family only — asymmetric rows broadcast across it).
    ``perturbations`` are multiplicative ``{field: scale}`` overrides
    folded into the parameter stacks (the protocol axis becomes ``Q*P``
    rows of one pytree), so sensitivity sweeps ride the exact same
    executables as the baseline.  Returns efficiency ``[Q, P, B, M]``.
    """
    keys = tuple(protocols)
    unknown = [k for k in keys
               if k not in SYMMETRIC_PARAMS and k not in ASYMMETRIC_PARAMS]
    if unknown:
        raise ValueError(f"unknown protocol keys {unknown}; "
                         f"choose from {sorted(SIMULATORS)}")
    perts = [dict(p) for p in (perturbations or [{}])]
    active_fields: set = set()
    if any(k in SYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(SymmetricFlitParams)}
    if any(k in ASYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(AsymmetricLaneParams)}
    for p in perts:
        _check_perturbation(p)
        # a perturbation that touches NO field of the selected families
        # would silently produce a baseline row labeled as perturbed
        if p and not set(p) & active_fields:
            raise ValueError(
                f"perturbation {p} applies to no parameter of the selected "
                f"protocols {keys}; applicable fields: "
                f"{sorted(active_fields)}")
    x = _f32(np.asarray(x).reshape(-1))
    y = _f32(np.asarray(y).reshape(-1))
    b = _f32(np.asarray(backlogs).reshape(-1))
    n_q, n_b, n_m = len(perts), b.shape[0], x.shape[0]

    per_key: Dict[str, jnp.ndarray] = {}            # key -> [Q, B, M]
    sym_keys = [k for k in keys if k in SYMMETRIC_PARAMS]
    if sym_keys:
        pstack = SymmetricFlitParams.stack(
            [SYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in sym_keys])
        grid = _run_symmetric(pstack, x, y, b, int(n_flits))
        grid = grid.reshape((n_q, len(sym_keys), n_b, n_m))
        for i, k in enumerate(sym_keys):
            per_key[k] = grid[:, i]
    asym_keys = [k for k in keys if k in ASYMMETRIC_PARAMS]
    if asym_keys:
        pstack = AsymmetricLaneParams.stack(
            [ASYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in asym_keys])
        grid = _run_asymmetric(pstack, x, y, int(n_accesses))
        grid = grid.reshape((n_q, len(asym_keys), n_m))
        for i, k in enumerate(asym_keys):
            per_key[k] = jnp.broadcast_to(grid[:, i, None, :],
                                          (n_q, n_b, n_m))
    return jnp.stack([per_key[k] for k in keys], axis=1)   # [Q, P, B, M]


# -- scalar entry points (thin wrappers over a [1, 1, 1] grid) ----------------


def simulate_symmetric(params: SymmetricFlitParams, x: float, y: float,
                       n_flits: int = 2048,
                       backlog: float = 64) -> float:
    """Single-point symmetric simulation; shares the sweep compile cache."""
    _check_mix(x, y)
    pstack = SymmetricFlitParams.stack([params])
    eff = _run_symmetric(pstack, _f32([x]), _f32([y]), _f32([backlog]),
                         int(n_flits))
    return float(eff[0, 0, 0])


def simulate_asymmetric(params: AsymmetricLaneParams, x: float, y: float,
                        n_accesses: int = 4096) -> float:
    """Single-point asymmetric simulation; shares the sweep compile cache."""
    _check_mix(x, y)
    pstack = AsymmetricLaneParams.stack([params])
    eff = _run_asymmetric(pstack, _f32([x]), _f32([y]), int(n_accesses))
    return float(eff[0, 0])


_PIPELINING_PAD_K = 8     # pad the ready-table so all k <= 8 share one exe


def simulate_lpddr6_pipelining(num_devices: int, n_lines: int = 512,
                               ucie_line_ui: float = 16,
                               device_line_ui: float = 64) -> float:
    """Single-k Fig-13 pipelining simulation; shares the sweep cache."""
    max_k = max(int(num_devices), _PIPELINING_PAD_K)
    u = _run_pipelining(jnp.asarray([num_devices], jnp.int32),
                        _f32([ucie_line_ui]), _f32([device_line_ui]),
                        max_k, int(n_lines))
    return float(u[0, 0, 0])


# -- sweep API ---------------------------------------------------------------


#: The five canonical read:write mixes every validation sweep covers.
CANONICAL_MIXES: Tuple[Tuple[float, float], ...] = (
    (1.0, 0.0), (2.0, 1.0), (1.0, 1.0), (1.0, 2.0), (0.0, 1.0))

SYMMETRIC_PARAMS: Dict[str, SymmetricFlitParams] = {
    "cxl_unopt": SymmetricFlitParams.cxl_unopt(),
    "cxl_opt": SymmetricFlitParams.cxl_opt(),
    "chi": SymmetricFlitParams.chi(),
}

ASYMMETRIC_PARAMS: Dict[str, AsymmetricLaneParams] = {
    "lpddr6_asym": AsymmetricLaneParams.lpddr6(),
    "hbm_asym": AsymmetricLaneParams.hbm(),
}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Output of :func:`sweep`.

    ``efficiency`` is ``[P, M]`` when a single backlog was requested and
    ``[P, B, M]`` for a backlog grid; axes follow ``protocols`` /
    ``backlogs`` / ``mixes`` order.
    """

    protocols: Tuple[str, ...]
    mixes: Tuple[Tuple[float, float], ...]
    backlogs: Optional[Tuple[float, ...]]
    efficiency: jnp.ndarray

    def for_protocol(self, key: str) -> jnp.ndarray:
        return self.efficiency[self.protocols.index(key)]


def _normalize_mixes(mixes) -> Tuple[Tuple[float, float], ...]:
    if mixes is None:
        return CANONICAL_MIXES
    out = []
    for m in mixes:
        if hasattr(m, "x") and hasattr(m, "y"):     # TrafficMix
            x, y = float(m.x), float(m.y)
        else:
            x, y = m
            x, y = float(x), float(y)
        _check_mix(x, y)
        out.append((x, y))
    return tuple(out)


def sweep(protocols: Optional[Sequence[str]] = None,
          mixes=None,
          backlogs: Union[None, float, Sequence[float]] = None,
          *, n_flits: int = 2048, n_accesses: int = 4096) -> SweepResult:
    """Evaluate a full ``protocols x backlogs x mixes`` grid in one compiled
    call per simulator family.

    Compatibility wrapper over the shared design-space engine
    (:func:`simulate_grid` — what :class:`repro.core.space.DesignSpace`
    lowers onto): identical numerics, identical compile-cache keys.

    Args:
      protocols: keys from :data:`SIMULATORS` (default: all five).
      mixes: sequence of ``(x, y)`` tuples or ``TrafficMix`` objects
        (default: the five canonical mixes).
      backlogs: ``None`` (default 64), a scalar, or a sequence.  A sequence
        adds a ``B`` axis; backlog only affects the symmetric family (the
        asymmetric rows are broadcast across it).
      n_flits / n_accesses: static simulation lengths per family.

    Returns a :class:`SweepResult` whose ``efficiency`` grid is directly
    comparable to ``ANALYTIC[key].bw_eff(x, y)``.
    """
    keys = tuple(protocols) if protocols is not None else tuple(SIMULATORS)
    if not keys:
        raise ValueError("sweep() needs at least one protocol key")
    mix_tuples = _normalize_mixes(mixes)
    if not mix_tuples:
        raise ValueError("sweep() needs at least one traffic mix")
    squeeze_b = backlogs is None or np.ndim(backlogs) == 0
    if backlogs is None:
        backlog_vals: Tuple[float, ...] = (64.0,)
    else:
        backlog_vals = tuple(
            float(b) for b in np.atleast_1d(np.asarray(backlogs)))

    x = _f32([m[0] for m in mix_tuples])
    y = _f32([m[1] for m in mix_tuples])
    eff = simulate_grid(keys, x, y, backlog_vals, n_flits=n_flits,
                        n_accesses=n_accesses)[0]          # [P, B, M]
    if squeeze_b:
        return SweepResult(protocols=keys, mixes=mix_tuples, backlogs=None,
                           efficiency=eff[:, 0, :])
    return SweepResult(protocols=keys, mixes=mix_tuples,
                       backlogs=backlog_vals, efficiency=eff)


def sweep_perturbed(perturbations: Sequence[Mapping[str, float]],
                    protocols: Optional[Sequence[str]] = None,
                    mixes=None,
                    backlogs: Union[None, float, Sequence[float]] = None,
                    *, n_flits: int = 2048, n_accesses: int = 4096):
    """Protocol-parameter sensitivity sweep: multiplicative ``{field:
    scale}`` perturbations (slot counts, credit limits, lane splits) over
    the existing pytree param stacks.

    Front-end over the axes-first API: returns a
    :class:`repro.core.space.SpaceResult` whose ``sim_efficiency`` array
    carries a ``protocol_param`` axis — include ``{}`` as the first
    perturbation to get the baseline row for free.
    """
    from repro.core.space import DesignSpace, axis
    keys = tuple(protocols) if protocols is not None else tuple(SIMULATORS)
    axes = [axis("protocol_param", list(perturbations)),
            axis("protocol", keys),
            axis("mix", _normalize_mixes(mixes))]
    if backlogs is not None and np.ndim(backlogs) > 0:
        axes.append(axis("backlog", list(np.atleast_1d(backlogs))))
        default_backlog = 64.0
    else:
        default_backlog = 64.0 if backlogs is None else float(backlogs)
    return DesignSpace(axes, default_backlog=default_backlog,
                       n_flits=n_flits, n_accesses=n_accesses).evaluate(
        metrics=("sim_efficiency",))


#: Default queue-depth axis for knee extraction — doubling steps wide
#: enough to bracket every simulated protocol's saturation cliff.
KNEE_BACKLOGS: Tuple[float, ...] = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                                    128.0)


def backlog_knees(mixes=None,
                  backlogs: Sequence[float] = KNEE_BACKLOGS,
                  knee_frac: float = 0.95,
                  n_flits: int = 2048,
                  per_mix: bool = False) -> Dict[str, Any]:
    """Efficiency-cliff knee per simulated protocol: the smallest request
    backlog at which simulated data efficiency reaches ``knee_frac`` of
    that protocol's best efficiency over the backlog axis.

    By default the knee is maximized over ``mixes`` (conservative: a
    protocol must hit its knee on every mix) and the result is a scalar
    per protocol.  With ``per_mix=True`` the per-mix knees are returned as
    a ``[M]`` array per protocol — this is what lets the bridge follow
    each workload's own HLO-derived mix along the configs axis instead of
    the canonical-mix envelope.

    One :func:`sweep` call over the ``[P, B, M]`` grid — repeated calls
    with the same grid shape reuse the warm executable.  Asymmetric
    protocols are backlog-independent, so their knee is the smallest
    backlog probed.  The result feeds ``SelectionConstraints.
    max_backlog_knee``: a queue-depth budget the selector enforces.
    """
    res = sweep(mixes=mixes, backlogs=backlogs, n_flits=n_flits)
    eff = np.asarray(res.efficiency)                    # [P, B, M]
    b = np.asarray(res.backlogs, dtype=np.float64)
    knees: Dict[str, Any] = {}
    for i, key in enumerate(res.protocols):
        e = eff[i]                                      # [B, M]
        ok = e >= knee_frac * e.max(axis=0, keepdims=True)
        first = np.argmax(ok, axis=0)                   # per-mix knee index
        knees[key] = b[first] if per_mix else float(b[first].max())
    return knees


def sweep_pipelining(ks: Sequence[int], n_lines: int = 512,
                     ucie_line_ui: Union[float, Sequence[float]] = 16,
                     device_line_ui: Union[float, Sequence[float]] = 64,
                     ) -> jnp.ndarray:
    """Batched Fig-13 model, one compiled call.

    Scalar ``ucie_line_ui`` / ``device_line_ui`` give link utilization
    ``[K]`` over device counts ``ks`` (legacy behavior).  Passing
    sequences sweeps the joint ``[K, U, D]`` grid — modeling faster DRAM
    generations (smaller ``device_line_ui``) and faster UCIe links
    (smaller ``ucie_line_ui``) behind the logic die.
    """
    ks = tuple(int(k) for k in ks)
    squeeze = (np.ndim(ucie_line_ui) == 0 and np.ndim(device_line_ui) == 0)
    us = _f32(np.atleast_1d(np.asarray(ucie_line_ui, dtype=np.float64)))
    ds = _f32(np.atleast_1d(np.asarray(device_line_ui, dtype=np.float64)))
    max_k = max(max(ks), _PIPELINING_PAD_K)
    util = _run_pipelining(jnp.asarray(ks, jnp.int32), us, ds,
                           max_k, int(n_lines))
    return util[:, 0, 0] if squeeze else util


# -- convenience: analytic counterparts for the property tests ---------------

ANALYTIC = {
    "cxl_unopt": CXLMemOnUCIe(),
    "cxl_opt": CXLMemOptOnUCIe(),
    "chi": CHIOnUCIe(),
    "lpddr6_asym": LPDDR6OnUCIe(),
    "hbm_asym": HBMOnUCIe(),
}

SIMULATORS = {
    "cxl_unopt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_unopt(), x, y),
    "cxl_opt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_opt(), x, y),
    "chi": lambda x, y: simulate_symmetric(SymmetricFlitParams.chi(), x, y),
    "lpddr6_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.lpddr6(), x, y),
    "hbm_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.hbm(), x, y),
}
