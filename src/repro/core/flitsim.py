"""Flit-level discrete-event link simulator — batched, jit-cached sweep engine.

Validates the paper's closed-form bandwidth-efficiency expressions with a
cycle-level simulation of slot scheduling — the executable counterpart of
the Appendix (Fig 13) timing analysis.  Three simulator families:

  * symmetric   — slot/granule scheduler for approaches C/D/E (256 B flits
    per direction per step; greedy packing per the paper: "pack as many
    headers as possible into an H-slot and leave as many G-slots for data").
  * asymmetric  — lane-group/UI scheduler for approaches A/B.
  * pipelining  — Fig 13: k LPDDR6 devices time-multiplexed behind the
    logic die; utilization -> 100% at k=4.

The memory is modeled with zero processing latency: steady-state throughput
(what the closed forms predict) is latency-independent; queue feedback —
headers stealing data slots and vice versa — emerges naturally and is
exactly what the analytic max() terms capture.

Batched API
-----------
``SymmetricFlitParams`` and ``AsymmetricLaneParams`` are registered pytrees,
so parameter *stacks* (one leading axis per protocol, optionally folded with
a perturbation axis) flow straight through ``jax.vmap``.  One jitted
``lax.scan`` evaluates an entire ``[P protocols, B backlogs, M mixes]`` grid
in a single compiled program.  :func:`simulate_grid` is the engine entry
point the axes-first :class:`repro.core.space.DesignSpace` lowers onto;
the retired ``sweep`` front-end survives as the private ``_sweep_impl``
engine body:

    res = _sweep_impl()                         # 5 protocols x 5 mixes
    res = _sweep_impl(mixes=grid, backlogs=[16, 64, 128])
    res.efficiency                              # [P, B, M] (or [P, M])
    flitsim.sweep_perturbed([{}, {"credit_lines": 0.5}])   # sensitivity

``_sweep_pipelining_impl`` batches the Fig-13 model over device counts — and,
when ``ucie_line_ui`` / ``device_line_ui`` are sequences, over the full
``[k x ucie_line_ui x device_line_ui]`` joint grid (faster DRAM generations
behind the logic die).  Compiled executables are memoized in the SHARED
design-space cache (:mod:`repro.core.space`) keyed on (family, grid shape,
static lengths, :class:`repro.core.space.SimConfig`) — a second
identically-shaped sweep from ANY front-end (a ``DesignSpace``
evaluation, a scalar ``simulate_*`` call) reuses the warm executable with
zero retracing, and alternating sim configs never invalidates other
configs' entries.  ``compile_cache_stats()`` exposes this module's slice
of the shared counters; the scalar entry points ``simulate_symmetric`` /
``simulate_asymmetric`` / ``simulate_lpddr6_pipelining`` are thin wrappers
over a ``[1, 1, 1]`` grid, so they share the same cache and numerics
bit-for-bit with the batched grid.

Convergence-adaptive execution (``sim=ADAPTIVE_SIM``)
-----------------------------------------------------
Every front-end accepts a ``sim=`` :class:`repro.core.space.SimConfig`.
The default (:data:`FIXED_SIM`) runs the full fixed horizon — bit-identical
to the pre-config engine and to every pinned golden.  ``ADAPTIVE_SIM``
swaps the ``lax.scan`` cores for chunked ``lax.while_loop`` cores with
batched early exit:

* the loop advances the whole vmapped grid one chunk of C cycles at a
  time (inner ``lax.scan``, optionally unrolled), sampling cumulative and
  time-weighted delivery accumulators at chunk boundaries;
* each cell's *report* reconstructs the fixed engine's warm-window average
  ``[N/4, N]``: the observed ``[N/4, n]`` prefix is kept verbatim and the
  unobserved tail ``[n, N]`` is padded with a triangularly-weighted
  trailing-window steady estimate (triangular weighting suppresses the
  periodic-aliasing error of short windows to second order);
* a cell counts as converged when its report is stable to ``tol`` AND —
  for the symmetric family — its queue/credit pools are not drifting
  (slow write-buffer fill produces metastable plateaus that a pure
  output-stability test cannot distinguish from steady state);
* the loop exits when every cell converged, when the straggler count
  drops below the escalation budget (large grids only — the stragglers
  are then re-simulated EXACTLY at the full fixed horizon in a tiny
  padded flat-cell program), or at the horizon (where the report equals
  the fixed warm-window average by construction — exactly so when the
  chunk count is a multiple of 4, which the divisor selection prefers;
  horizons with no usable chunk divisor fall back to the fixed engine).

``last_run_info()`` exposes the cycles-to-convergence telemetry
(per-family cycles run, straggler counts, per-cell convergence histogram)
that ``bench_flitsim`` reports.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import space as space_mod
from repro.core.space import (
    ADAPTIVE_SIM, FIXED_SIM, PALLAS_SIM, CacheStats, SimConfig,
    cached_program,
)
from repro.core.protocols.chi_ucie import CHIOnUCIe
from repro.core.protocols.cxl_mem import CXLMemOnUCIe
from repro.core.protocols.cxl_mem_opt import CXLMemOptOnUCIe
from repro.core.protocols.hbm_ucie import HBMOnUCIe
from repro.core.protocols.lpddr6_ucie import LPDDR6OnUCIe


def _f32(v) -> jnp.ndarray:
    return jnp.asarray(v, dtype=jnp.float32)


def _check_mix(x: float, y: float) -> None:
    """Reject degenerate mixes loudly (the traced cores would emit NaN)."""
    if x < 0 or y < 0 or x + y <= 0:
        raise ValueError(f"invalid traffic mix x={x} y={y}: need x, y >= 0 "
                         "and x + y > 0")

def _register_params_pytree(cls):
    """Register a frozen params dataclass as a pytree (all fields leaves).

    Lets a *stack* of parameter sets (every field a ``[P]`` array) pass
    through ``jax.vmap`` / ``jax.jit`` like any other array pytree.
    """
    names = tuple(f.name for f in dataclasses.fields(cls))
    jax.tree_util.register_pytree_node(
        cls,
        lambda p: (tuple(getattr(p, n) for n in names), None),
        lambda _, children: cls(*children),
    )
    return cls


def apply_perturbation(obj, pert: Mapping[str, float]):
    """Multiplicatively scale the named fields of a frozen dataclass.

    The shared perturbation core behind every sensitivity axis: the flit
    simulators' ``protocol_param`` (scaling :class:`SymmetricFlitParams` /
    :class:`AsymmetricLaneParams` stacks) and the analytic catalog's
    ``catalog_param`` (scaling :class:`repro.core.ucie.UCIePhy` pJ/b and
    density fields).  Fields ``obj`` doesn't have are ignored — validate
    applicability upstream (:func:`check_perturbation` for flit params,
    ``UCIePhy.perturbed`` for catalog params).
    """
    fields = {f.name for f in dataclasses.fields(type(obj))}
    rep = {k: float(getattr(obj, k)) * float(s)
           for k, s in pert.items() if k in fields}
    return dataclasses.replace(obj, **rep) if rep else obj


class _Stackable:
    """Mixin: stack N parameter sets into one pytree of ``[N]`` f32 arrays."""

    @classmethod
    def stack(cls, params: Sequence["_Stackable"]):
        names = [f.name for f in dataclasses.fields(cls)]
        return cls(*[_f32([getattr(p, n) for p in params]) for n in names])

    def perturbed(self, pert: Mapping[str, float]) -> "_Stackable":
        """Scale the named fields multiplicatively (fields this family
        doesn't have are ignored — validated upstream)."""
        return apply_perturbation(self, pert)


@_register_params_pytree
@dataclasses.dataclass(frozen=True)
class SymmetricFlitParams(_Stackable):
    """Slot geometry for a symmetric flit protocol."""

    g_slots: Any                 # payload-capable slots per flit
    h_slots: Any                 # header-only slots per flit
    reqs_per_h: Any              # requests fitting the header slot
    resps_per_h: Any
    reqs_per_g: Any              # requests per payload slot (header overflow)
    resps_per_g: Any
    data_slots_per_line: Any     # slots per 64 B line
    slot_bits: Any               # payload slot size in bits
    flit_bits: Any = 2048        # 256 B
    #: in-flight read-return credit, in flits' worth of payload slots —
    #: the credit limit is ``credit_lines * g_slots`` slots (default 8
    #: flits, the pre-perturbation constant)
    credit_lines: Any = 8.0
    #: write-buffer depth on the memory side, in flits' worth of payload
    #: slots — the write-request gate is ``write_buffer_lines * g_slots``
    #: slots.  Defaults to ``credit_lines`` (the engine historically
    #: reused the read credit as the write-buffer bound; a distinct field
    #: makes the write path independently perturbable while preserving
    #: the default numerics bit-for-bit).
    write_buffer_lines: Any = None

    def __post_init__(self):
        if self.write_buffer_lines is None:
            object.__setattr__(self, "write_buffer_lines",
                               self.credit_lines)

    @classmethod
    def cxl_unopt(cls) -> "SymmetricFlitParams":
        # 1 H + 14 G usable; 16 B slots; 1 req / 2 resp per slot.
        return cls(g_slots=14, h_slots=1, reqs_per_h=1, resps_per_h=2,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def cxl_opt(cls) -> "SymmetricFlitParams":
        # 15 G + 1 HS (10 B, headers only); 1 req / 4 resp per slot.
        return cls(g_slots=15, h_slots=1, reqs_per_h=1, resps_per_h=4,
                   reqs_per_g=1, resps_per_g=4, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def chi(cls) -> "SymmetricFlitParams":
        # 12 granules of 20 B, no dedicated header slot; 16 B payload/granule.
        return cls(g_slots=12, h_slots=0, reqs_per_h=0, resps_per_h=0,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=160)   # granule is 20 B on the wire


@_register_params_pytree
@dataclasses.dataclass(frozen=True)
class AsymmetricLaneParams(_Stackable):
    """Lane-group geometry for the asymmetric mappings (A/B)."""

    total_lanes: Any
    read_lanes: Any
    write_lanes: Any
    cmd_lanes: Any
    cmd_bits_per_access: Any
    access_bits: Any = 576

    @classmethod
    def lpddr6(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=74, read_lanes=36, write_lanes=24,
                   cmd_lanes=10, cmd_bits_per_access=96)

    @classmethod
    def hbm(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=138, read_lanes=72, write_lanes=36,
                   cmd_lanes=24, cmd_bits_per_access=96)


#: every flit-simulator parameter field a perturbation may scale
PERTURBABLE_FIELDS: Tuple[str, ...] = tuple(sorted(
    {f.name for f in dataclasses.fields(SymmetricFlitParams)}
    | {f.name for f in dataclasses.fields(AsymmetricLaneParams)}))


def check_perturbation(pert: Mapping[str, float]) -> None:
    """Reject ``{field: scale}`` perturbations naming unknown flit-simulator
    parameter fields (catalog perturbations are validated by
    ``UCIePhy.perturbed`` against its own field set)."""
    unknown = sorted(k for k in pert if k not in PERTURBABLE_FIELDS)
    if unknown:
        raise ValueError(f"unknown perturbation fields {unknown}; choose "
                         f"from {PERTURBABLE_FIELDS}")


#: backwards-compatible alias (pre-shared-helper name)
_check_perturbation = check_perturbation


# -- simulator cores (traced params; static lengths only) ---------------------


def _symmetric_stepfn(p: SymmetricFlitParams, x, y, backlog):
    """Single-cycle kernel shared by the fixed and adaptive symmetric
    cores: ``step(core) -> (core', data_slots_delivered_this_cycle)``.

    ``core`` is the queue/credit state ``(rq, wq, wdata, rdata, resp, cr,
    cw)``; the data/warm accounting lives in the mode-specific wrappers so
    the fixed path stays bit-identical to the pre-config engine.
    """
    x, y, backlog = _f32(x), _f32(y), _f32(backlog)
    tot = x + y
    xr = x / tot
    yr = y / tot
    dpl = p.data_slots_per_line
    rdata_limit = p.credit_lines * p.g_slots  # in-flight read credit (slots)
    wbuf_limit = p.write_buffer_lines * p.g_slots  # write-buffer bound
    hdr_cap = p.reqs_per_h * p.h_slots + p.reqs_per_g * p.g_slots
    resp_cap = p.resps_per_h * p.h_slots + p.resps_per_g * p.g_slots
    reqs_per_g = jnp.maximum(_f32(p.reqs_per_g), 1e-9)
    resps_per_g = jnp.maximum(_f32(p.resps_per_g), 1e-9)

    def step(core):
        rq, wq, wdata, rdata, resp, cr, cw = core
        # -- generate traffic to hold the request backlog at `backlog` ------
        deficit = jnp.maximum(backlog - (rq + wq), 0.0)
        cr2 = cr + deficit * xr
        cw2 = cw + deficit * yr
        gen_r = jnp.floor(cr2)
        gen_w = jnp.floor(cw2)
        cr2, cw2 = cr2 - gen_r, cw2 - gen_w
        rq = rq + gen_r
        wq = wq + gen_w

        # -- SoC -> Mem flit: headers first (H then G), data fills the rest -
        # Both request kinds are credit-gated by their data path: reads by
        # the in-flight read-return credit, writes by the write buffer.
        credit_r = jnp.maximum(rdata_limit - rdata, 0.0) / dpl
        credit_w = jnp.maximum(wbuf_limit - wdata, 0.0) / dpl
        rq_elig = jnp.minimum(rq, credit_r)
        wq_elig = jnp.minimum(wq, credit_w)
        sent_req = jnp.minimum(rq_elig + wq_elig, hdr_cap)
        tot_q = jnp.maximum(rq_elig + wq_elig, 1e-9)
        sent_r = sent_req * rq_elig / tot_q
        sent_w = sent_req * wq_elig / tot_q
        g_hdr = (jnp.maximum(sent_req - p.reqs_per_h * p.h_slots, 0.0)
                 / reqs_per_g)
        d_s2m = jnp.minimum(wdata, p.g_slots - g_hdr)
        rq, wq = rq - sent_r, wq - sent_w
        wdata = wdata + sent_w * dpl - d_s2m   # data follows its request
        # a sent read instantly enqueues 4 data slots + 1 response (M2S);
        # a sent write enqueues 1 completion response
        rdata = rdata + sent_r * dpl
        resp = resp + sent_r + sent_w

        # -- Mem -> SoC flit: responses first, read data fills the rest -----
        sent_resp = jnp.minimum(resp, resp_cap)
        g_resp = (jnp.maximum(sent_resp - p.resps_per_h * p.h_slots, 0.0)
                  / resps_per_g)
        d_m2s = jnp.minimum(rdata, p.g_slots - g_resp)
        resp = resp - sent_resp
        rdata = rdata - d_m2s

        return (rq, wq, wdata, rdata, resp, cr2, cw2), d_s2m + d_m2s

    return step


def _symmetric_core_init():
    return tuple(jnp.zeros((), jnp.float32) for _ in range(7))


def _symmetric_efficiency(p: SymmetricFlitParams, x, y, backlog,
                          n_flits: int):
    """Saturation data efficiency of a symmetric full-duplex link.

    Data bits delivered (both directions, 512 b per line) over raw link
    capacity — directly comparable to the analytic ``bw_eff``.  Headers
    have priority; data fills the remaining G-slots.  Read requests are
    gated by credit-based flow control on the read-data return path (as
    CXL's credit mechanism does); writes by the memory-side write buffer.

    Fixed-horizon core: runs exactly ``n_flits`` cycles and averages over
    the warm window (the last three quarters) — the reference numerics
    every golden is pinned against.
    """
    kernel = _symmetric_stepfn(p, x, y, backlog)

    def step(carry, _):
        core, data_slots, warm_slots, warm = carry
        core, new_data = kernel(core)
        # warm-up: skip the first quarter of the run when accumulating
        warm = warm + 1
        is_warm = (warm > n_flits // 4).astype(jnp.float32)
        data_slots = data_slots + new_data * is_warm
        warm_slots = warm_slots + is_warm
        return (core, data_slots, warm_slots, warm), None

    init = (_symmetric_core_init(), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    (_, data_slots, warm_slots, _), _ = jax.lax.scan(
        step, init, None, length=n_flits)
    # data bits delivered over both-direction capacity during warm window
    data_bits = data_slots * 128.0           # 16 B of payload per data slot
    cap_bits = 2.0 * warm_slots * _f32(p.flit_bits)
    return data_bits / cap_bits


def _asymmetric_stepfn(p: AsymmetricLaneParams, x, y):
    """Single-access kernel shared by the fixed and adaptive asymmetric
    cores: ``step(core) -> core'`` over ``(t_read, t_write, t_cmd,
    credit)``."""
    x, y = _f32(x), _f32(y)
    xr = x / (x + y)
    r_ui = _f32(p.access_bits) / p.read_lanes
    w_ui = _f32(p.access_bits) / p.write_lanes
    c_ui = _f32(p.cmd_bits_per_access) / p.cmd_lanes

    def step(core):
        t_read, t_write, t_cmd, credit = core
        credit = credit + xr
        is_read = credit >= 1.0
        credit = jnp.where(is_read, credit - 1.0, credit)
        t_read = t_read + jnp.where(is_read, r_ui, 0.0)
        t_write = t_write + jnp.where(is_read, 0.0, w_ui)
        t_cmd = t_cmd + c_ui
        return (t_read, t_write, t_cmd, credit)

    return step


def _asymmetric_efficiency(p: AsymmetricLaneParams, x, y, n_accesses: int):
    """Lane-occupancy simulation: issue n accesses in x:y ratio, measure
    512*n/(total_lanes*T) — comparable to eq (3).  Fixed-horizon core."""
    kernel = _asymmetric_stepfn(p, x, y)

    def step(carry, _):
        return kernel(carry), None

    init = (jnp.zeros((), jnp.float32),) * 4
    (t_r, t_w, t_c, _), _ = jax.lax.scan(step, init, None, length=n_accesses)
    t_total = jnp.maximum(jnp.maximum(t_r, t_w), t_c)
    return 512.0 * n_accesses / (p.total_lanes * t_total)


def _pipelining_utilization(k, ucie_line_ui, device_line_ui,
                            max_k: int, n_lines: int):
    """Appendix Fig 13: k x12 LPDDR6 devices time-multiplexed behind the
    logic die.  The UCIe link moves a 64 B line in ``ucie_line_ui`` UI; each
    device sources a line every ``device_line_ui`` UI.  Returns link data
    utilization — 1.0 at k = 4.

    Commands are pipelined (ACT/RD interleaved at 8-bit granularity, Fig 13)
    so the command bus never limits: we model device ready-times only.
    The device ready-time table is padded to ``max_k`` so one executable
    serves every batched ``k`` (entries past k are never addressed).
    """
    k = jnp.asarray(k, jnp.int32)
    ucie_line_ui = _f32(ucie_line_ui)
    device_line_ui = _f32(device_line_ui)
    kernel = _pipelining_stepfn(k, ucie_line_ui, device_line_ui)

    def step(carry, _):
        return kernel(carry), None

    init = _pipelining_core_init(max_k)
    (_, last_finish, _), _ = jax.lax.scan(step, init, None, length=n_lines)
    return n_lines * ucie_line_ui / last_finish


def _pipelining_stepfn(k, ucie_line_ui, device_line_ui):
    """Single-line kernel shared by the fixed and adaptive pipelining
    cores: ``step(core) -> core'`` over ``(dev_ready, last_finish, idx)``."""
    def step(core):
        dev_ready, link_free, idx = core
        dev = idx % k
        start = jnp.maximum(dev_ready[dev], link_free)
        finish = start + ucie_line_ui
        dev_ready = dev_ready.at[dev].set(start + device_line_ui)
        return (dev_ready, finish, idx + 1)

    return step


def _pipelining_core_init(max_k: int):
    return (jnp.zeros((max_k,), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32))


# -- batched grid programs ----------------------------------------------------


def _symmetric_grid(pstack, x, y, backlogs, *, n_flits: int):
    """[P params] x [B backlogs] x [M mixes] -> efficiency [P, B, M]."""
    point = lambda p, b, xx, yy: _symmetric_efficiency(p, xx, yy, b, n_flits)
    over_m = jax.vmap(point, in_axes=(None, None, 0, 0))
    over_bm = jax.vmap(over_m, in_axes=(None, 0, None, None))
    over_pbm = jax.vmap(over_bm, in_axes=(0, None, None, None))
    return over_pbm(pstack, backlogs, x, y)


def _asymmetric_grid(pstack, x, y, *, n_accesses: int):
    """[P params] x [M mixes] -> efficiency [P, M] (backlog-independent)."""
    point = lambda p, xx, yy: _asymmetric_efficiency(p, xx, yy, n_accesses)
    over_m = jax.vmap(point, in_axes=(None, 0, 0))
    return jax.vmap(over_m, in_axes=(0, None, None))(pstack, x, y)


def _pipelining_grid(ks, ucie_line_uis, device_line_uis, *, max_k: int,
                     n_lines: int):
    """[K device-counts] x [U link-UIs] x [D device-UIs] -> utilization
    [K, U, D] — the joint faster-DRAM-generations sweep."""
    point = lambda k, u, d: _pipelining_utilization(k, u, d, max_k, n_lines)
    over_d = jax.vmap(point, in_axes=(None, None, 0))
    over_ud = jax.vmap(over_d, in_axes=(None, 0, None))
    over_kud = jax.vmap(over_ud, in_axes=(0, None, None))
    return over_kud(ks, ucie_line_uis, device_line_uis)


# -- trace-scan cores (the DesignSpace ``trace`` axis) ------------------------
#
# A trace is a sequence of (read_fraction, backlog) phases; the trace-scan
# cores run the phases BACK TO BACK through the shared single-cycle step
# kernels, carrying the queue/credit state across every phase boundary —
# a write-buffer filled by a prefill burst drains INTO the next decode
# phase instead of being reset, so backlog transients are simulated, not
# assumed away.  Every phase runs the same static ``cycles`` count (one
# executable per (grid shape, phase count, cycles)); phase DURATIONS are
# aggregation weights applied host-side by the design space.
#
# Accounting resets per phase; phase 0 keeps the fixed engine's quarter
# warm-up (so a SINGLE-phase trace is bit-identical to the fixed static
# cell) and later phases count every cycle — their "warm-up" is the real
# carried transient.


def _symmetric_trace_point(p, xs, ys, bls, *, n_phases: int, cycles: int):
    """Per-phase efficiency ``[N]`` of one symmetric cell over a phase
    sequence ``xs / ys / bls`` ``[N]``, queue/credit state carried."""

    def phase(core, inp):
        x, yv, b, thresh = inp
        kernel = _symmetric_stepfn(p, x, yv, b)

        def step(carry, _):
            c, data_slots, warm_slots, warm = carry
            c, new_data = kernel(c)
            warm = warm + 1
            is_warm = (warm > thresh).astype(jnp.float32)
            data_slots = data_slots + new_data * is_warm
            warm_slots = warm_slots + is_warm
            return (c, data_slots, warm_slots, warm), None

        init = (core, jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
        (core, data_slots, warm_slots, _), _ = jax.lax.scan(
            step, init, None, length=cycles)
        data_bits = data_slots * 128.0
        cap_bits = 2.0 * warm_slots * _f32(p.flit_bits)
        return core, data_bits / cap_bits

    threshs = jnp.concatenate([
        jnp.full((1,), cycles // 4, jnp.int32),
        jnp.zeros((n_phases - 1,), jnp.int32)])
    _, effs = jax.lax.scan(phase, _symmetric_core_init(),
                           (xs, ys, bls, threshs))
    return effs


def _symmetric_trace_grid(pstack, xs, ys, bls, *, n_phases: int,
                          cycles: int):
    """[P params] x [T traces] -> per-phase efficiency [P, T, N]."""
    point = lambda p, xr, yr, br: _symmetric_trace_point(
        p, xr, yr, br, n_phases=n_phases, cycles=cycles)
    over_t = jax.vmap(point, in_axes=(None, 0, 0, 0))
    return jax.vmap(over_t, in_axes=(0, None, None, None))(pstack, xs, ys,
                                                           bls)


def _asymmetric_trace_point(p, xs, ys, *, n_phases: int, cycles: int):
    """Per-phase efficiency ``[N]`` of one asymmetric cell: lane clocks
    and the read/write credit accumulator carry across phases; each
    phase's efficiency comes from its lane-time DELTA."""

    def phase(carry, inp):
        core, t_prev = carry
        x, yv = inp
        kernel = _asymmetric_stepfn(p, x, yv)

        def step(c, _):
            return kernel(c), None

        core, _ = jax.lax.scan(step, core, None, length=cycles)
        t_r, t_w, t_c, _ = core
        t_total = jnp.maximum(jnp.maximum(t_r, t_w), t_c)
        eff = 512.0 * cycles / (p.total_lanes * (t_total - t_prev))
        return (core, t_total), eff

    init = ((jnp.zeros((), jnp.float32),) * 4, jnp.zeros((), jnp.float32))
    _, effs = jax.lax.scan(phase, init, (xs, ys))
    return effs


def _asymmetric_trace_grid(pstack, xs, ys, *, n_phases: int, cycles: int):
    """[P params] x [T traces] -> per-phase efficiency [P, T, N]."""
    point = lambda p, xr, yr: _asymmetric_trace_point(
        p, xr, yr, n_phases=n_phases, cycles=cycles)
    over_t = jax.vmap(point, in_axes=(None, 0, 0))
    return jax.vmap(over_t, in_axes=(0, None, None))(pstack, xs, ys)


# -- convergence-adaptive chunked cores (SimConfig mode="adaptive") -----------
#
# Each adaptive core is a ``lax.while_loop`` over chunks of C cycles (inner
# ``lax.scan`` with ``unroll=``) carrying per-cell running estimates; the
# WHOLE vmapped grid exits as soon as the slowest cell converges (or the
# straggler set shrinks below the escalation budget / the horizon is hit).
#
# The per-cell estimate is NOT the raw steady-state mean: it reconstructs
# the fixed engine's warm-window average ``[N/4, N]`` so the adaptive value
# tracks the fixed-mode value, transients included:
#
#   report = ( observed [N/4, n] prefix * its width
#            + mu_hat * (N - n) ) / (N - N/4)
#
# where ``mu_hat`` is a TRIANGULARLY-weighted trailing-window mean (the
# triangular window suppresses the O(T/w) periodic-aliasing error of a
# rectangular window to O((T/w)^2)), formed from cumulative data and
# time-weighted-data accumulators sampled at chunk boundaries.  A cell is
# converged when its report is stable to ``tol`` AND its queue/credit
# pools are not drifting (the drift guard catches slow write-buffer-fill
# metastability a short stability test cannot see).  On grids of >=
# _ESCALATION_MIN_CELLS cells the loop may exit with up to cells //
# _ESCALATION_BUDGET_DIV unconverged stragglers, which are re-simulated
# EXACTLY (full fixed horizon, bit-identical numerics) in a tiny padded
# flat-cell program — so a handful of slow cells cannot hold the whole
# grid at the full horizon.

#: chunks between pool snapshots for the drift guard
_DRIFT_SPAN = 3
#: max pool movement per chunk (slots) still considered "steady" — steady
#: boundary aliasing measures <= ~1.4 slots/chunk; slow-fill transients
#: measure 8-34 slots/chunk
_DRIFT_TOL_SLOTS = 2.0
#: never exit before this many chunks (two comparable reports + warm-up)
_MIN_EXIT_CHUNKS = 4
#: straggler escalation only pays off on grids at least this large (on
#: small grids the fixed per-cycle dispatch cost of a second full-horizon
#: pass outweighs the saved chunks)
_ESCALATION_MIN_CELLS = 256
#: max stragglers the early exit may leave behind: cells // this
_ESCALATION_BUDGET_DIV = 8


def _divisor_chunk(horizon: int, chunk: int) -> int:
    """Effective chunk: near ``horizon / 16`` (so per-chunk estimate
    overhead stays amortized for long horizons), at least the configured
    ``chunk``, at most ``horizon / 8`` (so short horizons still get >= 8
    convergence checks), snapped down to an exact divisor of ``horizon``
    so the chunked loop lands on the fixed horizon precisely.

    Divisors making the chunk count a multiple of 4 are preferred — then
    the reconstructed warm window starts exactly at ``horizon // 4`` and
    the at-horizon report equals the fixed warm-window average.  Returns
    a value < 8 when ``horizon`` has no usable divisor (e.g. a prime);
    the runners fall back to the fixed engine in that case rather than
    degrade to per-cycle chunking."""
    horizon = int(horizon)
    cap = min(max(int(chunk), horizon // 16), max(horizon // 8, 1))
    best = 1
    for c in range(cap, 7, -1):
        if horizon % c:
            continue
        if (horizon // c) % 4 == 0:
            return c
        best = max(best, c)
    return best


def _tri_window_mean(Dh, TDh, k, m, chunk: float, denom_per_cycle):
    """Triangular-weighted mean of the per-cycle delivery over the chunk
    window ``(m, k]`` (apex at the midpoint), from cumulative ``D`` and
    time-weighted ``TD = sum(t * d_t)`` boundary histories."""
    mid = (m + k + 1) // 2
    idx = lambda H, i: jax.lax.dynamic_index_in_dim(H, i, axis=0,
                                                    keepdims=False)
    D_m, D_mid, D_k = idx(Dh, m), idx(Dh, mid), idx(Dh, k)
    TD_m, TD_mid, TD_k = idx(TDh, m), idx(TDh, mid), idx(TDh, k)
    b_i = m.astype(jnp.float32) * chunk
    b_m = mid.astype(jnp.float32) * chunk
    b_j = k.astype(jnp.float32) * chunk
    c1 = b_m - b_i
    c2 = b_j - b_m
    w_sum = c1 * (c1 + 1.0) / 2.0 + c2 * (c2 - 1.0) / 2.0
    num = ((TD_mid - TD_m) - b_i * (D_mid - D_m)
           + b_j * (D_k - D_mid) - (TD_k - TD_mid))
    return num / (jnp.maximum(w_sum, 1.0) * denom_per_cycle)


def _symmetric_grid_adaptive(pstack, x, y, backlogs, *, n_flits: int,
                             chunk: int, unroll: int, tol: float,
                             budget: int):
    """Chunked early-exit symmetric core over the ``[P, B, M]`` grid.

    Returns ``(report, converged, chunks_run, conv_at_chunk)`` where
    ``report`` reconstructs the fixed warm-window average (see the module
    section comment), ``converged`` marks cells whose report is trusted,
    and ``conv_at_chunk`` is each cell's first stable chunk (-1 = never).
    """
    P = pstack.g_slots.shape[0]
    B = backlogs.shape[0]
    M = x.shape[0]
    K = n_flits // chunk
    K0 = max(K // 4, 1)           # fixed warm window starts at chunk K0
    min_k = max(_MIN_EXIT_CHUNKS, K0 + 1)
    ch = jnp.float32(chunk)
    fb = pstack.flit_bits[:, None, None]
    denom = 2.0 * fb / 128.0      # capacity bits per cycle / bits per slot

    def cell_chunk(p, b, xx, yy, core, D, TD, t):
        kernel = _symmetric_stepfn(p, xx, yy, b)

        def step(c, _):
            core, D, TD, t = c
            core, nd = kernel(core)
            t = t + 1.0
            D = D + nd
            TD = TD + t * nd
            return (core, D, TD, t), None

        (core, D, TD, t), _ = jax.lax.scan(
            step, (core, D, TD, t), None, length=chunk, unroll=unroll)
        return core, D, TD, t

    over_m = jax.vmap(cell_chunk, in_axes=(None, None, 0, 0, 0, 0, 0, 0))
    over_bm = jax.vmap(over_m, in_axes=(None, 0, None, None, 0, 0, 0, 0))
    over_pbm = jax.vmap(over_bm, in_axes=(0, None, None, None, 0, 0, 0, 0))

    def report(k, Dh, TDh):
        m = jnp.maximum(k - 4, (k + 1) // 2)
        mu = _tri_window_mean(Dh, TDh, k, m, ch, denom)
        D_K0 = Dh[K0]
        D_k = jax.lax.dynamic_index_in_dim(Dh, k, axis=0, keepdims=False)
        wA = jnp.maximum((k - K0).astype(jnp.float32), 1.0) * ch
        A = (D_k - D_K0) / (wA * denom)
        kf = (k - K0).astype(jnp.float32)
        return jnp.where(k > K0,
                         (A * kf + mu * (K - k).astype(jnp.float32))
                         / float(K - K0), mu)

    zeros = lambda: jnp.zeros((P, B, M), jnp.float32)

    def body(state):
        (k, core, D, TD, t, Dh, TDh, Ph, rep, conv, conv_at, unconv) = state
        core, D, TD, t = over_pbm(pstack, backlogs, x, y, core, D, TD, t)
        k = k + 1
        Dh = Dh.at[k].set(D)
        TDh = TDh.at[k].set(TD)
        pools = jnp.stack(core[:5])          # rq, wq, wdata, rdata, resp
        Ph = Ph.at[k].set(pools)
        new_rep = report(k, Dh, TDh)
        prev_pools = jax.lax.dynamic_index_in_dim(
            Ph, jnp.maximum(k - _DRIFT_SPAN, 0), axis=0, keepdims=False)
        drift = jnp.max(jnp.abs(pools - prev_pools), axis=0) / _DRIFT_SPAN
        delta = jnp.abs(new_rep - rep) / jnp.maximum(jnp.abs(new_rep),
                                                     1e-9)
        conv = ((delta <= tol) & (drift < _DRIFT_TOL_SLOTS)
                & (k >= min_k) & (k > _DRIFT_SPAN)) | (k >= K)
        conv_at = jnp.where((conv_at < 0) & conv, k, conv_at)
        unconv = jnp.sum(jnp.where(conv, 0, 1))
        return (k, core, D, TD, t, Dh, TDh, Ph, new_rep, conv, conv_at,
                unconv)

    def cond(state):
        k, unconv = state[0], state[-1]
        return (k < K) & (unconv > budget)

    init = (jnp.zeros((), jnp.int32),
            tuple(zeros() for _ in range(7)),
            zeros(), zeros(), zeros(),
            jnp.zeros((K + 1, P, B, M), jnp.float32),
            jnp.zeros((K + 1, P, B, M), jnp.float32),
            jnp.zeros((K + 1, 5, P, B, M), jnp.float32),
            zeros(), jnp.zeros((P, B, M), bool),
            -jnp.ones((P, B, M), jnp.int32),
            jnp.asarray(P * B * M + budget + 1, jnp.int32))
    (k, _, _, _, _, _, _, _, rep, conv, conv_at, _) = jax.lax.while_loop(
        cond, body, init)
    return rep, conv, k, conv_at


def _symmetric_cells_grid(pcells, xs, ys, bs, *, n_flits: int):
    """Flat per-cell fixed-horizon program for straggler escalation: each
    cell carries its own (param row, mix, backlog) — numerics identical to
    the fixed grid core."""
    point = lambda p, xx, yy, b: _symmetric_efficiency(p, xx, yy, b,
                                                       n_flits)
    return jax.vmap(point)(pcells, xs, ys, bs)


def _asymmetric_grid_adaptive(pstack, x, y, *, n_accesses: int, chunk: int,
                              unroll: int, tol: float, budget: int):
    """Chunked early-exit asymmetric core over the ``[P, M]`` grid.

    The busiest-lane time grows linearly in steady state, so the report
    extrapolates the fixed-horizon value ``512 N / (lanes * T(N))`` from
    the observed ``T(n)`` plus the trailing slope — killing the ``C/n``
    tail a plain cumulative estimate would carry.
    """
    P = pstack.total_lanes.shape[0]
    M = x.shape[0]
    K = n_accesses // chunk
    min_k = _MIN_EXIT_CHUNKS
    ch = jnp.float32(chunk)
    lanes = pstack.total_lanes[:, None]

    def cell_chunk(p, xx, yy, core):
        kernel = _asymmetric_stepfn(p, xx, yy)

        def step(c, _):
            return kernel(c), None

        core, _ = jax.lax.scan(step, core, None, length=chunk,
                               unroll=unroll)
        return core

    over_m = jax.vmap(cell_chunk, in_axes=(None, 0, 0, 0))
    over_pm = jax.vmap(over_m, in_axes=(0, None, None, 0))

    def report(k, Th):
        T_k = jax.lax.dynamic_index_in_dim(Th, k, axis=0, keepdims=False)
        ahat = (T_k - Th[1]) / jnp.maximum(
            (k - 1).astype(jnp.float32) * ch, 1.0)
        tail = (K - k).astype(jnp.float32) * ch
        return 512.0 * n_accesses / (lanes * jnp.maximum(
            T_k + ahat * tail, 1e-9))

    def body(state):
        k, core, Th, rep, conv, conv_at, unconv = state
        core = over_pm(pstack, x, y, core)
        k = k + 1
        T = jnp.maximum(jnp.maximum(core[0], core[1]), core[2])
        Th = Th.at[k].set(T)
        new_rep = report(k, Th)
        delta = jnp.abs(new_rep - rep) / jnp.maximum(jnp.abs(new_rep),
                                                     1e-9)
        conv = ((delta <= tol) & (k >= min_k)) | (k >= K)
        conv_at = jnp.where((conv_at < 0) & conv, k, conv_at)
        unconv = jnp.sum(jnp.where(conv, 0, 1))
        return (k, core, Th, new_rep, conv, conv_at, unconv)

    def cond(state):
        k, unconv = state[0], state[-1]
        return (k < K) & (unconv > budget)

    zeros = lambda: jnp.zeros((P, M), jnp.float32)
    init = (jnp.zeros((), jnp.int32),
            tuple(zeros() for _ in range(4)),
            jnp.zeros((K + 1, P, M), jnp.float32),
            zeros(), jnp.zeros((P, M), bool),
            -jnp.ones((P, M), jnp.int32),
            jnp.asarray(P * M + budget + 1, jnp.int32))
    (k, _, _, rep, conv, conv_at, _) = jax.lax.while_loop(cond, body, init)
    return rep, conv, k, conv_at


def _asymmetric_cells_grid(pcells, xs, ys, *, n_accesses: int):
    """Flat per-cell fixed-horizon asymmetric program (escalation)."""
    point = lambda p, xx, yy: _asymmetric_efficiency(p, xx, yy, n_accesses)
    return jax.vmap(point)(pcells, xs, ys)


def _pipelining_grid_adaptive(ks, ucie_line_uis, device_line_uis, *,
                              max_k: int, n_lines: int, chunk: int,
                              unroll: int, tol: float):
    """Chunked early-exit Fig-13 pipelining core over ``[K, U, D]``.

    Same linear-growth extrapolation as the asymmetric core (the link
    free-time grows exactly linearly once the k-device rotation fills).
    """
    Kk = ks.shape[0]
    U = ucie_line_uis.shape[0]
    Dn = device_line_uis.shape[0]
    K = n_lines // chunk
    min_k = min(_MIN_EXIT_CHUNKS, K)
    ch = jnp.float32(chunk)
    ucie = ucie_line_uis[None, :, None]

    def cell_chunk(k_dev, u, d, core):
        kernel = _pipelining_stepfn(k_dev, u, d)

        def step(c, _):
            return kernel(c), None

        core, _ = jax.lax.scan(step, core, None, length=chunk,
                               unroll=unroll)
        return core

    over_d = jax.vmap(cell_chunk, in_axes=(None, None, 0, 0))
    over_ud = jax.vmap(over_d, in_axes=(None, 0, None, 0))
    over_kud = jax.vmap(over_ud, in_axes=(0, None, None, 0))

    def report(k, Th):
        T_k = jax.lax.dynamic_index_in_dim(Th, k, axis=0, keepdims=False)
        ahat = (T_k - Th[1]) / jnp.maximum(
            (k - 1).astype(jnp.float32) * ch, 1.0)
        tail = (K - k).astype(jnp.float32) * ch
        return n_lines * ucie / jnp.maximum(T_k + ahat * tail, 1e-9)

    def body(state):
        k, core, Th, rep, conv, conv_at, unconv = state
        core = over_kud(ks, ucie_line_uis, device_line_uis, core)
        k = k + 1
        Th = Th.at[k].set(core[1])
        new_rep = report(k, Th)
        delta = jnp.abs(new_rep - rep) / jnp.maximum(jnp.abs(new_rep),
                                                     1e-9)
        conv = ((delta <= tol) & (k >= min_k)) | (k >= K)
        conv_at = jnp.where((conv_at < 0) & conv, k, conv_at)
        unconv = jnp.sum(jnp.where(conv, 0, 1))
        return (k, core, Th, new_rep, conv, conv_at, unconv)

    def cond(state):
        k, unconv = state[0], state[-1]
        return (k < K) & (unconv > 0)

    init = (jnp.zeros((), jnp.int32),
            (jnp.zeros((Kk, U, Dn, max_k), jnp.float32),
             jnp.zeros((Kk, U, Dn), jnp.float32),
             jnp.zeros((Kk, U, Dn), jnp.int32)),
            jnp.zeros((K + 1, Kk, U, Dn), jnp.float32),
            jnp.zeros((Kk, U, Dn), jnp.float32),
            jnp.zeros((Kk, U, Dn), bool),
            -jnp.ones((Kk, U, Dn), jnp.int32),
            jnp.asarray(Kk * U * Dn + 1, jnp.int32))
    (k, _, _, rep, conv, conv_at, _) = jax.lax.while_loop(cond, body, init)
    return rep, conv, k, conv_at


# -- shared compile cache (repro.core.space) ---------------------------------


def compile_cache_stats() -> CacheStats:
    """This module's slice of the SHARED design-space compile cache
    (families ``flitsim.*``): hits / misses, one miss == one compile."""
    return space_mod.cache_stats(space_mod.FLITSIM_FAMILIES)


def clear_compile_cache() -> None:
    """Drop this module's cached executables and reset its counters."""
    space_mod.clear_cache(space_mod.FLITSIM_FAMILIES)


#: telemetry from the most recent ADAPTIVE run per engine family —
#: cycles executed, horizon, straggler count, and the cycles-to-convergence
#: histogram the benchmarks report (see :func:`last_run_info`)
_LAST_RUN_INFO: Dict[str, Dict[str, Any]] = {}


def last_run_info() -> Dict[str, Dict[str, Any]]:
    """Per-family telemetry of the most recent adaptive run: ``cycles_run``
    (main-loop chunks executed), ``sequential_depth`` (the run's true
    sequential depth — the horizon whenever a straggler-escalation pass
    ran), ``horizon`` / ``chunk`` / ``stragglers`` / ``cells``, plus a
    ``converged_cycles`` histogram ({cycles: cell count}; stragglers and
    horizon-exits count under ``"horizon"``).

    Engine telemetry (PR 6): ``engine`` (``"xla"`` / ``"pallas"``),
    ``launches`` (device programs the runner dispatched — the pallas host
    loop issues one per chunk plus one per escalation pass; the XLA
    ``while_loop`` cores are a single launch), ``elapsed_s`` (runner wall
    time, device work blocked to completion), and
    ``cycles_per_sec_per_cell`` (executed main-loop cycles per second per
    grid cell — the throughput number the BENCH million-cell row reports).
    The asymmetric periodic detector additionally reports a ``periods``
    histogram ({detected credit period: cell count}).

    Streaming dispatch telemetry (PR 10): ``stream.*`` families carry a
    ``stream`` record — ``dispatches``, ``prefetch`` (bounded in-flight
    depth), ``pad_cells`` (replicated tail cells across all dispatches)
    and ``overlap_frac`` (fraction of host marshalling wall time spent
    while at least one dispatch was in flight on the device).

    Fixed-mode runs do not update it.  The raw arrays are kept lazily on
    device so the hot path pays no host sync; this accessor materializes
    them ONCE per recorded run (the materialized view is memoized, so
    polling telemetry from a dispatch loop never re-syncs)."""
    out: Dict[str, Dict[str, Any]] = {}
    for fam, info in _LAST_RUN_INFO.items():
        cached = info.get("_materialized")
        if cached is not None:
            out[fam] = cached
            continue
        d = {k: v for k, v in info.items() if not k.startswith("_")}
        if d.get("mode") in ("trace", "stream"):
            # trace-scan runs (``family.trace`` keys) and streaming
            # dispatch runs report their counters directly; no
            # convergence histogram
            info["_materialized"] = d
            out[fam] = d
            continue
        chunk = d["chunk"]
        conv_at = np.asarray(info["_conv_at"]).reshape(-1)
        d["cycles_run"] = int(np.asarray(info["_k_exit"])) * chunk
        # the straggler escalation pass runs the FULL horizon, so the
        # run's true sequential depth is the horizon whenever any cell
        # was escalated — cycles_run alone would overstate the depth cut
        d["sequential_depth"] = (d["horizon"] if d["stragglers"]
                                 else d["cycles_run"])
        d["cells"] = int(conv_at.size)
        vals, counts = np.unique(conv_at, return_counts=True)
        d["converged_cycles"] = {
            ("horizon" if v < 0 else str(int(v) * chunk)): int(c)
            for v, c in zip(vals, counts)}
        if d.get("elapsed_s"):
            d["cycles_per_sec_per_cell"] = d["cycles_run"] / d["elapsed_s"]
        if info.get("_periods") is not None:
            p = np.asarray(info["_periods"]).reshape(-1)
            pv, pc = np.unique(p[p > 0], return_counts=True)
            d["periods"] = {int(v): int(c) for v, c in zip(pv, pc)}
        info["_materialized"] = d
        out[fam] = d
    return out


def _record_adaptive(family: str, horizon: int, chunk: int, k_exit,
                     conv_at, stragglers: int, *, engine: str = "xla",
                     launches: int = 1, elapsed_s: Optional[float] = None,
                     periods=None) -> None:
    _LAST_RUN_INFO[family] = {
        "mode": "adaptive", "horizon": int(horizon), "chunk": int(chunk),
        "stragglers": int(stragglers), "engine": engine,
        "launches": int(launches), "elapsed_s": elapsed_s,
        "_k_exit": k_exit, "_conv_at": conv_at, "_periods": periods,
    }


def _record_stream(family: str, *, dispatches: int, prefetch: int,
                   pad_cells: int, overlap_frac: float, cells: int,
                   elapsed_s: Optional[float] = None,
                   marshal_s: Optional[float] = None) -> None:
    """Telemetry for a streaming dispatch run (``stream.*`` families):
    dispatch count, bounded in-flight depth, replicated pad-cell total,
    and the marshal-vs-device overlap fraction (how much of the host's
    index-marshalling wall time ran while a previous chunk was still in
    flight — the async win over the strictly sequential loop).
    ``marshal_s`` is the total host marshalling wall time, so
    ``marshal_s / elapsed_s`` bounds the async win available."""
    _LAST_RUN_INFO[family] = {
        "mode": "stream", "dispatches": int(dispatches),
        "prefetch": int(prefetch), "pad_cells": int(pad_cells),
        "overlap_frac": float(overlap_frac), "cells": int(cells),
        "elapsed_s": elapsed_s, "marshal_s": marshal_s,
    }


def _record_trace(family: str, phases: int, cycles: int,
                  cells: int) -> None:
    """Telemetry for a trace-scan run, keyed ``family + ".trace"`` so it
    never clobbers the same family's adaptive record: per-phase cycle
    count, total cycles, grid cells simulated, and the state-carry depth
    (cycles whose initial state came from a PREVIOUS phase)."""
    _LAST_RUN_INFO[family + ".trace"] = {
        "mode": "trace", "phases": int(phases),
        "cycles_per_phase": int(cycles),
        "cycles_run": int(phases) * int(cycles),
        "trace_cells": int(cells),
        "state_carry_depth": (int(phases) - 1) * int(cycles),
        "engine": "xla",
    }


def _escalate_stragglers(family: str, cells_grid_fn, horizon: int, rep,
                         conv_np: np.ndarray, args_builder):
    """Re-simulate unconverged straggler cells EXACTLY at the full fixed
    horizon in a padded flat-cell program, scattering the exact values
    back over the adaptive reports.  ``args_builder(idx)`` maps the padded
    ``[S, ndim]`` straggler indices to the flat-cell program's arguments.
    """
    idx = _pad_pow2(np.argwhere(~conv_np))
    args = args_builder(idx)
    cells_fn = cached_program(family, ("cells", idx.shape[0], horizon),
                              cells_grid_fn, args)
    exact = np.asarray(cells_fn(*args))
    rep_np = np.asarray(rep).copy()
    rep_np[~conv_np] = exact[:int((~conv_np).sum())]
    return jnp.asarray(rep_np)


def _escalation_budget(cells: int, chunk: int, horizon: int) -> int:
    """Max stragglers the early exit may strand: roughly the break-even
    point where re-simulating S cells at the full horizon costs what one
    more full-grid chunk would (S * horizon ~= cells * chunk), floored by
    the cells // _ESCALATION_BUDGET_DIV policy cap."""
    if cells < _ESCALATION_MIN_CELLS:
        return 0
    return min(cells // _ESCALATION_BUDGET_DIV,
               max((cells * chunk) // horizon, 1))


def _gather_cells(pstack, rows) -> Any:
    """Per-cell parameter pytree: row ``rows[i]`` of every stacked leaf."""
    return jax.tree_util.tree_map(
        lambda leaf: jnp.asarray(np.asarray(leaf)[rows]), pstack)


def _pad_pow2(idx: np.ndarray) -> np.ndarray:
    """Pad straggler indices to the next power-of-two bucket (repeating
    the first row) so escalation compiles once per bucket size."""
    bucket = 1 << max(idx.shape[0] - 1, 0).bit_length()
    return np.concatenate([idx, np.repeat(idx[:1], bucket - idx.shape[0],
                                          axis=0)])


# -- fused-kernel engine (SimConfig engine="pallas") + periodic detector ------
#
# The row-stacked operand layouts and the per-chunk compute contracts live
# in repro.kernels.flit_sim (ref.py documents them; kernel.py is the
# Pallas transcription sharing the same compute bodies).  The kernels
# package imports this module for the step functions, so everything below
# imports it lazily.
#
# The asymmetric family additionally gets a PERIOD-EXACT detector (both
# engines): the credit accumulator advances by the rational read fraction
# x/(x+y) each access, so the credit state — which alone determines every
# future lane increment — is exactly periodic with denominator
# q = (x+y)/gcd(x,y).  The runner observes ~2 maximal periods
# (ref.PERIOD_OBS sequential steps), detects each cell's period from the
# credit phase, extrapolates the per-lane busy times exactly to the full
# horizon, and escalates the (rare) undetected cells through the usual
# exact full-horizon path — closing the asymmetric warm window at ~128
# steps instead of the chunked core's ~1280/4096.
#
# The symmetric family mirrors it (PR 10) with a stricter certificate:
# the pool/credit core's proportional-split division breaks bitwise
# orbits at saturated backlogs, so the detector requires an EXACT f32
# match of the full 7-component core against the lagged observation row
# plus an integer-valued delivery window.  Where that holds (low-backlog
# and degenerate-mix cells lock into period <= PERIOD_MAX orbits) the
# warm-window delivery sum extrapolates in closed form BIT-IDENTICALLY
# to the fixed engine; saturated grids are mostly undetected and fall
# through to the chunked adaptive core unchanged.


def _sym_param_rows(pstack, x, y, backlogs):
    """Row-stack a symmetric grid into the kernels' [SYM_ROWS, P*B*M]
    layout (cell order matches ``rep.reshape(P, B, M)``)."""
    from repro.kernels.flit_sim import ref as fs_ref
    P, B, M = pstack.g_slots.shape[0], backlogs.shape[0], x.shape[0]
    rows = [jnp.repeat(_f32(getattr(pstack, f.name)), B * M)
            for f in dataclasses.fields(SymmetricFlitParams)]
    rows.append(jnp.tile(_f32(x), P * B))
    rows.append(jnp.tile(_f32(y), P * B))
    rows.append(jnp.tile(jnp.repeat(_f32(backlogs), M), P))
    pad = jnp.zeros_like(rows[0])
    return jnp.stack(rows + [pad] * (fs_ref.SYM_ROWS - len(rows)))


def _asym_param_rows(pstack, x, y):
    """Row-stack an asymmetric grid into [ASYM_ROWS, P*M]."""
    from repro.kernels.flit_sim import ref as fs_ref
    P, M = pstack.total_lanes.shape[0], x.shape[0]
    rows = [jnp.repeat(_f32(getattr(pstack, f.name)), M)
            for f in dataclasses.fields(AsymmetricLaneParams)]
    rows.append(jnp.tile(_f32(x), P))
    rows.append(jnp.tile(_f32(y), P))
    pad = jnp.zeros_like(rows[0])
    return jnp.stack(rows + [pad] * (fs_ref.ASYM_ROWS - len(rows)))


def _pipe_param_rows(ks, ucie_line_uis, device_line_uis):
    """Row-stack a pipelining grid into [ASYM_ROWS, K*U*D]."""
    from repro.kernels.flit_sim import ref as fs_ref
    Kk, U, Dn = (ks.shape[0], ucie_line_uis.shape[0],
                 device_line_uis.shape[0])
    rows = [jnp.repeat(_f32(ks), U * Dn),
            jnp.tile(jnp.repeat(_f32(ucie_line_uis), Dn), Kk),
            jnp.tile(_f32(device_line_uis), Kk * U)]
    pad = jnp.zeros_like(rows[0])
    return jnp.stack(rows + [pad] * (fs_ref.ASYM_ROWS - len(rows)))


def _scal_row(values) -> jnp.ndarray:
    """Broadcast-scalar [1, SCAL_COLS] operand from leading values."""
    from repro.kernels.flit_sim import ref as fs_ref
    row = np.zeros((1, fs_ref.SCAL_COLS), np.float32)
    row[0, :len(values)] = values
    return jnp.asarray(row)


def _run_asymmetric_periodic(pstack, x, y, horizon: int, sim: SimConfig):
    """Period-exact asymmetric run (one launch + exact escalation of
    undetected cells).  Returns the report grid, or ``None`` when the
    grid is mostly aperiodic and the chunked core is the better tool."""
    from repro.kernels.flit_sim import ops as fs_ops
    from repro.kernels.flit_sim import ref as fs_ref
    P, M = pstack.total_lanes.shape[0], x.shape[0]
    cells = P * M
    t0 = time.perf_counter()
    # the row-stacking runs INSIDE the cached program: the whole periodic
    # run is one dispatch from the host's point of view
    if sim.engine == "pallas":
        tile, cpad = fs_ops.tile_for(cells)

        def build(ps, xs, ys):
            rows = fs_ops.pad_cells(_asym_param_rows(ps, xs, ys), cpad)
            return fs_ops.asymmetric_periodic_launch(
                rows, n_accesses=horizon, tile=tile, cells=cells)[0]
    else:
        def build(ps, xs, ys):
            return fs_ref.asymmetric_periodic_compute(
                _asym_param_rows(ps, xs, ys), n_accesses=horizon)
    fn = cached_program("flitsim.asymmetric",
                        (P, M, horizon, "periodic") + sim.key(),
                        build, (pstack, x, y))
    out = fn(pstack, x, y)
    det_np = np.asarray(out[1, :cells]) > 0.5
    undet = int((~det_np).sum())
    if undet > max(cells // 4, 8):
        return None
    rep = out[0, :cells].reshape(P, M)
    launches = 1
    if undet:
        conv_np = det_np.reshape(P, M)
        rep = _escalate_stragglers(
            "flitsim.asymmetric",
            functools.partial(_asymmetric_cells_grid, n_accesses=horizon),
            horizon, rep, conv_np,
            lambda idx: (_gather_cells(pstack, idx[:, 0]),
                         jnp.asarray(np.asarray(x)[idx[:, 1]]),
                         jnp.asarray(np.asarray(y)[idx[:, 1]])))
        launches += 1
    jax.block_until_ready(rep)
    conv_at = np.where(det_np, 1, -1).astype(np.int32).reshape(P, M)
    _record_adaptive("flitsim.asymmetric", horizon, fs_ref.PERIOD_OBS, 1,
                     conv_at, undet, engine=sim.engine, launches=launches,
                     elapsed_s=time.perf_counter() - t0,
                     periods=out[2, :cells])
    return rep


def _run_symmetric_periodic(pstack, x, y, backlogs, horizon: int,
                            sim: SimConfig):
    """Period-exact symmetric run (one launch + exact escalation of
    undetected cells).  Detection is an EXACT f32 match of the full
    7-component pool/credit core against a lagged observation row — a
    trajectory certificate, so detected cells reproduce the fixed
    engine's report bit-for-bit.  Returns the report grid, or ``None``
    when the grid is mostly aperiodic (saturated backlogs) and the
    chunked core is the better tool."""
    from repro.kernels.flit_sim import ops as fs_ops
    from repro.kernels.flit_sim import ref as fs_ref
    P, B, M = pstack.g_slots.shape[0], backlogs.shape[0], x.shape[0]
    cells = P * B * M
    t0 = time.perf_counter()
    # the row-stacking runs INSIDE the cached program: the whole periodic
    # run is one dispatch from the host's point of view
    if sim.engine == "pallas":
        tile, cpad = fs_ops.tile_for(cells, fs_ops.SYM_PERIODIC_MAX_TILE)

        def build(ps, xs, ys, bs):
            rows = fs_ops.pad_cells(_sym_param_rows(ps, xs, ys, bs), cpad)
            return fs_ops.symmetric_periodic_launch(
                rows, n_flits=horizon, tile=tile, cells=cells)[0]
    else:
        def build(ps, xs, ys, bs):
            return fs_ref.symmetric_periodic_compute(
                _sym_param_rows(ps, xs, ys, bs), n_flits=horizon)
    fn = cached_program("flitsim.symmetric",
                        (P, B, M, horizon, "periodic") + sim.key(),
                        build, (pstack, x, y, backlogs))
    out = fn(pstack, x, y, backlogs)
    det_np = np.asarray(out[1, :cells]) > 0.5
    undet = int((~det_np).sum())
    if undet > max(cells // 4, 8):
        return None
    rep = out[0, :cells].reshape(P, B, M)
    launches = 1
    if undet:
        conv_np = det_np.reshape(P, B, M)
        rep = _escalate_stragglers(
            "flitsim.symmetric",
            functools.partial(_symmetric_cells_grid, n_flits=horizon),
            horizon, rep, conv_np,
            lambda idx: (_gather_cells(pstack, idx[:, 0]),
                         jnp.asarray(np.asarray(x)[idx[:, 2]]),
                         jnp.asarray(np.asarray(y)[idx[:, 2]]),
                         jnp.asarray(np.asarray(backlogs)[idx[:, 1]])))
        launches += 1
    jax.block_until_ready(rep)
    conv_at = np.where(det_np, 1, -1).astype(np.int32).reshape(P, B, M)
    _record_adaptive("flitsim.symmetric", horizon, fs_ref.SYM_PERIOD_OBS,
                     1, conv_at, undet, engine=sim.engine,
                     launches=launches,
                     elapsed_s=time.perf_counter() - t0,
                     periods=out[2, :cells])
    return rep


def _run_symmetric_pallas(pstack, x, y, backlogs, horizon: int,
                          chunk: int, sim: SimConfig):
    """Host-driven adaptive symmetric loop on the fused chunk kernel: one
    launch per chunk; report / drift / convergence evaluated in-kernel;
    the host reads back one flag row per chunk to steer the early exit.
    Chunk-boundary histories stay as a host-side list of device rows (the
    kernel receives exactly the rows the report formula needs)."""
    from repro.kernels.flit_sim import ops as fs_ops
    P, B, M = pstack.g_slots.shape[0], backlogs.shape[0], x.shape[0]
    cells = P * B * M
    K = horizon // chunk
    K0 = max(K // 4, 1)
    min_k = max(_MIN_EXIT_CHUNKS, K0 + 1)
    budget = _escalation_budget(cells, chunk, horizon)
    t0 = time.perf_counter()
    tile, cpad = fs_ops.tile_for(cells)
    params = fs_ops.pad_cells(_sym_param_rows(pstack, x, y, backlogs),
                              cpad)
    state = jnp.zeros((fs_ops.SYM_ROWS, cpad), jnp.float32)
    zrow = jnp.zeros((1, cpad), jnp.float32)
    z5 = jnp.zeros((5, cpad), jnp.float32)
    z6 = jnp.zeros((6, cpad), jnp.float32)
    Dh, TDh, Ph = [zrow], [zrow], [z5]

    def hist_for(k: int):
        m = max(k - 4, (k + 1) // 2)
        mid = (m + k + 1) // 2
        return m, mid, jnp.concatenate([
            Ph[max(k - _DRIFT_SPAN, 0)],
            Dh[m] if m < k else zrow, TDh[m] if m < k else zrow,
            Dh[mid] if mid < k else zrow, TDh[mid] if mid < k else zrow,
            Dh[K0] if k > K0 else zrow, z6])

    def scal_for(k: int, m: int, mid: int):
        return _scal_row([k, m, mid, K0, K, chunk, sim.tol,
                          1.0 if (k >= min_k and k > _DRIFT_SPAN) else 0.0,
                          1.0 if k >= K else 0.0, _DRIFT_TOL_SLOTS])

    m1, mid1, hist1 = hist_for(1)
    launch = cached_program(
        "flitsim.symmetric",
        (P, B, M, horizon, "pallas-chunk") + sim.key(),
        functools.partial(fs_ops.symmetric_chunk_launch, chunk=chunk,
                          tile=tile, cells=cells),
        (params, state, hist1, scal_for(1, m1, mid1)))
    conv_at = np.full(cells, -1, np.int32)
    conv_np = np.zeros(cells, bool)
    k = 0
    while k < K:
        k += 1
        m, mid, hist = hist_for(k)
        state, conv = launch(params, state, hist, scal_for(k, m, mid))
        Dh.append(state[7:8])
        TDh.append(state[8:9])
        Ph.append(state[0:5])
        conv_np = np.asarray(conv)
        conv_at[(conv_at < 0) & conv_np] = k
        if int((~conv_np).sum()) <= budget:
            break
    rep = state[10, :cells].reshape(P, B, M)
    stragglers = int((~conv_np).sum()) if budget > 0 else 0
    launches = k
    if stragglers:
        rep = _escalate_stragglers(
            "flitsim.symmetric",
            functools.partial(_symmetric_cells_grid, n_flits=horizon),
            horizon, rep, conv_np.reshape(P, B, M),
            lambda idx: (_gather_cells(pstack, idx[:, 0]),
                         jnp.asarray(np.asarray(x)[idx[:, 2]]),
                         jnp.asarray(np.asarray(y)[idx[:, 2]]),
                         jnp.asarray(np.asarray(backlogs)[idx[:, 1]])))
        launches += 1
    jax.block_until_ready(rep)
    _record_adaptive("flitsim.symmetric", horizon, chunk, k,
                     conv_at.reshape(P, B, M), stragglers,
                     engine="pallas", launches=launches,
                     elapsed_s=time.perf_counter() - t0)
    return rep


def _run_pipelining_pallas(ks, ucie_line_uis, device_line_uis,
                           horizon: int, chunk: int, sim: SimConfig):
    """Host-driven adaptive pipelining loop on the fused chunk kernel
    (same shape as the symmetric loop; no drift guard / escalation —
    the rotation report converges monotonically)."""
    from repro.kernels.flit_sim import ops as fs_ops
    Kk, U, Dn = (ks.shape[0], ucie_line_uis.shape[0],
                 device_line_uis.shape[0])
    cells = Kk * U * Dn
    K = horizon // chunk
    min_k = min(_MIN_EXIT_CHUNKS, K)
    t0 = time.perf_counter()
    tile, cpad = fs_ops.tile_for(cells)
    params = fs_ops.pad_cells(
        _pipe_param_rows(ks, ucie_line_uis, device_line_uis), cpad)
    state = jnp.zeros((fs_ops.PIPE_ROWS, cpad), jnp.float32)
    hist = jnp.zeros((fs_ops.ASYM_ROWS, cpad), jnp.float32)

    def scal_for(k: int):
        return _scal_row([k, K, chunk, sim.tol,
                          1.0 if k >= min_k else 0.0,
                          1.0 if k >= K else 0.0, horizon])

    launch = cached_program(
        "flitsim.pipelining",
        (Kk, U, Dn, horizon, "pallas-chunk") + sim.key(),
        functools.partial(fs_ops.pipelining_chunk_launch, chunk=chunk,
                          tile=tile, cells=cells),
        (params, state, hist, scal_for(1)))
    conv_at = np.full(cells, -1, np.int32)
    k = 0
    while k < K:
        k += 1
        state, conv = launch(params, state, hist, scal_for(k))
        if k == 1:      # T1 anchor for the linear-growth extrapolation
            hist = jnp.concatenate(
                [state[8:9], jnp.zeros((7, cpad), jnp.float32)])
        conv_np = np.asarray(conv)
        conv_at[(conv_at < 0) & conv_np] = k
        if int((~conv_np).sum()) == 0:
            break
    rep = state[10, :cells].reshape(Kk, U, Dn)
    jax.block_until_ready(rep)
    _record_adaptive("flitsim.pipelining", horizon, chunk, k,
                     conv_at.reshape(Kk, U, Dn), 0, engine="pallas",
                     launches=k, elapsed_s=time.perf_counter() - t0)
    return rep


def _run_symmetric(pstack, x, y, backlogs, n_flits: int,
                   sim: Optional[SimConfig] = None):
    sim = sim if sim is not None else FIXED_SIM
    P, B, M = pstack.g_slots.shape[0], backlogs.shape[0], x.shape[0]
    if sim.mode == "fixed":
        fn = cached_program(
            "flitsim.symmetric", (P, B, M, n_flits) + sim.key(),
            functools.partial(_symmetric_grid, n_flits=n_flits),
            (pstack, x, y, backlogs))
        return fn(pstack, x, y, backlogs)
    horizon = sim.horizon(n_flits)
    chunk = _divisor_chunk(horizon, sim.chunk)
    if chunk < 8:               # divisor-poor horizon: adaptive degrades
        return _run_symmetric(pstack, x, y, backlogs, horizon,
                              sim=FIXED_SIM)
    from repro.kernels.flit_sim.ref import (
        SYM_PERIOD_OBS, SYM_PERIODIC_MAX_BACKLOG,
    )
    if (horizon // 4 >= SYM_PERIOD_OBS
            and float(np.max(np.asarray(backlogs)))
            <= SYM_PERIODIC_MAX_BACKLOG):
        # period-exact cut (both engines): observe the pool-state window
        # before the warm window opens and extrapolate bitwise; falls
        # through to the chunked core on mostly aperiodic grids (None).
        # Saturated grids skip the probe outright (see the
        # SYM_PERIODIC_MAX_BACKLOG note in kernels/flit_sim/ref.py)
        rep = _run_symmetric_periodic(pstack, x, y, backlogs, horizon,
                                      sim)
        if rep is not None:
            return rep
    if sim.engine == "pallas":
        return _run_symmetric_pallas(pstack, x, y, backlogs, horizon,
                                     chunk, sim)
    t0 = time.perf_counter()
    budget = _escalation_budget(P * B * M, chunk, horizon)
    fn = cached_program(
        "flitsim.symmetric", (P, B, M, horizon) + sim.key(),
        functools.partial(_symmetric_grid_adaptive, n_flits=horizon,
                          chunk=chunk, unroll=int(sim.unroll),
                          tol=float(sim.tol), budget=budget),
        (pstack, x, y, backlogs))
    rep, conv, k_exit, conv_at = fn(pstack, x, y, backlogs)
    stragglers = 0
    if budget > 0:                      # budget 0 can only exit converged
        conv_np = np.asarray(conv)
        stragglers = int((~conv_np).sum())
        if stragglers:
            rep = _escalate_stragglers(
                "flitsim.symmetric",
                functools.partial(_symmetric_cells_grid, n_flits=horizon),
                horizon, rep, conv_np,
                lambda idx: (_gather_cells(pstack, idx[:, 0]),
                             jnp.asarray(np.asarray(x)[idx[:, 2]]),
                             jnp.asarray(np.asarray(y)[idx[:, 2]]),
                             jnp.asarray(np.asarray(backlogs)[idx[:, 1]])))
    jax.block_until_ready(rep)
    _record_adaptive("flitsim.symmetric", horizon, chunk, k_exit, conv_at,
                     stragglers, engine="xla",
                     launches=1 + (1 if stragglers else 0),
                     elapsed_s=time.perf_counter() - t0)
    return rep


def _run_asymmetric(pstack, x, y, n_accesses: int,
                    sim: Optional[SimConfig] = None):
    sim = sim if sim is not None else FIXED_SIM
    P, M = pstack.total_lanes.shape[0], x.shape[0]
    if sim.mode == "fixed":
        fn = cached_program(
            "flitsim.asymmetric", (P, M, n_accesses) + sim.key(),
            functools.partial(_asymmetric_grid, n_accesses=n_accesses),
            (pstack, x, y))
        return fn(pstack, x, y)
    horizon = sim.horizon(n_accesses)
    chunk = _divisor_chunk(horizon, sim.chunk)
    if chunk < 8:
        return _run_asymmetric(pstack, x, y, horizon, sim=FIXED_SIM)
    from repro.kernels.flit_sim.ref import PERIOD_OBS
    if horizon >= PERIOD_OBS:
        # period-exact cut (both engines): observe ~2 credit periods and
        # extrapolate; falls through to the chunked core on mostly
        # aperiodic grids (None)
        rep = _run_asymmetric_periodic(pstack, x, y, horizon, sim)
        if rep is not None:
            return rep
    t0 = time.perf_counter()
    budget = _escalation_budget(P * M, chunk, horizon)
    fn = cached_program(
        "flitsim.asymmetric", (P, M, horizon) + sim.key(),
        functools.partial(_asymmetric_grid_adaptive, n_accesses=horizon,
                          chunk=chunk, unroll=int(sim.unroll),
                          tol=float(sim.tol), budget=budget),
        (pstack, x, y))
    rep, conv, k_exit, conv_at = fn(pstack, x, y)
    stragglers = 0
    if budget > 0:
        conv_np = np.asarray(conv)
        stragglers = int((~conv_np).sum())
        if stragglers:
            rep = _escalate_stragglers(
                "flitsim.asymmetric",
                functools.partial(_asymmetric_cells_grid,
                                  n_accesses=horizon),
                horizon, rep, conv_np,
                lambda idx: (_gather_cells(pstack, idx[:, 0]),
                             jnp.asarray(np.asarray(x)[idx[:, 1]]),
                             jnp.asarray(np.asarray(y)[idx[:, 1]])))
    jax.block_until_ready(rep)
    _record_adaptive("flitsim.asymmetric", horizon, chunk, k_exit, conv_at,
                     stragglers, engine="xla",
                     launches=1 + (1 if stragglers else 0),
                     elapsed_s=time.perf_counter() - t0)
    return rep


def _run_pipelining(ks, ucie_line_uis, device_line_uis, max_k: int,
                    n_lines: int, sim: Optional[SimConfig] = None):
    sim = sim if sim is not None else FIXED_SIM
    shape = (ks.shape[0], ucie_line_uis.shape[0], device_line_uis.shape[0])
    if sim.mode == "fixed":
        fn = cached_program(
            "flitsim.pipelining", shape + (max_k, n_lines) + sim.key(),
            functools.partial(_pipelining_grid, max_k=max_k,
                              n_lines=n_lines),
            (ks, ucie_line_uis, device_line_uis))
        return fn(ks, ucie_line_uis, device_line_uis)
    horizon = sim.horizon(n_lines)
    chunk = _divisor_chunk(horizon, sim.chunk)
    if chunk < 8:
        return _run_pipelining(ks, ucie_line_uis, device_line_uis, max_k,
                               horizon, sim=FIXED_SIM)
    if sim.engine == "pallas":
        from repro.kernels.flit_sim.ref import PIPE_MAX_K
        if max_k <= PIPE_MAX_K:     # kernel holds PIPE_MAX_K device rows
            return _run_pipelining_pallas(ks, ucie_line_uis,
                                          device_line_uis, horizon, chunk,
                                          sim)
    t0 = time.perf_counter()
    fn = cached_program(
        "flitsim.pipelining", shape + (max_k, horizon) + sim.key(),
        functools.partial(_pipelining_grid_adaptive, max_k=max_k,
                          n_lines=horizon, chunk=chunk,
                          unroll=int(sim.unroll), tol=float(sim.tol)),
        (ks, ucie_line_uis, device_line_uis))
    rep, conv, k_exit, conv_at = fn(ks, ucie_line_uis, device_line_uis)
    jax.block_until_ready(rep)
    _record_adaptive("flitsim.pipelining", horizon, chunk, k_exit, conv_at,
                     0,                 # exits only converged / at horizon
                     engine="xla", launches=1,
                     elapsed_s=time.perf_counter() - t0)
    return rep


def _run_symmetric_trace(pstack, xs, ys, bls, cycles: int,
                         sim: SimConfig):
    """Trace-scan runner: ``xs/ys/bls`` are ``[T, N]`` phase grids;
    returns per-phase efficiency ``[P, T, N]``.  Shapes (not phase data)
    key the cache, so alternating same-shaped traces stays warm."""
    P = pstack.flit_bits.shape[0]
    T, N = xs.shape
    fn = cached_program(
        "flitsim.symmetric", ("trace", P, T, N, cycles) + sim.key(),
        functools.partial(_symmetric_trace_grid, n_phases=N,
                          cycles=cycles),
        (pstack, xs, ys, bls))
    rep = fn(pstack, xs, ys, bls)
    _record_trace("flitsim.symmetric", N, cycles, P * T)
    return rep


def _run_asymmetric_trace(pstack, xs, ys, cycles: int, sim: SimConfig):
    """Trace-scan runner for the asymmetric family: ``[P, T, N]``."""
    P = pstack.total_lanes.shape[0]
    T, N = xs.shape
    fn = cached_program(
        "flitsim.asymmetric", ("trace", P, T, N, cycles) + sim.key(),
        functools.partial(_asymmetric_trace_grid, n_phases=N,
                          cycles=cycles),
        (pstack, xs, ys))
    rep = fn(pstack, xs, ys)
    _record_trace("flitsim.asymmetric", N, cycles, P * T)
    return rep


# -- engine entry point (what DesignSpace lowers onto) ------------------------


def simulate_grid(protocols: Sequence[str], x, y, backlogs, *,
                  perturbations: Optional[Sequence[Mapping[str, float]]]
                  = None,
                  n_flits: int = 2048,
                  n_accesses: int = 4096,
                  sim: Optional[SimConfig] = None) -> jnp.ndarray:
    """Evaluate the full ``[Q perturbations, P protocols, B backlogs,
    M mixes]`` grid, one compiled call per simulator family.

    ``x`` / ``y`` are flat ``[M]`` mix arrays; ``backlogs`` is ``[B]``
    (symmetric family only — asymmetric rows broadcast across it).
    ``perturbations`` are multiplicative ``{field: scale}`` overrides
    folded into the parameter stacks (the protocol axis becomes ``Q*P``
    rows of one pytree), so sensitivity sweeps ride the exact same
    executables as the baseline.  Returns efficiency ``[Q, P, B, M]``.

    ``sim`` selects the execution config: :data:`FIXED_SIM` (default,
    bit-identical full-horizon scan) or :data:`ADAPTIVE_SIM` (chunked
    early-exit cores, <= tol-scale deviation; see
    :func:`last_run_info` for the cycles-to-convergence telemetry).
    """
    keys = tuple(protocols)
    unknown = sorted(k for k in keys
                     if k not in SYMMETRIC_PARAMS
                     and k not in ASYMMETRIC_PARAMS)
    if unknown:
        raise ValueError(f"unknown protocol keys {unknown}; "
                         f"choose from {sorted(SIMULATORS)}")
    perts = [dict(p) for p in (perturbations or [{}])]
    active_fields: set = set()
    if any(k in SYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(SymmetricFlitParams)}
    if any(k in ASYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(AsymmetricLaneParams)}
    for p in perts:
        _check_perturbation(p)
        # a perturbation that touches NO field of the selected families
        # would silently produce a baseline row labeled as perturbed
        if p and not set(p) & active_fields:
            raise ValueError(
                f"perturbation {p} applies to no parameter of the selected "
                f"protocols {keys}; applicable fields: "
                f"{sorted(active_fields)}")
    x = _f32(np.asarray(x).reshape(-1))
    y = _f32(np.asarray(y).reshape(-1))
    b = _f32(np.asarray(backlogs).reshape(-1))
    n_q, n_b, n_m = len(perts), b.shape[0], x.shape[0]

    per_key: Dict[str, jnp.ndarray] = {}            # key -> [Q, B, M]
    sym_keys = [k for k in keys if k in SYMMETRIC_PARAMS]
    if sym_keys:
        pstack = SymmetricFlitParams.stack(
            [SYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in sym_keys])
        grid = _run_symmetric(pstack, x, y, b, int(n_flits), sim=sim)
        grid = grid.reshape((n_q, len(sym_keys), n_b, n_m))
        for i, k in enumerate(sym_keys):
            per_key[k] = grid[:, i]
    asym_keys = [k for k in keys if k in ASYMMETRIC_PARAMS]
    if asym_keys:
        pstack = AsymmetricLaneParams.stack(
            [ASYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in asym_keys])
        grid = _run_asymmetric(pstack, x, y, int(n_accesses), sim=sim)
        grid = grid.reshape((n_q, len(asym_keys), n_m))
        for i, k in enumerate(asym_keys):
            per_key[k] = jnp.broadcast_to(grid[:, i, None, :],
                                          (n_q, n_b, n_m))
    return jnp.stack([per_key[k] for k in keys], axis=1)   # [Q, P, B, M]


def simulate_trace_grid(protocols: Sequence[str], xs, ys, backlogs, *,
                        perturbations: Optional[
                            Sequence[Mapping[str, float]]] = None,
                        n_flits: int = 2048, n_accesses: int = 4096,
                        sim: Optional[SimConfig] = None) -> jnp.ndarray:
    """Evaluate ``T`` traffic traces of ``N`` phases each through the
    trace-scan cores: per-PHASE efficiency ``[Q, P, T, N]``.

    ``xs`` / ``ys`` / ``backlogs`` are ``[T, N]`` phase grids (read /
    write mix percentages and queue backlog per phase).  Queue and credit
    state carries across phase boundaries inside each (protocol, trace)
    cell, so phase ``n``'s efficiency includes the transient inherited
    from phase ``n-1``; a single-phase trace is bit-identical to the
    fixed static cell at the same (mix, backlog).  Asymmetric protocols
    ignore the backlog grid, exactly as in :func:`simulate_grid`.

    Every phase runs ``sim.trace_cycles`` cycles (default: the family's
    static horizon — ``n_flits`` symmetric, ``n_accesses`` asymmetric).
    Phase DURATIONS are not consumed here: the design space applies them
    as aggregation weights over the returned per-phase grid.
    """
    sim = sim if sim is not None else FIXED_SIM
    keys = tuple(protocols)
    unknown = sorted(k for k in keys
                     if k not in SYMMETRIC_PARAMS
                     and k not in ASYMMETRIC_PARAMS)
    if unknown:
        raise ValueError(f"unknown protocol keys {unknown}; "
                         f"choose from {sorted(SIMULATORS)}")
    perts = [dict(p) for p in (perturbations or [{}])]
    active_fields: set = set()
    if any(k in SYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(SymmetricFlitParams)}
    if any(k in ASYMMETRIC_PARAMS for k in keys):
        active_fields |= {f.name
                          for f in dataclasses.fields(AsymmetricLaneParams)}
    for p in perts:
        _check_perturbation(p)
        if p and not set(p) & active_fields:
            raise ValueError(
                f"perturbation {p} applies to no parameter of the selected "
                f"protocols {keys}; applicable fields: "
                f"{sorted(active_fields)}")
    xs = _f32(np.asarray(xs))
    ys = _f32(np.asarray(ys))
    bls = _f32(np.asarray(backlogs))
    if xs.ndim != 2 or xs.shape != ys.shape or xs.shape != bls.shape:
        raise ValueError(
            f"trace phase grids must share one [T, N] shape; got "
            f"xs {xs.shape}, ys {ys.shape}, backlogs {bls.shape}")
    n_q, (n_t, n_p) = len(perts), xs.shape

    per_key: Dict[str, jnp.ndarray] = {}            # key -> [Q, T, N]
    sym_keys = [k for k in keys if k in SYMMETRIC_PARAMS]
    if sym_keys:
        cycles = int(sim.trace_cycles or n_flits)
        pstack = SymmetricFlitParams.stack(
            [SYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in sym_keys])
        grid = _run_symmetric_trace(pstack, xs, ys, bls, cycles, sim)
        grid = grid.reshape((n_q, len(sym_keys), n_t, n_p))
        for i, k in enumerate(sym_keys):
            per_key[k] = grid[:, i]
    asym_keys = [k for k in keys if k in ASYMMETRIC_PARAMS]
    if asym_keys:
        cycles = int(sim.trace_cycles or n_accesses)
        pstack = AsymmetricLaneParams.stack(
            [ASYMMETRIC_PARAMS[k].perturbed(p) for p in perts
             for k in asym_keys])
        grid = _run_asymmetric_trace(pstack, xs, ys, cycles, sim)
        grid = grid.reshape((n_q, len(asym_keys), n_t, n_p))
        for i, k in enumerate(asym_keys):
            per_key[k] = grid[:, i]
    return jnp.stack([per_key[k] for k in keys], axis=1)   # [Q, P, T, N]


# -- scalar entry points (thin wrappers over a [1, 1, 1] grid) ----------------


def simulate_symmetric(params: SymmetricFlitParams, x: float, y: float,
                       n_flits: int = 2048,
                       backlog: float = 64) -> float:
    """Single-point symmetric simulation; shares the sweep compile cache."""
    _check_mix(x, y)
    pstack = SymmetricFlitParams.stack([params])
    eff = _run_symmetric(pstack, _f32([x]), _f32([y]), _f32([backlog]),
                         int(n_flits))
    return float(eff[0, 0, 0])


def simulate_asymmetric(params: AsymmetricLaneParams, x: float, y: float,
                        n_accesses: int = 4096) -> float:
    """Single-point asymmetric simulation; shares the sweep compile cache."""
    _check_mix(x, y)
    pstack = AsymmetricLaneParams.stack([params])
    eff = _run_asymmetric(pstack, _f32([x]), _f32([y]), int(n_accesses))
    return float(eff[0, 0])


_PIPELINING_PAD_K = 8     # pad the ready-table so all k <= 8 share one exe


def simulate_lpddr6_pipelining(num_devices: int, n_lines: int = 512,
                               ucie_line_ui: float = 16,
                               device_line_ui: float = 64) -> float:
    """Single-k Fig-13 pipelining simulation; shares the sweep cache."""
    max_k = max(int(num_devices), _PIPELINING_PAD_K)
    u = _run_pipelining(jnp.asarray([num_devices], jnp.int32),
                        _f32([ucie_line_ui]), _f32([device_line_ui]),
                        max_k, int(n_lines))
    return float(u[0, 0, 0])


# -- sweep API ---------------------------------------------------------------


#: The five canonical read:write mixes every validation sweep covers.
CANONICAL_MIXES: Tuple[Tuple[float, float], ...] = (
    (1.0, 0.0), (2.0, 1.0), (1.0, 1.0), (1.0, 2.0), (0.0, 1.0))

SYMMETRIC_PARAMS: Dict[str, SymmetricFlitParams] = {
    "cxl_unopt": SymmetricFlitParams.cxl_unopt(),
    "cxl_opt": SymmetricFlitParams.cxl_opt(),
    "chi": SymmetricFlitParams.chi(),
}

ASYMMETRIC_PARAMS: Dict[str, AsymmetricLaneParams] = {
    "lpddr6_asym": AsymmetricLaneParams.lpddr6(),
    "hbm_asym": AsymmetricLaneParams.hbm(),
}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Output of :func:`sweep`.

    ``efficiency`` is ``[P, M]`` when a single backlog was requested and
    ``[P, B, M]`` for a backlog grid; axes follow ``protocols`` /
    ``backlogs`` / ``mixes`` order.
    """

    protocols: Tuple[str, ...]
    mixes: Tuple[Tuple[float, float], ...]
    backlogs: Optional[Tuple[float, ...]]
    efficiency: jnp.ndarray

    def for_protocol(self, key: str) -> jnp.ndarray:
        return self.efficiency[self.protocols.index(key)]


def _normalize_mixes(mixes) -> Tuple[Tuple[float, float], ...]:
    if mixes is None:
        return CANONICAL_MIXES
    out = []
    for m in mixes:
        if hasattr(m, "x") and hasattr(m, "y"):     # TrafficMix
            x, y = float(m.x), float(m.y)
        else:
            x, y = m
            x, y = float(x), float(y)
        _check_mix(x, y)
        out.append((x, y))
    return tuple(out)


def _sweep_impl(protocols: Optional[Sequence[str]] = None,
                mixes=None,
                backlogs: Union[None, float, Sequence[float]] = None,
                *, n_flits: int = 2048, n_accesses: int = 4096,
                sim: Optional[SimConfig] = None) -> SweepResult:
    """Engine body of the retired ``sweep`` front-end — internal
    callers (``backlog_knees``) use this directly, warning-free."""
    keys = tuple(protocols) if protocols is not None else tuple(SIMULATORS)
    if not keys:
        raise ValueError("sweep() needs at least one protocol key")
    mix_tuples = _normalize_mixes(mixes)
    if not mix_tuples:
        raise ValueError("sweep() needs at least one traffic mix")
    squeeze_b = backlogs is None or np.ndim(backlogs) == 0
    if backlogs is None:
        backlog_vals: Tuple[float, ...] = (64.0,)
    else:
        backlog_vals = tuple(
            float(b) for b in np.atleast_1d(np.asarray(backlogs)))

    x = _f32([m[0] for m in mix_tuples])
    y = _f32([m[1] for m in mix_tuples])
    eff = simulate_grid(keys, x, y, backlog_vals, n_flits=n_flits,
                        n_accesses=n_accesses, sim=sim)[0]  # [P, B, M]
    if squeeze_b:
        return SweepResult(protocols=keys, mixes=mix_tuples, backlogs=None,
                           efficiency=eff[:, 0, :])
    return SweepResult(protocols=keys, mixes=mix_tuples,
                       backlogs=backlog_vals, efficiency=eff)


def sweep_perturbed(perturbations: Sequence[Mapping[str, float]],
                    protocols: Optional[Sequence[str]] = None,
                    mixes=None,
                    backlogs: Union[None, float, Sequence[float]] = None,
                    *, n_flits: int = 2048, n_accesses: int = 4096,
                    sim: Optional[SimConfig] = None):
    """Protocol-parameter sensitivity sweep: multiplicative ``{field:
    scale}`` perturbations (slot counts, credit limits, lane splits) over
    the existing pytree param stacks.

    Front-end over the axes-first API: returns a
    :class:`repro.core.space.SpaceResult` whose ``sim_efficiency`` array
    carries a ``protocol_param`` axis — include ``{}`` as the first
    perturbation to get the baseline row for free.
    """
    from repro.core.space import DesignSpace, axis
    keys = tuple(protocols) if protocols is not None else tuple(SIMULATORS)
    axes = [axis("protocol_param", list(perturbations)),
            axis("protocol", keys),
            axis("mix", _normalize_mixes(mixes))]
    if backlogs is not None and np.ndim(backlogs) > 0:
        axes.append(axis("backlog", list(np.atleast_1d(backlogs))))
        default_backlog = 64.0
    else:
        default_backlog = 64.0 if backlogs is None else float(backlogs)
    return DesignSpace(axes, default_backlog=default_backlog,
                       n_flits=n_flits, n_accesses=n_accesses,
                       sim=sim).evaluate(metrics=("sim_efficiency",))


#: Default queue-depth axis for knee extraction — doubling steps wide
#: enough to bracket every simulated protocol's saturation cliff.
KNEE_BACKLOGS: Tuple[float, ...] = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                                    128.0)


def backlog_knees(mixes=None,
                  backlogs: Sequence[float] = KNEE_BACKLOGS,
                  knee_frac: float = 0.95,
                  n_flits: int = 2048,
                  per_mix: bool = False,
                  sim: Optional[SimConfig] = None) -> Dict[str, Any]:
    """Efficiency-cliff knee per simulated protocol: the smallest request
    backlog at which simulated data efficiency reaches ``knee_frac`` of
    that protocol's best efficiency over the backlog axis.

    By default the knee is maximized over ``mixes`` (conservative: a
    protocol must hit its knee on every mix) and the result is a scalar
    per protocol.  With ``per_mix=True`` the per-mix knees are returned as
    a ``[M]`` array per protocol — this is what lets the bridge follow
    each workload's own HLO-derived mix along the configs axis instead of
    the canonical-mix envelope.

    One :func:`sweep` call over the ``[P, B, M]`` grid — repeated calls
    with the same grid shape reuse the warm executable.  Asymmetric
    protocols are backlog-independent, so their knee is the smallest
    backlog probed.  The result feeds ``SelectionConstraints.
    max_backlog_knee``: a queue-depth budget the selector enforces.
    """
    res = _sweep_impl(mixes=mixes, backlogs=backlogs, n_flits=n_flits,
                      sim=sim)
    eff = np.asarray(res.efficiency)                    # [P, B, M]
    b = np.asarray(res.backlogs, dtype=np.float64)
    knees: Dict[str, Any] = {}
    for i, key in enumerate(res.protocols):
        e = eff[i]                                      # [B, M]
        ok = e >= knee_frac * e.max(axis=0, keepdims=True)
        first = np.argmax(ok, axis=0)                   # per-mix knee index
        knees[key] = b[first] if per_mix else float(b[first].max())
    return knees


def _sweep_pipelining_impl(ks: Sequence[int], n_lines: int = 512,
                           ucie_line_ui: Union[float, Sequence[float]] = 16,
                           device_line_ui: Union[float, Sequence[float]] = 64,
                           sim: Optional[SimConfig] = None) -> jnp.ndarray:
    """Engine body of the retired ``sweep_pipelining`` front-end
    — the ``k`` / ``ucie_line_ui`` / ``device_line_ui`` axes lower here."""
    ks = tuple(int(k) for k in ks)
    squeeze = (np.ndim(ucie_line_ui) == 0 and np.ndim(device_line_ui) == 0)
    us = _f32(np.atleast_1d(np.asarray(ucie_line_ui, dtype=np.float64)))
    ds = _f32(np.atleast_1d(np.asarray(device_line_ui, dtype=np.float64)))
    max_k = max(max(ks), _PIPELINING_PAD_K)
    util = _run_pipelining(jnp.asarray(ks, jnp.int32), us, ds,
                           max_k, int(n_lines), sim=sim)
    return util[:, 0, 0] if squeeze else util


# -- convenience: analytic counterparts for the property tests ---------------

ANALYTIC = {
    "cxl_unopt": CXLMemOnUCIe(),
    "cxl_opt": CXLMemOptOnUCIe(),
    "chi": CHIOnUCIe(),
    "lpddr6_asym": LPDDR6OnUCIe(),
    "hbm_asym": HBMOnUCIe(),
}

SIMULATORS = {
    "cxl_unopt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_unopt(), x, y),
    "cxl_opt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_opt(), x, y),
    "chi": lambda x, y: simulate_symmetric(SymmetricFlitParams.chi(), x, y),
    "lpddr6_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.lpddr6(), x, y),
    "hbm_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.hbm(), x, y),
}
