"""Flit-level discrete-event link simulator (jax.lax.scan).

Validates the paper's closed-form bandwidth-efficiency expressions with a
cycle-level simulation of slot scheduling — the executable counterpart of
the Appendix (Fig 13) timing analysis.  Three simulators:

  * ``simulate_symmetric``  — slot/granule scheduler for approaches C/D/E
    (256 B flits per direction per step; greedy packing per the paper:
    "pack as many headers as possible into an H-slot and leave as many
    G-slots for data").
  * ``simulate_asymmetric`` — lane-group/UI scheduler for approaches A/B.
  * ``simulate_lpddr6_pipelining`` — Fig 13: k LPDDR6 devices time-
    multiplexed behind the logic die; utilization -> 100% at k=4.

The memory is modeled with zero processing latency: steady-state throughput
(what the closed forms predict) is latency-independent; queue feedback —
headers stealing data slots and vice versa — emerges naturally and is
exactly what the analytic max() terms capture.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.protocols.chi_ucie import CHIOnUCIe
from repro.core.protocols.cxl_mem import CXLMemOnUCIe
from repro.core.protocols.cxl_mem_opt import CXLMemOptOnUCIe
from repro.core.protocols.hbm_ucie import HBMOnUCIe
from repro.core.protocols.lpddr6_ucie import LPDDR6OnUCIe


@dataclasses.dataclass(frozen=True)
class SymmetricFlitParams:
    """Slot geometry for a symmetric flit protocol."""

    g_slots: int                 # payload-capable slots per flit
    h_slots: int                 # header-only slots per flit
    reqs_per_h: float            # requests fitting the header slot
    resps_per_h: float
    reqs_per_g: float            # requests per payload slot (header overflow)
    resps_per_g: float
    data_slots_per_line: int     # slots per 64 B line
    slot_bits: int               # payload slot size in bits
    flit_bits: int = 2048        # 256 B

    @classmethod
    def cxl_unopt(cls) -> "SymmetricFlitParams":
        # 1 H + 14 G usable; 16 B slots; 1 req / 2 resp per slot.
        return cls(g_slots=14, h_slots=1, reqs_per_h=1, resps_per_h=2,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def cxl_opt(cls) -> "SymmetricFlitParams":
        # 15 G + 1 HS (10 B, headers only); 1 req / 4 resp per slot.
        return cls(g_slots=15, h_slots=1, reqs_per_h=1, resps_per_h=4,
                   reqs_per_g=1, resps_per_g=4, data_slots_per_line=4,
                   slot_bits=128)

    @classmethod
    def chi(cls) -> "SymmetricFlitParams":
        # 12 granules of 20 B, no dedicated header slot; 16 B payload/granule.
        return cls(g_slots=12, h_slots=0, reqs_per_h=0, resps_per_h=0,
                   reqs_per_g=1, resps_per_g=2, data_slots_per_line=4,
                   slot_bits=160)   # granule is 20 B on the wire


def simulate_symmetric(params: SymmetricFlitParams, x: float, y: float,
                       n_flits: int = 2048,
                       backlog: int = 64) -> float:
    """Saturation data efficiency of a symmetric full-duplex link.

    Returns data bits delivered (both directions, 512 b per line) over raw
    link capacity (2 * n_flits * 2048 b) — directly comparable to the
    analytic ``bw_eff``.

    Scheduling per the paper: headers have priority ("pack as many headers
    as possible into an H-slot"), data fills the remaining G-slots.  Read
    requests are gated by credit-based flow control on the read-data return
    path (as CXL's credit mechanism does) — without it, a saturated M2S
    direction would let writes over-deliver and distort the delivered mix.
    """
    xr = x / (x + y)
    yr = y / (x + y)
    dpl = params.data_slots_per_line
    rdata_limit = 8.0 * params.g_slots    # in-flight read-data credit (slots)

    def step(carry, _):
        (rq, wq, wdata, rdata, resp, cr, cw, data_slots, warm_slots,
         warm) = carry
        # -- generate traffic to hold the request backlog at `backlog` ------
        deficit = jnp.maximum(backlog - (rq + wq), 0.0)
        cr2 = cr + deficit * xr
        cw2 = cw + deficit * yr
        gen_r = jnp.floor(cr2)
        gen_w = jnp.floor(cw2)
        cr2, cw2 = cr2 - gen_r, cw2 - gen_w
        rq = rq + gen_r
        wq = wq + gen_w

        # -- SoC -> Mem flit: headers first (H then G), data fills the rest -
        # Both request kinds are credit-gated by their data path: reads by
        # the in-flight read-return credit, writes by the write buffer.
        credit_r = jnp.maximum(rdata_limit - rdata, 0.0) / dpl
        credit_w = jnp.maximum(rdata_limit - wdata, 0.0) / dpl
        rq_elig = jnp.minimum(rq, credit_r)
        wq_elig = jnp.minimum(wq, credit_w)
        hdr_cap = (params.reqs_per_h * params.h_slots
                   + params.reqs_per_g * params.g_slots)
        sent_req = jnp.minimum(rq_elig + wq_elig, hdr_cap)
        tot_q = jnp.maximum(rq_elig + wq_elig, 1e-9)
        sent_r = sent_req * rq_elig / tot_q
        sent_w = sent_req * wq_elig / tot_q
        g_hdr = (jnp.maximum(sent_req - params.reqs_per_h * params.h_slots,
                             0.0) / max(params.reqs_per_g, 1e-9))
        d_s2m = jnp.minimum(wdata, params.g_slots - g_hdr)
        rq, wq = rq - sent_r, wq - sent_w
        wdata = wdata + sent_w * dpl - d_s2m   # data follows its request
        # a sent read instantly enqueues 4 data slots + 1 response (M2S);
        # a sent write enqueues 1 completion response
        rdata = rdata + sent_r * dpl
        resp = resp + sent_r + sent_w

        # -- Mem -> SoC flit: responses first, read data fills the rest -----
        resp_cap = (params.resps_per_h * params.h_slots
                    + params.resps_per_g * params.g_slots)
        sent_resp = jnp.minimum(resp, resp_cap)
        g_resp = (jnp.maximum(sent_resp - params.resps_per_h * params.h_slots,
                              0.0) / max(params.resps_per_g, 1e-9))
        d_m2s = jnp.minimum(rdata, params.g_slots - g_resp)
        resp = resp - sent_resp
        rdata = rdata - d_m2s

        new_data = d_s2m + d_m2s
        # warm-up: skip the first quarter of the run when accumulating
        warm = warm + 1
        is_warm = (warm > n_flits // 4).astype(jnp.float32)
        data_slots = data_slots + new_data * is_warm
        warm_slots = warm_slots + is_warm
        return (rq, wq, wdata, rdata, resp, cr2, cw2, data_slots,
                warm_slots, warm), None

    init = tuple(jnp.zeros((), jnp.float32) for _ in range(9)) + (
        jnp.zeros((), jnp.int32),)
    (rq, wq, wd, rd, rs, _, _, data_slots, warm_slots, _), _ = jax.lax.scan(
        step, init, None, length=n_flits)
    # data bits delivered over both-direction capacity during warm window
    data_bits = data_slots * 128.0           # 16 B of payload per data slot
    cap_bits = 2.0 * warm_slots * params.flit_bits
    return float(data_bits / cap_bits)


@dataclasses.dataclass(frozen=True)
class AsymmetricLaneParams:
    """Lane-group geometry for the asymmetric mappings (A/B)."""

    total_lanes: int
    read_lanes: int
    write_lanes: int
    cmd_lanes: int
    cmd_bits_per_access: int
    access_bits: int = 576

    @classmethod
    def lpddr6(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=74, read_lanes=36, write_lanes=24,
                   cmd_lanes=10, cmd_bits_per_access=96)

    @classmethod
    def hbm(cls) -> "AsymmetricLaneParams":
        return cls(total_lanes=138, read_lanes=72, write_lanes=36,
                   cmd_lanes=24, cmd_bits_per_access=96)


def simulate_asymmetric(params: AsymmetricLaneParams, x: float, y: float,
                        n_accesses: int = 4096) -> float:
    """Lane-occupancy simulation: issue n accesses in x:y ratio, measure
    512*(n)/total_lanes*T — comparable to eq (3)."""
    xr = x / (x + y)

    def step(carry, i):
        t_read, t_write, t_cmd, credit = carry
        credit = credit + xr
        is_read = credit >= 1.0
        credit = jnp.where(is_read, credit - 1.0, credit)
        r_ui = params.access_bits / params.read_lanes
        w_ui = params.access_bits / params.write_lanes
        c_ui = params.cmd_bits_per_access / params.cmd_lanes
        t_read = t_read + jnp.where(is_read, r_ui, 0.0)
        t_write = t_write + jnp.where(is_read, 0.0, w_ui)
        t_cmd = t_cmd + c_ui
        return (t_read, t_write, t_cmd, credit), None

    init = (jnp.zeros((), jnp.float32),) * 4
    (t_r, t_w, t_c, _), _ = jax.lax.scan(step, init, jnp.arange(n_accesses))
    t_total = jnp.maximum(jnp.maximum(t_r, t_w), t_c)
    return float(512.0 * n_accesses / (params.total_lanes * t_total))


def simulate_lpddr6_pipelining(num_devices: int, n_lines: int = 512,
                               ucie_line_ui: int = 16,
                               device_line_ui: int = 64) -> float:
    """Appendix Fig 13: k x12 LPDDR6 devices time-multiplexed behind the
    logic die.  The UCIe link moves a 64 B line in 16 UI (36 read lanes at
    32 GT/s); each device sources a line every 64 UI (its DQ runs at 1/4 the
    UCIe rate).  Returns link data utilization — 1.0 at k = 4.

    Commands are pipelined (ACT/RD interleaved at 8-bit granularity, Fig 13)
    so the command bus never limits: we model device ready-times only.
    """
    def step(carry, i):
        dev_ready, link_free = carry
        dev = i % num_devices
        start = jnp.maximum(dev_ready[dev], link_free)
        finish = start + ucie_line_ui
        dev_ready = dev_ready.at[dev].set(start + device_line_ui)
        return (dev_ready, finish), finish

    dev_ready = jnp.zeros((num_devices,), jnp.float32)
    (_, _), finishes = jax.lax.scan(
        step, (dev_ready, jnp.zeros((), jnp.float32)),
        jnp.arange(n_lines))
    total_time = finishes[-1]
    busy_time = n_lines * ucie_line_ui
    return float(busy_time / total_time)


# -- convenience: analytic counterparts for the property tests ---------------

ANALYTIC = {
    "cxl_unopt": CXLMemOnUCIe(),
    "cxl_opt": CXLMemOptOnUCIe(),
    "chi": CHIOnUCIe(),
    "lpddr6_asym": LPDDR6OnUCIe(),
    "hbm_asym": HBMOnUCIe(),
}

SIMULATORS = {
    "cxl_unopt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_unopt(), x, y),
    "cxl_opt": lambda x, y: simulate_symmetric(SymmetricFlitParams.cxl_opt(), x, y),
    "chi": lambda x, y: simulate_symmetric(SymmetricFlitParams.chi(), x, y),
    "lpddr6_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.lpddr6(), x, y),
    "hbm_asym": lambda x, y: simulate_asymmetric(AsymmetricLaneParams.hbm(), x, y),
}
