"""UCIe PHY metric model — Table 1 and §IV.B of the paper.

Every quantity the protocol mappings (A-E) scale from lives here:
raw bandwidth, linear (shoreline) and areal bandwidth density, power
efficiency (pJ/b), dynamic power-gating parameters, and round-trip latency.

The canonical instances (``UCIE_S_32G``, ``UCIE_A_32G_55U``) carry the
paper's published density numbers (see DESIGN.md §6.4 for the one
ambiguity in the paper's UCIe-A arithmetic — we adopt the published
numbers as ground truth since Figures 10-12 scale from them).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping, Optional, Tuple


class Packaging(enum.Enum):
    STANDARD = "UCIe-S"   # 2D, 100-130um bump pitch, 25mm reach
    ADVANCED = "UCIe-A"   # 2.5D, 25-55um bump pitch, 2mm reach
    THREE_D = "UCIe-3D"   # hybrid bonding, <=9um pitch


# Idle lane power fraction under fine-grained dynamic power gating
# (§IV.B: "consuming p fraction (p = 0.15) of peak power").
IDLE_POWER_FRACTION = 0.15

# <1ns entry/exit with 85% savings (Table 1) -> we treat gating as free
# to enter/exit at flit granularity, consistent with the paper's analysis.
POWER_GATE_ENTRY_NS = 1.0

#: UCIePhy fields an analytic ``catalog_param`` perturbation may scale
#: (multiplicatively) — the closed-form counterpart of
#: :data:`repro.core.flitsim.PERTURBABLE_FIELDS`: PHY power efficiency and
#: the published shoreline/areal bandwidth densities.
PERTURBABLE_PHY_FIELDS: Tuple[str, ...] = (
    "areal_density_gbs_mm2", "linear_density_gbs_mm", "power_pj_per_bit")


@dataclasses.dataclass(frozen=True)
class UCIePhy:
    """One UCIe module configuration (per direction width)."""

    name: str
    packaging: Packaging
    data_rate_gtps: float          # per-lane signaling rate
    lanes_per_direction: int       # N data lanes each way (16 S / 64 A)
    bump_pitch_um: float
    modules_stacked: int = 2       # paper's density calcs double-stack
    # Published density numbers (GB/s per mm shoreline / per mm^2).
    linear_density_gbs_mm: float = 0.0
    areal_density_gbs_mm2: float = 0.0
    power_pj_per_bit: float = 0.5
    channel_reach_mm: float = 25.0
    # Footprint of the density reference block (both modules).
    edge_mm: float = 0.0
    depth_mm: float = 0.0

    @property
    def raw_bandwidth_gbs(self) -> float:
        """Both directions, all stacked modules, GB/s (GT/s * lanes / 8)."""
        return (2 * self.lanes_per_direction * self.modules_stacked
                * self.data_rate_gtps) / 8.0

    @property
    def raw_bandwidth_per_direction_gbs(self) -> float:
        return (self.lanes_per_direction * self.modules_stacked
                * self.data_rate_gtps) / 8.0

    def scaled(self, data_rate_gtps: float) -> "UCIePhy":
        """Same module at a different data rate (density scales linearly).

        §V: "UCIe should increase the operating frequency while continuing
        to be bump-limited with constant power efficiency."
        """
        f = data_rate_gtps / self.data_rate_gtps
        return dataclasses.replace(
            self,
            name=f"{self.name}@{data_rate_gtps:g}G",
            data_rate_gtps=data_rate_gtps,
            linear_density_gbs_mm=self.linear_density_gbs_mm * f,
            areal_density_gbs_mm2=self.areal_density_gbs_mm2 * f,
        )

    def perturbed(self, pert: Mapping[str, float]) -> "UCIePhy":
        """Multiplicative ``{field: scale}`` perturbation of the analytic
        PHY parameters — the catalog counterpart of the flit simulator's
        ``protocol_param`` scaling (see ``flitsim.apply_perturbation``).

        Only :data:`PERTURBABLE_PHY_FIELDS` may be scaled; anything else
        raises rather than silently producing a baseline labelled as
        perturbed.
        """
        unknown = sorted(k for k in pert if k not in PERTURBABLE_PHY_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown catalog perturbation fields {unknown}; choose "
                f"from {PERTURBABLE_PHY_FIELDS}")
        if not pert:
            return self
        return dataclasses.replace(
            self, **{k: getattr(self, k) * float(s)
                     for k, s in pert.items()})


# --- Canonical instances (paper §IV.B) -------------------------------------

# "A doubly stacked UCIe-S at 32G has a b/w = 2 directions x 32 data lanes
#  x 32 GT/s = 256 GB/s, bandwidth density is 224 GB/s/mm (linear) and
#  145.44 GB/s/mm2 at 110 um bump-pitch."
# x32 link footprint: 1.143mm (die edge) x 1.54mm (depth).
UCIE_S_32G = UCIePhy(
    name="UCIe-S-32G-110u",
    packaging=Packaging.STANDARD,
    data_rate_gtps=32.0,
    lanes_per_direction=16,        # x16 module; x32 link = 2 modules stacked
    bump_pitch_um=110.0,
    modules_stacked=2,
    linear_density_gbs_mm=224.0,
    areal_density_gbs_mm2=145.44,
    power_pj_per_bit=0.5,          # §IV.B: "0.25 to 0.5 pJ/b for UCIe-A/S"
    channel_reach_mm=25.0,
    edge_mm=1.143,
    depth_mm=1.54,
)

# "UCIe-A delivers 512 GB/s bandwidth for 64 data lanes; at 55um bump-pitch,
#  the bandwidth density is 658.44 GB/s/mm and 416.27 GB/s/mm2."
# UCIe-A fixed die-edge 388.8um; depth 1585um at 55um pitch.
UCIE_A_32G_55U = UCIePhy(
    name="UCIe-A-32G-55u",
    packaging=Packaging.ADVANCED,
    data_rate_gtps=32.0,
    lanes_per_direction=64,
    bump_pitch_um=55.0,
    modules_stacked=2,
    linear_density_gbs_mm=658.44,
    areal_density_gbs_mm2=416.27,
    power_pj_per_bit=0.25,
    channel_reach_mm=2.0,
    edge_mm=2 * 0.3888,
    depth_mm=1.585,
)

# 45um-pitch UCIe-A variant (depth 1043um). Density scales with bump count
# ~ (55/45)^2 areally; we scale the published 55u numbers by pitch ratio.
UCIE_A_32G_45U = dataclasses.replace(
    UCIE_A_32G_55U,
    name="UCIe-A-32G-45u",
    bump_pitch_um=45.0,
    depth_mm=1.043,
    linear_density_gbs_mm=658.44 * (55.0 / 45.0),
    areal_density_gbs_mm2=416.27 * (55.0 / 45.0) ** 2,
)


# --- Forward-looking UCIe 2.0 / 48G data points (§V scaling) ----------------
#
# §V: "UCIe should increase the operating frequency while continuing to be
# bump-limited with constant power efficiency" — the 48 GT/s generation
# keeps the lane counts and bump pitches of today's modules, so density
# scales linearly with data rate at constant pJ/b (``UCIePhy.scaled``).

# Standard package at 48 GT/s: 256 -> 384 GB/s per doubly-stacked x32 link.
UCIE_S_48G_110U = dataclasses.replace(
    UCIE_S_32G.scaled(48.0), name="UCIe-S-48G-110u")

# Advanced package at 48 GT/s on the 45um pitch: the paper's densest
# 2.5D point scaled to the next signaling generation.
UCIE_A_48G_45U = dataclasses.replace(
    UCIE_A_32G_45U.scaled(48.0), name="UCIe-A-48G-45u")


def table1() -> dict:
    """Reproduce the key-metrics rows of Table 1 from the model."""
    return {
        "UCIe-2D": {
            "data_rates_gtps": [4, 8, 12, 16, 24, 32],
            "width_per_direction": 16,
            "bump_pitch_um": (100, 130),
            "channel_reach_mm": 25,
            "bw_shoreline_gbs_mm": (28, 224),
            "bw_density_gbs_mm2": (22, 125),
            "power_pj_per_bit": {"<=16G": 0.5, ">16G": 0.6},
            "latency_roundtrip_ns": 2.0,
        },
        "UCIe-2.5D": {
            "data_rates_gtps": [4, 8, 12, 16, 24, 32],
            "width_per_direction": 64,
            "bump_pitch_um": (25, 55),
            "channel_reach_mm": 2,
            "bw_shoreline_gbs_mm": (165, 1317),
            "bw_density_gbs_mm2": None,  # 2.5D @ 45um covered by areal row
            "power_pj_per_bit": 0.25,
            "latency_roundtrip_ns": 2.0,
        },
        "UCIe-3D": {
            "data_rates_gtps": [4],
            "width_per_direction": 80,
            "bump_pitch_um": (1, 9),
            "channel_reach_mm": 0.0,
            "bw_density_gbs_mm2": (4000, 300000),
            "power_pj_per_bit": (0.01, 0.05),
            "latency_roundtrip_ns": 1.0,
        },
    }
