"""Axes-first design-space API over one shared batched engine.

The paper's headline claims come from sweeping protocol, PHY, traffic mix,
backlog and shoreline dimensions *jointly*.  This module is the single
front door to those sweeps:

  * :func:`axis` / :class:`Axis` / :class:`AxisSet` — a declarative spec of
    named design-space axes (``phy``, ``read_fraction``, ``mix``,
    ``backlog``, ``shoreline_mm``, ``workload_config``, ``protocol``,
    ``protocol_param``, ``catalog_param``, and the pipelining axes ``k`` /
    ``ucie_line_ui`` / ``device_line_ui``).
  * :class:`DesignSpace` — lowers any requested axis combination onto the
    existing batched ``lax.scan``/``vmap`` cores (flit simulators, analytic
    catalog, Fig-13 pipelining) through one shared shape-keyed compile
    cache, so the full joint space resolves in one compiled program per
    engine family.
  * :class:`SpaceResult` / :class:`SpaceArray` — named-axis outputs with
    label coordinates and ``sel()`` / ``isel()`` / ``argbest()`` /
    ``frontier()`` queries, replacing the four bespoke result dataclasses
    the legacy front-ends returned.
  * :func:`joint_frontier` — the first capability only expressible here:
    the joint (mix x backlog x shoreline) frontier that merges the
    flit-simulated efficiency grid with the analytic catalog grid and
    reports where simulation and the closed forms disagree.

The deprecated positional front-ends (``flitsim.sweep`` /
``sweep_pipelining``, ``memsys.catalog_grid``, ``selector.rank_grid``)
were retired in PR 10 after a deprecation cycle; their engines live on
as the private ``_sweep_impl`` / ``_sweep_pipelining_impl`` /
``_catalog_grid_impl`` / ``_rank_grid_impl`` functions this module
lowers onto, sharing the cache below — the migration table further down
maps each retired idiom to its axes-first replacement.

Shared compile cache
--------------------
Every batched engine memoizes its compiled executable here, keyed on
``(family, *static_key)`` where the static key encodes the catalog / param
stack and every grid shape and static length.  ``cache_stats()`` exposes
hit/miss counters globally or per family — one miss == one trace+compile;
tests assert the full joint space compiles exactly once per engine family
and that the ``_*_impl`` engines run warm against a space-primed cache.

Migration: PHY sweeps and feasibility masking
---------------------------------------------
The PHY is a first-class ``phy`` axis and feasibility is a first-class
mask; the pre-axis idioms map onto them as follows:

=====================================================  ======================
legacy idiom                                           axes-first equivalent
=====================================================  ======================
``approach_grid(phy, x, y).linear``                    ``DesignSpace([axis("phy", [phy]), axis("mix", ...)]).evaluate()`` →
                                                       ``res["linear_density_gbs_mm"].sel(phy=phy.name)``
two ``approach_grid`` calls (UCIe-A, UCIe-S)           one ``axis("phy", [UCIE_A_32G_55U, UCIE_S_32G, UCIE_A_48G_45U, ...])``
catalog keys ``"E:cxl-mem-opt/UCIe-A"``                system ``"E:cxl-mem-opt"`` x phy coordinate ``"UCIe-A-32G-55u"``
``rank_grid(x, y, constraints).best_keys()``           ``mask = res.feasible(constraints)`` then
                                                       ``res.frontier("bandwidth_gbs", where=mask)``
``grid_ranking(..., valid_mask=...)`` (bridge)         ``res.feasible(constraints)`` — the backlog-knee budget follows the
                                                       ``workload_config`` axis automatically
``flitsim.sweep_perturbed({field: scale})``            ``axis("protocol_param", [...])`` (flit params) /
                                                       ``axis("catalog_param", [...])`` (PHY pJ/b + densities)
``flitsim.sweep(mixes, backlogs)``                     ``DesignSpace([axis("backlog", ...), axis("mix", ...)],
                                                       sim=...).evaluate(metrics=("sim_efficiency",))``
``flitsim.sweep_pipelining(ks, ...)``                  ``axis("k", ks)`` [x ``axis("ucie_line_ui", ...)`` x
                                                       ``axis("device_line_ui", ...)``] → ``res["utilization"]``
``memsys.catalog_grid(x, y, shorelines)``              ``axis("read_fraction", ...)`` [x ``axis("shoreline_mm",
                                                       ...)``] → ``res["bandwidth_gbs"]`` etc.
whole-space materialize at 10^6+ cells                 ``evaluate(metrics=(m,), stream=StreamConfig(...))`` —
                                                       streamed chunks, running on-device frontier reductions
                                                       (:mod:`repro.core.streaming`)
explorer ``phy_frontier_report()`` / ``joint_frontier  ``space.report(ReportSpec(sections=...))`` /
(...)`` / ``serving_frontier(...)`` call sites         :func:`repro.core.report.build_report` — typed
                                                       ``FrontierReport`` sections, one API
=====================================================  ======================

Feasible-set masks are plain boolean :class:`SpaceArray` values:
``res.feasible(constraints)`` composes with ANY axis combination, and
``sel()`` / ``argbest()`` / ``frontier()`` accept them via ``where=``
(masked-out cells become NaN under ``sel``, are excluded from ``argbest``
/ ``frontier``, and grid points with no admissible system read
``"(none)"``, matching ``GridRanking.best_keys()``).

Simulation execution config (:class:`SimConfig`)
------------------------------------------------
The flit simulators run in one of two modes, selected by a
:class:`SimConfig` threaded through ``DesignSpace(sim=...)`` /
``evaluate(sim=...)`` and every engine entry point (``_sweep_impl``,
``backlog_knees``, ``joint_frontier``, ``bridge_design_space``):

* ``mode="fixed"`` (default) — the full fixed-horizon ``lax.scan``
  (n_flits=2048 / n_accesses=4096 / n_lines=512), bit-identical to the
  pre-config engine.  All pinned goldens are produced in this mode.
* ``mode="adaptive"`` — chunked ``lax.while_loop`` cores with batched
  early exit: the whole vmapped grid stops as soon as every cell's
  reconstructed fixed-window estimate has converged (see
  :mod:`repro.core.flitsim` for the algorithm).  Deviates from fixed by
  <= ``tol``-scale amounts while cutting the sequential depth several-x.

The config participates in the shared compile-cache key
(:meth:`SimConfig.key`), so switching between configs never invalidates
warm executables of other configs — each (family, grid shape, config)
triple compiles once and stays warm.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union,
)

import jax
import numpy as np

# =========================================================================
# Shared shape-keyed compile cache
# =========================================================================

#: cache families owned by the flit-simulation engine
FLITSIM_FAMILIES: Tuple[str, ...] = (
    "flitsim.symmetric", "flitsim.asymmetric", "flitsim.pipelining")
#: cache families owned by the analytic memory-system engine
MEMSYS_FAMILIES: Tuple[str, ...] = ("memsys.catalog", "memsys.approach")
#: cache families owned by the streaming chunk engine
#: (:mod:`repro.core.streaming`): ONE executable per chunk shape, reused
#: across every chunk and every dispatch of a streamed evaluation
STREAM_FAMILIES: Tuple[str, ...] = ("stream.sim", "stream.catalog")
#: every registered engine family — ``cache_stats(families=...)``
#: validates against this set (plus any ad-hoc family already counted)
KNOWN_FAMILIES: Tuple[str, ...] = (
    FLITSIM_FAMILIES + MEMSYS_FAMILIES + STREAM_FAMILIES)


@dataclasses.dataclass
class CacheStats:
    """Compile-cache counters: one miss == one trace+compile."""

    hits: int = 0
    misses: int = 0


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Execution config for the flit-simulation engines.

    ``mode="fixed"`` runs the full fixed-horizon ``lax.scan`` — bit-identical
    to the pre-config engine and to every pinned golden.  ``mode="adaptive"``
    runs the chunked early-exit cores: a ``lax.while_loop`` over chunks of
    ``chunk`` cycles (inner ``lax.scan`` with ``unroll=``) that stops as
    soon as every grid cell's reconstructed fixed-window estimate is stable
    to within ``tol`` (relative), or the horizon is hit.

    ``max_cycles`` overrides the per-family horizon (defaults: the caller's
    ``n_flits`` / ``n_accesses`` / ``n_lines``); ``chunk`` is shrunk per
    family to an exact divisor of the horizon (>= 8 chunks per run).  The
    config participates in the shared compile-cache key (:meth:`key`), so
    alternating configs never invalidates other configs' warm executables.

    ``engine`` picks the adaptive execution backend: ``"xla"`` (default)
    runs the chunked ``lax.while_loop`` cores; ``"pallas"`` runs the fused
    single-launch-per-chunk Pallas kernels from
    :mod:`repro.kernels.flit_sim` (``interpret=True`` off-TPU, real
    lowering on TPU).  The fixed mode is engine-independent by design —
    it must stay bit-identical to every pinned golden — so
    ``engine="pallas"`` requires ``mode="adaptive"``.
    """

    mode: str = "fixed"
    chunk: int = 128
    unroll: int = 4
    tol: float = 1e-3
    max_cycles: Optional[int] = None
    engine: str = "xla"
    #: cycles simulated per trace PHASE (``trace``-axis evaluations only);
    #: ``None`` uses the family's static horizon, which makes a default
    #: single-phase trace bit-identical to its static (mix, backlog) cell
    trace_cycles: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("fixed", "adaptive"):
            raise ValueError(f"SimConfig.mode must be 'fixed' or "
                             f"'adaptive', got {self.mode!r}")
        if self.engine not in ("xla", "pallas"):
            raise ValueError(f"SimConfig.engine must be 'xla' or "
                             f"'pallas', got {self.engine!r}")
        if self.engine == "pallas" and self.mode != "adaptive":
            raise ValueError(
                "SimConfig(engine='pallas') requires mode='adaptive': the "
                "fixed mode is pinned bit-identical to the golden numerics "
                "and always runs the XLA scan core")
        if int(self.chunk) < 8:
            raise ValueError(f"SimConfig.chunk must be >= 8, got "
                             f"{self.chunk}")
        if int(self.unroll) < 1:
            raise ValueError(f"SimConfig.unroll must be >= 1, got "
                             f"{self.unroll}")
        if not self.tol > 0.0:
            raise ValueError(f"SimConfig.tol must be > 0, got {self.tol}")
        if self.max_cycles is not None and int(self.max_cycles) < 1:
            raise ValueError(f"SimConfig.max_cycles must be >= 1, got "
                             f"{self.max_cycles}")
        if self.trace_cycles is not None and int(self.trace_cycles) < 8:
            raise ValueError(f"SimConfig.trace_cycles must be >= 8, got "
                             f"{self.trace_cycles}")

    def horizon(self, default: int) -> int:
        """Resolved horizon for a family whose fixed length is ``default``.

        The adaptive runner shrinks ``chunk`` to an exact divisor of the
        horizon (at least 8 chunks per run) so the chunked loop can always
        reproduce the fixed window exactly at full depth.
        """
        return int(self.max_cycles) if self.max_cycles is not None \
            else int(default)

    def key(self) -> Tuple:
        """Static cache-key component — distinct configs get distinct
        compiled executables; re-using a config re-uses its executable.

        ``trace_cycles`` appends only when set, keeping the default keys
        (and every golden pinned on them) unchanged."""
        trace = () if self.trace_cycles is None \
            else (int(self.trace_cycles),)
        if self.mode == "fixed":
            return ("fixed",) + trace
        return ("adaptive", int(self.chunk), int(self.unroll),
                float(self.tol), self.max_cycles, self.engine) + trace


#: the default config: bit-identical fixed-horizon simulation
FIXED_SIM = SimConfig()
#: convergence-adaptive early-exit simulation (benchmarks / explorer
#: default; <= tol-scale deviation from FIXED_SIM)
ADAPTIVE_SIM = SimConfig(mode="adaptive")
#: convergence-adaptive simulation on the fused Pallas kernels — one
#: launch per chunk instead of ~chunk dispatched ops (<= tol-scale
#: deviation from FIXED_SIM, same gate as ADAPTIVE_SIM)
PALLAS_SIM = SimConfig(mode="adaptive", engine="pallas")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Execution config for the tiled/streaming evaluation mode.

    ``DesignSpace.evaluate(..., stream=StreamConfig(...))`` switches from
    the materialized engines to the streaming engine
    (:mod:`repro.core.streaming`): the cell space is flattened along
    ``axis_order``, cut into chunks of at most ``chunk_cells`` cells per
    device, and every chunk runs through ONE cached executable that is
    ``shard_map``-ped over ``devices`` devices.  Frontier / argbest /
    feasibility resolve as running on-device reductions, so full per-cell
    metric tensors never exist on host or device — only the reduced
    winner codes (one small integer per cell) come back.

    * ``chunk_cells`` — the per-device, per-dispatch cell budget (the
      peak number of cells resident at once, asserted by the streaming
      benchmarks).  Clamped down when the space is smaller.
    * ``axis_order`` — the chunked cell-axis order (default: canonical
      :data:`AXIS_ORDER`).  Must be a permutation of the space's cell
      axes; it changes the dispatch order only, never the result.
    * ``devices`` — shard width (default: every local device; CPU runs
      expose more via ``XLA_FLAGS=--xla_force_host_platform_device_count``).
    * ``mode`` — argbest direction; ``None`` picks the metric's natural
      direction (``min`` for ``pj_per_bit`` / ``power_w``, else ``max``).
    * ``constraints`` — optional
      :class:`repro.core.selector.SelectionConstraints` folded into the
      on-device reduction for analytic metrics (cells with no admissible
      system read ``"(none)"``, matching the materialized frontier).
    * ``prefetch`` — bounded in-flight dispatch depth of the async
      double-buffered loop: the host marshals chunk ``t+1``'s cell
      indices (pure numpy) while up to ``prefetch`` earlier chunks are
      still executing on the device, and retires results strictly FIFO
      so the running reductions fold in the SAME order as the
      sequential loop (``prefetch=1``) — winners stay bit-identical at
      every depth.
    """

    chunk_cells: int = 4096
    axis_order: Optional[Tuple[str, ...]] = None
    devices: Optional[int] = None
    mode: Optional[str] = None
    constraints: Any = None
    prefetch: int = 2

    def __post_init__(self):
        if int(self.chunk_cells) < 1:
            raise ValueError(f"StreamConfig.chunk_cells must be >= 1, got "
                             f"{self.chunk_cells}")
        if int(self.prefetch) < 1:
            raise ValueError(f"StreamConfig.prefetch must be >= 1, got "
                             f"{self.prefetch}")
        if self.devices is not None and int(self.devices) < 1:
            raise ValueError(f"StreamConfig.devices must be >= 1, got "
                             f"{self.devices}")
        if self.mode not in (None, "max", "min"):
            raise ValueError(f"StreamConfig.mode must be None, 'max' or "
                             f"'min', got {self.mode!r}")
        if self.axis_order is not None:
            object.__setattr__(self, "axis_order",
                               tuple(str(a) for a in self.axis_order))

    def key(self) -> Tuple:
        """Static cache-key component (constraint VALUES are traced
        inputs, so changing a threshold reuses the warm executable; the
        constraint STRUCTURE — which checks are active — is static)."""
        cons = self.constraints
        cons_key = None if cons is None else (
            cons.packaging, cons.max_relative_bit_cost is not None,
            cons.max_backlog_knee is not None,
            cons.max_power_w is not None,
            cons.required_bandwidth_gbs is not None)
        return (int(self.chunk_cells), self.axis_order,
                None if self.devices is None else int(self.devices),
                self.mode, int(self.prefetch), cons_key)


_PROGRAMS: Dict[Tuple, Any] = {}
_FAMILY_STATS: Dict[str, CacheStats] = {}
#: executables retained per engine family; oldest-inserted evicted beyond
#: this (an interactive loop minting fresh catalogs/shapes must not pin
#: every compiled program forever)
MAX_PROGRAMS_PER_FAMILY = 32


def cached_program(family: str, key: Tuple, build_fn: Callable,
                   example_args: Tuple):
    """Return a compiled executable for ``build_fn`` memoized on
    ``(family, *key)``.

    Ahead-of-time compilation (``lower().compile()``) is preferred; if the
    backend refuses, the jitted callable (with jax's own in-memory cache)
    is stored instead.  A second identically-keyed request is a cache hit
    and runs the warm executable with zero retracing.  Each family keeps
    at most :data:`MAX_PROGRAMS_PER_FAMILY` executables (FIFO eviction).
    """
    stats = _FAMILY_STATS.setdefault(family, CacheStats())
    full_key = (family,) + tuple(key)
    entry = _PROGRAMS.get(full_key)
    if entry is not None:
        stats.hits += 1
        return entry
    stats.misses += 1
    jitted = jax.jit(build_fn)
    try:
        entry = jitted.lower(*example_args).compile()
    except Exception:          # pragma: no cover - backend-specific fallback
        entry = jitted
    family_keys = [k for k in _PROGRAMS if k[0] == family]
    if len(family_keys) >= MAX_PROGRAMS_PER_FAMILY:
        del _PROGRAMS[family_keys[0]]        # dict order == insertion order
    _PROGRAMS[full_key] = entry
    return entry


def cache_stats(families: Optional[Sequence[str]] = None) -> CacheStats:
    """Aggregate hit/miss counters, optionally restricted to ``families``.

    Unknown family names raise ``KeyError`` — they used to aggregate
    nothing, so a typo like ``"flitsim.symetric"`` silently reported zero
    compiles instead of failing the assertion that cited it.
    """
    if families is not None:
        known = set(KNOWN_FAMILIES) | set(_FAMILY_STATS)
        bad = sorted(set(families) - known)
        if bad:
            raise KeyError(f"unknown cache families {bad}; choose from "
                           f"{sorted(known)}")
    out = CacheStats()
    for fam, st in _FAMILY_STATS.items():
        if families is None or fam in families:
            out.hits += st.hits
            out.misses += st.misses
    return out


def clear_cache(families: Optional[Sequence[str]] = None) -> None:
    """Drop cached executables (all, or only ``families``) and reset the
    matching counters."""
    for key in list(_PROGRAMS):
        if families is None or key[0] in families:
            del _PROGRAMS[key]
    for fam in list(_FAMILY_STATS):
        if families is None or fam in families:
            del _FAMILY_STATS[fam]


# =========================================================================
# Axes
# =========================================================================

#: sentinel mix value: resolve to each workload config's own HLO-derived mix
OWN_MIX = "own"

#: canonical axis order — result dims always follow this order (with the
#: implicit ``system`` / ``protocol`` / ``approach`` dims leading; the
#: ``phy`` axis trails the stack dim, mirroring how ``protocol`` leads
#: ``backlog``)
AXIS_ORDER: Tuple[str, ...] = (
    "catalog_param", "phy", "protocol_param", "protocol", "backlog",
    "trace", "workload_config", "mix", "read_fraction", "shoreline_mm",
    "k", "ucie_line_ui", "device_line_ui")

_MIX_LIKE = ("mix", "read_fraction")


def _mix_label(x: float, y: float) -> str:
    return f"{x:g}R{y:g}W"


def _as_mix_tuple(v) -> Tuple[float, float]:
    if hasattr(v, "x") and hasattr(v, "y"):         # TrafficMix
        x, y = float(v.x), float(v.y)
    else:
        x, y = v
        x, y = float(x), float(y)
    if x < 0 or y < 0 or x + y <= 0:
        raise ValueError(f"invalid traffic mix x={x} y={y}: need x, y >= 0 "
                         "and x + y > 0")
    return x, y


def _as_workload(v) -> Tuple[str, Any]:
    """Normalize a workload_config entry to (name, TrafficMix)."""
    from repro.core.traffic import TrafficMix
    name, w = v
    if hasattr(w, "read_bytes_per_chip"):           # RooflineReport-like
        w = TrafficMix.from_bytes(w.read_bytes_per_chip,
                                  w.write_bytes_per_chip)
    elif not (hasattr(w, "x") and hasattr(w, "y")):
        x, y = _as_mix_tuple(w)
        w = TrafficMix(x, y)
    return str(name), w


def _as_perturbation(v) -> Tuple[str, Tuple[Tuple[str, float], ...]]:
    """Normalize a protocol_param entry to (label, sorted field->scale)."""
    if isinstance(v, Mapping):
        label, pert = None, v
    else:
        label, pert = v
    items = tuple(sorted((str(k), float(s)) for k, s in pert.items()))
    if label is None:
        # "+"-joined (not ","): labels land in CSV benchmark columns
        label = "+".join(f"{k}x{s:g}" for k, s in items) or "baseline"
    return str(label), items


@dataclasses.dataclass(frozen=True)
class Axis:
    """One named design-space axis: canonical values plus display labels."""

    name: str
    values: Tuple[Any, ...]
    labels: Tuple[Any, ...]

    def __len__(self) -> int:
        return len(self.values)

    def index(self, label) -> int:
        """Position of ``label`` (accepts raw values for mix-like axes and
        ``UCIePhy`` objects for the ``phy`` axis)."""
        if label in self.labels:
            return self.labels.index(label)
        if self.name == "phy" and label in self.values:
            return self.values.index(label)
        if self.name == "mix" and label != OWN_MIX:
            return self.labels.index(_mix_label(*_as_mix_tuple(label)))
        if self.name in ("backlog", "shoreline_mm", "read_fraction",
                         "ucie_line_ui", "device_line_ui"):
            return self.labels.index(float(label))
        if self.name == "k":
            return self.labels.index(int(label))
        raise KeyError(f"label {label!r} not on axis {self.name!r}: "
                       f"{self.labels}")


def axis(name: str, values: Sequence[Any],
         labels: Optional[Sequence[Any]] = None) -> Axis:
    """Build a validated :class:`Axis`; values are normalized per axis kind.

    ``mix`` accepts ``(x, y)`` tuples, ``TrafficMix`` objects, or the
    :data:`OWN_MIX` sentinel (resolved per ``workload_config``).
    ``workload_config`` accepts a mapping or ``(name, mix-or-report)``
    pairs.  ``protocol_param`` accepts ``{field: scale}`` dicts or
    ``(label, dict)`` pairs — multiplicative perturbations applied to the
    flit-simulator parameter stacks; ``catalog_param`` is its analytic
    twin (PHY pJ/b and shoreline/areal density scales).  ``phy`` accepts
    :class:`repro.core.ucie.UCIePhy` instances (labels: their names).
    """
    vals = list(values.items()) if isinstance(values, Mapping) else \
        list(values)
    if not vals:
        raise ValueError(f"axis {name!r} needs at least one value")
    if name == "phy":
        from repro.core.ucie import UCIePhy
        bad = [v for v in vals if not isinstance(v, UCIePhy)]
        if bad:
            raise ValueError(f"axis 'phy' values must be UCIePhy "
                             f"instances, got {bad}")
        norm = list(vals)
        labs = [p.name for p in vals]
        if len(set(labs)) != len(labs):
            raise ValueError(f"duplicate phy names on the axis: {labs}")
    elif name == "catalog_param":
        from repro.core.ucie import PERTURBABLE_PHY_FIELDS
        norm = [_as_perturbation(v) for v in vals]
        for _, items in norm:
            unknown = sorted(k for k, _ in items
                             if k not in PERTURBABLE_PHY_FIELDS)
            if unknown:
                raise ValueError(
                    f"unknown catalog perturbation fields {unknown}; "
                    f"choose from {PERTURBABLE_PHY_FIELDS}")
        labs = [lab for lab, _ in norm]
    elif name == "mix":
        norm = [OWN_MIX if (isinstance(v, str) and v == OWN_MIX)
                else _as_mix_tuple(v) for v in vals]
        labs = [OWN_MIX if v == OWN_MIX else _mix_label(*v) for v in norm]
    elif name == "read_fraction":
        norm = [float(v) for v in vals]
        for r in norm:
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"read_fraction {r} outside [0, 1]")
        labs = list(norm)
    elif name == "workload_config":
        norm = [_as_workload(v) for v in vals]
        labs = [n for n, _ in norm]
    elif name == "protocol":
        norm = [str(v) for v in vals]
        labs = list(norm)
    elif name == "trace":
        from repro.traces.trace import TrafficTrace, pad_traces
        bad = [v for v in vals if not isinstance(v, TrafficTrace)]
        if bad:
            raise ValueError(f"axis 'trace' values must be TrafficTrace "
                             f"instances, got {bad}")
        # pad to one shared phase count so the whole axis runs as ONE
        # [T, N] grid through one compiled executable
        norm = list(pad_traces(vals))
        labs = [t.name for t in norm]
        if len(set(labs)) != len(labs):
            raise ValueError(f"duplicate trace names on the axis: {labs}")
    elif name == "protocol_param":
        norm = [_as_perturbation(v) for v in vals]
        labs = [lab for lab, _ in norm]
    elif name == "k":
        norm = [int(v) for v in vals]
        labs = list(norm)
    elif name in ("backlog", "shoreline_mm", "ucie_line_ui",
                  "device_line_ui"):
        norm = [float(v) for v in vals]
        labs = list(norm)
    else:
        raise ValueError(f"unknown axis name {name!r}; choose from "
                         f"{AXIS_ORDER}")
    if labels is not None:
        if len(labels) != len(norm):
            raise ValueError(f"axis {name!r}: {len(labels)} labels for "
                             f"{len(norm)} values")
        labs = list(labels)
    return Axis(name=name, values=tuple(norm), labels=tuple(labs))


class AxisSet:
    """Ordered, validated collection of axes (canonical order, unique
    names, ``mix``/``read_fraction`` mutually exclusive)."""

    def __init__(self, *axes: Union[Axis, Sequence[Axis]]):
        flat: List[Axis] = []
        for a in axes:
            if isinstance(a, Axis):
                flat.append(a)
            else:
                flat.extend(a)
        names = [a.name for a in flat]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names in {names}")
        if "mix" in names and "read_fraction" in names:
            raise ValueError("axes 'mix' and 'read_fraction' are mutually "
                             "exclusive — both name the traffic-mix axis")
        if "trace" in names:
            clash = sorted(set(names) & {"backlog", "mix", "read_fraction",
                                         "workload_config"})
            if clash:
                raise ValueError(
                    f"axis 'trace' is exclusive with {clash}: a trace's "
                    "phases already carry the mix and backlog trajectory")
        self._axes: Dict[str, Axis] = {
            name: next(a for a in flat if a.name == name)
            for name in sorted(names, key=AXIS_ORDER.index)}

    def __contains__(self, name: str) -> bool:
        return name in self._axes

    def __getitem__(self, name: str) -> Axis:
        return self._axes[name]

    def __iter__(self):
        return iter(self._axes.values())

    def __len__(self) -> int:
        return len(self._axes)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._axes)

    def get(self, name: str) -> Optional[Axis]:
        return self._axes.get(name)

    def mix_axis(self) -> Optional[Axis]:
        return self._axes.get("mix") or self._axes.get("read_fraction")


# =========================================================================
# Named-axis results
# =========================================================================


def _union_layout(a: "SpaceArray", b: "SpaceArray"
                  ) -> Tuple[Tuple[str, ...], Tuple[Tuple[Any, ...], ...]]:
    """Union of two arrays' named dims (a's order first, b's extras
    appended), with coords reconciled — mismatched labels on a shared dim
    are an error, not a silent broadcast."""
    dims = list(a.dims) + [d for d in b.dims if d not in a.dims]
    coords = []
    for d in dims:
        ca = a.coord(d) if d in a.dims else None
        cb = b.coord(d) if d in b.dims else None
        if ca is not None and cb is not None and ca != cb:
            raise ValueError(f"dim {d!r} has mismatched coords: "
                             f"{ca} vs {cb}")
        coords.append(ca if ca is not None else cb)
    return tuple(dims), tuple(coords)


def _expand_to(dims: Tuple[str, ...], coords, arr: "SpaceArray"
               ) -> np.ndarray:
    """View of ``arr.values`` broadcastable over the ``dims`` layout."""
    unknown = [d for d in arr.dims if d not in dims]
    if unknown:
        raise ValueError(f"dims {unknown} of the operand are not in the "
                         f"target layout {dims}")
    perm = sorted(range(len(arr.dims)),
                  key=lambda i: dims.index(arr.dims[i]))
    v = np.transpose(arr.values, perm)
    shape = tuple(len(coords[j]) if dims[j] in arr.dims else 1
                  for j in range(len(dims)))
    return v.reshape(shape)


def _as_mask(where, like: "SpaceArray") -> "SpaceArray":
    """Normalize a ``where=`` operand to a boolean :class:`SpaceArray`
    (raw arrays are taken over ``like``'s layout)."""
    if isinstance(where, SpaceArray):
        return SpaceArray(where.dims, where.coords,
                          np.asarray(where.values, bool))
    return SpaceArray(like.dims, like.coords,
                      np.broadcast_to(np.asarray(where, bool), like.shape))


@dataclasses.dataclass(frozen=True)
class SpaceArray:
    """A metric array with named dims and label coordinates."""

    dims: Tuple[str, ...]
    coords: Tuple[Tuple[Any, ...], ...]      # labels, aligned with dims
    values: np.ndarray

    def __post_init__(self):
        if len(self.dims) != len(self.coords) or \
                tuple(len(c) for c in self.coords) != self.values.shape:
            raise ValueError(
                f"dims {self.dims} / coords "
                f"{tuple(len(c) for c in self.coords)} do not match value "
                f"shape {self.values.shape}")

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.values.shape

    def coord(self, dim: str) -> Tuple[Any, ...]:
        return self.coords[self.dims.index(dim)]

    def _label_index(self, dim: str, label) -> int:
        labels = self.coord(dim)
        if label in labels:
            return labels.index(label)
        # a UCIePhy (or anything named) selects by its name on a phy dim
        if getattr(label, "name", None) in labels:
            return labels.index(label.name)
        if dim == "mix" and label != OWN_MIX:
            try:
                return labels.index(_mix_label(*_as_mix_tuple(label)))
            except (TypeError, ValueError):
                pass
        try:
            return labels.index(float(label))
        except (TypeError, ValueError):
            raise KeyError(f"label {label!r} not on dim {dim!r}: {labels}")

    def isel(self, **indexers: int) -> "SpaceArray":
        """Integer selection; each selected dim is dropped."""
        out = self.values
        dims, coords = list(self.dims), list(self.coords)
        for dim in sorted(indexers, key=self.dims.index, reverse=True):
            ax = dims.index(dim)
            out = np.take(out, indexers[dim], axis=ax)
            del dims[ax], coords[ax]
        return SpaceArray(tuple(dims), tuple(coords), np.asarray(out))

    def sel(self, *, where=None, **labels) -> "SpaceArray":
        """Label-based selection; each selected dim is dropped.

        ``where`` (a boolean :class:`SpaceArray`, e.g. from
        :meth:`SpaceResult.feasible`, or a raw broadcastable array) masks
        the selected values: cells outside the mask become NaN.  A
        ``SpaceArray`` mask is label-selected alongside the data, so the
        same mask composes with any slicing.
        """
        out = self.isel(**{d: self._label_index(d, v)
                           for d, v in labels.items()})
        if where is None:
            return out
        w = _as_mask(where, self)
        w = w.isel(**{d: w._label_index(d, v) for d, v in labels.items()
                      if d in w.dims})
        dims, coords = _union_layout(out, w)
        if dims != out.dims:
            raise ValueError(
                f"where-mask dims {w.dims} are not a subset of the "
                f"selected array dims {out.dims}")
        wv = np.broadcast_to(_expand_to(dims, coords, w), out.shape)
        return SpaceArray(out.dims, out.coords,
                          np.where(wv, out.values, np.nan))

    def argbest(self, dim: str = "system", mode: str = "max",
                where=None) -> "SpaceArray":
        """Best label along ``dim`` per remaining point.

        ``where`` (boolean :class:`SpaceArray` or broadcastable array)
        restricts the candidates: masked-out entries never win, and points
        where NOTHING is admissible read ``"(none)"`` (the
        ``GridRanking.best_keys()`` sentinel).  A mask carrying extra dims
        (e.g. a per-shoreline feasibility mask applied to a per-system
        latency column) broadcasts the result over them.
        """
        if mode not in ("max", "min"):
            raise ValueError(f"mode must be 'max' or 'min', got {mode!r}")
        if where is None:
            ax = self.dims.index(dim)
            idx = (np.argmax if mode == "max" else np.argmin)(self.values,
                                                              axis=ax)
            labels = np.asarray(self.coord(dim), dtype=object)[idx]
            dims = self.dims[:ax] + self.dims[ax + 1:]
            coords = self.coords[:ax] + self.coords[ax + 1:]
            return SpaceArray(dims, coords, labels)
        w = _as_mask(where, self)
        dims, coords = _union_layout(self, w)
        if dim not in dims:
            raise KeyError(f"dim {dim!r} not in {dims}")
        shape = tuple(len(c) for c in coords)
        vals = np.broadcast_to(_expand_to(dims, coords, self), shape)
        wv = np.broadcast_to(_expand_to(dims, coords, w), shape)
        fill = -np.inf if mode == "max" else np.inf
        masked = np.where(wv, np.asarray(vals, np.float64), fill)
        ax = dims.index(dim)
        idx = (np.argmax if mode == "max" else np.argmin)(masked, axis=ax)
        labels = np.asarray(coords[ax], dtype=object)[idx]
        labels = np.where(wv.any(axis=ax), labels, "(none)")
        return SpaceArray(dims[:ax] + dims[ax + 1:],
                          coords[:ax] + coords[ax + 1:],
                          np.asarray(labels, dtype=object))

    def best(self, dim: str = "system", mode: str = "max",
             where=None) -> "SpaceArray":
        """Best value along ``dim`` per remaining point (NaN where the
        ``where`` mask admits nothing)."""
        if mode not in ("max", "min"):
            raise ValueError(f"mode must be 'max' or 'min', got {mode!r}")
        if where is None:
            ax = self.dims.index(dim)
            red = (np.max if mode == "max" else np.min)(self.values,
                                                        axis=ax)
            dims = self.dims[:ax] + self.dims[ax + 1:]
            coords = self.coords[:ax] + self.coords[ax + 1:]
            return SpaceArray(dims, coords, np.asarray(red))
        w = _as_mask(where, self)
        dims, coords = _union_layout(self, w)
        shape = tuple(len(c) for c in coords)
        vals = np.broadcast_to(_expand_to(dims, coords, self), shape)
        wv = np.broadcast_to(_expand_to(dims, coords, w), shape)
        fill = -np.inf if mode == "max" else np.inf
        masked = np.where(wv, np.asarray(vals, np.float64), fill)
        ax = dims.index(dim)
        red = (np.max if mode == "max" else np.min)(masked, axis=ax)
        red = np.where(wv.any(axis=ax), red, np.nan)
        return SpaceArray(dims[:ax] + dims[ax + 1:],
                          coords[:ax] + coords[ax + 1:], np.asarray(red))


@dataclasses.dataclass(frozen=True)
class SpaceResult:
    """Named-axis evaluation of a :class:`DesignSpace`.

    ``arrays`` maps metric name -> :class:`SpaceArray`; every array's dims
    are a subset of the implicit stack dims (``system`` / ``protocol`` /
    ``approach``) plus the requested axes, in canonical order.  ``sim``
    records the :class:`SimConfig` the flit-simulated metrics were
    evaluated under (``None`` for results predating the config).
    """

    axes: AxisSet
    arrays: Dict[str, SpaceArray]
    sim: Optional["SimConfig"] = None

    def __getitem__(self, metric: str) -> SpaceArray:
        return self.arrays[metric]

    def __contains__(self, metric: str) -> bool:
        return metric in self.arrays

    @property
    def metrics(self) -> Tuple[str, ...]:
        return tuple(self.arrays)

    def sel(self, *, where=None, **labels) -> "SpaceResult":
        """Label-select across every array carrying the named dims.

        Arrays without a requested dim pass through untouched, but a dim
        present on NO array is an error — a typo must not silently return
        the unfiltered result.  ``where`` (a boolean :class:`SpaceArray`,
        e.g. from :meth:`feasible`) NaN-masks every array that carries all
        of the mask's (post-selection) dims; arrays that don't pass
        through untouched.
        """
        known = {d for arr in self.arrays.values() for d in arr.dims}
        missing = [d for d in labels if d not in known]
        if missing:
            raise KeyError(f"dims {missing} not present on any array; "
                           f"available dims: {sorted(known)}")
        w_sel = None
        if where is not None:
            w_sel = _as_mask(where, next(iter(self.arrays.values())))
            w_sel = w_sel.isel(**{d: w_sel._label_index(d, v)
                                  for d, v in labels.items()
                                  if d in w_sel.dims})
        out = {}
        for name, arr in self.arrays.items():
            use = {d: v for d, v in labels.items() if d in arr.dims}
            a2 = arr.isel(**{d: arr._label_index(d, v)
                             for d, v in use.items()}) if use else arr
            if w_sel is not None and set(w_sel.dims) <= set(a2.dims):
                a2 = a2.sel(where=w_sel)
            out[name] = a2
        return SpaceResult(axes=self.axes, arrays=out, sim=self.sim)

    def argbest(self, metric: str, dim: str = "system",
                mode: str = "max", where=None) -> SpaceArray:
        return self.arrays[metric].argbest(dim, mode, where=where)

    def frontier(self, metric: str, dim: str = "system",
                 mode: str = "max", where=None) -> SpaceArray:
        """Alias of :meth:`argbest` — the winning label per grid point.

        ``where=res.feasible(constraints)`` restricts the frontier to the
        admissible set; points where nothing is admissible read
        ``"(none)"``.
        """
        return self.argbest(metric, dim, mode, where=where)

    def feasible(self, constraints=None, *,
                 catalog: Optional[Mapping[str, Any]] = None,
                 sim: Optional["SimConfig"] = None) -> SpaceArray:
        """First-class feasibility: a boolean :class:`SpaceArray` marking
        which (system, grid-point) cells satisfy ``constraints``
        (:class:`repro.core.selector.SelectionConstraints`).

        The mask composes with ARBITRARY axes — pass it to ``sel()`` /
        ``argbest()`` / ``frontier()`` via ``where=``.  Constraint
        semantics:

        * packaging / relative bit cost — per system; with a ``phy`` axis
          the packaging constraint masks along the phy dim instead of
          parsing ``/UCIe-A`` key suffixes.
        * ``max_backlog_knee`` — the queue-depth budget follows the most
          specific traffic information available: per ``workload_config``
          (each workload's OWN HLO-derived mix — the bridge semantics),
          else per mix point along the ``mix``/``read_fraction`` axis,
          else the canonical-mix envelope.
        * ``max_power_w`` / ``required_bandwidth_gbs`` — point-dependent,
          read from the evaluated ``power_w`` / ``bandwidth_gbs`` arrays.

        ``catalog`` must echo the ``DesignSpace(catalog=...)`` mapping when
        a custom one was evaluated (the result only carries keys).
        ``sim`` selects the :class:`SimConfig` the backlog-knee extraction
        runs under (default: this result's config, falling back to the
        fixed engine — the mode every pinned knee golden was produced in).
        """
        from repro.core import memsys
        from repro.core import selector as selector_mod
        if constraints is None:
            constraints = selector_mod.SelectionConstraints()
        base = None
        for m in ANALYTIC_METRICS:
            if m in self.arrays:
                base = self.arrays[m]
                break
        if base is None:
            raise ValueError(
                "feasible() needs at least one analytic catalog metric "
                f"({ANALYTIC_METRICS}) on the result; evaluate them first")
        dims, coords = base.dims, base.coords
        keys = base.coord("system")
        mask = np.ones(tuple(len(c) for c in coords), dtype=bool)

        def apply(sub_dims, sub_vals):
            sub = SpaceArray(tuple(sub_dims),
                             tuple(coords[dims.index(d)] for d in sub_dims),
                             np.asarray(sub_vals))
            return np.broadcast_to(_expand_to(dims, coords, sub),
                                   mask.shape)

        phy_ax = self.axes.get("phy")
        if phy_ax is not None and "phy" in dims:
            items = dict(memsys.approach_catalog_items())
            missing = [k for k in keys if k not in items]
            if missing:
                raise ValueError(f"unknown approach keys {missing} on the "
                                 "system axis of a phy-stacked result")
            items = tuple((k, items[k]) for k in keys)
            if constraints.packaging:
                mask &= apply(("phy",), [
                    p.packaging.value == constraints.packaging
                    for p in phy_ax.values])
            if constraints.max_relative_bit_cost is not None:
                mask &= apply(("system",), [
                    ms.relative_bit_cost <= constraints.max_relative_bit_cost
                    for _, ms in items])
        else:
            items = (memsys.default_catalog_items() if catalog is None
                     else tuple(catalog.items()))
            if tuple(k for k, _ in items) != tuple(keys):
                raise ValueError(
                    "catalog keys do not match the result's system axis; "
                    "pass feasible(catalog=...) matching the evaluated "
                    "DesignSpace(catalog=...)")
            static = selector_mod.system_mask(
                items, dataclasses.replace(constraints,
                                           max_backlog_knee=None))
            mask &= apply(("system",), static)

        if constraints.max_backlog_knee is not None:
            mask &= self._knee_mask(keys, constraints, apply,
                                    sim if sim is not None else self.sim)

        if constraints.max_power_w is not None:
            pw = self.arrays.get("power_w")
            if pw is None:
                raise ValueError("a max_power_w constraint needs the "
                                 "'power_w' metric on the result")
            mask &= apply(pw.dims, pw.values <= constraints.max_power_w)
        if constraints.required_bandwidth_gbs is not None:
            bw = self.arrays.get("bandwidth_gbs")
            if bw is None:
                raise ValueError("a required_bandwidth_gbs constraint "
                                 "needs the 'bandwidth_gbs' metric on the "
                                 "result")
            mask &= apply(bw.dims,
                          bw.values >= constraints.required_bandwidth_gbs)
        return SpaceArray(dims, coords, mask)

    def _knee_mask(self, keys, constraints, apply,
                   sim: Optional["SimConfig"] = None) -> np.ndarray:
        """Backlog-knee admissibility at the most specific mix available:
        per workload config, else per mix point, else the envelope."""
        from repro.core import flitsim
        from repro.core import selector as selector_mod
        budget = constraints.max_backlog_knee
        simkeys = [selector_mod.sim_key_for(k) for k in keys]
        cfg = self.axes.get("workload_config")
        mix_ax = self.axes.mix_axis()
        if cfg is not None:
            mixes = [(w.x, w.y) for _, w in cfg.values]
            per_dims = ("system", "workload_config")
        elif mix_ax is not None and OWN_MIX not in mix_ax.values:
            if mix_ax.name == "read_fraction":
                mixes = [(100.0 * r, 100.0 - 100.0 * r)
                         for r in mix_ax.values]
            else:
                mixes = list(mix_ax.values)
            per_dims = ("system", mix_ax.name)
        else:
            knees = selector_mod._default_knees()
            sub = [sk is None or knees[sk] <= budget for sk in simkeys]
            return apply(("system",), sub)
        per = flitsim.backlog_knees(mixes=mixes, per_mix=True, sim=sim)
        sub = np.ones((len(keys), len(mixes)), dtype=bool)
        for i, sk in enumerate(simkeys):
            if sk is not None:
                sub[i] = per[sk] <= budget
        return apply(per_dims, sub)


def regimes(labels: Sequence[Any], fracs: Sequence[float]
            ) -> List[Tuple[float, float, Any]]:
    """Contiguous (lo, hi, label) regimes along a fraction axis.

    Boundaries fall at the midpoint between the last grid sample of one
    winner and the first of the next; the regimes tile [0, 1] exactly.
    """
    labels = list(labels)
    fracs = [float(f) for f in fracs]
    out: List[Tuple[float, float, Any]] = []
    start, lo = 0, 0.0
    for j in range(1, len(labels) + 1):
        if j == len(labels) or labels[j] != labels[start]:
            hi = 1.0 if j == len(labels) else (fracs[j - 1] + fracs[j]) / 2.0
            out.append((lo, hi, labels[start]))
            start, lo = j, hi
    return out


# =========================================================================
# DesignSpace
# =========================================================================

#: analytic catalog metrics (dims: system [x configs] [x mix] [x shoreline])
ANALYTIC_METRICS: Tuple[str, ...] = (
    "bandwidth_gbs", "pj_per_bit", "power_w", "gbs_per_watt")
#: per-system static columns (dims: system)
SYSTEM_METRICS: Tuple[str, ...] = ("latency_ns", "relative_bit_cost")
#: flit-simulated metrics (dims: [pert x] protocol [x backlog] ...)
SIM_METRICS: Tuple[str, ...] = ("sim_efficiency", "analytic_efficiency")
#: PHY-absolute flit-simulated metric (needs a ``phy`` axis or
#: ``DesignSpace(phy=...)``): simulated efficiency x the PHY's raw link
#: bandwidth -> absolute GB/s, so the simulation-corrected frontier sweeps
#: PHY generations (32G/48G) like the closed forms do
SIM_PHY_METRICS: Tuple[str, ...] = ("sim_bandwidth_gbs",)
#: approach-density metrics on a PHY (dims: approach [x configs] [x mix])
APPROACH_METRICS: Tuple[str, ...] = (
    "linear_density_gbs_mm", "areal_density_gbs_mm2", "approach_pj_per_bit")
#: Fig-13 pipelining metric (dims: k [x ucie_line_ui] [x device_line_ui])
PIPELINE_METRICS: Tuple[str, ...] = ("utilization",)
#: trace-scan metrics (need a ``trace`` axis): duration-weighted
#: efficiency over the phase sequence (dims: [pert x] protocol x trace)
#: and the raw per-phase grid (... x phase) with state carried across
#: phase boundaries
TRACE_METRICS: Tuple[str, ...] = ("trace_efficiency",
                                  "trace_phase_efficiency")
#: PHY-absolute trace metric (needs a ``phy`` axis or
#: ``DesignSpace(phy=...)``): duration-weighted efficiency x raw link
#: bandwidth -> delivered GB/s over the serving trace
TRACE_PHY_METRICS: Tuple[str, ...] = ("trace_bandwidth_gbs",)


class DesignSpace:
    """A declarative, axes-first view of the paper's design space.

    ``DesignSpace(axes).evaluate()`` lowers the requested axis combination
    onto the batched engines — the analytic catalog program, the flit
    simulators, and the Fig-13 pipelining model — through the shared
    compile cache, and returns a :class:`SpaceResult`.

        space = DesignSpace([
            axis("workload_config", reports.items()),
            axis("mix", [OWN_MIX, (2, 1), (1, 1)]),
            axis("backlog", [4, 64]),
            axis("shoreline_mm", [4.0, 8.0]),
        ])
        res = space.evaluate()
        res["bandwidth_gbs"].argbest("system")      # frontier labels
        res["sim_efficiency"].sel(backlog=64)

    Every distinct (engine, stack, grid-shape, static-length) combination
    compiles exactly once; identically-shaped requests — from this class or
    from any legacy wrapper — run the warm executable.
    """

    def __init__(self, axes: Union[AxisSet, Sequence[Axis]], *,
                 catalog: Optional[Dict[str, Any]] = None,
                 phy: Any = None,
                 default_shoreline_mm: float = 8.0,
                 default_backlog: float = 64.0,
                 n_flits: int = 2048, n_accesses: int = 4096,
                 n_lines: int = 512,
                 sim: Optional[SimConfig] = None):
        self.axes = axes if isinstance(axes, AxisSet) else AxisSet(axes)
        self.catalog = catalog
        self.phy = phy
        self.default_shoreline_mm = float(default_shoreline_mm)
        self.default_backlog = float(default_backlog)
        self.n_flits = int(n_flits)
        self.n_accesses = int(n_accesses)
        self.n_lines = int(n_lines)
        self.sim = sim if sim is not None else FIXED_SIM
        mix_ax = self.axes.mix_axis()
        if mix_ax is not None and mix_ax.name == "mix":
            if OWN_MIX in mix_ax.values and \
                    "workload_config" not in self.axes:
                raise ValueError("mix axis uses OWN_MIX but no "
                                 "workload_config axis provides the mixes")
        if "phy" in self.axes:
            if self.phy is not None:
                raise ValueError("pass the PHY either as "
                                 "DesignSpace(phy=...) or as a 'phy' "
                                 "axis, not both")
            if self.catalog is not None:
                raise ValueError(
                    "a 'phy' axis stacks the per-approach templates "
                    "(memsys.approach_catalog_items) and is incompatible "
                    "with a custom catalog= of PHY-baked systems")

    # -- lowering helpers ---------------------------------------------------

    def _mix_arrays(self) -> Tuple[np.ndarray, np.ndarray, Tuple[str, ...]]:
        """x / y arrays over the present (workload_config, mix) axes.

        Returns float32 arrays shaped ``[C, M]`` / ``[C]`` / ``[M]`` (or
        ``[1]`` when neither axis is present) plus the dim names covered.
        """
        cfg = self.axes.get("workload_config")
        mix_ax = self.axes.mix_axis()
        if mix_ax is not None and mix_ax.name == "read_fraction":
            mixes = [(100.0 * r, 100.0 - 100.0 * r)
                     for r in mix_ax.values]
        elif mix_ax is not None:
            mixes = list(mix_ax.values)
        else:
            mixes = None
        if cfg is not None and mixes is not None:
            x = np.empty((len(cfg), len(mixes)), np.float32)
            y = np.empty_like(x)
            for c, (_, own) in enumerate(cfg.values):
                for m, mx in enumerate(mixes):
                    xx, yy = (own.x, own.y) if mx == OWN_MIX else mx
                    x[c, m], y[c, m] = xx, yy
            return x, y, ("workload_config", mix_ax.name)
        if cfg is not None:
            x = np.asarray([w.x for _, w in cfg.values], np.float32)
            y = np.asarray([w.y for _, w in cfg.values], np.float32)
            return x, y, ("workload_config",)
        if mixes is not None:
            if OWN_MIX in mixes:
                raise ValueError("OWN_MIX requires a workload_config axis")
            x = np.asarray([m[0] for m in mixes], np.float32)
            y = np.asarray([m[1] for m in mixes], np.float32)
            return x, y, (mix_ax.name,)
        return (np.asarray([100.0], np.float32),
                np.asarray([0.0], np.float32), ())

    def _default_metrics(self) -> Tuple[str, ...]:
        out: List[str] = []
        names = self.axes.names
        if self.axes.mix_axis() is not None or "workload_config" in names:
            if self.phy is not None:
                out += list(APPROACH_METRICS)
            elif "phy" in names:
                # a phy axis serves both views: the PHY-stacked catalog
                # and the Fig 10-12 approach-density sweeps
                out += (list(ANALYTIC_METRICS) + list(SYSTEM_METRICS)
                        + list(APPROACH_METRICS))
            else:
                out += list(ANALYTIC_METRICS) + list(SYSTEM_METRICS)
            if ("backlog" in names or "protocol" in names
                    or "protocol_param" in names):
                out += list(SIM_METRICS)
                if "phy" in names or self.phy is not None:
                    out += list(SIM_PHY_METRICS)
        if "trace" in names:
            out += list(TRACE_METRICS)
            if "phy" in names or self.phy is not None:
                out += list(TRACE_PHY_METRICS)
        if "k" in names:
            out += list(PIPELINE_METRICS)
        if not out:
            raise ValueError(
                f"no metric is evaluable over axes {names}; add a traffic "
                "axis (mix/read_fraction/workload_config), a trace axis, "
                "or a pipelining axis (k)")
        return tuple(out)

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, metrics: Optional[Sequence[str]] = None, *,
                 sim: Optional[SimConfig] = None,
                 stream: Optional[StreamConfig] = None):
        """Resolve the requested metrics over the full joint axis space.

        ``sim`` overrides the ``DesignSpace(sim=...)`` config for this
        evaluation only — the flit-simulated metrics run fixed-horizon or
        convergence-adaptive accordingly (analytic metrics are closed
        forms and unaffected).

        ``stream`` (a :class:`StreamConfig`) switches to the tiled /
        streaming engine for 10^6–10^8-cell spaces: the cell space is
        chunked along the configured axis order, every chunk runs through
        ONE cached executable ``shard_map``-ped across devices, and
        frontier / argbest / feasibility resolve as running on-device
        reductions (full per-cell tensors never exist).  Streaming
        reduces exactly ONE metric per call and returns a
        :class:`repro.core.streaming.StreamResult` (winner labels
        bit-identical to the materialized path) instead of a
        :class:`SpaceResult`.
        """
        if stream is not None:
            from repro.core import streaming
            return streaming.stream_evaluate(
                self, metrics, sim if sim is not None else self.sim,
                stream)
        cfg = sim if sim is not None else self.sim
        wanted = tuple(metrics) if metrics is not None else \
            self._default_metrics()
        known = (ANALYTIC_METRICS + SYSTEM_METRICS + SIM_METRICS
                 + SIM_PHY_METRICS + APPROACH_METRICS + PIPELINE_METRICS
                 + TRACE_METRICS + TRACE_PHY_METRICS)
        unknown = [m for m in wanted if m not in known]
        if unknown:
            raise ValueError(f"unknown metrics {unknown}; choose from "
                             f"{known}")
        arrays: Dict[str, SpaceArray] = {}
        if any(m in wanted for m in ANALYTIC_METRICS + SYSTEM_METRICS):
            arrays.update(self._eval_catalog(wanted))
        if any(m in wanted for m in APPROACH_METRICS):
            arrays.update(self._eval_approaches(wanted))
        if any(m in wanted for m in SIM_METRICS + SIM_PHY_METRICS):
            arrays.update(self._eval_sim(wanted, cfg))
        if any(m in wanted for m in TRACE_METRICS + TRACE_PHY_METRICS):
            arrays.update(self._eval_trace(wanted, cfg))
        if any(m in wanted for m in PIPELINE_METRICS):
            arrays.update(self._eval_pipelining(wanted, cfg))
        return SpaceResult(axes=self.axes, arrays=arrays, sim=cfg)

    def _perturbations(self) -> List[Dict[str, float]]:
        cp_ax = self.axes.get("catalog_param")
        return ([dict(p) for _, p in cp_ax.values]
                if cp_ax is not None else [{}])

    def _eval_catalog(self, wanted) -> Dict[str, SpaceArray]:
        from repro.core import memsys
        phy_ax = self.axes.get("phy")
        cp_ax = self.axes.get("catalog_param")
        perts = self._perturbations()
        x, y, mix_dims = self._mix_arrays()
        sl_ax = self.axes.get("shoreline_mm")
        if sl_ax is not None:
            sl = np.asarray(sl_ax.values, np.float32)
            xb, yb = x[..., None], y[..., None]
        else:
            sl = np.float32(self.default_shoreline_mm)
            xb, yb = x, y
        if phy_ax is not None:
            # PHY-stacked engine: (catalog_param x phy) folded into the
            # phys stack, approaches as the system dim (no bus baselines)
            items = memsys.approach_catalog_items()
            phys = [phy.perturbed(p) for p in perts for phy in phy_ax.values]
            grids = memsys.run_catalog_phys_program(items, phys, xb, yb, sl)
            lead = (len(perts), len(phy_ax), len(items))
            # [Q*F, S, ...] -> [Q, S, F, ...] (system before phy)
            grids = [np.moveaxis(
                np.asarray(g).reshape(lead + np.asarray(g).shape[2:]), 2, 1)
                for g in grids]
            extra_dims: Tuple[str, ...] = ("phy",)
            extra_coords: Tuple[Tuple[Any, ...], ...] = (phy_ax.labels,)
        else:
            items = (memsys.default_catalog_items() if self.catalog is None
                     else tuple(self.catalog.items()))
            flat = (memsys.perturbed_catalog_items(items, perts)
                    if cp_ax is not None else items)
            grids = memsys.run_catalog_program(flat, xb, yb, sl)
            lead = (len(perts), len(items))
            grids = [np.asarray(g).reshape(lead + np.asarray(g).shape[1:])
                     for g in grids]
            extra_dims, extra_coords = (), ()
        bw, pjb, pw, gpw = grids
        keys = tuple(k for k, _ in items)
        dims = ("catalog_param", "system") + extra_dims + mix_dims + (
            ("shoreline_mm",) if sl_ax is not None else ())
        coords = ((cp_ax.labels if cp_ax is not None else ("baseline",)),
                  keys) + extra_coords \
            + tuple(self.axes[d].labels for d in mix_dims) \
            + ((sl_ax.labels,) if sl_ax is not None else ())
        if cp_ax is None:
            dims, coords = dims[1:], coords[1:]
        vals = {"bandwidth_gbs": bw, "pj_per_bit": pjb, "power_w": pw,
                "gbs_per_watt": gpw}
        out: Dict[str, SpaceArray] = {}
        for name in ANALYTIC_METRICS:
            if name in wanted:
                v = np.asarray(vals[name])
                if cp_ax is None:
                    v = v[0]
                # squeeze the placeholder mix point when no traffic axis
                v = v.reshape(tuple(len(c) for c in coords))
                out[name] = SpaceArray(dims, coords, v)
        if "latency_ns" in wanted:
            out["latency_ns"] = SpaceArray(
                ("system",), (keys,),
                np.asarray([ms.latency_ns for _, ms in items], np.float32))
        if "relative_bit_cost" in wanted:
            out["relative_bit_cost"] = SpaceArray(
                ("system",), (keys,),
                np.asarray([ms.relative_bit_cost for _, ms in items],
                           np.float32))
        return out

    def _eval_approaches(self, wanted) -> Dict[str, SpaceArray]:
        from repro.core import memsys
        phy_ax = self.axes.get("phy")
        cp_ax = self.axes.get("catalog_param")
        perts = self._perturbations()
        if self.phy is None and phy_ax is None:
            raise ValueError("approach metrics need DesignSpace(phy=...) "
                             "or a 'phy' axis")
        base_phys = (list(phy_ax.values) if phy_ax is not None
                     else [self.phy])
        phys = [p.perturbed(q) for q in perts for p in base_phys]
        x, y, mix_dims = self._mix_arrays()
        lin, areal, pjb = memsys.run_approach_phys_program(phys, x, y)
        from repro.core.protocols import ALL_APPROACHES
        keys = tuple(ALL_APPROACHES)
        lead = (len(perts), len(base_phys), len(keys))
        dims = ("catalog_param", "approach") + (
            ("phy",) if phy_ax is not None else ()) + mix_dims
        coords = ((cp_ax.labels if cp_ax is not None else ("baseline",)),
                  keys) + ((phy_ax.labels,) if phy_ax is not None else ()) \
            + tuple(self.axes[d].labels for d in mix_dims)
        out: Dict[str, SpaceArray] = {}
        vals = {"linear_density_gbs_mm": lin,
                "areal_density_gbs_mm2": areal,
                "approach_pj_per_bit": pjb}
        for name in APPROACH_METRICS:
            if name not in wanted:
                continue
            # [Q*F, A, ...] -> [Q, A, F, ...] (approach before phy)
            v = np.asarray(vals[name])
            v = np.moveaxis(v.reshape(lead + v.shape[2:]), 2, 1)
            if cp_ax is None:
                v = v[0]
            if phy_ax is None:
                # drop the singleton phy dim (after approach)
                v = np.take(v, 0, axis=2 if cp_ax is not None else 1)
            v = v.reshape(tuple(len(c) for c in
                                (coords if cp_ax is not None
                                 else coords[1:])))
            out[name] = SpaceArray(
                dims if cp_ax is not None else dims[1:],
                coords if cp_ax is not None else coords[1:], v)
        return out

    def _sim_protocols(self) -> Tuple[str, ...]:
        from repro.core import flitsim
        ax = self.axes.get("protocol")
        keys = tuple(ax.values) if ax is not None else \
            tuple(flitsim.SIMULATORS)
        unknown = [k for k in keys if k not in flitsim.SIMULATORS]
        if unknown:
            raise ValueError(f"unknown protocol keys {unknown}; choose "
                             f"from {sorted(flitsim.SIMULATORS)}")
        return keys

    def _eval_sim(self, wanted, sim: SimConfig) -> Dict[str, SpaceArray]:
        from repro.core import flitsim
        keys = self._sim_protocols()
        x, y, mix_dims = self._mix_arrays()
        mix_shape = x.shape
        xf = x.reshape(-1)
        yf = y.reshape(-1)
        if np.any(xf < 0) or np.any(yf < 0) or np.any(xf + yf <= 0):
            raise ValueError("invalid traffic mix in the lowered grid")
        bl_ax = self.axes.get("backlog")
        backlogs = np.asarray(bl_ax.values if bl_ax is not None
                              else [self.default_backlog], np.float32)
        pert_ax = self.axes.get("protocol_param")
        perts = ([dict(p) for _, p in pert_ax.values]
                 if pert_ax is not None else [{}])
        eff = np.asarray(flitsim.simulate_grid(
            keys, xf, yf, backlogs, perturbations=perts,
            n_flits=self.n_flits, n_accesses=self.n_accesses, sim=sim))
        # eff: [Q, P, B, Mf] -> named dims, dropping absent axes
        eff = eff.reshape(eff.shape[:3] + mix_shape)
        dims: List[str] = ["protocol_param", "protocol", "backlog"]
        coords: List[Tuple] = [
            pert_ax.labels if pert_ax is not None else ("baseline",),
            keys,
            bl_ax.labels if bl_ax is not None else (self.default_backlog,)]
        dims += list(mix_dims)
        coords += [self.axes[d].labels for d in mix_dims]
        if pert_ax is None:
            eff = eff[0]
            dims, coords = dims[1:], coords[1:]
        if bl_ax is None:
            ax_b = dims.index("backlog")
            eff = np.take(eff, 0, axis=ax_b)
            del dims[ax_b], coords[ax_b]
        if not mix_dims:                     # placeholder 100R0W point
            eff = eff[..., 0]
        out: Dict[str, SpaceArray] = {}
        if "sim_efficiency" in wanted:
            out["sim_efficiency"] = SpaceArray(
                tuple(dims), tuple(coords), np.asarray(eff))
        if "sim_bandwidth_gbs" in wanted:
            phy_ax = self.axes.get("phy")
            if phy_ax is not None:
                phys = list(phy_ax.values)
            elif self.phy is not None:
                phys = [self.phy]
            else:
                raise ValueError(
                    "the 'sim_bandwidth_gbs' metric threads the PHY's raw "
                    "link bandwidth into the simulated efficiency — add a "
                    "'phy' axis or pass DesignSpace(phy=...)")
            raw = np.asarray([p.raw_bandwidth_gbs for p in phys],
                             np.float32)
            ax_p = dims.index("protocol")
            v = (np.expand_dims(np.asarray(eff), ax_p + 1)
                 * raw.reshape((len(raw),)
                               + (1,) * (np.ndim(eff) - ax_p - 1)))
            bdims = tuple(dims[:ax_p + 1]) + ("phy",) \
                + tuple(dims[ax_p + 1:])
            bcoords = tuple(coords[:ax_p + 1]) \
                + (tuple(p.name for p in phys),) \
                + tuple(coords[ax_p + 1:])
            if phy_ax is None:          # DesignSpace(phy=...): no phy dim
                v = np.take(v, 0, axis=ax_p + 1)
                bdims = bdims[:ax_p + 1] + bdims[ax_p + 2:]
                bcoords = bcoords[:ax_p + 1] + bcoords[ax_p + 2:]
            out["sim_bandwidth_gbs"] = SpaceArray(bdims, bcoords, v)
        if "analytic_efficiency" in wanted:
            an = np.stack([np.asarray(flitsim.ANALYTIC[k].bw_eff(xf, yf),
                                      np.float32) for k in keys])
            an = an.reshape((len(keys),) + mix_shape)
            adims = ("protocol",) + mix_dims
            acoords = (keys,) + tuple(self.axes[d].labels
                                      for d in mix_dims)
            if not mix_dims:
                an = an[..., 0]
            out["analytic_efficiency"] = SpaceArray(adims, acoords, an)
        return out

    def _eval_trace(self, wanted, sim: SimConfig) -> Dict[str, SpaceArray]:
        from repro.core import flitsim
        tr_ax = self.axes.get("trace")
        if tr_ax is None:
            raise ValueError("trace metrics ('trace_efficiency', ...) "
                             "need a 'trace' axis")
        keys = self._sim_protocols()
        traces = tr_ax.values           # axis() padded them to a common N
        xs = np.asarray([[100.0 * r for r in t.read_fractions]
                         for t in traces], np.float32)
        ys = 100.0 - xs
        bls = np.asarray([t.backlogs for t in traces], np.float32)
        pert_ax = self.axes.get("protocol_param")
        perts = ([dict(p) for _, p in pert_ax.values]
                 if pert_ax is not None else [{}])
        eff = np.asarray(flitsim.simulate_trace_grid(
            keys, xs, ys, bls, perturbations=perts,
            n_flits=self.n_flits, n_accesses=self.n_accesses, sim=sim))
        # eff: per-phase [Q, P, T, N]; the duration-weighted aggregate is
        # computed host-side in f64 with per-trace normalized weights, so
        # a single-phase trace (w == d/d == 1.0 exactly) stays
        # bit-identical to its static cell through the f32 round-trip
        d = np.asarray([t.durations for t in traces], np.float64)
        w = d / d.sum(axis=1, keepdims=True)                    # [T, N]
        agg = np.einsum("qptn,tn->qpt", eff.astype(np.float64),
                        w).astype(np.float32)
        dims: List[str] = ["protocol_param", "protocol", "trace"]
        coords: List[Tuple] = [
            pert_ax.labels if pert_ax is not None else ("baseline",),
            keys, tr_ax.labels]
        if pert_ax is None:
            eff, agg = eff[0], agg[0]
            dims, coords = dims[1:], coords[1:]
        out: Dict[str, SpaceArray] = {}
        if "trace_efficiency" in wanted:
            out["trace_efficiency"] = SpaceArray(
                tuple(dims), tuple(coords), agg)
        if "trace_phase_efficiency" in wanted:
            out["trace_phase_efficiency"] = SpaceArray(
                tuple(dims) + ("phase",),
                tuple(coords) + (tuple(range(eff.shape[-1])),), eff)
        if "trace_bandwidth_gbs" in wanted:
            phy_ax = self.axes.get("phy")
            if phy_ax is not None:
                phys = list(phy_ax.values)
            elif self.phy is not None:
                phys = [self.phy]
            else:
                raise ValueError(
                    "the 'trace_bandwidth_gbs' metric threads the PHY's "
                    "raw link bandwidth into the trace-scan efficiency — "
                    "add a 'phy' axis or pass DesignSpace(phy=...)")
            raw = np.asarray([p.raw_bandwidth_gbs for p in phys],
                             np.float32)
            ax_p = dims.index("protocol")
            v = (np.expand_dims(np.asarray(agg), ax_p + 1)
                 * raw.reshape((len(raw),)
                               + (1,) * (np.ndim(agg) - ax_p - 1)))
            bdims = tuple(dims[:ax_p + 1]) + ("phy",) \
                + tuple(dims[ax_p + 1:])
            bcoords = tuple(coords[:ax_p + 1]) \
                + (tuple(p.name for p in phys),) \
                + tuple(coords[ax_p + 1:])
            if phy_ax is None:          # DesignSpace(phy=...): no phy dim
                v = np.take(v, 0, axis=ax_p + 1)
                bdims = bdims[:ax_p + 1] + bdims[ax_p + 2:]
                bcoords = bcoords[:ax_p + 1] + bcoords[ax_p + 2:]
            out["trace_bandwidth_gbs"] = SpaceArray(bdims, bcoords, v)
        return out

    def _eval_pipelining(self, wanted, sim: SimConfig
                         ) -> Dict[str, SpaceArray]:
        from repro.core import flitsim
        k_ax = self.axes.get("k")
        if k_ax is None:
            raise ValueError("the 'utilization' metric needs a 'k' axis")
        u_ax = self.axes.get("ucie_line_ui")
        d_ax = self.axes.get("device_line_ui")
        us = tuple(u_ax.values) if u_ax is not None else (16.0,)
        ds = tuple(d_ax.values) if d_ax is not None else (64.0,)
        util = np.asarray(flitsim._sweep_pipelining_impl(
            k_ax.values, n_lines=self.n_lines, ucie_line_ui=us,
            device_line_ui=ds, sim=sim))        # [K, U, D]
        dims: List[str] = ["k"]
        coords: List[Tuple] = [k_ax.labels]
        if u_ax is not None:
            dims.append("ucie_line_ui")
            coords.append(u_ax.labels)
        else:
            util = util[:, 0]
        if d_ax is not None:
            dims.append("device_line_ui")
            coords.append(d_ax.labels)
        else:
            util = util[..., 0]
        if "utilization" not in wanted:
            return {}
        return {"utilization": SpaceArray(tuple(dims), tuple(coords),
                                          util)}

    # -- unified frontier reports -------------------------------------------

    def report(self, spec=None) -> Dict[str, Any]:
        """ONE entry point for every frontier report.

        ``spec`` is a :class:`repro.core.report.ReportSpec` naming the
        sections to build — ``"joint"`` (:func:`joint_frontier`),
        ``"phy"`` / ``"sim_phy"`` (the PHY-stacked analytic and
        simulation-corrected frontiers), ``"serving"``
        (:meth:`serving_frontier`), and ``"frontier"`` (this instance's
        own metric frontier over its axes).  Returns ``{section:``
        :class:`repro.core.report.FrontierReport` ``}``; each payload is
        byte-identical to the legacy builder it replaces (the
        ``design_space.json`` sections are unchanged).
        """
        from repro.core.report import build_report
        return build_report(spec, space=self)

    # -- serving frontier ---------------------------------------------------

    @staticmethod
    def serving_frontier(models=None, qps_points=None,
                         **kwargs) -> Dict[str, Any]:
        """Per-(model, QPS) serving frontier: synthetic serving traces
        evaluated through the ``trace`` axis, winners mapped to catalog
        memory approaches.  Delegates to
        :func:`repro.traces.frontier.serving_frontier` (see there for the
        knobs); this is the entry point ``dryrun --all`` and the explorer
        ``--serving`` mode persist as the ``serving_frontier`` section of
        ``design_space.json``."""
        from repro.traces.frontier import (DEFAULT_MODELS, DEFAULT_QPS,
                                           serving_frontier)
        return serving_frontier(
            models if models is not None else DEFAULT_MODELS,
            qps_points if qps_points is not None else DEFAULT_QPS,
            **kwargs)


# =========================================================================
# Joint analytic-vs-simulated frontier (new capability)
# =========================================================================


def joint_frontier(n_fracs: int = 21,
                   backlogs: Sequence[float] = (2.0, 8.0, 64.0),
                   shorelines: Sequence[float] = (4.0, 8.0, 16.0),
                   catalog: Optional[Dict[str, Any]] = None,
                   n_flits: int = 2048,
                   constraints=None,
                   sim: Optional[SimConfig] = None,
                   phys: Optional[Sequence[Any]] = None) -> Dict[str, Any]:
    """Joint (mix x backlog x shoreline) frontier merging the flit-simulated
    efficiency grid with the analytic catalog grid.

    For every catalog system backed by a flit simulator, the analytic
    bandwidth is rescaled by the simulated/analytic efficiency ratio at
    each (mix, backlog) point; systems without a simulator (bus baselines)
    keep their closed-form bandwidth.  The report marks the read-fraction
    regions where the simulation-corrected winner differs from the analytic
    winner — i.e. where the paper's closed forms and the cycle-level
    simulation *disagree* about the best memory system — per (backlog,
    shoreline) cell, plus each protocol's worst simulated-vs-analytic
    relative error.

    This is the first capability only expressible in the unified axes-first
    API: it needs the analytic catalog axes and the flit-simulation axes
    resolved over one shared mix grid in a single evaluation.

    ``constraints`` (optional :class:`repro.core.selector.
    SelectionConstraints`) restricts BOTH frontiers to the feasible set
    via :meth:`SpaceResult.feasible` — infeasible cells never win, and
    cells with no admissible system read ``"(none)"``.

    ``sim`` selects the flit-simulation config (:data:`FIXED_SIM`
    default; pass :data:`ADAPTIVE_SIM` for the convergence-adaptive
    early-exit engine — what the benchmarks and the explorer use).

    The report folds in a ``sim_bandwidth_gbs`` section: the SAME
    simulated-efficiency grid threaded onto each PHY generation's raw
    link bandwidth (``phys`` — default UCIe-A/S at 32G plus the 48G
    points), so PHY generations, queue depths and simulation corrections
    land in ONE frontier section with zero extra compiles.
    """
    from repro.core.selector import sim_key_for
    fracs = np.linspace(0.0, 1.0, n_fracs)
    space = DesignSpace(
        [axis("read_fraction", fracs),
         axis("backlog", backlogs),
         axis("shoreline_mm", shorelines)],
        catalog=catalog, n_flits=n_flits, sim=sim)
    metrics = ANALYTIC_METRICS[:1] + SIM_METRICS
    if constraints is not None:
        metrics = metrics + ("power_w",)
    res = space.evaluate(metrics=metrics)
    bw = res["bandwidth_gbs"]                  # [S, M, L]
    sim = res["sim_efficiency"]                # [P, B, M]
    ana = res["analytic_efficiency"]           # [P, M]
    keys = bw.coord("system")
    protocols = sim.coord("protocol")
    ratio = sim.values / np.maximum(ana.values[:, None, :], 1e-9)
    rel_err = {p: float(np.max(np.abs(ratio[i] - 1.0)))
               for i, p in enumerate(protocols)}

    n_b = sim.values.shape[1]
    corrected = np.repeat(bw.values[:, None, :, :], n_b, axis=1)
    for s, key in enumerate(keys):
        simkey = sim_key_for(key)
        if simkey is not None and simkey in protocols:
            p = protocols.index(simkey)
            corrected[s] = bw.values[s][None] * ratio[p][:, :, None]

    feas = res.feasible(constraints, catalog=catalog) \
        if constraints is not None else None
    analytic_best = bw.argbest("system", where=feas).values    # [M, L]
    if feas is not None:
        corrected = np.where(feas.values[:, None, :, :], corrected,
                             -np.inf)
    sim_best_idx = np.argmax(corrected, axis=0)            # [B, M, L]
    sim_best = np.asarray(keys, dtype=object)[sim_best_idx]
    if feas is not None:
        none_cells = ~feas.values.any(axis=0)[None]        # [1, M, L]
        sim_best = np.where(np.broadcast_to(none_cells, sim_best.shape),
                            "(none)", sim_best)
    disagree = sim_best != analytic_best[None]
    regions: List[Dict[str, Any]] = []
    for b, bl in enumerate(sim.coord("backlog")):
        for l, sl in enumerate(bw.coord("shoreline_mm")):
            if not disagree[b, :, l].any():
                continue
            for lo, hi, pair in regimes(
                    [(a, s) for a, s in zip(analytic_best[:, l],
                                            sim_best[b, :, l])],
                    fracs):
                if pair[0] != pair[1]:
                    regions.append({
                        "backlog": float(bl), "shoreline_mm": float(sl),
                        "read_fraction_lo": lo, "read_fraction_hi": hi,
                        "analytic_best": str(pair[0]),
                        "simulated_best": str(pair[1])})
    # -- folded PHY-absolute section ------------------------------------
    # the same simulated-efficiency grid threaded onto each PHY's raw
    # link bandwidth: winner regimes per (phy, backlog) with no extra
    # simulation or compile (raw bandwidth is a per-PHY scale)
    from repro.core.selector import approach_key_for
    if phys is None:
        from repro.core.ucie import (
            UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G, UCIE_S_48G_110U)
        phys = [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U,
                UCIE_A_48G_45U]
    proto_arr = np.asarray(protocols, dtype=object)
    sim_section: Dict[str, Any] = {
        "phys": [p.name for p in phys],
        "backlogs": [float(b) for b in backlogs],
        "read_fractions": fracs.tolist(),
        "peak_gbs_by_phy": {},
        "best_protocol_by_phy": {},
        "regimes_by_phy_backlog": {},
    }
    for p in phys:
        gbs = sim.values * np.float32(p.raw_bandwidth_gbs)   # [P, B, M]
        regs_by_bl = {}
        for b, bl in enumerate(sim.coord("backlog")):
            win = proto_arr[np.argmax(gbs[:, b, :], axis=0)]
            regs_by_bl[f"{bl:g}"] = [
                {"read_fraction_lo": lo, "read_fraction_hi": hi,
                 "best": str(lab), "approach": approach_key_for(str(lab))}
                for lo, hi, lab in regimes(win.tolist(), fracs)]
        sim_section["regimes_by_phy_backlog"][p.name] = regs_by_bl
        at70 = proto_arr[int(np.argmax(
            gbs[:, -1, int(round(0.7 * (n_fracs - 1)))]))]
        sim_section["best_protocol_by_phy"][p.name] = str(at70)
        sim_section["peak_gbs_by_phy"][p.name] = float(gbs.max())

    return {
        "read_fractions": fracs.tolist(),
        "backlogs": [float(b) for b in backlogs],
        "shorelines": [float(s) for s in shorelines],
        "keys": list(keys),
        "protocol_rel_err": rel_err,
        "analytic_best": analytic_best.astype(str).tolist(),
        "simulated_best": sim_best.astype(str).tolist(),
        "disagreement_fraction": float(disagree.mean()),
        "disagreement_regions": regions,
        "sim_bandwidth_gbs": sim_section,
    }
