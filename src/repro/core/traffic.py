"""Traffic-mix abstraction: ``xRyW`` — x reads, y writes of 64 B lines.

The paper evaluates every approach over representative read/write mixes
(x >= 0, y >= 0, not both 0); data transferred for xRyW is 512*(x+y) bits.
All model functions accept jnp arrays for x and y, so whole mix grids are
evaluated in one vectorized call (and are differentiable, which the
selector exploits).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp

CACHE_LINE_BYTES = 64
CACHE_LINE_BITS = 512


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """x reads : y writes (64-byte cache lines)."""

    x: float
    y: float

    def __post_init__(self):
        if self.x < 0 or self.y < 0 or (self.x == 0 and self.y == 0):
            raise ValueError(f"invalid mix x={self.x} y={self.y}")

    @property
    def name(self) -> str:
        def fmt(v: float) -> str:
            return f"{v:g}"
        return f"{fmt(self.x)}R{fmt(self.y)}W"

    @property
    def read_fraction(self) -> float:
        return self.x / (self.x + self.y)

    @property
    def data_bits(self) -> float:
        return CACHE_LINE_BITS * (self.x + self.y)

    @classmethod
    def from_bytes(cls, read_bytes: float, write_bytes: float) -> "TrafficMix":
        """Bridge from HLO byte counts to the paper's unit (64 B lines).

        Normalized so x + y == 100 (keeps the closed forms well-scaled).
        """
        rx = max(read_bytes, 0.0) / CACHE_LINE_BYTES
        wy = max(write_bytes, 0.0) / CACHE_LINE_BYTES
        tot = rx + wy
        if tot <= 0:
            return cls(1.0, 0.0)
        return cls(100.0 * rx / tot, 100.0 * wy / tot)


# The representative mixes used across Figures 10-12 style sweeps
# (100%R ... 100%W).  Keys are read-percentages.
PAPER_MIXES: Tuple[TrafficMix, ...] = (
    TrafficMix(1, 0),   # 100% reads
    TrafficMix(4, 1),   # 80/20
    TrafficMix(3, 1),   # 75/25
    TrafficMix(2, 1),   # 67/33 (the paper's canonical "predominant" mix)
    TrafficMix(1, 1),   # 50/50
    TrafficMix(1, 2),   # 33/67
    TrafficMix(1, 3),   # 25/75
    TrafficMix(0, 1),   # 100% writes
)


def mix_grid(n: int = 101):
    """(x, y) arrays sweeping read fraction 0..1 — for vectorized evaluation.

    Every point keeps x + y = 100, so the endpoints are the valid pure-read
    (100, 0) and pure-write (0, 100) mixes — the degenerate (0, 0) point
    can never appear and no clamping is needed.
    """
    r = jnp.linspace(0.0, 1.0, n)
    x = 100.0 * r
    y = 100.0 - x
    return x, y


def mixes_named(mixes: Sequence[TrafficMix] = PAPER_MIXES):
    x = jnp.array([m.x for m in mixes], dtype=jnp.float32)
    y = jnp.array([m.y for m in mixes], dtype=jnp.float32)
    names = [m.name for m in mixes]
    return x, y, names
