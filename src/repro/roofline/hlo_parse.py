"""Loop-weighted HLO cost model.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts scanned-layer/microbatch programs by ~L×.  This module parses
the post-SPMD HLO text and computes, with bodies weighted by their
``known_trip_count`` backend config:

    flops            — 2 * out_elems * contraction for every dot
    bytes accessed   — per-instruction result + operand bytes (fusions
                       count boundary buffers only, XLA-style)
    collective bytes — operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
                       (+ their -start async forms), by kind

Everything is per-device (the HLO is the per-partition SPMD module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}

# opcodes whose result/operands we exclude from bytes-accessed accounting
_BYTES_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "custom-call",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "copy-done", "opt-barrier", "partition-id", "replica-id", "domain",
    "add-dependency",
}

# Elementwise / layout ops a TPU-style fusion pass would fold into their
# consumers — their intermediates never reach HBM.  The CPU-backend HLO we
# analyze is barely fused, so byte accounting must emulate fusion: a
# fusible op's result is only materialized when a non-fusible consumer
# reads it (or it is a root/carried value).
_FUSIBLE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "negate",
    "abs", "maximum", "minimum", "compare", "select", "and", "or", "not",
    "xor", "convert", "broadcast", "iota", "reshape", "sqrt", "rsqrt",
    "cbrt", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "clamp", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
    "reduce-precision", "logistic", "sine", "cosine", "tan", "atan2",
    "erf", "pad", "real", "imag", "expand", "bitcast-convert",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count"?:\s*\{"?n"?:\s*"?(\d+)')
_CALLED_RE = re.compile(r"(?:condition|body|calls|to_apply)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every array shape in a type string
    (handles tuples by summing)."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str                    # raw text after the opening paren
    operands: List[str]
    called: List[str]
    trip: int = 1


@dataclasses.dataclass
class Metrics:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Metrics", scale: float = 1.0):
        self.flops += scale * other.flops
        self.bytes_accessed += scale * other.bytes_accessed
        self.collective_bytes += scale * other.collective_bytes
        for k, v in other.by_kind.items():
            self.by_kind[k] = self.by_kind.get(k, 0.0) + scale * v


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Metrics] = {}
        # CPU XLA wraps single ops in fusion(...) calls=%wrapped_X — a
        # fusion whose body is purely elementwise behaves like a fusible
        # elementwise op for TPU-fusion emulation
        self._body_fusible = {
            name: all(i.opcode in _FUSIBLE or i.opcode == "parameter"
                      for i in instrs)
            for name, instrs in self.comps.items()}
        # "transparent" ops move no bytes on TPU: dtype converts, layout
        # copies, bitcasts (XLA CPU materializes f32 copies of bf16 tensors
        # around every dot — pure CPU-backend artifacts)
        _transp = {"convert", "bitcast", "copy", "parameter", "reshape"}
        self._body_transparent = {
            name: all(i.opcode in _transp for i in instrs)
            for name, instrs in self.comps.items()}

    def _eff_opcode(self, ins: Instr) -> str:
        if ins.opcode == "fusion" and ins.called and all(
                self._body_fusible.get(c, False) for c in ins.called):
            return "add"          # any _FUSIBLE member: "elementwise"
        return ins.opcode

    def _is_transparent(self, ins: Instr) -> bool:
        if ins.opcode in ("convert", "bitcast", "copy", "reshape"):
            return True
        if ins.opcode == "fusion" and ins.called:
            return all(self._body_transparent.get(c, False)
                       for c in ins.called)
        return False

    def _inplace_update_operand(self, ins: Instr) -> Optional[int]:
        """If a fusion's only real op is a dynamic-update-slice (possibly
        convert/bitcast-wrapped), return the index of the fusion operand
        feeding the DUS *update*, else None."""
        _transp = {"convert", "bitcast", "copy", "parameter", "reshape",
                   "constant"}
        for cname in ins.called:
            body = self.comps.get(cname, [])
            real = [i for i in body if i.opcode not in _transp]
            if len(real) != 1 or real[0].opcode != "dynamic-update-slice":
                return None
            dus = real[0]
            if len(dus.operands) < 2:
                return None
            by_name = {i.name: i for i in body}
            # resolve the update operand back to a parameter index
            cur = dus.operands[1]
            for _ in range(16):
                i2 = by_name.get(cur)
                if i2 is None:
                    return None
                if i2.opcode == "parameter":
                    # Instr.rest holds the text after "parameter(" -> "N)..."
                    m = re.match(r"(\d+)", i2.rest or "")
                    if m:
                        return int(m.group(1))
                    pm = re.match(r"param_(\d+)", i2.name)
                    if pm:
                        return int(pm.group(1))
                    return None
                if not i2.operands:
                    return None
                cur = i2.operands[0]
        return None

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, type_str, opcode, rest = im.groups()
            args = rest.split(")")[0]
            operands = _OPERAND_RE.findall(args)
            called = _CALLED_RE.findall(rest)
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            self.comps[cur].append(Instr(name, type_str, opcode, rest,
                                         operands, called, trip))

    # -- per-computation metrics (one execution) ---------------------------
    def metrics(self, comp: Optional[str] = None) -> Metrics:
        comp = comp or self.entry or next(iter(self.comps))
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Metrics()          # cycle guard
        out = Metrics()
        instrs = self.comps.get(comp, [])
        by_name = {i.name: i for i in instrs}
        shapes = {i.name: i.type_str for i in instrs}

        # fusion emulation: a fusible op materializes only when some
        # non-fusible consumer reads it (or nothing in this computation
        # consumes it — root / loop-carried value)
        consumers: Dict[str, List[str]] = {}
        consumers_i: Dict[str, List[Instr]] = {}
        for i in instrs:
            for op in i.operands:
                consumers.setdefault(op, []).append(self._eff_opcode(i))
                consumers_i.setdefault(op, []).append(i)

        def _narrowing(ins: Instr, by_name, direct: List[Instr]) -> float:
            """1.0, or the dtype-size ratio if every real consumer of a
            collective result (through GTE/copy) is a narrowing convert."""
            src_m = _SHAPE_RE.search(ins.type_str)
            if not src_m:
                return 1.0
            src_sz = _DTYPE_BYTES.get(src_m.group(1), 4)
            frontier = list(direct)
            real: List[Instr] = []
            for _ in range(64):
                if not frontier:
                    break
                nxt = []
                for c in frontier:
                    if c.opcode in ("get-tuple-element", "copy", "bitcast",
                                    "tuple"):
                        nxt.extend(consumers_i.get(c.name, []))
                    else:
                        real.append(c)
                frontier = nxt
            if not real:
                return 1.0
            sizes = []
            for c in real:
                body_ok = c.opcode == "convert"
                if c.opcode == "fusion" and c.called:
                    body_ok = all(self._body_transparent.get(cc, False)
                                  for cc in c.called)
                if not body_ok:
                    return 1.0
                mm = _SHAPE_RE.search(c.type_str)
                if not mm:
                    return 1.0
                sizes.append(_DTYPE_BYTES.get(mm.group(1), 4))
            narrow = max(sizes)
            return min(1.0, narrow / src_sz)

        def resolve(name: str, depth: int = 16) -> str:
            """Follow transparent producers (convert/copy/bitcast chains)
            to the underlying data source."""
            while depth > 0:
                ins = by_name.get(name)
                if ins is None or not self._is_transparent(ins) \
                        or not ins.operands:
                    return name
                name = ins.operands[0]
                depth -= 1
            return name

        def materialized(name: str) -> bool:
            ins = by_name.get(name)
            if ins is None:
                return False
            if self._is_transparent(ins):
                return False
            eff = self._eff_opcode(ins)
            if eff in _BYTES_SKIP:
                return eff == "parameter"
            if eff not in _FUSIBLE:
                return True
            cons = consumers.get(name)
            if not cons:
                return True                     # root or carried out
            return any(c not in _FUSIBLE for c in cons)

        def op_bytes(names: List[str]) -> float:
            """Collective payload bytes: operand element count at the
            dtype of the resolved (pre-convert) source."""
            total = 0.0
            for n in names:
                t = shapes.get(n)
                if t is None or t.startswith("("):
                    continue
                elems = _shape_elems_bytes(t)[0]
                src_t = shapes.get(resolve(n), t)
                if src_t.startswith("("):
                    src_t = t
                m = _SHAPE_RE.search(src_t)
                dtype_size = _DTYPE_BYTES.get(m.group(1), 4) if m else 4
                total += elems * dtype_size
            return total

        def read_bytes(names: List[str]) -> float:
            """Operand reads, resolved through transparent chains to the
            true producer; fused (non-materialized) producers were already
            charged at their own inputs."""
            total = 0.0
            for n in names:
                t = shapes.get(n)
                if t is None or t.startswith("("):
                    continue
                src = resolve(n)
                if materialized(src):
                    st = shapes.get(src, t)
                    if not st.startswith("("):
                        total += _shape_elems_bytes(st)[1]
            return total

        for ins in instrs:
            oc = ins.opcode
            if oc == "while":
                body_cond = Metrics()
                for cname in ins.called:
                    if cname in self.comps:
                        body_cond.add(self.metrics(cname))
                out.add(body_cond, scale=max(ins.trip, 1))
                continue
            if oc in ("call", "conditional"):
                for cname in ins.called:
                    if cname in self.comps:
                        out.add(self.metrics(cname))
                continue
            if oc == "fusion":
                # flops/collectives: descend (dots may live inside)
                for cname in ins.called:
                    if cname in self.comps:
                        inner = self.metrics(cname)
                        out.flops += inner.flops
                        out.collective_bytes += inner.collective_bytes
                        for k, v in inner.by_kind.items():
                            out.by_kind[k] = out.by_kind.get(k, 0.0) + v
                # in-place updates: a fusion that is just a (convert-
                # wrapped) dynamic-update-slice writes only the updated
                # region when the buffer is donated/aliased (scan ys,
                # KV-cache token writes) — charge 2x the update operand
                upd_idx = self._inplace_update_operand(ins)
                if upd_idx is not None and upd_idx < len(ins.operands):
                    t = shapes.get(ins.operands[upd_idx])
                    if t and not t.startswith("("):
                        out.bytes_accessed += 2.0 * _shape_elems_bytes(t)[1]
                    continue
                # bytes: boundary accounting with TPU-fusion emulation —
                # purely-elementwise fusions materialize only when a
                # non-fusible consumer reads them
                if materialized(ins.name):
                    out.bytes_accessed += _shape_elems_bytes(
                        ins.type_str)[1]
                out.bytes_accessed += read_bytes(ins.operands)
                continue
            if oc in ("dynamic-update-slice", "scatter"):
                upd = ins.operands[1 if oc == "dynamic-update-slice" else 2] \
                    if len(ins.operands) > 1 else None
                t = shapes.get(upd) if upd else None
                if t and not t.startswith("("):
                    out.bytes_accessed += 2.0 * _shape_elems_bytes(t)[1]
                continue

            if oc == "dot":
                res_elems = _shape_elems_bytes(ins.type_str)[0]
                lhs_t = shapes.get(ins.operands[0], "") if ins.operands \
                    else ""
                ldims = _dims(lhs_t)
                cm = _CONTRACT_RE.search(ins.rest)
                contraction = 1
                if cm and cm.group(1) and ldims:
                    for i in cm.group(1).split(","):
                        ii = int(i)
                        if ii < len(ldims):
                            contraction *= ldims[ii]
                out.flops += 2.0 * res_elems * contraction
            elif oc == "convolution":
                # rough: 2 * out_elems * (in_ch * kernel_spatial)
                res_elems = _shape_elems_bytes(ins.type_str)[0]
                k_t = shapes.get(ins.operands[1], "") if len(
                    ins.operands) > 1 else ""
                kd = _dims(k_t)
                out.flops += 2.0 * res_elems * (
                    float(np.prod(kd[:-1])) if kd else 1.0)

            if oc in _COLLECTIVE_OPS:
                cb = op_bytes(ins.operands)
                # XLA-CPU float normalization upcasts bf16 dots AND the
                # partial-sum collectives around them to f32; TPU runs
                # these collectives natively in bf16.  Charge at the
                # jax-level dtype: if every real consumer narrows the
                # result, scale the payload accordingly.
                cb *= _narrowing(ins, by_name, consumers_i.get(ins.name, []))
                kind = oc.replace("-start", "")
                out.collective_bytes += cb
                out.by_kind[kind] = out.by_kind.get(kind, 0.0) + cb

            if oc not in _BYTES_SKIP:
                # fused elementwise intermediates never reach HBM: charge
                # writes only for materialized results, reads only from
                # materialized producers
                if materialized(ins.name):
                    out.bytes_accessed += _shape_elems_bytes(
                        ins.type_str)[1]
                out.bytes_accessed += read_bytes(ins.operands)

        self._memo[comp] = out
        return out


def loop_weighted_metrics(hlo_text: str) -> Metrics:
    return HloCostModel(hlo_text).metrics()
