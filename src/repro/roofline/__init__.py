from repro.roofline.analysis import RooflineReport, analyze, memsys_bridge
from repro.roofline.hlo_parse import HloCostModel, loop_weighted_metrics
from repro.roofline.hw import V5E, ChipSpec
