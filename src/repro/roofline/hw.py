"""Target hardware model: TPU v5e chip + pod constants (+ the paper's
UCIe-Memory alternatives for the memory system).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12        # per chip
    hbm_bandwidth: float = 819e9           # bytes/s
    hbm_capacity: float = 16e9             # bytes
    ici_link_bandwidth: float = 50e9       # bytes/s per link (~50 GB/s)
    ici_links: int = 4
    dcn_bandwidth: float = 25e9            # bytes/s per host across pods


V5E = ChipSpec()


def memsys_alternatives(shoreline_mm: float = 8.0):
    """The paper's memory systems sized to the v5e die shoreline — what the
    HBM term becomes if the chip's memory were attached via UCIe-Memory."""
    from repro.core import TrafficMix, standard_catalog
    return standard_catalog(), shoreline_mm
