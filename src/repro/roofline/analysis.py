"""Roofline analysis from compiled artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` supplies FLOPs/bytes (per-partition SPMD module —
multiplied back to global by ``chips``... it reports the per-device
program, so per-chip seconds = value / peak directly; we keep the formulas
of the assignment by treating HLO_FLOPs as global = per_device × chips).

collective_bytes comes from parsing the post-SPMD HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
operand, with while-loop bodies multiplied by their trip counts
(best-effort: the loop bound constant from the condition computation).

The paper bridge: HLO byte counts -> xRyW traffic mix -> each UCIe-Memory
approach's delivered bandwidth/power for this workload (EXPERIMENTS.md
§Memsys).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.roofline.hw import V5E, ChipSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

#: filename of the aggregate design-space report (bridge + joint frontier)
#: written NEXT TO the per-cell dry-run artifacts — it has a different
#: schema, so every per-cell ``*.json`` glob must skip this name
DESIGN_SPACE_JSON = "design_space.json"

#: top-level keys every per-cell dry-run artifact carries
CELL_ARTIFACT_KEYS = ("arch", "shape", "mesh", "roofline")

#: design-space dimensions per-cell consumers do NOT understand — an
#: artifact declaring them (in an ``axes`` list/mapping) is an aggregate
#: export of the axes-first API, not a workload cell
NON_CELL_AXES = ("phy", "catalog_param")


def is_cell_artifact(d) -> bool:
    """True when a decoded dry-run JSON is a per-cell workload artifact.

    Aggregate exports (the ``design_space.json`` report, axes-first dumps
    carrying ``phy`` / ``catalog_param`` dimensions) share the artifact
    directory; consumers iterating per-cell ``*.json`` files must SKIP
    anything failing this predicate instead of crashing on missing keys.
    """
    if not isinstance(d, dict):
        return False
    if not all(k in d for k in CELL_ARTIFACT_KEYS):
        return False
    axes = d.get("axes") or ()
    return not any(a in axes for a in NON_CELL_AXES)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"=\s+[a-z0-9\[\],{}() ]*?\b(" + "|".join(
    _COLLECTIVES) + r")(?:-(?:start|done))?\(")
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if m and not stripped.startswith(("ROOT", "//")) and "= " not in \
                stripped.split("(")[0]:
            cur_name = m.group(1)
            cur_lines = []
            comps[cur_name] = ""
            continue
        if stripped.startswith("}"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def _loop_trip_count(cond_text: str) -> int:
    """Best-effort loop bound: the largest integer constant compared in the
    condition computation."""
    cands = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(cands) if cands else 1


def collective_bytes(hlo: str) -> Tuple[float, Dict[str, float]]:
    """Total collective operand bytes per device program (loop-weighted),
    plus a per-op-kind breakdown."""
    comps = _split_computations(hlo)
    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}

    def walk(name: str, depth: int = 0) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if depth > 32 or name not in comps:
            return 0.0, {}
        total = 0.0
        by_kind: Dict[str, float] = {}
        body = comps[name]
        memo[name] = (0.0, {})          # cycle guard
        for line in body.splitlines():
            im = _INSTR_RE.search(line)
            if im:
                kind = im.group(1)
                # operand shapes: everything inside the call parens
                call = line[im.end():]
                operand_bytes = _shape_bytes(call.split(")")[0])
                total += operand_bytes
                by_kind[kind] = by_kind.get(kind, 0.0) + operand_bytes
            if _WHILE_RE.search(line) and "= " in line:
                called = _CALLED_RE.findall(line)
                trip = 1
                inner_total, inner_kinds = 0.0, {}
                for cname in called:
                    if "cond" in cname or "condition" in cname:
                        trip = _loop_trip_count(comps.get(cname, ""))
                for cname in called:
                    t, k = walk(cname, depth + 1)
                    inner_total += t
                    for kk, vv in k.items():
                        inner_kinds[kk] = inner_kinds.get(kk, 0.0) + vv
                total += trip * inner_total
                for kk, vv in inner_kinds.items():
                    by_kind[kk] = by_kind.get(kk, 0.0) + trip * vv
            elif ("call(" in line or "conditional(" in line
                  or "fusion(" in line) and "= " in line:
                for cname in _CALLED_RE.findall(line):
                    t, k = walk(cname, depth + 1)
                    total += t
                    for kk, vv in k.items():
                        by_kind[kk] = by_kind.get(kk, 0.0) + vv
        memo[name] = (total, by_kind)
        return memo[name]

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: sum every computation once
        tot, kinds = 0.0, {}
        for name in comps:
            t, k = walk(name)
            tot += t
            for kk, vv in k.items():
                kinds[kk] = kinds.get(kk, 0.0) + vv
        return tot, kinds
    return walk(entry)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float                     # 6 N D (active N for MoE)
    useful_flops_ratio: float              # model_flops / global HLO flops
    read_bytes_per_chip: float = 0.0
    write_bytes_per_chip: float = 0.0
    peak_memory_bytes: float = 0.0
    notes: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(arch: str, shape_name: str, mesh_desc: str, chips: int,
            cost: Dict[str, float], hlo: str, model_flops: float,
            chip: ChipSpec = V5E, peak_memory_bytes: float = 0.0,
            notes: str = "") -> RooflineReport:
    """All counts are per-device.  ``cost`` (XLA's cost_analysis) counts
    while-loop bodies once, so the loop-weighted HLO cost model supplies
    flops/bytes/collectives; the raw XLA numbers are kept by the caller
    for reference."""
    from repro.roofline.hlo_parse import loop_weighted_metrics
    m = loop_weighted_metrics(hlo)
    flops = m.flops
    bytes_total = m.bytes_accessed
    coll_bytes = m.collective_bytes

    # read/write split from XLA's (loop-unweighted) output fraction
    xla_total = float(cost.get("bytes accessed", 0.0))
    xla_out = float(cost.get("bytes accessedout{}",
                             cost.get("bytes accessed out{}", 0.0)))
    w_frac = (xla_out / xla_total) if xla_total > 0 else 0.33
    out_bytes = bytes_total * w_frac
    read_bytes = bytes_total - out_bytes

    compute_s = flops / chip.peak_bf16_flops
    memory_s = bytes_total / chip.hbm_bandwidth
    collective_s = coll_bytes / chip.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    global_flops = flops * chips
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_desc, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=bytes_total,
        collective_bytes_per_chip=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=(model_flops / global_flops
                            if global_flops else 0.0),
        read_bytes_per_chip=read_bytes, write_bytes_per_chip=out_bytes,
        peak_memory_bytes=peak_memory_bytes, notes=notes)


def _systems_dict(report: RooflineReport, keys, bw_gbs, pj,
                  latency_ns) -> Dict[str, Any]:
    """Per-system bridge metrics from stacked ``[S]`` catalog-grid columns."""
    out: Dict[str, Any] = {}
    for i, key in enumerate(keys):
        bw = float(bw_gbs[i]) * 1e9
        p = float(pj[i])
        out[key] = {
            "bandwidth_gbs": bw / 1e9,
            "pj_per_bit": p,
            "memory_term_s": (report.hlo_bytes_per_chip / bw
                              if bw > 0 else float("inf")),
            "interconnect_energy_j_per_step":
                report.hlo_bytes_per_chip * 8.0 * p * 1e-12,
            "latency_ns": float(latency_ns[i]),
        }
    return out


def memsys_bridge(report: RooflineReport, shoreline_mm: float = 8.0,
                  chip: ChipSpec = V5E) -> Dict[str, Any]:
    """The paper bridge: this workload's traffic mix under every memory
    system the paper models -> memory-term seconds + interconnect power.

    The whole catalog is evaluated through the stacked, jit-cached
    ``repro.core.memsys._catalog_grid_impl`` program — one compiled call,
    not a per-system Python loop."""
    from repro.core import TrafficMix
    from repro.core.memsys import _catalog_grid_impl as catalog_grid
    mix = TrafficMix.from_bytes(report.read_bytes_per_chip,
                                report.write_bytes_per_chip)
    grid = catalog_grid(mix.x, mix.y, shoreline_mm)
    return {"mix": mix.name,
            "read_fraction": mix.read_fraction,
            "hbm_baseline_memory_s": report.memory_s,
            "systems": _systems_dict(
                report, grid.keys, np.asarray(grid.bandwidth_gbs),
                np.asarray(grid.pj_per_bit), np.asarray(grid.latency_ns))}


def bridge_design_space(reports: Dict[str, RooflineReport],
                        n_fracs: int = 41,
                        shorelines=(2.0, 4.0, 8.0, 16.0),
                        constraints=None,
                        objective: str = "bandwidth",
                        sim=None) -> Dict[str, Any]:
    """Per-workload design-space frontier over the full
    ``[configs x catalog x mix-grid x shoreline]`` space in ONE batched
    evaluation — a compatibility wrapper over the axes-first
    :class:`repro.core.space.DesignSpace` API.

    The axes: a ``workload_config`` axis (one HLO-derived mix per named
    :class:`RooflineReport`), a ``mix`` axis whose first entry is the
    :data:`repro.core.space.OWN_MIX` sentinel (each workload's own mix)
    followed by the shared dense read-fraction grid, and a
    ``shoreline_mm`` axis.  The whole space lowers onto one stacked
    catalog program in the shared compile cache (one compile per grid
    shape, warm thereafter — for this wrapper AND for any other front-end
    requesting the same shape).

    Each workload cell reports its whole frontier, not one point:

      * ``systems`` — per-system bridge metrics at its own mix (identical
        to :func:`memsys_bridge` for the same shoreline),
      * ``best`` — the winning system at its own mix / reference shoreline,
      * ``crossovers`` — read-fraction regimes of the winning system along
        the dense mix axis (where the paper's conclusion flips),
      * ``shoreline_frontier`` + ``shoreline_sensitive`` — the winner at
        its own mix per shoreline budget.

    ``constraints`` (default :class:`SelectionConstraints`) applies to the
    whole space through the first-class feasibility mask
    (:meth:`repro.core.space.SpaceResult.feasible` composed via
    ``frontier(..., where=mask)``) — packaging, power caps, and the
    flit-simulation-derived ``max_backlog_knee`` queue-depth budget all
    mask the same grid.  The knee budget follows the CONFIGS axis
    automatically: ``feasible()`` threads each workload's own HLO-derived
    mix into :func:`repro.core.flitsim.backlog_knees` (``per_mix=True``),
    so a protocol is excluded for the workloads whose own mix needs a
    deeper queue than the budget — not by the canonical-mix envelope.

    ``sim`` (optional :class:`repro.core.space.SimConfig`) selects the
    flit-simulation config the knee extraction runs under — the analytic
    catalog metrics are closed forms and unaffected.  Default: the fixed
    engine (what every pinned knee golden was produced in).
    """
    from repro.core import TrafficMix, mix_grid
    from repro.core import space as space_mod
    from repro.core.selector import SelectionConstraints
    if constraints is None:
        constraints = SelectionConstraints()
    names = list(reports)
    mixes = [TrafficMix.from_bytes(reports[n].read_bytes_per_chip,
                                   reports[n].write_bytes_per_chip)
             for n in names]
    gx, gy = np.asarray(mix_grid(n_fracs), dtype=np.float64)
    sl = np.asarray(shorelines, dtype=np.float64)
    # the reference budget (where `best`/`systems` are reported) is always
    # evaluated exactly — appended to the axis if the caller's shoreline
    # list doesn't contain it, never silently snapped to a neighbor
    if not np.any(np.abs(sl - constraints.shoreline_mm) < 1e-9):
        sl = np.sort(np.append(sl, constraints.shoreline_mm))
    l_ref = int(np.argmin(np.abs(sl - constraints.shoreline_mm)))

    # configs axis on top of the mix axis: the OWN_MIX sentinel resolves to
    # each workload's own mix in column 0, columns 1: are the shared grid
    space = space_mod.DesignSpace(space_mod.AxisSet(
        space_mod.axis("workload_config", list(zip(names, mixes))),
        space_mod.axis("mix",
                       [space_mod.OWN_MIX] + list(zip(gx, gy))),
        space_mod.axis("shoreline_mm", sl),
    ), sim=sim)
    res = space.evaluate(metrics=space_mod.ANALYTIC_METRICS
                         + space_mod.SYSTEM_METRICS)
    # first-class feasibility: one boolean mask for the whole space; the
    # backlog-knee budget follows the workload_config axis inside it
    feas = res.feasible(constraints)
    metric, mode = {
        "bandwidth": ("bandwidth_gbs", "max"),
        "power": ("pj_per_bit", "min"),
        "gbs_per_watt": ("gbs_per_watt", "max"),
        "latency": ("latency_ns", "min"),
    }[objective]
    front = res.frontier(metric, "system", mode, where=feas)
    best_keys = front.values                            # [C, M+1, L] labels
    keys = res["bandwidth_gbs"].coord("system")
    bw = np.asarray(res["bandwidth_gbs"].values)        # [S, C, M+1, L]
    pj = np.asarray(res["pj_per_bit"].values)
    lat = np.asarray(res["latency_ns"].values)
    fracs = gx / 100.0

    out: Dict[str, Any] = {
        "read_fractions": fracs.tolist(),
        "shorelines": sl.tolist(),
        "reference_shoreline_mm": float(sl[l_ref]),
        "objective": objective,
        "keys": list(keys),
        "workloads": {},
    }
    for c, name in enumerate(names):
        rep = reports[name]
        # regimes tile [0, 1] contiguously: each boundary is the midpoint
        # between the last grid point of one winner and the first of the
        # next (the crossover lies between the two samples)
        crossovers = [
            {"read_fraction_lo": lo, "read_fraction_hi": hi,
             "best": str(label)}
            for lo, hi, label in space_mod.regimes(
                best_keys[c, 1:, l_ref].tolist(), fracs)]
        sl_frontier = {f"{s:g}mm": str(best_keys[c, 0, l])
                       for l, s in enumerate(sl)}
        out["workloads"][name] = {
            "mix": mixes[c].name,
            "read_fraction": mixes[c].read_fraction,
            "hbm_baseline_memory_s": rep.memory_s,
            "best": str(best_keys[c, 0, l_ref]),
            "feasible": best_keys[c, 0, l_ref] != "(none)",
            "systems": _systems_dict(rep, keys, bw[:, c, 0, l_ref],
                                     pj[:, c, 0, l_ref], lat),
            "crossovers": crossovers,
            "shoreline_frontier": sl_frontier,
            "shoreline_sensitive": len(set(sl_frontier.values())) > 1,
        }
    return out
