"""Attention: GQA with RoPE, causal / local-window / cross variants.

Two execution paths:

  * ``attend_chunked`` — flash-style streaming softmax over KV chunks
    (lax.scan, fp32 running max/sum).  Used for training and prefill; keeps
    the score tensor at [B, Sq, K, G, chunk] instead of [B, Sq, Skv, H].
    This is also the pure-jnp oracle for the Pallas flash kernel.
  * ``attend_decode`` — single new token against a KV cache; plain einsum
    with a length mask (the cache seq dim may be sharded across 'model' for
    context-parallel decode; XLA partitions the softmax reductions).

Layout: q [B, Sq, K, G, hd] (H = K*G query heads grouped by KV head),
k/v [B, Skv, K, hd].  GQA never materializes repeated KV.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, apply_rope, cast, rope_angles
from repro.models.schema import Leaf
from repro.models.sharding import ShardingCtx

NEG_INF = -1e30


def attn_schema(cfg: ModelConfig, cross: bool = False):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": Leaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Leaf((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Leaf((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Leaf((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = Leaf((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = Leaf((k, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = Leaf((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def qkv_project(params, x, cfg: ModelConfig, ctx: ShardingCtx,
                positions=None, rope_on: bool = True):
    """x: [B, S, d] -> q [B,S,K,G,hd], k/v [B,S,K,hd]."""
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // k
    q = jnp.einsum("bsd,dhx->bshx", x, cast(params["wq"]))
    kk = jnp.einsum("bsd,dkx->bskx", x, cast(params["wk"]))
    v = jnp.einsum("bsd,dkx->bskx", x, cast(params["wv"]))
    if "bq" in params:
        q = q + cast(params["bq"])
        kk = kk + cast(params["bk"])
        v = v + cast(params["bv"])
    if rope_on and positions is not None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        kk = apply_rope(kk, cos, sin)
    # TP layout for attention internals, in preference order:
    #   1. KV heads divisible by TP      -> shard kv_heads (q, k, v)
    #   2. total Q heads divisible by TP -> constrain the FLAT head dim;
    #      the [B,S,H,hd]->[B,S,K,G,hd] reshape lets XLA split the TP axis
    #      across (K, G) (e.g. 16 -> [8,2]) and partially shard K/V — this
    #      follows the weight-induced sharding instead of fighting it
    #      (q-seq constraints here caused involuntary full remat).
    #   3. fallback: shard the query sequence (full attention per rank
    #      over replicated K/V) — keeps fp32 score tensors 1/TP-sized.
    tp = ctx.tp_size()
    h_total = k * g
    sq = q.shape[1]
    if tp > 1 and k % tp == 0:
        q = q.reshape(q.shape[0], q.shape[1], k, g, hd)
        q = ctx.constrain(q, "batch", "seq", "kv_heads", None, None)
        kk = ctx.constrain(kk, "batch", "seq", "kv_heads", None)
        v = ctx.constrain(v, "batch", "seq", "kv_heads", None)
    elif tp > 1 and h_total % tp == 0 and not ctx.force_seq_attn:
        q = ctx.constrain(q, "batch", "seq", "heads", None)
        q = q.reshape(q.shape[0], q.shape[1], k, g, hd)
        # k/v left to propagation: XLA partially shards K over the leading
        # factor of the (K, G) split
    elif tp > 1 and sq % tp == 0 and sq > 1:
        q = q.reshape(q.shape[0], q.shape[1], k, g, hd)
        q = ctx.constrain(q, "batch", "attn_q_seq", None, None, None)
        kk = ctx.constrain(kk, "batch", None, None, None)
        v = ctx.constrain(v, "batch", None, None, None)
    else:
        q = q.reshape(q.shape[0], q.shape[1], k, g, hd)
    return q, kk, v


def out_project(params, o, cfg: ModelConfig, ctx: ShardingCtx):
    """o: [B, S, K, G, hd] -> [B, S, d]."""
    b, s, k, g, hd = o.shape
    o = o.reshape(b, s, k * g, hd)
    out = jnp.einsum("bshx,hxd->bsd", o, cast(params["wo"]))
    return ctx.constrain(out, "batch", "seq", "embed_act")


def _chunk_mask(q_pos, kv_pos, causal: bool, window: int):
    """q_pos: [Sq], kv_pos: [Ck] -> bool [Sq, Ck] (True = attend)."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, chunk: int = 1024):
    """Streaming-softmax attention.

    q: [B, Sq, K, G, hd]; k, v: [B, Skv, K, hd].
    q_offset: absolute position of q[0] relative to k[0] (prefill: 0 when
    Sq == Skv; decode chunks: cache length).
    Returns [B, Sq, K, G, hd].
    """
    b, sq, kh, g, hd = q.shape
    skv = k.shape[1]
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    n_chunks = skv // chunk
    scale = (1.0 / jnp.sqrt(hd)).astype(jnp.float32)

    q_pos = jnp.arange(sq) + q_offset

    kc = k.reshape(b, n_chunks, chunk, kh, hd)
    vc = v.reshape(b, n_chunks, chunk, kh, hd)
    kc = jnp.moveaxis(kc, 1, 0)          # [n, B, chunk, K, hd]
    vc = jnp.moveaxis(vc, 1, 0)

    def step(carry, inputs):
        m_run, l_run, acc = carry
        ki, vi, idx = inputs
        kv_pos = idx * chunk + jnp.arange(chunk)
        # dots read bf16 operands directly, accumulating fp32 (TPU-native;
        # avoids materializing fp32 copies of K/V — §Perf hillclimb)
        s = jnp.einsum("bqkgx,bckx->bqkgc", q, ki,
                       preferred_element_type=jnp.float32)
        s = s * scale
        mask = _chunk_mask(q_pos, kv_pos, causal, window)     # [Sq, C]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bqkgc,bckx->bqkgx", p.astype(q.dtype), vi,
                            preferred_element_type=jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, sq, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, g, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.astype(q.dtype)


def attend_decode(q, k_cache, v_cache, cache_len=None, valid_mask=None):
    """One-token attention against a cache.

    q: [B, 1, K, G, hd]; caches: [B, S, K, hd].
    cache_len: scalar or [B] — number of valid positions (the new token's
    K/V must already be written, i.e. cache_len INCLUDES it); OR
    valid_mask: [B, S] bool (ring buffers / arbitrary validity).
    """
    b, _, kh, g, hd = q.shape
    s = k_cache.shape[1]
    scale = (1.0 / jnp.sqrt(hd)).astype(jnp.float32)
    # read the cache at its storage dtype; accumulate fp32 in the dot
    logits = jnp.einsum("bqkgx,bskx->bqkgs", q, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if valid_mask is None:
        pos = jnp.arange(s)
        valid_mask = pos[None, :] < jnp.reshape(
            jnp.asarray(cache_len), (-1, 1))
    logits = jnp.where(valid_mask[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqkgs,bskx->bqkgx", w.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
