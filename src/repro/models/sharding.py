"""Sharding context + logical-axis rules (MaxText-style).

Parameters and activations carry *logical* axis names; a ``ShardingCtx``
maps them to mesh axes with divisibility guards.  The same model code runs:

  * unsharded on one CPU device (smoke tests)          — ctx = ShardingCtx()
  * on the production mesh (16,16) / (2,16,16)          — ctx = from_mesh(mesh)

Mesh contract (DESIGN.md §4):
  'model' — tensor parallel (heads / ffn / vocab / experts)    [intra-pod ICI]
  'data'  — FSDP parameter dim + batch                          [intra-pod ICI]
  'pod'   — pure data parallel (gradient all-reduce only)       [DCN]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (None = replicated)
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "batch": "__dp__",          # expands to ('pod','data') / ('data',)
    "seq": "__seq__",           # tp-sharded under sequence-parallelism
    "seq_kv": "__tp__",         # KV-cache length (context parallel decode)
    "vocab": "__tp__",
    "embed": "__fsdp__",        # FSDP parameter dim
    "embed_act": None,          # activation feature dim stays replicated
    "heads": "__tp__",
    "kv_heads": "__tp__",
    "attn_q_seq": "__tp__",     # q-seq sharding when head counts don't divide
    "head_dim": None,
    "mlp": "__tp__",
    "experts": "__tp__",
    "expert_mlp": None,
    "layers": None,
    "lru": "__tp__",
    "ssm_inner": "__tp__",
    "ssm_state": None,
    "conv": None,
    "norm": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()           # ('pod','data') or ('data',)
    tp_axis: Optional[str] = None           # 'model'
    fsdp_axis: Optional[str] = None         # 'data'
    rules: Optional[Dict[str, Optional[str]]] = None
    sequence_parallel: bool = False
    #: disable the flat-head attention constraint (baseline reproduction)
    force_seq_attn: bool = False

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name: str) -> int:
        if not self.enabled:
            return 1
        return self.mesh.shape[name]

    def dp_size(self) -> int:
        return int(np.prod([self.axis_size(a) for a in self.dp_axes])) or 1

    def tp_size(self) -> int:
        return self.axis_size(self.tp_axis) if self.tp_axis else 1

    def _resolve(self, logical: Optional[str]):
        """Logical axis -> mesh axis (or tuple), before divisibility checks."""
        if logical is None or not self.enabled:
            return None
        rules = dict(DEFAULT_RULES)
        if self.rules:
            rules.update(self.rules)
        tgt = rules.get(logical)
        if tgt == "__dp__":
            return self.dp_axes if self.dp_axes else None
        if tgt == "__tp__":
            return self.tp_axis
        if tgt == "__fsdp__":
            return self.fsdp_axis
        if tgt == "__seq__":
            return self.tp_axis if self.sequence_parallel else None
        return tgt

    def spec(self, axes: Tuple[Optional[str], ...],
             shape: Optional[Tuple[int, ...]] = None) -> P:
        """Build a PartitionSpec from logical axes, dropping non-divisible,
        over-subscribed, or duplicate-axis assignments to replication."""
        out = []
        used: set = set()
        for i, logical in enumerate(axes):
            mesh_axes = self._resolve(logical)
            if mesh_axes is None:
                out.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes_t = (mesh_axes,)
            else:
                mesh_axes_t = tuple(mesh_axes)
            if any(a in used for a in mesh_axes_t):
                out.append(None)            # a mesh axis may appear once
                continue
            if shape is not None:
                total = int(np.prod([self.axis_size(a) for a in mesh_axes_t]))
                if total == 0 or shape[i] % total != 0:
                    out.append(None)
                    continue
            used.update(mesh_axes_t)
            out.append(mesh_axes_t[0] if len(mesh_axes_t) == 1 else mesh_axes_t)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, axes, shape=None) -> Optional[NamedSharding]:
        if not self.enabled:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x, *axes):
        """with_sharding_constraint by logical axes (no-op when disabled)."""
        if not self.enabled:
            return x
        spec = self.spec(tuple(axes), tuple(x.shape))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def from_mesh(mesh: Mesh, sequence_parallel: bool = False,
              rules: Optional[Dict[str, Optional[str]]] = None,
              force_seq_attn: bool = False) -> ShardingCtx:
    names = mesh.axis_names
    if "pod" in names:
        dp_axes: Tuple[str, ...] = ("pod", "data")
    else:
        dp_axes = ("data",)
    return ShardingCtx(mesh=mesh, dp_axes=dp_axes,
                       tp_axis="model" if "model" in names else None,
                       fsdp_axis="data" if "data" in names else None,
                       rules=rules, sequence_parallel=sequence_parallel,
                       force_seq_attn=force_seq_attn)
