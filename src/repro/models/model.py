"""Public model API: build(cfg) -> Model with init / loss / prefill / decode
and per-shape abstract input specs (the dry-run's ShapeDtypeStruct source).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.compat import tree_flatten_with_path, tree_map_with_path
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import encdec as encdec_mod
from repro.models import schema as schema_mod
from repro.models import transformer as tf_mod
from repro.models.layers import COMPUTE_DTYPE
from repro.models.sharding import ShardingCtx


def cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """Mean CE over valid tokens; fp32; optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- schema / params -----------------------------------------------------
    @property
    def schema(self):
        if self.cfg.is_encdec:
            return encdec_mod.encdec_schema(self.cfg)
        return tf_mod.model_schema(self.cfg)

    def init(self, key: jax.Array):
        return schema_mod.init_params(self.schema, key)

    def param_specs(self, ctx: ShardingCtx):
        return schema_mod.param_specs(self.schema, ctx)

    def param_shardings(self, ctx: ShardingCtx):
        return schema_mod.param_shardings(self.schema, ctx)

    def abstract_params(self):
        return schema_mod.abstract_params(self.schema)

    def param_count(self) -> int:
        return schema_mod.param_count(self.schema)

    # -- forwards --------------------------------------------------------------
    def _forward(self, params, inputs, ctx, *, mode, caches=None,
                 positions=None):
        if self.cfg.is_encdec:
            return encdec_mod.forward_encdec(
                params, inputs, self.cfg, ctx, mode=mode, caches=caches,
                positions=positions)
        return tf_mod.forward(params, inputs, self.cfg, ctx, mode=mode,
                              caches=caches, positions=positions)

    def loss(self, params, batch: Dict[str, Any], ctx: ShardingCtx):
        """-> (loss, metrics).  batch must contain 'labels' aligned with the
        token positions of the logits (frontends prepend unlabeled prefix)."""
        logits, _, aux = self._forward(params, batch, ctx, mode="train")
        labels = batch["labels"]
        if self.cfg.frontend == "vision" and "patch_embeds" in batch:
            # logits cover [patches; tokens] — score text positions only
            p = batch["patch_embeds"].shape[1]
            logits = logits[:, p:, :]
        # next-token prediction: shift
        ce = cross_entropy(logits[:, :-1, :], labels[:, 1:],
                           mask=(labels[:, 1:] >= 0))
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(self, params, inputs: Dict[str, Any], ctx: ShardingCtx,
                pad_cache_to: Optional[int] = None):
        logits, caches, _ = self._forward(params, inputs, ctx, mode="prefill")
        if pad_cache_to is not None:
            caches = self.pad_caches(caches, pad_cache_to)
        return logits, caches

    def pad_caches(self, caches, target_len: int):
        """Extend attention KV caches' seq dim to target_len (for decode
        continuation after prefill).  Ring (local) caches and recurrent
        states are fixed-size and left untouched."""
        cfg = self.cfg

        def pad_kv(kv, axis):
            def _p(t):
                cur = t.shape[axis]
                if cur >= target_len:
                    return t
                pad = [(0, 0)] * t.ndim
                pad[axis] = (0, target_len - cur)
                return jnp.pad(t, pad)
            return jax.tree.map(_p, kv)

        if cfg.is_encdec:
            return tree_map_with_path(
                lambda path, t: (pad_kv(t, 2)
                                 if any(getattr(p, "key", None) == "self"
                                        for p in path) else t),
                caches)
        if cfg.family == "ssm" or cfg.attention == "local":
            # pure-SSM states are seqlen-free; hybrids use ring + states
            if cfg.block_pattern:
                out = {}
                for name, c in caches.items():
                    out[name] = c          # rings/states fixed-size
                return out
            return caches
        axis = 2 if (cfg.scan_layers and cfg.homogeneous()) else 1
        return pad_kv(caches, axis)

    def decode_step(self, params, tokens, caches, positions,
                    ctx: ShardingCtx):
        """tokens [B,1] int32; positions [B,1] int32 (absolute)."""
        logits, new_caches, _ = self._forward(
            params, {"tokens": tokens}, ctx, mode="decode", caches=caches,
            positions=positions)
        return logits, new_caches

    # -- abstract inputs for the dry-run ---------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        emb = functools.partial(jax.ShapeDtypeStruct, dtype=COMPUTE_DTYPE)

        if shape.kind == "train":
            if cfg.is_encdec:
                return {"frames": emb((b, s, cfg.d_model)),
                        "tokens": tok((b, s)), "labels": tok((b, s))}
            if cfg.frontend == "vision":
                p = cfg.frontend_tokens
                return {"tokens": tok((b, s - p)),
                        "patch_embeds": emb((b, p, cfg.d_model)),
                        "labels": tok((b, s - p))}
            return {"tokens": tok((b, s)), "labels": tok((b, s))}

        if shape.kind == "prefill":
            if cfg.is_encdec:
                return {"frames": emb((b, s, cfg.d_model)),
                        "tokens": tok((b, s))}
            if cfg.frontend == "vision":
                p = cfg.frontend_tokens
                return {"tokens": tok((b, s - p)),
                        "patch_embeds": emb((b, p, cfg.d_model))}
            return {"tokens": tok((b, s))}

        # decode: one token against caches of length s
        caches = jax.eval_shape(
            lambda: self.init_decode_caches(b, s))
        return {"tokens": tok((b, 1)),
                "positions": tok((b, 1)),
                "caches": caches}

    def init_decode_caches(self, batch: int, max_len: int):
        if self.cfg.is_encdec:
            cfg = self.cfg
            kv = {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads,
                                  cfg.head_dim), COMPUTE_DTYPE),
                  "v": jnp.zeros((batch, max_len, cfg.num_kv_heads,
                                  cfg.head_dim), COMPUTE_DTYPE)}
            per_layer = {"self": kv, "cross": jax.tree.map(jnp.copy, kv)}
            return jax.tree.map(
                lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype),
                per_layer)
        return tf_mod.init_decode_caches(self.cfg, batch, max_len)

    # -- sharding for inputs ----------------------------------------------------
    def input_shardings(self, shape: ShapeSpec, ctx: ShardingCtx,
                        specs: Dict[str, Any]):
        """NamedShardings matching input_specs structure."""
        stacked = (self.cfg.is_encdec
                   or (self.cfg.scan_layers and self.cfg.homogeneous()))

        def shard_one(path_leaf):
            path, leaf = path_leaf
            nd = len(leaf.shape)
            name = path[0]
            if name == "caches":
                axes = ["layers"] if stacked else []
                rest = nd - len(axes)
                axes = axes + ["batch"] + [None] * (rest - 1)
                if rest == 4:
                    # attn KV caches [B, S, K, hd] (context-parallel decode:
                    # seq over TP) — also shards SSM state [B, H, P, N] on H
                    axes[-3] = "seq_kv"
                return ctx.sharding(tuple(axes), leaf.shape)
            axes = ["batch"] + [None] * (nd - 1)
            if name in ("patch_embeds", "frames"):
                axes = ["batch", None, "embed_act"]
            return ctx.sharding(tuple(axes), leaf.shape)

        flat, treedef = tree_flatten_with_path(specs)
        out = []
        for path, leaf in flat:
            names = tuple(getattr(p, "key", getattr(p, "idx", None))
                          for p in path)
            out.append(shard_one((names, leaf)))
        return jax.tree.unflatten(treedef, out)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
