"""Mixture-of-Experts block — expert parallelism via shard_map.

Design (DESIGN.md §4):
  * expert weights sharded [experts -> 'model', embed -> 'data' (FSDP)]
  * activations enter dp-sharded and TP-replicated (the baseline layout),
    so each model-rank routes the *full local* token block, packs only the
    tokens destined for its local experts (sort-free: cumsum positions),
    runs the expert FFN, and a psum over 'model' combines expert outputs
    AND restores TP replication — no explicit all-to-all needed.
  * the FSDP all-gather of expert weights over 'data' is explicit
    (jax.lax.all_gather inside the shard_map), mirroring what XLA's
    sharded-weight gather does for the dense layers.

Capacity-factor token dropping (standard top-k capacity MoE) with an
auxiliary load-balancing loss.  The single-device path (ctx disabled) runs
the identical packing math with E_local = E and no collectives, so smoke
tests exercise the same numerics the production mesh runs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import cast
from repro.models.schema import Leaf
from repro.models.sharding import ShardingCtx


def moe_schema(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {
        "router": Leaf((d, e), ("embed_act", "experts"), init="normal"),
        "wi": Leaf((e, d, f), ("experts", "embed", "expert_mlp"), fan_axis=1),
        "wo": Leaf((e, f, d), ("experts", "expert_mlp", "embed"), fan_axis=1),
    }
    if cfg.mlp_gated:
        s["wg"] = Leaf((e, d, f), ("experts", "embed", "expert_mlp"),
                       fan_axis=1)
    return s


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(8, c)


def _moe_local(xt, router_w, wi, wg, wo, cfg: ModelConfig,
               e_local: int, rank, capacity: int):
    """Per-device MoE compute.

    xt: [T, d] local tokens (replicated across TP ranks);
    wi/wg/wo: this rank's expert slab [E_local, d, f] / [E_local, f, d];
    rank: TP rank (experts [rank*E_local, (rank+1)*E_local) are local).
    Returns (out [T, d] — only local experts' contribution, aux metrics).
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    # router in fp32: top-k tie stability across shardings/reduction orders
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # [T, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                            # [E]
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                              # [T*k]
    flat_w = top_w.reshape(-1)
    e0 = rank * e_local
    local_id = flat_e - e0                                  # [T*k]
    is_local = (local_id >= 0) & (local_id < e_local)

    # position within each local expert via cumsum of one-hot [T*k, E_local]
    onehot = jax.nn.one_hot(jnp.where(is_local, local_id, e_local),
                            e_local + 1, dtype=jnp.int32)[:, :e_local]
    pos = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    pos_in_e = jnp.sum(pos * onehot, axis=1)                # [T*k]
    keep = is_local & (pos_in_e < capacity)
    dest = jnp.where(keep, local_id * capacity + pos_in_e, e_local * capacity)

    tok = jnp.arange(t * k) // k
    gathered = jnp.take(xt, tok, axis=0)                    # [T*k, d]
    xe = jnp.zeros((e_local * capacity + 1, d), xt.dtype).at[dest].add(
        jnp.where(keep[:, None], gathered, 0))
    xe = xe[:-1].reshape(e_local, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", xe, cast(wi))
    if wg is not None:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast(wg))) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, cast(wo))

    ye_flat = jnp.concatenate(
        [ye.reshape(e_local * capacity, d),
         jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = jnp.take(ye_flat, dest, axis=0)               # [T*k, d]
    contrib = contrib * (flat_w * keep).astype(contrib.dtype)[:, None]
    out = jnp.zeros((t, d), xt.dtype).at[tok].add(contrib)

    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / jnp.maximum(
        jnp.sum(is_local.astype(jnp.float32)), 1.0)
    return out, aux, dropped


def moe_block(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    wg = params.get("wg")

    if not ctx.enabled or ctx.tp_size() == 1:
        xt = x.reshape(b * s, d)
        cap = _capacity(b * s, cfg)
        out, aux, _ = _moe_local(xt, params["router"], params["wi"], wg,
                                 params["wo"], cfg, cfg.num_experts, 0, cap)
        return out.reshape(b, s, d), aux

    mesh = ctx.mesh
    tp = ctx.tp_axis
    fsdp = ctx.fsdp_axis
    e_local = cfg.num_experts // ctx.tp_size()
    dp_spec = tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]

    t_local = (b // ctx.dp_size()) * s
    cap = _capacity(t_local, cfg)

    x_spec = P(dp_spec, None, None)
    gated = wg is not None
    all_axes = tuple(ctx.dp_axes) + (tp,)

    def _sharded(xb, router_w, wi, wo, *rest):
        # FSDP gather of this rank's expert slab over 'data'
        wi_full = jax.lax.all_gather(wi, fsdp, axis=1, tiled=True)
        wo_full = jax.lax.all_gather(wo, fsdp, axis=2, tiled=True)
        wg_full = (jax.lax.all_gather(rest[0], fsdp, axis=1, tiled=True)
                   if gated else None)
        rank = jax.lax.axis_index(tp)
        xt = xb.reshape(-1, d)
        out, aux, dropped = _moe_local(xt, router_w, wi_full, wg_full,
                                       wo_full, cfg, e_local, rank, cap)
        # combine expert contributions across TP ranks; aux averaged over
        # the whole mesh so the out_spec can declare it replicated
        out = jax.lax.psum(out, tp)
        aux = jax.lax.pmean(aux, all_axes)
        return out.reshape(xb.shape), aux

    in_specs = [x_spec, P(None, None), P(tp, fsdp, None), P(tp, None, fsdp)]
    args = [x, params["router"], params["wi"], params["wo"]]
    if gated:
        in_specs.append(P(tp, fsdp, None))
        args.append(wg)
    fn = shard_map(
        _sharded, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(x_spec, P()), check_vma=False)
    out, aux = fn(*args)
    return out, aux
