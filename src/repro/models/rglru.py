"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    i_t = sigmoid(W_i x_t)                  (input gate, block-diagonal)
    r_t = sigmoid(W_r x_t)                  (recurrence gate, block-diagonal)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses jax.lax.associative_scan over the sequence (this is the
pure-jnp oracle for the Pallas ``rglru_scan`` kernel); decode is a single
recurrence step carrying h.  The block wraps the LRU with the Griffin
recurrent-block structure: linear in, short depthwise conv, gated output.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cast
from repro.models.schema import Leaf
from repro.models.sharding import ShardingCtx

RG_LRU_C = 8.0


def rglru_schema(cfg: ModelConfig):
    d = cfg.d_model
    lru = d                                  # lru width == d_model (RG-2B)
    hn = max(cfg.lru_heads, 1)
    bs = lru // hn
    return {
        "wx": Leaf((d, lru), ("embed", "lru")),
        "wgate": Leaf((d, lru), ("embed", "lru")),
        "conv_w": Leaf((cfg.conv_width, lru), ("conv", "lru"), init="fan_in"),
        "conv_b": Leaf((lru,), ("lru",), init="zeros"),
        "gate_i_w": Leaf((hn, bs, bs), ("lru", None, None), fan_axis=1),
        "gate_i_b": Leaf((hn, bs), ("lru", None), init="zeros"),
        "gate_r_w": Leaf((hn, bs, bs), ("lru", None, None), fan_axis=1),
        "gate_r_b": Leaf((hn, bs), ("lru", None), init="zeros"),
        "lam": Leaf((lru,), ("lru",), init="normal"),
        "wo": Leaf((lru, d), ("lru", "embed")),
    }


def _block_diag(x, w, b):
    """x: [B, S, lru], w: [Hn, bs, bs] -> [B, S, lru]."""
    bsz, s, lru = x.shape
    hn, blk, _ = w.shape
    xh = x.reshape(bsz, s, hn, blk)
    y = jnp.einsum("bshi,hij->bshj", xh, w) + b
    return y.reshape(bsz, s, lru)


def _gates(params, xb):
    """-> (log_a, gated_input) both [B, S, lru] fp32."""
    i = jax.nn.sigmoid(_block_diag(xb, cast(params["gate_i_w"]),
                                   cast(params["gate_i_b"])).astype(jnp.float32))
    r = jax.nn.sigmoid(_block_diag(xb, cast(params["gate_r_w"]),
                                   cast(params["gate_r_b"])).astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(
        params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xb.astype(jnp.float32))
    return log_a, gated


def lru_scan(log_a, x):
    """Associative linear recurrence h_t = a_t h_{t-1} + x_t over axis 1.

    log_a, x: [B, S, C] fp32 -> h: [B, S, C] fp32.
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    la, h = jax.lax.associative_scan(combine, (log_a, x), axis=1)
    return h


def _conv1d(x, w, b, state=None):
    """Causal depthwise conv, width W.  x: [B, S, C]; w: [W, C].

    state: [B, W-1, C] carried inputs for decode; returns (y, new_state).
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :]
    return y + b, new_state


def rglru_block(params, x, cfg: ModelConfig, ctx: ShardingCtx,
                state: Tuple = None, decode: bool = False):
    """Griffin recurrent block.  x: [B, S, d].

    state: (h [B, lru] fp32, conv [B, W-1, lru]) when decoding.
    Returns (out [B, S, d], new_state).
    """
    xb = jnp.einsum("bsd,dl->bsl", x, cast(params["wx"]))
    gate = jnp.einsum("bsd,dl->bsl", x, cast(params["wgate"]))
    xb = ctx.constrain(xb, "batch", "seq", "lru")

    conv_state = state[1] if state is not None else None
    xb, new_conv = _conv1d(xb, cast(params["conv_w"]), cast(params["conv_b"]),
                           conv_state)

    log_a, gated = _gates(params, xb)
    if decode:
        h_prev = state[0]                            # [B, lru] fp32
        h = jnp.exp(log_a[:, 0]) * h_prev + gated[:, 0]
        hs = h[:, None, :]
        new_h = h
    else:
        hs = lru_scan(log_a, gated)                  # [B, S, lru]
        new_h = hs[:, -1]
    hs = ctx.constrain(hs.astype(x.dtype), "batch", "seq", "lru")
    out = jax.nn.gelu(gate) * hs
    out = jnp.einsum("bsl,ld->bsd", out, cast(params["wo"]))
    out = ctx.constrain(out, "batch", "seq", "embed_act")
    return out, (new_h, new_conv)


def init_state(cfg: ModelConfig, batch: int):
    lru = cfg.d_model
    return (jnp.zeros((batch, lru), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1, lru), jnp.float32))
