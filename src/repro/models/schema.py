"""Parameter schema: one structural source of truth for shapes, logical
axes, and initializers — ``init_params`` and ``param_specs`` both derive
from it, so sharding metadata can never drift from the arrays."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]         # logical axis names per dim
    init: str = "fan_in"                    # fan_in | normal | zeros | ones
    dtype: Any = jnp.float32
    fan_axis: int = 0                       # which dim is fan-in for scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = Dict[str, Any]          # nested dict of Leaf


def stack(schema: Schema, n: int) -> Schema:
    """Add a leading 'layers' axis of size n to every leaf (scan stacking)."""
    def _s(leaf: Leaf) -> Leaf:
        return Leaf((n,) + leaf.shape, ("layers",) + leaf.axes,
                    leaf.init, leaf.dtype, leaf.fan_axis + 1)
    return jax.tree.map(_s, schema,
                        is_leaf=lambda x: isinstance(x, Leaf))


def init_params(schema: Schema, key: jax.Array) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, Leaf))
    keys = jax.random.split(key, len(leaves))

    def _init(leaf: Leaf, k):
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, leaf.dtype)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, leaf.dtype)
        if leaf.init == "normal":
            return (jax.random.normal(k, leaf.shape) * 0.02).astype(leaf.dtype)
        # fan_in scaled
        fan = leaf.shape[leaf.fan_axis] if leaf.shape else 1
        std = 1.0 / np.sqrt(max(fan, 1))
        return (jax.random.normal(k, leaf.shape) * std).astype(leaf.dtype)

    return jax.tree.unflatten(treedef, [_init(l, k)
                                        for l, k in zip(leaves, keys)])


def param_specs(schema: Schema, ctx: ShardingCtx):
    """PartitionSpec pytree matching the schema."""
    return jax.tree.map(lambda l: ctx.spec(l.axes, l.shape), schema,
                        is_leaf=lambda x: isinstance(x, Leaf))


def param_shardings(schema: Schema, ctx: ShardingCtx):
    return jax.tree.map(lambda l: ctx.sharding(l.axes, l.shape), schema,
                        is_leaf=lambda x: isinstance(x, Leaf))


def abstract_params(schema: Schema) -> Dict[str, Any]:
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), schema,
        is_leaf=lambda x: isinstance(x, Leaf))


def param_count(schema: Schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, Leaf))
    return int(sum(np.prod(l.shape) for l in leaves))
