"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

Layers are homogeneous for most archs -> ``lax.scan`` over stacked layer
params (small HLO, per-layer FSDP all-gather stays inside the loop);
heterogeneous patterns (RecurrentGemma's rec/rec/attn) use a python loop.
Per-layer remat (``jax.checkpoint``) keeps saved activations at layer
boundaries only.

Three modes:
  train   — full forward, no caches, returns logits (+ MoE aux loss)
  prefill — builds per-layer caches, returns last-position logits + caches
  decode  — one token per sequence against caches (pos may vary per batch)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cast, embed, embedding_schema, mlp, mlp_schema, rmsnorm, rmsnorm_schema,
    unembed,
)
from repro.models.schema import Leaf, init_params, stack
from repro.models.sharding import ShardingCtx


# -- schemas -------------------------------------------------------------------

def block_schema(cfg: ModelConfig, kind: str):
    d = cfg.d_model
    s: Dict[str, Any] = {"ln1": rmsnorm_schema(d), "ln2": rmsnorm_schema(d)}
    if kind == "attn":
        s["attn"] = attn.attn_schema(cfg)
        s["mlp"] = mlp_schema(cfg)
    elif kind == "moe":
        s["attn"] = attn.attn_schema(cfg)
        s["moe"] = moe_mod.moe_schema(cfg)
    elif kind == "rec":
        s["rec"] = rglru_mod.rglru_schema(cfg)
        s["mlp"] = mlp_schema(cfg)
    elif kind == "ssm":
        s = {"ln1": rmsnorm_schema(d), "ssm": ssm_mod.ssm_schema(cfg)}
    else:
        raise ValueError(kind)
    return s


def model_schema(cfg: ModelConfig):
    s: Dict[str, Any] = {
        "embedding": embedding_schema(cfg),
        "final_norm": rmsnorm_schema(cfg.d_model),
    }
    if cfg.frontend == "vision":
        s["frontend"] = {"proj": Leaf((cfg.d_model, cfg.d_model),
                                      ("embed", "embed_act"))}
    kinds = cfg.layer_kinds()
    if cfg.scan_layers and cfg.homogeneous():
        s["blocks"] = stack(block_schema(cfg, kinds[0]), cfg.num_layers)
    else:
        s["blocks"] = {f"layer_{i:02d}": block_schema(cfg, k)
                       for i, k in enumerate(kinds)}
    return s


# -- per-block apply -----------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    k = cfg.num_kv_heads
    hd = cfg.head_dim
    length = min(max_len, cfg.window) if cfg.attention == "local" else max_len
    shape = (batch, length, k, hd)
    from repro.models.layers import COMPUTE_DTYPE
    return {"k": jnp.zeros(shape, COMPUTE_DTYPE),
            "v": jnp.zeros(shape, COMPUTE_DTYPE)}


def _ring_gather(kv, window: int):
    """kv: [B, S, K, hd] -> ring cache [B, W, K, hd]: slot j holds the
    newest position p <= S-1 with p % W == j."""
    s = kv.shape[1]
    if s <= window:
        pad = jnp.zeros((kv.shape[0], window - s) + kv.shape[2:], kv.dtype)
        return jnp.concatenate([kv, pad], axis=1)
    j = jnp.arange(window)
    p = (s - 1) - ((s - 1 - j) % window)
    return jnp.take(kv, p, axis=1)


def attn_block(lp, x, cfg: ModelConfig, ctx: ShardingCtx, *,
               mode: str, positions, cache=None):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    causal = True
    window = cfg.window if cfg.attention == "local" else 0
    new_cache = None

    if mode == "decode":
        b = x.shape[0]
        pos = positions[:, 0]                              # [B]
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, ctx,
                                   positions=positions)
        if window > 0:
            slot = pos % window
            kc = cache["k"].at[jnp.arange(b), slot].set(k[:, 0])
            vc = cache["v"].at[jnp.arange(b), slot].set(v[:, 0])
            j = jnp.arange(kc.shape[1])
            valid = (j[None, :] <= pos[:, None]) | (pos[:, None] >= window - 1)
            o = attn.attend_decode(q, kc, vc, cache_len=None,
                                   valid_mask=valid)
        else:
            kc = cache["k"].at[jnp.arange(b), pos].set(k[:, 0])
            vc = cache["v"].at[jnp.arange(b), pos].set(v[:, 0])
            o = attn.attend_decode(q, kc, vc, cache_len=pos + 1)
        new_cache = {"k": kc, "v": vc}
    else:
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, ctx,
                                   positions=positions)
        o = attn.attend_chunked(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            if window > 0:
                new_cache = {"k": _ring_gather(k, window),
                             "v": _ring_gather(v, window)}
            else:
                new_cache = {"k": k, "v": v}

    x = x + attn.out_project(lp["attn"], o, cfg, ctx)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        m, aux = moe_mod.moe_block(lp["moe"], h2, cfg, ctx)
    else:
        m = mlp(lp["mlp"], h2, cfg, ctx)
    return x + m, new_cache, aux


def rec_block(lp, x, cfg: ModelConfig, ctx: ShardingCtx, *,
              mode: str, positions, cache=None):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    state = cache if mode == "decode" else None
    o, new_state = rglru_mod.rglru_block(lp["rec"], h, cfg, ctx,
                                         state=state,
                                         decode=(mode == "decode"))
    x = x + o
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h2, cfg, ctx)
    new_cache = new_state if mode in ("decode", "prefill") else None
    return x, new_cache, jnp.zeros((), jnp.float32)


def ssm_block_apply(lp, x, cfg: ModelConfig, ctx: ShardingCtx, *,
                    mode: str, positions, cache=None):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    state = cache if mode == "decode" else None
    o, new_state = ssm_mod.ssm_block(lp["ssm"], h, cfg, ctx, state=state,
                                     decode=(mode == "decode"))
    new_cache = new_state if mode in ("decode", "prefill") else None
    return x + o, new_cache, jnp.zeros((), jnp.float32)


_BLOCK_FNS = {"attn": attn_block, "moe": attn_block, "rec": rec_block,
              "ssm": ssm_block_apply}


def _cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "moe"):
        return _attn_cache_init(cfg, batch, max_len)
    if kind == "rec":
        return rglru_mod.init_state(cfg, batch)
    if kind == "ssm":
        return ssm_mod.init_state(cfg, batch)
    raise ValueError(kind)


# -- model forward --------------------------------------------------------------

def _inputs_to_embeds(params, inputs: Dict[str, Any], cfg: ModelConfig,
                      ctx: ShardingCtx):
    x = embed(params["embedding"], inputs["tokens"], ctx)
    if cfg.frontend == "vision" and "patch_embeds" in inputs:
        pe = jnp.einsum("bpd,de->bpe", cast(inputs["patch_embeds"]),
                        cast(params["frontend"]["proj"]))
        x = jnp.concatenate([pe, x], axis=1)
        x = ctx.constrain(x, "batch", "seq", "embed_act")
    return x


def forward(params, inputs: Dict[str, Any], cfg: ModelConfig,
            ctx: ShardingCtx, *, mode: str, caches=None, positions=None):
    """Shared forward.  Returns (hidden or logits info, caches, aux).

    train:   (logits [B,S,V], None, aux)
    prefill: (last_logits [B,V], caches, aux)
    decode:  (logits [B,V], caches, aux)   — inputs["tokens"]: [B, 1],
             positions [B, 1] = current absolute position per sequence.
    """
    kinds = cfg.layer_kinds()
    if mode == "decode":
        x = embed(params["embedding"], inputs["tokens"], ctx)
    else:
        x = _inputs_to_embeds(params, inputs, cfg, ctx)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (1, s))

    aux_total = jnp.zeros((), jnp.float32)
    scanned = cfg.scan_layers and cfg.homogeneous()

    if scanned:
        kind = kinds[0]
        block_fn = _BLOCK_FNS[kind]

        def body(lp, x, cache):
            return block_fn(lp, x, cfg, ctx, mode=mode, positions=positions,
                            cache=cache)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        if mode == "train":
            def scan_fn(carry, lp):
                x, aux = carry
                x2, _, a = body(lp, x, None)
                return (x2, aux + a), None
            (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total),
                                             params["blocks"])
            new_caches = None
        elif mode == "prefill":
            def scan_fn(carry, lp):
                x, aux = carry
                x2, new_c, a = body(lp, x, None)
                return (x2, aux + a), new_c
            (x, aux_total), new_caches = jax.lax.scan(
                scan_fn, (x, aux_total), params["blocks"])
        else:                                   # decode: caches required
            def scan_fn(carry, xs):
                x, aux = carry
                lp, cache_l = xs
                x2, new_c, a = body(lp, x, cache_l)
                return (x2, aux + a), new_c
            (x, aux_total), new_caches = jax.lax.scan(
                scan_fn, (x, aux_total), (params["blocks"], caches))
    else:
        new_caches = {}
        for i, kind in enumerate(kinds):
            lp = params["blocks"][f"layer_{i:02d}"]
            block_fn = _BLOCK_FNS[kind]
            fn = functools.partial(block_fn, cfg=cfg, ctx=ctx, mode=mode,
                                   positions=positions)
            if cfg.remat and mode == "train":
                fn = jax.checkpoint(fn)
            cache_l = None
            if mode == "decode":
                cache_l = caches[f"layer_{i:02d}"]
            elif mode == "prefill":
                cache_l = None
            x, new_c, a = fn(lp, x, cache=cache_l)
            aux_total = aux_total + a
            if mode in ("prefill", "decode"):
                new_caches[f"layer_{i:02d}"] = new_c
        if mode == "train":
            new_caches = None

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    if mode == "train":
        logits = unembed(params["embedding"], x, cfg, ctx)
        return logits, None, aux_total
    if mode == "prefill":
        last = x[:, -1:, :]
        logits = unembed(params["embedding"], last, cfg, ctx)[:, 0]
        return logits, new_caches, aux_total
    logits = unembed(params["embedding"], x, cfg, ctx)[:, 0]
    return logits, new_caches, aux_total


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Zero caches for decode-only lowering (the dry-run's decode shapes)."""
    kinds = cfg.layer_kinds()
    if cfg.scan_layers and cfg.homogeneous():
        c0 = _cache_init(cfg, kinds[0], batch, max_len)
        return jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), c0)
    return {f"layer_{i:02d}": _cache_init(cfg, k, batch, max_len)
            for i, k in enumerate(kinds)}
