from repro.models.model import Model, build, cross_entropy
from repro.models.sharding import ShardingCtx, from_mesh
