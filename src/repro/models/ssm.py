"""Mamba2 block — SSD (state-space duality), chunked algorithm.

Per head h with scalar decay a_t = exp(dt_t * A_h)  (A_h = -exp(A_log)):

    state_t = a_t * state_{t-1} + dt_t * B_t  x_t^T      ([N, P] outer)
    y_t     = C_t . state_t + D_h * x_t

Training/prefill uses the chunked SSD form (arXiv:2405.21060 §6, the
"minimal" formulation): intra-chunk quadratic attention-like term with the
decay kernel L, plus an inter-chunk recurrence over per-chunk states via
lax.scan.  This is the pure-jnp oracle for the Pallas ``ssd_scan`` kernel.
Decode carries (conv_state, ssm_state [B, H, P, N]).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cast, rmsnorm
from repro.models.schema import Leaf
from repro.models.sharding import ShardingCtx


def ssm_schema(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    g, n, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    d_conv = di + 2 * g * n
    d_proj = 2 * di + 2 * g * n + nh
    return {
        "in_proj": Leaf((d, d_proj), ("embed", "ssm_inner")),
        "conv_w": Leaf((cfg.conv_width, d_conv), ("conv", "ssm_inner"),
                       init="fan_in"),
        "conv_b": Leaf((d_conv,), ("ssm_inner",), init="zeros"),
        "a_log": Leaf((nh,), (None,), init="ones"),
        "d_skip": Leaf((nh,), (None,), init="ones"),
        "dt_bias": Leaf((nh,), (None,), init="zeros"),
        "norm_scale": Leaf((di,), ("ssm_inner",), init="ones"),
        "out_proj": Leaf((di, d), ("ssm_inner", "embed")),
    }


def _segsum(log_a):
    """log_a: [..., Q] -> cumulative decay matrix [..., Q, Q]:
    out[i, j] = sum_{k=j+1..i} log_a[k]  (lower triangular, -inf above)."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # sum_{j+1..i}
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, b, c, a_log_neg, chunk: int,
                init_state=None):
    """Chunked SSD scan.

    x:  [B, S, H, P]   (inputs per head)
    dt: [B, S, H]      (softplus-ed step sizes, fp32)
    b:  [B, S, G, N]   c: [B, S, G, N]   (G groups broadcast over H)
    a_log_neg: [H]     (A = -exp(a_log))
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g                                # heads per group

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = -jnp.exp(a_log_neg.astype(jnp.float32))           # [H] negative
    da = dtf * a                                          # [B, S, H] log-decay
    xdt = xf * dtf[..., None]                             # dt-scaled input

    def resh(t, extra):
        return t.reshape((bsz, nc, chunk) + extra)

    xc = resh(xdt, (h, p))
    dac = resh(da, (h,))
    bc = resh(b.astype(jnp.float32), (g, n))
    cc = resh(c.astype(jnp.float32), (g, n))

    # --- intra-chunk (diagonal block): y = (C B^T . L) x -------------------
    lmat = jnp.exp(_segsum(jnp.moveaxis(dac, -1, 2)))     # [B, nc, H, Q, Q]
    # scores[b,l,h,i,j] = C_i . B_j  (broadcast G over H)
    cbh = jnp.einsum("blqgn,blkgn->blgqk", cc, bc)        # [B,nc,G,Q,Q]
    cbh = jnp.repeat(cbh, hg, axis=2)                     # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("blhqk,blhqk,blkhp->blqhp",
                        cbh, lmat, xc)

    # --- per-chunk final states -------------------------------------------
    da_cum = jnp.cumsum(dac, axis=2)                      # [B,nc,Q,H]
    da_tot = da_cum[:, :, -1, :]                          # [B,nc,H]
    decay_to_end = jnp.exp(da_tot[:, :, None, :] - da_cum)  # [B,nc,Q,H]
    # states[b,l,h,n,p] = sum_q decay * B_q x_q^T
    states = jnp.einsum("blqhn,blqh,blqhp->blhnp",
                        jnp.repeat(bc, hg, axis=3), decay_to_end, xc)

    # --- inter-chunk recurrence over chunk states (lax.scan) ---------------
    if init_state is None:
        s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    else:
        s0 = jnp.swapaxes(init_state.astype(jnp.float32), -1, -2)

    def chunk_step(carry, inp):
        st_prev = carry                                   # [B,H,N,P]
        st_c, da_t = inp                                  # [B,H,N,P], [B,H]
        st_new = st_c + jnp.exp(da_t)[..., None, None] * st_prev
        return st_new, st_prev

    states_t = jnp.moveaxis(states, 1, 0)                 # [nc,B,H,N,P]
    da_tot_t = jnp.moveaxis(da_tot, 1, 0)                 # [nc,B,H]
    final_state, prev_states = jax.lax.scan(
        chunk_step, s0, (states_t, da_tot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # [B,nc,H,N,P]

    # --- inter-chunk contribution: y += C . (decay_in * prev_state) --------
    decay_in = jnp.exp(da_cum)                            # [B,nc,Q,H]
    y_off = jnp.einsum("blqgn,blqh,blhnp->blqhp",
                       cc, decay_in, prev_states) if g == 1 else \
        jnp.einsum("blqhn,blqh,blhnp->blqhp",
                   jnp.repeat(cc, hg, axis=3), decay_in, prev_states)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, jnp.swapaxes(final_state, -1, -2)           # [B,H,P,N]


def ssm_block(params, x, cfg: ModelConfig, ctx: ShardingCtx,
              state: Tuple = None, decode: bool = False):
    """x: [B, S, d] -> (out [B, S, d], new_state (conv, ssm))."""
    from repro.models.rglru import _conv1d                 # shared causal conv

    di, g, n, nh, p = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    proj = jnp.einsum("bsd,de->bse", x, cast(params["in_proj"]))
    proj = ctx.constrain(proj, "batch", "seq", "ssm_inner")
    z, xbc, dt_raw = jnp.split(proj, [di, di + di + 2 * g * n], axis=-1)

    conv_state = state[0] if state is not None else None
    xbc, new_conv = _conv1d(xbc, cast(params["conv_w"]),
                            cast(params["conv_b"]), conv_state)
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [di, di + g * n], axis=-1)
    bsz, s = x.shape[0], x.shape[1]
    xs = xs.reshape(bsz, s, nh, p)
    b = b.reshape(bsz, s, g, n)
    c = c.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    # shard heads over TP: the intra-chunk decay tensor [B,nc,H,Q,Q] is the
    # memory hot-spot and inherits this sharding through the einsums
    xs = ctx.constrain(xs, "batch", "seq", "heads", None)
    dt = ctx.constrain(dt, "batch", "seq", "heads")

    if decode:
        ssm_state = state[1]                              # [B, H, P, N] fp32
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        da = jnp.exp(dt[:, 0] * a)                        # [B, H]
        bx = jnp.einsum("bhp,bgn->bhpn",
                        (xs[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
                        b[:, 0].astype(jnp.float32))
        new_ssm = da[..., None, None] * ssm_state + bx
        y = jnp.einsum("bhpn,bgn->bhp", new_ssm, c[:, 0].astype(jnp.float32))
        y = y[:, None]                                    # [B, 1, H, P]
    else:
        init = state[1] if state is not None else None
        y, new_ssm = ssd_chunked(xs, dt, b, c, params["a_log"],
                                 min(cfg.ssm_chunk, s), init)

    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)                                 # gated
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, cast(params["out_proj"]))
    out = ctx.constrain(out, "batch", "seq", "embed_act")
    return out, (new_conv, new_ssm)


def init_state(cfg: ModelConfig, batch: int):
    d_conv = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return (jnp.zeros((batch, cfg.conv_width - 1, d_conv), jnp.float32),
            jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32))
