"""Shared building blocks: RMSNorm, embeddings, MLPs, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import Leaf
from repro.models.sharding import ShardingCtx

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# -- RMSNorm ------------------------------------------------------------------

def rmsnorm_schema(d: int):
    return {"scale": Leaf((d,), ("norm",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# -- Embedding / unembedding --------------------------------------------------

def embedding_schema(cfg: ModelConfig):
    v = cfg.padded_vocab
    s = {"embed": Leaf((v, cfg.d_model), ("vocab", "embed"), init="normal")}
    if not cfg.tie_embeddings:
        s["unembed"] = Leaf((cfg.d_model, v), ("embed", "vocab"))
    return s


def embed(params, tokens, ctx: ShardingCtx):
    table = cast(params["embed"])
    out = jnp.take(table, tokens, axis=0)
    return ctx.constrain(out, "batch", "seq", "embed_act")


def unembed(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    if cfg.tie_embeddings:
        w = cast(params["embed"]).T
    else:
        w = cast(params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padding columns so softmax/argmax never see them
        vidx = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(vidx < cfg.vocab_size, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return ctx.constrain(logits, "batch", "seq", "vocab")


# -- MLP ------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    s = {"wi": Leaf((d, f), ("embed", "mlp")),
         "wo": Leaf((f, d), ("mlp", "embed"))}
    if cfg.mlp_gated:
        s["wg"] = Leaf((d, f), ("embed", "mlp"))
    return s


def mlp(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    h = jnp.einsum("bsd,df->bsf", x, cast(params["wi"]))
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,df->bsf", x, cast(params["wg"]))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = ctx.constrain(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, cast(params["wo"]))
    return ctx.constrain(out, "batch", "seq", "embed_act")


# -- RoPE -----------------------------------------------------------------------

def rope_angles(positions, hd: int, theta: float = 10000.0):
    """positions: [B, S] (use [1, S] to share across batch) ->
    (cos, sin) each [B, S, hd//2]."""
    assert positions.ndim == 2, "positions must be [B, S]"
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, S, <head dims...>, hd]; cos/sin: [B, S, hd//2] or [S, hd//2].

    Head axes are broadcast by inserting singleton dims before the last."""
    half = x.shape[-1] // 2
    while cos.ndim < x.ndim:
        cos = jnp.expand_dims(cos, -2)
        sin = jnp.expand_dims(sin, -2)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
