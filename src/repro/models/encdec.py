"""Encoder-decoder transformer (seamless-m4t family).

Encoder consumes precomputed modality frame embeddings (the audio frontend
is a stub per the assignment); decoder is a causal LM with cross-attention
into the encoder output.  Both stacks are homogeneous -> lax.scan.

Caches for decode: per-decoder-layer self-attention K/V plus
cross-attention K/V precomputed once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    cast, mlp, mlp_schema, rmsnorm, rmsnorm_schema, unembed,
)
from repro.models.schema import Leaf, stack
from repro.models.sharding import ShardingCtx


def enc_block_schema(cfg: ModelConfig):
    return {"ln1": rmsnorm_schema(cfg.d_model),
            "attn": attn.attn_schema(cfg),
            "ln2": rmsnorm_schema(cfg.d_model),
            "mlp": mlp_schema(cfg)}


def dec_block_schema(cfg: ModelConfig):
    return {"ln1": rmsnorm_schema(cfg.d_model),
            "attn": attn.attn_schema(cfg),
            "lnx": rmsnorm_schema(cfg.d_model),
            "xattn": attn.attn_schema(cfg, cross=True),
            "ln2": rmsnorm_schema(cfg.d_model),
            "mlp": mlp_schema(cfg)}


def encdec_schema(cfg: ModelConfig):
    d = cfg.d_model
    v = cfg.padded_vocab
    return {
        "embedding": {
            "embed": Leaf((v, d), ("vocab", "embed"), init="normal"),
            "unembed": Leaf((d, v), ("embed", "vocab")),
        },
        "frontend": {"adapter": Leaf((d, d), ("embed", "embed_act"))},
        "encoder": {"blocks": stack(enc_block_schema(cfg),
                                    cfg.encoder_layers),
                    "final_norm": rmsnorm_schema(d)},
        "decoder": {"blocks": stack(dec_block_schema(cfg), cfg.num_layers)},
        "final_norm": rmsnorm_schema(d),
    }


def _enc_block(lp, x, cfg, ctx, positions):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, ctx, positions=positions)
    o = attn.attend_chunked(q, k, v, causal=False)
    x = x + attn.out_project(lp["attn"], o, cfg, ctx)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x + mlp(lp["mlp"], h2, cfg, ctx)


def encode(params, frames, cfg: ModelConfig, ctx: ShardingCtx):
    """frames: [B, Se, d] precomputed frontend embeddings -> [B, Se, d]."""
    x = jnp.einsum("bsd,de->bse", cast(frames),
                   cast(params["frontend"]["adapter"]))
    x = ctx.constrain(x, "batch", "seq", "embed_act")
    se = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(se)[None, :], (1, se))

    def body(lp, x):
        return _enc_block(lp, x, cfg, ctx, positions)

    if cfg.remat:
        body = jax.checkpoint(body)

    def scan_fn(x, lp):
        return body(lp, x), None

    x, _ = jax.lax.scan(scan_fn, x, params["encoder"]["blocks"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _dec_block(lp, x, enc_or_cross, cfg, ctx, *, mode, positions,
               self_cache=None):
    """enc_or_cross: encoder output [B,Se,d] (train/prefill) or
    precomputed cross (k, v) dict (decode)."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    new_cache = None
    if mode == "decode":
        b = x.shape[0]
        pos = positions[:, 0]
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, ctx,
                                   positions=positions)
        kc = self_cache["k"].at[jnp.arange(b), pos].set(k[:, 0])
        vc = self_cache["v"].at[jnp.arange(b), pos].set(v[:, 0])
        o = attn.attend_decode(q, kc, vc, cache_len=pos + 1)
        new_cache = {"k": kc, "v": vc}
    else:
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, ctx,
                                   positions=positions)
        o = attn.attend_chunked(q, k, v, causal=True)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    x = x + attn.out_project(lp["attn"], o, cfg, ctx)

    # cross attention
    hx = rmsnorm(lp["lnx"], x, cfg.norm_eps)
    if mode == "decode":
        xk, xv = enc_or_cross["k"], enc_or_cross["v"]
        g = cfg.num_heads // cfg.num_kv_heads
        qx = jnp.einsum("bsd,dhx->bshx", hx, cast(lp["xattn"]["wq"]))
        qx = qx.reshape(qx.shape[0], qx.shape[1], cfg.num_kv_heads, g,
                        cfg.head_dim)
        ox = attn.attend_decode(qx, xk, xv, cache_len=xk.shape[1])
    else:
        qx, _, _ = attn.qkv_project(lp["xattn"], hx, cfg, ctx,
                                    rope_on=False, positions=None)
        xk = jnp.einsum("bsd,dkx->bskx", enc_or_cross,
                        cast(lp["xattn"]["wk"]))
        xv = jnp.einsum("bsd,dkx->bskx", enc_or_cross,
                        cast(lp["xattn"]["wv"]))
        ox = attn.attend_chunked(qx, xk, xv, causal=False)
    x = x + attn.out_project(lp["xattn"], ox, cfg, ctx)

    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h2, cfg, ctx)
    if mode == "prefill":
        new_cache = {"self": new_cache,
                     "cross": {"k": xk, "v": xv}}
    elif mode == "decode":
        new_cache = {"self": new_cache, "cross": enc_or_cross}
    return x, new_cache


def forward_encdec(params, inputs: Dict[str, Any], cfg: ModelConfig,
                   ctx: ShardingCtx, *, mode: str, caches=None,
                   positions=None):
    """train: inputs {frames [B,Se,d], tokens [B,St]} -> logits [B,St,V]
    prefill: same -> (last logits [B,V], caches)
    decode: inputs {tokens [B,1]}, caches, positions [B,1] -> (logits, caches)
    """
    if mode == "decode":
        x = jnp.take(cast(params["embedding"]["embed"]),
                     inputs["tokens"], axis=0)
        x = ctx.constrain(x, "batch", "seq", "embed_act")

        def scan_fn(carry, xs):
            x, = carry
            lp, cache_l = xs
            x2, new_c = _dec_block(lp, x, cache_l["cross"], cfg, ctx,
                                   mode="decode", positions=positions,
                                   self_cache=cache_l["self"])
            return (x2,), new_c
        (x,), new_caches = jax.lax.scan(
            scan_fn, (x,), (params["decoder"]["blocks"], caches))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embedding"], x, cfg, ctx)[:, 0]
        return logits, new_caches, jnp.zeros((), jnp.float32)

    enc = encode(params, inputs["frames"], cfg, ctx)
    tokens = inputs["tokens"]
    x = jnp.take(cast(params["embedding"]["embed"]), tokens, axis=0)
    x = ctx.constrain(x, "batch", "seq", "embed_act")
    st = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(st)[None, :], (1, st))

    def body(lp, x):
        return _dec_block(lp, x, enc, cfg, ctx, mode=mode,
                          positions=positions)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    def scan_fn(carry, lp):
        x, = carry
        x2, new_c = body(lp, x)
        return (x2,), new_c

    (x,), new_caches = jax.lax.scan(scan_fn, (x,),
                                    params["decoder"]["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if mode == "train":
        logits = unembed(params["embedding"], x, cfg, ctx)
        return logits, None, jnp.zeros((), jnp.float32)
    last = x[:, -1:, :]
    logits = unembed(params["embedding"], last, cfg, ctx)[:, 0]
    return logits, new_caches, jnp.zeros((), jnp.float32)
