"""Paper reproduction: on-package memory over UCIe (approaches A-E),
roofline analysis of compiled workloads, and the workload->design-space
bridge connecting them.

Importing the package applies :mod:`repro.compat` — version-tolerant JAX
aliases plus layout-invariant (partitionable) threefry RNG, which every
sharded-init / elastic-checkpoint path relies on.  Keeping the flip here
makes it unconditional: any ``import repro.<anything>`` gets it, rather
than only the modules that happen to import a compat alias.
"""
from repro import compat  # noqa: F401
