"""Pure-jnp oracle for the RG-LRU linear recurrence:

    h_t = exp(log_a_t) * h_{t-1} + b_t

Sequential lax.scan form (the associative-scan form in repro.models.rglru
is validated against this too)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_ref(log_a, b, h0=None):
    """log_a, b: [B, S, C] fp32 -> h: [B, S, C]."""
    bsz, s, c = b.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, c), jnp.float32)

    def step(h, inp):
        la, bt = inp
        h = jnp.exp(la) * h + bt
        return h, h

    xs = (jnp.moveaxis(log_a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0))
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1)
