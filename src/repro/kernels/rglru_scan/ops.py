"""jit'd wrapper for the RG-LRU scan: Pallas on TPU, associative-scan
(jnp) elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan as _pallas_lru


def lru(log_a, b):
    """log_a, b: [B, S, C] -> h [B, S, C] fp32."""
    if jax.default_backend() == "tpu":
        return _pallas_lru(log_a, b)
    from repro.models.rglru import lru_scan
    return lru_scan(log_a.astype("float32"), b.astype("float32"))
