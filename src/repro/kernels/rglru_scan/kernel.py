"""RG-LRU linear recurrence — Pallas TPU kernel.

    h_t = exp(log_a_t) * h_{t-1} + b_t       (elementwise over channels)

Grid: (B, num_channel_blocks, num_seq_blocks); the seq axis is innermost
and sequential, carrying h across blocks in VMEM scratch.  Within a block
the recurrence is evaluated in log-space with a numerically-safe blocked
prefix: for each position t in the block,

    h_t = exp(cs_t - cs_j) h_block_start-ish ...

A direct stable evaluation uses the within-block decay matrix
L[t, s] = exp(cs_t - cs_s) for t >= s (same segsum construction as SSD):

    h_t = exp(cs_t) * h_prev + sum_{s<=t} L[t, s] * b_s

computed as an [Q, Q] x [Q, bc] matmul per channel block — MXU-friendly
and avoids the exp(-cs) overflow of the naive prefix-division trick.
VMEM per program ~ Q*bc*3 + Q^2 floats (Q=128, bc=128 -> ~320 KB fp32).
"""
# repro-lint: disable-file=RL002
# This kernel deliberately does NOT share compute bodies with ref.py:
# ref.py is the O(T) sequential recurrence oracle, while the kernel
# evaluates the equivalent blocked decay-matrix form ([Q,Q] matmuls per
# channel block).  Equivalence is pinned numerically against lru_ref in
# tests/test_kernels.py, not by construction.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, b_ref, h_ref, state_ref, *, nq: int):
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    la = la_ref[0].astype(jnp.float32)            # [Q, bc]
    b = b_ref[0].astype(jnp.float32)              # [Q, bc]
    q = la.shape[0]

    cs = jnp.cumsum(la, axis=0)                   # [Q, bc] inclusive
    # h_t = exp(cs_t) * h_prev + sum_{s<=t} exp(cs_t - cs_s) b_s
    # The decay kernel is per-channel: evaluate channel-blocked einsum via
    # broadcasting rather than a single matmul (decay depends on channel).
    # [Q, Q, bc] is too large for VMEM at bc=128, Q=128 (8 MB fp32) on some
    # parts; keep Q modest (<=128) or split channels.
    diff = cs[:, None, :] - cs[None, :, :]        # [Q, Q, bc]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    lmat = jnp.where(tri[..., None], jnp.exp(diff), 0.0)
    h_prev = state_ref[...]                       # [1, bc]
    hs = jnp.einsum("tsc,sc->tc", lmat, b) + jnp.exp(cs) * h_prev
    state_ref[...] = hs[-1:, :]
    h_ref[0] = hs.astype(h_ref.dtype)


def rglru_scan(log_a, b, *, block_seq: int = 128, block_ch: int = 128,
               interpret: bool = False):
    """log_a, b: [B, S, C] -> h: [B, S, C] (fp32)."""
    bsz, s, c = b.shape
    q = min(block_seq, s)
    bc = min(block_ch, c)
    assert s % q == 0 and c % bc == 0, (s, q, c, bc)
    nq, ncb = s // q, c // bc

    kernel = functools.partial(_kernel, nq=nq)
    return pl.pallas_call(
        kernel,
        grid=(bsz, ncb, nq),
        in_specs=[
            pl.BlockSpec((1, q, bc), lambda ib, ic, iq: (ib, iq, ic)),
            pl.BlockSpec((1, q, bc), lambda ib, ic, iq: (ib, iq, ic)),
        ],
        out_specs=pl.BlockSpec((1, q, bc), lambda ib, ic, iq: (ib, iq, ic)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b)
