"""SSD (Mamba2) chunked scan — Pallas TPU kernel.

Grid: (B, H, num_chunks); chunks are the innermost sequential axis,
carrying the inter-chunk SSM state [P, N] in VMEM scratch.  Per program:

    x  : [Q, P]   (this head's chunk inputs)
    dt : [Q, 1]
    b,c: [Q, N]   (G=1 groups shared across heads)
    a  : [1, 1]   (this head's A = -exp(a_log))

Within the chunk the SSD closed form is evaluated with MXU matmuls:
    y_diag = ((C B^T) . L) (dt*x),  L = exp(segsum(dt*A))     [Q,Q]
    y_off  = (C . decay_in) state_prev
    state  = decay_total * state_prev + (decay_to_end*B)^T (dt*x)

Q defaults to 128/256 (MXU-aligned); VMEM per program ~ Q*(P+2N) + Q^2 +
P*N floats.
"""
# repro-lint: disable-file=RL002
# This kernel deliberately does NOT share compute bodies with ref.py:
# ref.py is the O(T) sequential lax.scan oracle, while the kernel
# evaluates the algebraically equivalent chunked closed form on the MXU
# (segsum decay matrices + matmuls).  Equivalence is pinned numerically
# against ssd_ref in tests/test_kernels.py, not by construction.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, y_ref, fs_ref,
            state_ref, *, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # [Q]
    bmat = b_ref[0].astype(jnp.float32)                 # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)                 # [Q, N]
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))       # scalar

    q = x.shape[0]
    da = dt * a                                          # [Q]
    cs = jnp.cumsum(da)                                  # [Q]
    xdt = x * dt[:, None]

    # L[i, j] = exp(sum_{k=j+1..i} da_k) for i >= j
    diff = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    lmat = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # [Q,Q]
    y = jax.lax.dot_general(cb * lmat, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk contribution and state update
    state = state_ref[...]                               # [P, N]
    decay_in = jnp.exp(cs)[:, None]                      # [Q,1]
    y = y + jax.lax.dot_general(cmat * decay_in, state,
                                (((1,), (1,)), ((), ())))
    decay_to_end = jnp.exp(cs[-1] - cs)[:, None]         # [Q,1]
    new_state = (jnp.exp(cs[-1]) * state
                 + jax.lax.dot_general(xdt, bmat * decay_to_end,
                                       (((0,), (0,)), ((), ()))))
    state_ref[...] = new_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        fs_ref[0, 0] = new_state.astype(fs_ref.dtype)


def ssd_scan(x, dt, b, c, a_log, *, chunk: int = 128,
             interpret: bool = False):
    """x: [B,S,H,P]; dt: [B,S,H]; b,c: [B,S,N]; a_log: [H]
    -> (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    kernel = functools.partial(_kernel, nc=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, q, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, q, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a_log)
    return y, fs
