"""jit'd wrapper for the SSD scan: Pallas on TPU, chunked-jnp elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan as _pallas_ssd
from repro.kernels.ssd_scan.ref import ssd_ref


def ssd(x, dt, b, c, a_log, chunk: int = 128):
    """x: [B,S,H,P]; dt: [B,S,H]; b,c: [B,S,N]; a_log: [H]."""
    if jax.default_backend() == "tpu":
        return _pallas_ssd(x, dt, b, c, a_log, chunk=chunk)
    from repro.models.ssm import ssd_chunked
    y, fs = ssd_chunked(x, dt, b[:, :, None, :], c[:, :, None, :],
                        a_log, chunk=min(chunk, x.shape[1]))
    return y, fs
