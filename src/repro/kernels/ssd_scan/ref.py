"""Pure-jnp oracle for the SSD chunk-scan kernel: the sequential recurrence

    state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * B_t x_t^T
    y_t     = C_t . state_t + D_h * x_t                       (D applied by caller)

evaluated step-by-step with lax.scan (the slow-but-obviously-correct form;
the chunked closed form in repro.models.ssm is itself validated against
this)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, b, c, a_log, init_state=None):
    """x: [B,S,H,P]; dt: [B,S,H]; b,c: [B,S,N] (G=1); a_log: [H]
    -> (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dtt * a)                  # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), final
