"""jit'd wrapper for flit packing: Pallas on TPU, jnp elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flit_pack.kernel import pack_flits as _pallas_pack
from repro.kernels.flit_pack.ref import (
    flits_needed, pack_flits_ref, unpack_flits_ref,
)


@functools.partial(jax.jit, static_argnames=())
def pack(lines, headers, hdr_meta):
    if jax.default_backend() == "tpu":
        return _pallas_pack(lines, headers, hdr_meta)
    return pack_flits_ref(lines, headers, hdr_meta)


unpack = unpack_flits_ref
