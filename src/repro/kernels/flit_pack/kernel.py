"""CXL.Mem-optimized flit packing — Pallas TPU kernel.

The paper's data-path hot-spot (Fig 9: one 256 B flit packed per 2 GHz
cycle).  On TPU we re-think the RTL mux tree as a VMEM-tiled streaming
gather: each program assembles BF flits from the already-slot-aligned data
stream plus the header stream, and computes the trailing 16-bit fold
checksum with a log2 XOR reduction tree (7 levels for 254 bytes — the
VPU analogue of the 5-gate-level CRC tree in Fig 9).

Grid: (num_flit_blocks,).  Blocks:
    slots   [BF*15, 16] int32  (the wrapper reshapes lines -> slots)
    headers [BF, 10]    int32
    meta    [BF, 4]     int32
    out     [BF, 256]   int32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flit_pack.ref import (
    DATA_BYTES, FLIT_BYTES, G_SLOTS, HS_BYTES, SLOT_BYTES, flits_needed,
)


def _xor_reduce(x, axis):
    """log2 XOR reduction tree along `axis` (power-of-two padded) — the
    lane-parallel equivalent of ref.py's sequential ``_xor_fold`` (XOR is
    associative, so the tree and the fold agree bit-for-bit; pinned
    against ``pack_flits_ref`` in tests/test_kernels.py)."""
    n = x.shape[axis]
    # pad to power of two with zeros (xor identity)
    p = 1
    while p < n:
        p *= 2
    if p != n:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p - n)
        x = jnp.pad(x, pad)
    while x.shape[axis] > 1:
        h = x.shape[axis] // 2
        lo = jax.lax.slice_in_dim(x, 0, h, axis=axis)
        hi = jax.lax.slice_in_dim(x, h, 2 * h, axis=axis)
        x = jnp.bitwise_xor(lo, hi)
    return jnp.squeeze(x, axis)


def _kernel(slots_ref, hdr_ref, meta_ref, out_ref, *, bf: int):
    slots = slots_ref[...]                        # [BF*15, 16]
    data = slots.reshape(bf, DATA_BYTES)          # [BF, 240]
    hdr = hdr_ref[...]                            # [BF, 10]
    meta = meta_ref[...]                          # [BF, 4]
    body = jnp.concatenate([data, hdr, meta], axis=1)   # [BF, 254]
    pairs = jnp.concatenate(
        [body, jnp.zeros((bf, 2), body.dtype)], axis=1).reshape(bf, 128, 2)
    lo = _xor_reduce(pairs[:, :, 0], axis=1)
    hi = _xor_reduce(pairs[:, :, 1], axis=1)
    out_ref[...] = jnp.concatenate(
        [body, lo[:, None], hi[:, None]], axis=1)


def pack_flits(lines, headers, hdr_meta, *, block_flits: int = 8,
               interpret: bool = False):
    """lines: [N, 64] int32; headers: [F, 10]; hdr_meta: [F, 4]
    -> flits [F, 256] int32.  F must equal flits_needed(N)."""
    n = lines.shape[0]
    f = headers.shape[0]
    assert f == flits_needed(n), (f, n)
    slots = lines.reshape(n * 4, SLOT_BYTES)
    pad_slots = f * G_SLOTS - n * 4
    if pad_slots:
        slots = jnp.concatenate(
            [slots, jnp.zeros((pad_slots, SLOT_BYTES), slots.dtype)], axis=0)

    bf = min(block_flits, f)
    fp = -(-f // bf) * bf
    if fp != f:
        headers = jnp.pad(headers, ((0, fp - f), (0, 0)))
        hdr_meta = jnp.pad(hdr_meta, ((0, fp - f), (0, 0)))
        slots = jnp.pad(slots, ((0, (fp - f) * G_SLOTS), (0, 0)))

    kernel = functools.partial(_kernel, bf=bf)
    out = pl.pallas_call(
        kernel,
        grid=(fp // bf,),
        in_specs=[
            pl.BlockSpec((bf * G_SLOTS, SLOT_BYTES), lambda i: (i, 0)),
            pl.BlockSpec((bf, HS_BYTES), lambda i: (i, 0)),
            pl.BlockSpec((bf, 4), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bf, FLIT_BYTES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((fp, FLIT_BYTES), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(slots, headers, hdr_meta)
    return out[:f]
