"""Pure-jnp oracle for CXL.Mem-optimized flit packing (paper Fig 8).

256 B flit layout (approach E):
    bytes [0, 240)   : 15 G-slots of 16 B — cache-line data (line i spans
                       4 consecutive G-slots; slots stream across flits)
    bytes [240, 250) : HS-slot (10 B) — one 62-bit request header
    bytes [250, 252) : Flit HDR (protocol id parked for NEXT flit, seq no)
    bytes [252, 254) : Credit
    bytes [254, 256) : CRC — 16-bit XOR-fold checksum over bytes [0, 254).
                       (The spec's CRC polynomial is not published in the
                       paper; the layout is what matters for the data path,
                       so a fold checksum stands in — documented.)

All byte values are carried as int32 in [0, 256) for TPU-friendliness.
Packing N cache lines (64 B each) requires ceil(4N / 15) flits.
"""
from __future__ import annotations

import jax.numpy as jnp

G_SLOTS = 15
SLOT_BYTES = 16
FLIT_BYTES = 256
HS_BYTES = 10
DATA_BYTES = G_SLOTS * SLOT_BYTES        # 240


def flits_needed(n_lines: int) -> int:
    return -(-4 * n_lines // G_SLOTS)


def pack_flits_ref(lines, headers, hdr_meta):
    """lines: [N, 64] int32 bytes; headers: [F, 10] int32 (one request/HS);
    hdr_meta: [F, 4] int32 (HDR0, HDR1, CRD0, CRD1) -> flits [F, 256] int32.
    """
    n = lines.shape[0]
    f = headers.shape[0]
    assert f == flits_needed(n), (f, n)
    slots = lines.reshape(n * 4, SLOT_BYTES)
    pad = f * G_SLOTS - n * 4
    if pad:
        slots = jnp.concatenate(
            [slots, jnp.zeros((pad, SLOT_BYTES), slots.dtype)], axis=0)
    data = slots.reshape(f, DATA_BYTES)
    body = jnp.concatenate([data, headers, hdr_meta], axis=1)  # [F, 254]
    crc = _xor_fold(body)
    return jnp.concatenate([body, crc], axis=1)


def _xor_fold(body):
    """16-bit XOR fold over byte pairs -> [F, 2] int32."""
    f, nb = body.shape
    if nb % 2:
        body = jnp.concatenate([body, jnp.zeros((f, 1), body.dtype)], axis=1)
    pairs = body.reshape(f, -1, 2)
    lo = jnp.bitwise_xor.reduce(pairs[:, :, 0], axis=1)
    hi = jnp.bitwise_xor.reduce(pairs[:, :, 1], axis=1)
    return jnp.stack([lo, hi], axis=1)


def unpack_flits_ref(flits, n_lines: int):
    """Inverse of pack (drops padding): -> (lines [N, 64], headers, meta,
    crc_ok [F] bool)."""
    f = flits.shape[0]
    body = flits[:, :254]
    crc = flits[:, 254:]
    ok = jnp.all(_xor_fold(body) == crc, axis=1)
    data = flits[:, :DATA_BYTES].reshape(f * G_SLOTS, SLOT_BYTES)
    lines = data[:n_lines * 4].reshape(n_lines, 64)
    headers = flits[:, DATA_BYTES:DATA_BYTES + HS_BYTES]
    meta = flits[:, DATA_BYTES + HS_BYTES:254]
    return lines, headers, meta, ok
