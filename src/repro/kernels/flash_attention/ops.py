"""jit'd wrapper: Pallas flash attention on TPU, jnp oracle elsewhere.

The backward pass uses the oracle via jax.custom_vjp (forward-optimized
deployment: serving/prefill hot path runs the kernel; training gradients
recompute with the XLA path, which remat makes the default anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_offset: int = 0):
    """q: [B, K, G, Sq, hd]; k, v: [B, K, Skv, hd] -> [B, K, G, Sq, hd]."""
    if _use_pallas():
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)


def _fwd(q, k, v, causal, window, q_offset):
    out = flash_attention(q, k, v, causal, window, q_offset)
    return out, (q, k, v)


def _bwd(causal, window, q_offset, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, q_offset=q_offset),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
