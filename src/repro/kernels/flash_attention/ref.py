"""Pure-jnp oracle for the flash-attention kernel (naive full softmax)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: [B, K, G, Sq, hd]; k, v: [B, K, Skv, hd] -> [B, K, G, Sq, hd]."""
    b, kh, g, sq, hd = q.shape
    skv = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgqh,bksh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq) + q_offset
    kv_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
