"""Flash-attention forward — Pallas TPU kernel.

Grid: (B, K, G, num_q_blocks, num_kv_blocks); the kv dimension is the
innermost, sequential ("arbitrary") axis, carrying the streaming-softmax
state (running max m, denominator l, accumulator acc) in VMEM scratch.

BlockSpec tiling (VMEM working set per program):
  q   : [1,1,1, bq, hd]   — revisited across kv blocks
  k/v : [1,1,   bk, hd]
  out : [1,1,1, bq, hd]   — written on the last kv block
  scratch: m [bq,1] f32, l [bq,1] f32, acc [bq, hd] f32

bq/bk default 512/512 with hd padded to a lane multiple by the wrapper;
MXU-aligned (multiples of 128) for the score matmuls [bq,hd]x[hd,bk].
Causal + local-window masking by absolute positions (q_offset supports
continuation chunks).  Fully-masked kv blocks are skipped via @pl.when.
"""
# repro-lint: disable-file=RL002
# This kernel deliberately does NOT share compute bodies with ref.py:
# ref.py materializes the full [T,T] softmax as the oracle, while the
# kernel runs the streaming (online-softmax) recurrence with running
# max/normalizer scratch.  Equivalence is pinned numerically against
# attention_ref in tests/test_kernels.py, not by construction.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, q_offset: int, bq: int, bk: int,
            n_kv: int, skv: int):
    ik = pl.program_id(4)
    iq = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kv_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # skip blocks that are entirely masked out (causal/window pruning)
    first_q = iq * bq + q_offset
    last_q = first_q + bq - 1
    first_kv = ik * bk
    last_kv = first_kv + bk - 1
    live = jnp.asarray(True)
    if causal:
        live &= first_kv <= last_q
    if window > 0:
        live &= last_kv > first_q - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = kv_pos < skv                              # kv padding
        if causal:
            mask &= kv_pos <= q_pos
        if window > 0:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_kv: int = 512, interpret: bool = False):
    """q: [B, K, G, Sq, hd]; k, v: [B, K, Skv, hd] -> [B, K, G, Sq, hd]."""
    b, kh, g, sq, hd = q.shape
    skv = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    # pad to block multiples (masks keep padded kv inert; padded q rows
    # are dropped after the call)
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    n_q, n_kv = sq_p // bq, skv_p // bk

    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, n_kv=n_kv, skv=skv)

    out = pl.pallas_call(
        kernel,
        grid=(b, kh, g, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b_, k_, g_, iq, ik: (b_, k_, g_, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, k_, g_, iq, ik: (b_, k_, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, k_, g_, iq, ik: (b_, k_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda b_, k_, g_, iq, ik: (b_, k_, g_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :, :sq, :]
