# Pallas TPU kernels for the framework's compute hot-spots + the paper's
# data-path hot-spot (flit packing).  Each subpackage: kernel.py
# (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd wrapper with a
# backend switch), ref.py (pure-jnp oracle).  Kernels are validated on CPU
# with interpret=True; the XLA path (ref) is used when lowering for
# non-TPU backends (e.g. the CPU dry-run).
