"""Backend dispatch for the fused flit-simulator kernels.

Real Pallas lowering on TPU; ``interpret=True`` everywhere else (the
interpret path traces to ordinary XLA ops, so the CPU tier-1 suite runs
the exact kernel bodies with no TPU in sight).  ``interpret=None`` in the
launch helpers means "auto" — callers (the flitsim runners, the tests)
can still force either mode explicitly.

The ``*_launch`` functions are the jit targets the engine's shared
compile cache (:func:`repro.core.space.cached_program`) memoizes: each is
one device program per adaptive chunk (symmetric / pipelining) or per
whole run (asymmetric periodic), returning the packed state rows plus the
convergence/detection flags the host loop reads back (one scalar-sized
sync per launch).
"""
from __future__ import annotations

import jax

from repro.kernels.flit_sim import kernel as _k
from repro.kernels.flit_sim.ref import (  # noqa: F401  (re-exported)
    ASYM_ROWS, PERIOD_EPS, PERIOD_MAX, PERIOD_OBS, PIPE_MAX_K, PIPE_ROWS,
    SCAL_COLS, SYM_PERIOD_OBS, SYM_PERIODIC_ROWS, SYM_ROWS,
)

pad_cells = _k.pad_cells
tile_for = _k.tile_for
SYM_PERIODIC_MAX_TILE = _k.SYM_PERIODIC_MAX_TILE


def default_interpret() -> bool:
    """Interpret (trace-to-XLA) everywhere but TPU."""
    return jax.default_backend() != "tpu"


def _resolve(interpret):
    return default_interpret() if interpret is None else bool(interpret)


def symmetric_chunk_launch(params, state, hist, scal, *, chunk: int,
                           tile: int, cells: int, interpret=None):
    """One symmetric chunk; returns (state_rows, conv flags [cells])."""
    out = _k.symmetric_chunk(params, state, hist, scal, chunk=chunk,
                             tile=tile, interpret=_resolve(interpret))
    return out, out[11, :cells] > 0.5


def asymmetric_periodic_launch(params, *, n_accesses: int, tile: int,
                               cells: int, interpret=None):
    """One-launch periodic run; returns (out_rows, detected [cells])."""
    out = _k.asymmetric_periodic(params, n_accesses=n_accesses, tile=tile,
                                 interpret=_resolve(interpret))
    return out, out[1, :cells] > 0.5


def symmetric_periodic_launch(params, *, n_flits: int, tile: int,
                              cells: int, interpret=None):
    """One-launch periodic run; returns (out_rows, detected [cells])."""
    out = _k.symmetric_periodic(params, n_flits=n_flits, tile=tile,
                                interpret=_resolve(interpret))
    return out, out[1, :cells] > 0.5


def pipelining_chunk_launch(params, state, hist, scal, *, chunk: int,
                            tile: int, cells: int, interpret=None):
    """One pipelining chunk; returns (state_rows, conv flags [cells])."""
    out = _k.pipelining_chunk(params, state, hist, scal, chunk=chunk,
                              tile=tile, interpret=_resolve(interpret))
    return out, out[11, :cells] > 0.5
