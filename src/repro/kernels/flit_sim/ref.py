"""Pure-jnp reference for the fused flit-simulator chunk contracts.

The Pallas kernels in :mod:`repro.kernels.flit_sim.kernel` and this
oracle share the compute bodies below verbatim — the kernel adds only the
tiling / ref plumbing — so kernel-vs-ref agreement is by construction and
the tests pin it bit-for-bit in ``interpret=True`` mode.

Every contract works on ROW-STACKED f32 arrays ``[rows, cells]`` (cells
last so the vector axis maps onto TPU lanes).  The row layouts:

symmetric ``params`` [16, C] (pad rows zero)::

    0..10  SymmetricFlitParams fields in dataclass order
           (g_slots .. write_buffer_lines)
    11 x   12 y   13 backlog

symmetric ``state`` [16, C] — also the chunk output layout::

    0..6   core (rq, wq, wdata, rdata, resp, cr, cw)
    7 D    cumulative data slots        8 TD   time-weighted sum(t * d_t)
    9 t    cycles simulated             10 rep  last report
    11 conv  convergence flag (output only)

symmetric ``hist`` [16, C] — chunk-boundary history rows the host gathers
from its per-chunk list (one launch per chunk keeps no cross-chunk
history on the device)::

    0..4   pools (rq, wq, wdata, rdata, resp) at chunk max(k-3, 0)
    5 D_m  6 TD_m  7 D_mid  8 TD_mid   (zeros when m == k / mid == k:
           the kernel substitutes the freshly computed accumulators)
    9 D_K0 (zeros when k <= K0)

symmetric ``scal`` [1, 128] broadcast scalars::

    0 k  1 m  2 mid  3 K0  4 K  5 chunk  6 tol
    7 exit_ok (k >= min_k and k > drift span)   8 at_horizon (k == K)
    9 drift_tol (slots / chunk)

asymmetric ``params`` [8, C]: AsymmetricLaneParams fields in dataclass
order (total_lanes .. access_bits) then 6 x, 7 y.  Output [8, C]:
0 rep, 1 detected, 2 period.

symmetric periodic: input is the symmetric ``params`` [16, C] stack;
output [8, C]: 0 rep, 1 detected, 2 period (pad rows zero).

pipelining ``params`` [8, C]: 0 k_devices, 1 ucie_line_ui,
2 device_line_ui.  ``state`` [16, C]: 0..7 dev_ready (padded to 8
devices), 8 link_free, 9 idx, 10 rep; output adds 11 conv.  ``hist``
[8, C]: 0 T1 (link free time after chunk 1; zeros at k == 1).  ``scal``
[1, 128]: 0 k, 1 K, 2 chunk, 3 tol, 4 exit_ok, 5 at_horizon, 6 n_lines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flitsim import (
    AsymmetricLaneParams, SymmetricFlitParams, _asymmetric_stepfn,
    _symmetric_stepfn,
)

#: rows per stacked operand (f32 sublane multiple)
SYM_ROWS = 16
ASYM_ROWS = 8
PIPE_ROWS = 16
#: broadcast-scalar operand shape (one full lane row)
SCAL_COLS = 128

#: largest credit-cycle denominator the period detector resolves; the
#: observation run is ~2 such periods (warm prefix + one full window)
PERIOD_MAX = 64
PERIOD_WINDOW = PERIOD_MAX + 1
PERIOD_WARM = PERIOD_MAX - 1
#: sequential steps the periodic observer executes
PERIOD_OBS = PERIOD_WARM + PERIOD_WINDOW
#: credit-phase match tolerance — true-period matches differ only by f32
#: accumulation noise (~1e-5 over the window) while non-matches differ by
#: a multiple of 1/PERIOD_MAX >= 1.5e-2
PERIOD_EPS = 1e-4

#: symmetric periodic detector: same warm/window geometry as the
#: asymmetric one, but the match predicate is EXACT f32 equality of the
#: whole 7-component pool/credit core against the lagged observation row
#: (plus an integer-valued delivery window, so every f32 sum below is
#: exact) — a state match is a trajectory certificate, so extrapolation
#: is bit-identical to the fixed engine wherever the cell detects
SYM_PERIOD_OBS = PERIOD_WARM + PERIOD_WINDOW
#: output rows of the symmetric periodic contract (0 rep, 1 detected,
#: 2 period; pad rows zero)
SYM_PERIODIC_ROWS = 8
#: probe-attempt gate: saturated pools re-round the proportional
#: read/write split every step, so their state period always exceeds
#: PERIOD_MAX and the observation probe is guaranteed wasted work (an
#: extra compiled program + SYM_PERIOD_OBS cycles).  Grids whose max
#: backlog exceeds this skip straight to the chunked core.  Purely a
#: cost heuristic — detection itself stays an exact state match.
SYM_PERIODIC_MAX_BACKLOG = 4.0

#: device-ready table width shared with flitsim._PIPELINING_PAD_K
PIPE_MAX_K = 8

#: drift-guard pool-snapshot span (mirrors flitsim._DRIFT_SPAN)
DRIFT_SPAN = 3.0


def symmetric_chunk_compute(params, state, hist, scal, *, chunk: int):
    """Advance every cell ``chunk`` cycles and re-evaluate report + drift
    + convergence — the whole per-chunk body of the adaptive symmetric
    core, one launch worth of work.  All operands/results row-stacked."""
    p = SymmetricFlitParams(*[params[i] for i in range(11)])
    x, y, backlog = params[11], params[12], params[13]
    step = _symmetric_stepfn(p, x, y, backlog)
    core = tuple(state[i] for i in range(7))
    D, TD, t = state[7], state[8], state[9]
    rep_prev = state[10]

    def body(_, carry):
        core, D, TD, t = carry
        core, nd = step(core)
        t = t + 1.0
        return core, D + nd, TD + t * nd, t

    core, D, TD, t = jax.lax.fori_loop(
        0, chunk, body, (core, D, TD, t))

    kf, mf, midf = scal[0, 0], scal[0, 1], scal[0, 2]
    K0f, Kf, ch = scal[0, 3], scal[0, 4], scal[0, 5]
    tol, exit_ok = scal[0, 6], scal[0, 7]
    at_hor, drift_tol = scal[0, 8], scal[0, 9]

    # report: triangular trailing-window mean blended with the observed
    # warm prefix — float transcription of flitsim's report()/
    # _tri_window_mean (chunk indices are small ints, exact in f32)
    denom = 2.0 * params[8] / 128.0
    D_m = jnp.where(mf == kf, D, hist[5])
    TD_m = jnp.where(mf == kf, TD, hist[6])
    D_mid = jnp.where(midf == kf, D, hist[7])
    TD_mid = jnp.where(midf == kf, TD, hist[8])
    b_i, b_m, b_j = mf * ch, midf * ch, kf * ch
    c1, c2 = b_m - b_i, b_j - b_m
    w_sum = c1 * (c1 + 1.0) / 2.0 + c2 * (c2 - 1.0) / 2.0
    num = ((TD_mid - TD_m) - b_i * (D_mid - D_m)
           + b_j * (D - D_mid) - (TD - TD_mid))
    mu = num / (jnp.maximum(w_sum, 1.0) * denom)
    wA = jnp.maximum(kf - K0f, 1.0) * ch
    A = (D - hist[9]) / (wA * denom)
    rep = jnp.where(kf > K0f,
                    (A * (kf - K0f) + mu * (Kf - kf)) / (Kf - K0f), mu)

    pools = jnp.stack(core[:5])
    drift = jnp.max(jnp.abs(pools - hist[0:5]), axis=0) / DRIFT_SPAN
    delta = jnp.abs(rep - rep_prev) / jnp.maximum(jnp.abs(rep), 1e-9)
    conv = (((delta <= tol) & (drift < drift_tol) & (exit_ok > 0.0))
            | (at_hor > 0.0)).astype(jnp.float32)

    pad = jnp.zeros_like(D)
    return jnp.stack(list(core) + [D, TD, t, rep, conv]
                     + [pad] * (SYM_ROWS - 12))


def asymmetric_periodic_compute(params, *, n_accesses: int):
    """One-launch period-exact asymmetric evaluation.

    Runs the PERIOD_OBS-step observation (warm prefix, then a
    PERIOD_WINDOW ring of per-step lane/credit boundaries), detects each
    cell's credit period from the credit phase, and extrapolates every
    lane's busy time exactly to the full horizon:

        T_lane(N) = T(n0) + m * [T(n0) - T(n0 - d)]
                  + [T(n0 - d + r) - T(n0 - d)]        N - n0 = m*d + r

    Exact because the per-period lane increments repeat exactly (the
    credit state is periodic with denominator q = (x+y)/gcd when the mix
    is rational; d == q whenever q <= PERIOD_MAX).  Undetected cells
    (q > PERIOD_MAX, or irrational mixes) are flagged for exact
    escalation by the caller.
    """
    W = PERIOD_WINDOW
    cells = params.shape[1]
    p = AsymmetricLaneParams(*[params[i] for i in range(6)])
    x, y = params[6], params[7]
    step = _asymmetric_stepfn(p, x, y)

    core = tuple(jnp.zeros((cells,), jnp.float32) for _ in range(4))
    core = jax.lax.fori_loop(0, PERIOD_WARM, lambda _, c: step(c), core)

    # observation window: 4 stacked W-row bands (t_read / t_write /
    # t_cmd / credit boundaries after each observed step)
    def obs(i, carry):
        core, win = carry
        core = step(core)
        for band, v in enumerate(core):
            win = jax.lax.dynamic_update_slice(
                win, v[None, :], (band * W + i, 0))
        return core, win

    win0 = jnp.zeros((4 * W, cells), jnp.float32)
    core, win = jax.lax.fori_loop(0, W, obs, (core, win0))
    tr, tw, tc, cr = (win[0:W], win[W:2 * W], win[2 * W:3 * W],
                      win[3 * W:4 * W])

    # smallest lag d with matching credit phase; the credit alone
    # determines all future increments, so a phase match is a period
    lag = cr[W - 1 - PERIOD_MAX:W - 1][::-1]          # row j <-> d = j+1
    ok = jnp.abs(cr[W - 1][None, :] - lag) < PERIOD_EPS
    detected = jnp.any(ok, axis=0)
    d = jnp.argmax(ok, axis=0).astype(jnp.int32) + 1

    rem = n_accesses - PERIOD_OBS
    m = rem // d
    r = rem - m * d
    rows = jax.lax.broadcasted_iota(jnp.int32, (W, cells), 0)
    sel_a = (rows == (W - 1 - d)[None, :]).astype(jnp.float32)
    sel_b = (rows == (W - 1 - d + r)[None, :]).astype(jnp.float32)

    def lane(t):
        t_cur = t[W - 1]
        t_a = jnp.sum(t * sel_a, axis=0)              # T(n0 - d)
        t_b = jnp.sum(t * sel_b, axis=0)              # T(n0 - d + r)
        return t_cur + m.astype(jnp.float32) * (t_cur - t_a) + (t_b - t_a)

    T = jnp.maximum(jnp.maximum(lane(tr), lane(tw)), lane(tc))
    rep = 512.0 * n_accesses / (params[0] * jnp.maximum(T, 1e-9))
    rep = jnp.where(detected, rep, 0.0)
    pad = jnp.zeros_like(rep)
    return jnp.stack([rep, detected.astype(jnp.float32),
                      jnp.where(detected, d, 0).astype(jnp.float32)]
                     + [pad] * (ASYM_ROWS - 3))


def symmetric_periodic_compute(params, *, n_flits: int):
    """One-launch period-exact symmetric evaluation.

    Runs the SYM_PERIOD_OBS-cycle observation (warm prefix, then a
    PERIOD_WINDOW ring of per-cycle core states and data-slot
    deliveries), detects each cell's pool-state period by EXACT f32
    equality of the full 7-component core against the lagged rows, and
    extrapolates the warm-window delivery sum in closed form to the full
    horizon::

        S(W0..N) = g(N - n0) - g(W0 - n0)
        g(M)     = (M // d) * P + C[M mod d]          n0 = SYM_PERIOD_OBS

    where ``P`` is the delivery sum over the last detected period of the
    window and ``C`` its prefix sums.  A state match is a trajectory
    certificate (the step map is state-only), so every future delivery
    repeats bit-for-bit; requiring the window deliveries to be
    integer-valued makes all the f32 sums above exact, and the report
    reproduces the fixed engine's sequential accumulation BITWISE.
    Undetected cells (aperiodic in f32, period > PERIOD_MAX, fractional
    deliveries, or still transient) are flagged for exact escalation by
    the caller.  Callers must keep ``n_flits // 4 >= SYM_PERIOD_OBS`` so
    the warm window opens after the observation ends.
    """
    W = PERIOD_WINDOW
    cells = params.shape[1]
    p = SymmetricFlitParams(*[params[i] for i in range(11)])
    x, y, backlog = params[11], params[12], params[13]
    step = _symmetric_stepfn(p, x, y, backlog)

    core = tuple(jnp.zeros((cells,), jnp.float32) for _ in range(7))
    core = jax.lax.fori_loop(0, PERIOD_WARM,
                             lambda _, c: step(c)[0], core)

    # observation window: 8 stacked W-row bands — the 7 core components
    # after each observed cycle plus that cycle's data-slot delivery
    def obs(i, carry):
        core, win = carry
        core, nd = step(core)
        for band, v in enumerate(core + (nd,)):
            win = jax.lax.dynamic_update_slice(
                win, v[None, :], (band * W + i, 0))
        return core, win

    win0 = jnp.zeros((8 * W, cells), jnp.float32)
    core, win = jax.lax.fori_loop(0, W, obs, (core, win0))
    dwin = win[7 * W:8 * W]

    # smallest lag d whose full core matches EXACTLY; the core alone
    # determines the whole future trajectory, so an exact match repeats
    # the delivery window verbatim forever
    ok = None
    for c in range(7):
        band = win[c * W:(c + 1) * W]
        lag = band[W - 1 - PERIOD_MAX:W - 1][::-1]    # row j <-> d = j+1
        eq = band[W - 1][None, :] == lag
        ok = eq if ok is None else ok & eq
    # integer-delivery gate: all f32 partial sums of an integer window
    # below 2^24 are exact, so the closed form equals the fixed engine's
    # sequential fold bit-for-bit
    is_int = (jnp.floor(dwin) == dwin).astype(jnp.float32)
    suffix = jnp.cumsum(is_int[::-1], axis=0)         # rows from the end
    need = jax.lax.broadcasted_iota(
        jnp.float32, (PERIOD_MAX, cells), 0) + 1.0
    ok = ok & (suffix[:PERIOD_MAX] == need)
    detected = jnp.any(ok, axis=0)
    d = jnp.argmax(ok, axis=0).astype(jnp.int32) + 1

    rows = jax.lax.broadcasted_iota(jnp.int32, (W, cells), 0)
    in_period = rows >= (W - d)[None, :]              # last d deliveries
    psum = jnp.sum(jnp.where(in_period, dwin, 0.0), axis=0)

    def g(M):                                         # M static >= 0
        m = M // d
        r = M - m * d
        pref = in_period & (rows < (W - d + r)[None, :])
        return (m.astype(jnp.float32) * psum
                + jnp.sum(jnp.where(pref, dwin, 0.0), axis=0))

    W0 = n_flits // 4
    S = g(n_flits - SYM_PERIOD_OBS) - g(W0 - SYM_PERIOD_OBS)

    # same expression order as flitsim._symmetric_efficiency
    data_bits = S * 128.0
    cap_bits = 2.0 * jnp.float32(n_flits - W0) * p.flit_bits
    rep = jnp.where(detected, data_bits / cap_bits, 0.0)
    pad = jnp.zeros_like(rep)
    return jnp.stack([rep, detected.astype(jnp.float32),
                      jnp.where(detected, d, 0).astype(jnp.float32)]
                     + [pad] * (SYM_PERIODIC_ROWS - 3))


def pipelining_chunk_compute(params, state, hist, scal, *, chunk: int):
    """Per-chunk body of the adaptive Fig-13 pipelining core, row-stacked.

    The per-cell device rotation (``dev = idx % k``; read/update row
    ``dev`` of the ready table) is expressed as a one-hot mask over the
    padded PIPE_MAX_K ready rows so the whole tile advances with dense
    vector ops — no per-cell dynamic indexing."""
    kdev, ucie, dev_ui = params[0], params[1], params[2]
    dev_ready = state[0:PIPE_MAX_K]
    link_free, idx = state[PIPE_MAX_K], state[PIPE_MAX_K + 1]
    rep_prev = state[PIPE_MAX_K + 2]
    rows = jax.lax.broadcasted_iota(
        jnp.float32, (PIPE_MAX_K, dev_ready.shape[1]), 0)

    def body(_, carry):
        dev_ready, link_free, idx = carry
        # idx and k are small exact f32 ints, so the float modulo is exact
        dev = idx - jnp.floor(idx / kdev) * kdev
        sel = rows == dev[None, :]
        ready = jnp.sum(jnp.where(sel, dev_ready, 0.0), axis=0)
        start = jnp.maximum(ready, link_free)
        dev_ready = jnp.where(sel, start + dev_ui, dev_ready)
        return dev_ready, start + ucie, idx + 1.0

    dev_ready, link_free, idx = jax.lax.fori_loop(
        0, chunk, body, (dev_ready, link_free, idx))

    kf, Kf, ch = scal[0, 0], scal[0, 1], scal[0, 2]
    tol, exit_ok, at_hor = scal[0, 3], scal[0, 4], scal[0, 5]
    n_lines = scal[0, 6]
    T1 = jnp.where(kf == 1.0, link_free, hist[0])
    ahat = (link_free - T1) / jnp.maximum((kf - 1.0) * ch, 1.0)
    rep = n_lines * ucie / jnp.maximum(
        link_free + ahat * (Kf - kf) * ch, 1e-9)
    delta = jnp.abs(rep - rep_prev) / jnp.maximum(jnp.abs(rep), 1e-9)
    conv = (((delta <= tol) & (exit_ok > 0.0))
            | (at_hor > 0.0)).astype(jnp.float32)

    pad = jnp.zeros_like(link_free)
    return jnp.stack(list(dev_ready) + [link_free, idx, rep, conv]
                     + [pad] * (PIPE_ROWS - PIPE_MAX_K - 4))


# -- jnp oracles (what the Pallas kernels are tested against) -----------------


def symmetric_chunk_ref(params, state, hist, scal, *, chunk: int):
    return symmetric_chunk_compute(params, state, hist, scal, chunk=chunk)


def asymmetric_periodic_ref(params, *, n_accesses: int):
    return asymmetric_periodic_compute(params, n_accesses=n_accesses)


def symmetric_periodic_ref(params, *, n_flits: int):
    return symmetric_periodic_compute(params, n_flits=n_flits)


def pipelining_chunk_ref(params, state, hist, scal, *, chunk: int):
    return pipelining_chunk_compute(params, state, hist, scal, chunk=chunk)
