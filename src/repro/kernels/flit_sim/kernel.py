"""Fused flit-simulator chunk kernels — Pallas.

One ``pallas_call`` advances every cell of a ``[rows, cells]`` tile a
whole chunk of cycles and re-evaluates the report / drift / convergence
summaries in-kernel, so the host sees ONE launch per chunk instead of the
~chunk dispatched ops of the XLA ``lax.scan`` cores.  The per-cell core
state (queues, credit pools, lane clocks, the asymmetric observation
window) stays on-chip for the whole chunk as the ``fori_loop`` carry —
it never round-trips through HBM between cycles; only the chunk-boundary
state/report rows are written back.

The compute bodies are shared verbatim with the pure-jnp oracle
(:mod:`repro.kernels.flit_sim.ref`), so kernel-vs-ref agreement is by
construction; the grid/BlockSpec plumbing here only tiles the cell axis.

Cells are padded to a multiple of the 128-lane tile (`pad_cells`); pad
cells replicate cell 0 so they converge identically and never gate an
early exit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flit_sim.ref import (
    ASYM_ROWS, PIPE_ROWS, SCAL_COLS, SYM_PERIODIC_ROWS, SYM_ROWS,
    asymmetric_periodic_compute, pipelining_chunk_compute,
    symmetric_chunk_compute, symmetric_periodic_compute,
)

#: jax renamed TPUCompilerParams -> CompilerParams; support both so the
#: CI floor (0.4.x) and latest lower the same source
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

#: cell-axis tile: one lane row minimum, a few VPU rows maximum — the
#: state working set per tile stays well under VMEM either way
MAX_TILE = 8192
LANE = 128

#: the symmetric periodic observer holds 8 PERIOD_WINDOW-row bands
#: (~520 rows of f32) per tile, so its cell tile is capped lower than
#: the chunk kernels' to keep the window ring inside VMEM
SYM_PERIODIC_MAX_TILE = 2048


def tile_for(cells: int, max_tile: int = MAX_TILE) -> tuple:
    """(tile, padded cell count) for a cell axis of ``cells``."""
    pad = -(-max(cells, 1) // LANE) * LANE
    tile = min(max_tile, pad)
    pad = -(-pad // tile) * tile
    return tile, pad


def pad_cells(rows: jnp.ndarray, padded: int) -> jnp.ndarray:
    """Pad the cell axis to ``padded`` columns by replicating cell 0."""
    short = padded - rows.shape[1]
    if short <= 0:
        return rows
    return jnp.concatenate(
        [rows, jnp.broadcast_to(rows[:, :1], (rows.shape[0], short))],
        axis=1)


def _row_specs(tile: int, row_counts, n_scal: int):
    """BlockSpecs: one [rows, tile] block per stacked operand plus the
    broadcast [1, SCAL_COLS] scalar rows."""
    specs = [pl.BlockSpec((r, tile), lambda i: (0, i)) for r in row_counts]
    specs += [pl.BlockSpec((1, SCAL_COLS), lambda i: (0, 0))] * n_scal
    return specs


def _sym_kernel(params_ref, state_ref, hist_ref, scal_ref, out_ref, *,
                chunk: int):
    out_ref[...] = symmetric_chunk_compute(
        params_ref[...], state_ref[...], hist_ref[...], scal_ref[...],
        chunk=chunk)


def symmetric_chunk(params, state, hist, scal, *, chunk: int, tile: int,
                    interpret: bool = False):
    """One adaptive symmetric chunk over padded ``[SYM_ROWS, C]`` rows."""
    c = params.shape[1]
    return pl.pallas_call(
        functools.partial(_sym_kernel, chunk=chunk),
        grid=(c // tile,),
        in_specs=_row_specs(tile, (SYM_ROWS, SYM_ROWS, SYM_ROWS), 1),
        out_specs=pl.BlockSpec((SYM_ROWS, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((SYM_ROWS, c), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(params, state, hist, scal)


def _asym_kernel(params_ref, out_ref, *, n_accesses: int):
    out_ref[...] = asymmetric_periodic_compute(
        params_ref[...], n_accesses=n_accesses)


def asymmetric_periodic(params, *, n_accesses: int, tile: int,
                        interpret: bool = False):
    """Whole asymmetric grid in ONE launch: observe ~2 periods, detect
    the credit period, extrapolate the lane clocks to the horizon."""
    c = params.shape[1]
    return pl.pallas_call(
        functools.partial(_asym_kernel, n_accesses=n_accesses),
        grid=(c // tile,),
        in_specs=_row_specs(tile, (ASYM_ROWS,), 0),
        out_specs=pl.BlockSpec((ASYM_ROWS, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((ASYM_ROWS, c), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(params)


def _sym_periodic_kernel(params_ref, out_ref, *, n_flits: int):
    out_ref[...] = symmetric_periodic_compute(
        params_ref[...], n_flits=n_flits)


def symmetric_periodic(params, *, n_flits: int, tile: int,
                       interpret: bool = False):
    """Whole symmetric grid in ONE launch: observe the pool-state window,
    detect exact f32 state periods, extrapolate the warm-window delivery
    sum bitwise to the horizon."""
    c = params.shape[1]
    return pl.pallas_call(
        functools.partial(_sym_periodic_kernel, n_flits=n_flits),
        grid=(c // tile,),
        in_specs=_row_specs(tile, (SYM_ROWS,), 0),
        out_specs=pl.BlockSpec((SYM_PERIODIC_ROWS, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((SYM_PERIODIC_ROWS, c), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(params)


def _pipe_kernel(params_ref, state_ref, hist_ref, scal_ref, out_ref, *,
                 chunk: int):
    out_ref[...] = pipelining_chunk_compute(
        params_ref[...], state_ref[...], hist_ref[...], scal_ref[...],
        chunk=chunk)


def pipelining_chunk(params, state, hist, scal, *, chunk: int, tile: int,
                     interpret: bool = False):
    """One adaptive Fig-13 pipelining chunk over padded rows."""
    c = params.shape[1]
    return pl.pallas_call(
        functools.partial(_pipe_kernel, chunk=chunk),
        grid=(c // tile,),
        in_specs=_row_specs(tile, (PIPE_ROWS, PIPE_ROWS, ASYM_ROWS), 1),
        out_specs=pl.BlockSpec((PIPE_ROWS, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((PIPE_ROWS, c), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(params, state, hist, scal)
