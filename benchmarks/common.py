"""Benchmark harness utilities: timing + CSV rows."""
from __future__ import annotations

import statistics
import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]     # name, us_per_call, derived

#: set by ``benchmarks/run.py --smoke`` (CI fast mode): clamp every timing
#: loop to one warmup + one iteration, so rows exist and assertions fire
#: but wall clock stays in CI budget.  Timings are then indicative only.
SMOKE = False


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            reduce: str = "median", min_total_us: float = 0.0) -> float:
    """Time ``fn(*args)`` in microseconds.

    ``warmup`` un-timed calls absorb trace+compile time so the reported
    number is steady-state execution only; each timed iteration is
    synchronized (``block_until_ready``) and measured independently, and
    ``reduce`` picks the statistic: "median" (default, robust to scheduler
    noise), "mean", or "min".  Under :data:`SMOKE`, warmup/iters clamp
    to 1.

    ``min_total_us`` auto-scales the measurement for sub-timer-resolution
    calls: when a probe call suggests the ``iters`` samples would span
    less than this total, each sample times an inner batch of calls and
    reports the per-call mean, so microsecond-scale kernels produce real
    fractional-``us`` rows instead of quantizing to 0.  Ignored under
    :data:`SMOKE` (timings there are indicative only).
    """
    if SMOKE:
        warmup, iters = min(warmup, 1), 1
    iters = max(iters, 1)
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    inner = 1
    if min_total_us > 0.0 and not SMOKE:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        probe_us = max((time.perf_counter() - t0) * 1e6, 1e-3)
        if probe_us * iters < min_total_us:
            inner = int(min_total_us / (probe_us * iters)) + 1
    samples: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6 / inner)
    try:
        return {"median": statistics.median, "mean": statistics.fmean,
                "min": min}[reduce](samples)
    except KeyError:
        raise ValueError(f"unknown reduce={reduce!r}") from None


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
