"""Benchmark harness utilities: timing + CSV rows."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]     # name, us_per_call, derived


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
