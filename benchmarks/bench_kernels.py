"""Per-kernel micro-benchmarks (CPU: interpret-mode correctness cost is
not meaningful wall-clock; the jnp oracle timing is reported, with the
kernel's analytic HBM traffic as `derived` — the quantity the roofline
uses for kernel substitution)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us


def run(rows: list):
    # flash attention oracle at serving-ish shape
    from repro.kernels.flash_attention.ref import attention_ref
    b, k, g, s, hd = 1, 8, 4, 1024, 128
    q = jax.random.normal(jax.random.PRNGKey(0), (b, k, g, s, hd),
                          jnp.bfloat16)
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, k, s, hd),
                           jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, k, s, hd), jnp.bfloat16)
    fn = jax.jit(lambda a, b_, c: attention_ref(a, b_, c))
    us = time_us(fn, q, kk, v, iters=3)
    kernel_bytes = (q.size + kk.size + v.size) * 2 + q.size * 2
    xla_bytes = kernel_bytes + b * k * g * s * s * 6   # materialized scores
    rows.append(("kernels/flash_attention", us,
                 f"hbm_bytes_kernel={kernel_bytes:.3g};"
                 f"hbm_bytes_xla~{xla_bytes:.3g};"
                 f"saving=x{xla_bytes / kernel_bytes:.1f}"))

    from repro.kernels.ssd_scan.ref import ssd_ref
    bsz, s2, h, p, n = 2, 512, 8, 64, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (bsz, s2, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bsz, s2, h)))
    bm = jax.random.normal(jax.random.PRNGKey(2), (bsz, s2, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(3), (bsz, s2, n)) * 0.3
    alog = jnp.zeros((h,))
    fn2 = jax.jit(lambda *a: ssd_ref(*a)[0])
    us2 = time_us(fn2, x, dt, bm, cm, alog, iters=3)
    rows.append(("kernels/ssd_scan", us2,
                 f"state_bytes={bsz*h*p*n*4};seq={s2}"))

    from repro.models.rglru import lru_scan
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(0),
                                            (2, 1024, 256)))
    bb = jax.random.normal(jax.random.PRNGKey(1), (2, 1024, 256))
    fn3 = jax.jit(lru_scan)
    us3 = time_us(fn3, la, bb, iters=3)
    rows.append(("kernels/rglru_scan", us3, "assoc_scan_oracle"))

    from repro.kernels.flit_sim import ops as fs_ops
    from repro.kernels.flit_sim import ref as fs_ref
    from repro.core.flitsim import _asym_param_rows, AsymmetricLaneParams
    from repro.core.traffic import mix_grid
    gx, gy = mix_grid(41)
    pstack = AsymmetricLaneParams.stack([AsymmetricLaneParams.lpddr6(),
                                         AsymmetricLaneParams.hbm()])
    cells = 2 * 41
    tile, cpad = fs_ops.tile_for(cells)
    prows = fs_ops.pad_cells(
        _asym_param_rows(pstack, jnp.asarray(gx), jnp.asarray(gy)), cpad)
    fn5 = jax.jit(lambda p: fs_ops.asymmetric_periodic_launch(
        p, n_accesses=4096, tile=tile, cells=cells, interpret=True)[0])
    us5 = time_us(fn5, prows, iters=5, min_total_us=10_000.0)
    det = int((jnp.asarray(fn5(prows))[1, :cells] > 0.5).sum())
    rows.append(("kernels/flit_sim_asym_periodic", us5,
                 f"cells={cells};detected={det};"
                 f"obs_steps={fs_ref.PERIOD_OBS};horizon=4096"))

    from repro.kernels.flit_pack.ref import pack_flits_ref, flits_needed
    n_lines = 15 * 64
    f = flits_needed(n_lines)
    lines = jax.random.randint(jax.random.PRNGKey(0), (n_lines, 64), 0, 256)
    hdrs = jnp.zeros((f, 10), jnp.int32)
    meta = jnp.zeros((f, 4), jnp.int32)
    fn4 = jax.jit(pack_flits_ref)
    us4 = time_us(fn4, lines, hdrs, meta, iters=5)
    gbs = n_lines * 64 / (us4 * 1e-6) / 1e9
    rows.append(("kernels/flit_pack", us4,
                 f"lines={n_lines};flits={f};cpu_pack_rate={gbs:.2f}GB/s"))
