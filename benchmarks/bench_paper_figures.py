"""Paper tables/figures reproduced from the core models.

  table1   — UCIe key metrics (Table 1)
  fig10    — BW density (linear/areal), UCIe-A approaches vs HBM4/LPDDR6
  fig11    — BW density, UCIe-S approaches vs HBM4/LPDDR6
  fig12    — power efficiency (pJ/b), UCIe-A and UCIe-S vs HBM4
  latency  — §IV.A round-trip latency comparison
  cost     — relative cost model ranking (§I/§V cost claims)
  selector — dense read-fraction grid ranked over the whole catalog in one
             batched call (the sweep-engine path)

Figure rows consume the stacked ``approach_grid`` batched evaluation: all
approaches' metrics over the full mix set come from one compiled call per
(phy, grid-shape) rather than a per-approach jit+loop.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_us
from repro.core import (
    HBM4, LPDDR6, MEASURED_FRONTEND_LATENCY_NS, PAPER_MIXES,
    UCIE_A_32G_55U, UCIE_S_32G, cost, latency_speedup, mix_grid,
    mixes_named, table1,
)
from repro.core.memsys import approach_grid
from repro.core.selector import _rank_grid_impl as rank_grid


def bench_table1(rows):
    t1 = table1()
    for variant, metrics in t1.items():
        derived = (f"rate_max={max(metrics['data_rates_gtps'])}GT/s;"
                   f"width={metrics['width_per_direction']};"
                   f"latency={metrics['latency_roundtrip_ns']}ns")
        rows.append((f"table1/{variant}", 0.0, derived))


def _mix_table(phy, tag, rows):
    x, y, names = mixes_named(PAPER_MIXES)
    # one stacked, compiled call covers every approach over the mix set;
    # the timing is for the whole grid, reported once on its own row
    us = time_us(lambda: approach_grid(phy, x, y).linear)
    ag = approach_grid(phy, x, y)
    rows.append((f"{tag}/grid_call", us,
                 f"approaches={len(ag.keys)};mixes={len(names)}"))
    for i, key in enumerate(ag.keys):
        best = float(jnp.max(ag.linear[i]))
        vs_hbm4 = best / HBM4.linear_density_gbs_mm
        vs_lp6 = best / LPDDR6.linear_density_gbs_mm
        derived = (f"best_lin={best:.0f}GB/s/mm;x{vs_hbm4:.2f}_vs_HBM4;"
                   f"x{vs_lp6:.1f}_vs_LPDDR6;"
                   f"best_areal={float(jnp.max(ag.areal[i])):.0f}")
        rows.append((f"{tag}/{key}", 0.0, derived))
    rows.append((f"{tag}/baseline_HBM4", 0.0,
                 f"lin={HBM4.linear_density_gbs_mm:.1f};"
                 f"areal={HBM4.areal_density_gbs_mm2:.1f}"))
    rows.append((f"{tag}/baseline_LPDDR6", 0.0,
                 f"lin={LPDDR6.linear_density_gbs_mm:.1f};"
                 f"areal={LPDDR6.areal_density_gbs_mm2:.1f}"))


def bench_fig10(rows):
    _mix_table(UCIE_A_32G_55U, "fig10_ucie_a", rows)


def bench_fig11(rows):
    _mix_table(UCIE_S_32G, "fig11_ucie_s", rows)


def bench_fig12(rows):
    x, y, names = mixes_named(PAPER_MIXES)
    for phy, tag in ((UCIE_A_32G_55U, "A"), (UCIE_S_32G, "S")):
        us = time_us(lambda p=phy: approach_grid(p, x, y).pj_per_bit)
        ag = approach_grid(phy, x, y)
        rows.append((f"fig12_{tag}/grid_call", us,
                     f"approaches={len(ag.keys)};mixes={len(names)}"))
        for i, key in enumerate(ag.keys):
            pj = ag.pj_per_bit[i]
            derived = (f"min={float(jnp.min(pj)):.3f}pJ/b;"
                       f"max={float(jnp.max(pj)):.3f};"
                       f"HBM4=0.9;best_vs_HBM4=x"
                       f"{0.9 / float(jnp.min(pj)):.2f}")
            rows.append((f"fig12_{tag}/{key}", 0.0, derived))


def bench_latency(rows):
    sp = latency_speedup()
    for name, ns in MEASURED_FRONTEND_LATENCY_NS.items():
        d = f"{ns}ns" + (f";speedup=x{sp[name]:.2f}"
                         if name in sp else ";(ours)")
        rows.append((f"latency/{name}", 0.0, d))


def bench_cost(rows):
    systems = cost.reference_systems()
    ranked = sorted(systems, key=lambda s: s.cost_per_gbs())
    for i, s in enumerate(ranked):
        rows.append((f"cost/{s.name}", 0.0,
                     f"rank={i};rel_cost={s.relative_cost():.1f};"
                     f"per_gbs={s.cost_per_gbs():.4f}"))


def bench_selector_grid(rows, n: int = 201):
    """Rank the full catalog over a dense read-fraction grid — hundreds of
    points resolved by one batched, compiled evaluation."""
    x, y = mix_grid(n)
    us = time_us(lambda: rank_grid(x, y).best_index)
    g = rank_grid(x, y)
    keys = g.best_keys()
    transitions = int(np.sum(keys[1:] != keys[:-1]))
    winners = ">".join(dict.fromkeys(keys.tolist()))   # ordered unique
    rows.append((f"selector_grid/{n}pt", us,
                 f"regimes={transitions + 1};best_by_read_fraction={winners}"))


def bench_design_space(rows, n: int = 41):
    """Axes-first DesignSpace: the [mix x shoreline] catalog space in one
    compiled call, asserted via the shared design-space cache counters."""
    from repro.core import DesignSpace, axis
    from repro.core.memsys import clear_grid_cache, grid_cache_stats

    shorelines = (2.0, 4.0, 8.0, 16.0)
    space = DesignSpace([axis("read_fraction", np.linspace(0.0, 1.0, n)),
                         axis("shoreline_mm", shorelines)])
    metrics = ("bandwidth_gbs", "gbs_per_watt")
    clear_grid_cache()
    us = time_us(lambda: space.evaluate(metrics=metrics)["bandwidth_gbs"]
                 .values)
    res = space.evaluate(metrics=metrics)
    stats = grid_cache_stats()
    assert stats.misses == 1, (
        f"expected the joint [mix x shoreline] space to compile once, "
        f"got {stats}")
    front = res.frontier("gbs_per_watt").sel(shoreline_mm=8.0)
    winners = ">".join(dict.fromkeys(front.values.tolist()))
    rows.append((f"design_space/{n}x{len(shorelines)}", us,
                 f"compiles={stats.misses};cache_hits={stats.hits};"
                 f"best_gbs_per_watt@8mm={winners}"))


def bench_phy_axis(rows, n: int = 41):
    """First-class phy axis: the whole catalog across four PHY generations
    (UCIe-A/S at 32G + the 48G scaling points) in ONE PHY-stacked compiled
    call per engine family — the Figs 10-12 sweeps without forked
    per-PHY code paths."""
    from repro.core import (
        DesignSpace, UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G,
        UCIE_S_48G_110U, axis,
    )
    from repro.core.memsys import clear_grid_cache, grid_cache_stats

    phys = [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U, UCIE_A_48G_45U]
    space = DesignSpace([
        axis("phy", phys),
        axis("read_fraction", np.linspace(0.0, 1.0, n)),
        axis("shoreline_mm", (4.0, 8.0)),
    ])
    metrics = ("bandwidth_gbs", "linear_density_gbs_mm")
    clear_grid_cache()
    us = time_us(lambda: space.evaluate(metrics=metrics)["bandwidth_gbs"]
                 .values)
    res = space.evaluate(metrics=metrics)
    stats = grid_cache_stats()
    assert stats.misses == 2, (
        f"expected the PHY-stacked space to compile once per memsys "
        f"family (catalog + approach), got {stats}")
    bw = res["bandwidth_gbs"]
    winners = ";".join(
        f"{p.name}="
        + str(bw.sel(phy=p.name, shoreline_mm=8.0).argbest("system")
              .values[n // 2])
        for p in phys)
    rows.append((f"phy_axis/{len(phys)}x{n}x2", us,
                 f"compiles={stats.misses};best@50R50W:{winners}"))


def bench_sim_phy_frontier(rows, n: int = 21):
    """Simulation-corrected PHY-absolute frontier: flit-simulated
    efficiency threaded onto each PHY generation's raw link bandwidth
    (``sim_bandwidth_gbs``), swept over [phy x backlog x read_fraction]
    under the convergence-adaptive engine in one compiled call per
    simulator family."""
    from repro.core import (
        ADAPTIVE_SIM, DesignSpace, UCIE_A_32G_55U, UCIE_A_48G_45U,
        UCIE_S_32G, UCIE_S_48G_110U, axis, flitsim,
    )

    phys = [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U, UCIE_A_48G_45U]
    space = DesignSpace([
        axis("phy", phys),
        axis("read_fraction", np.linspace(0.0, 1.0, n)),
        axis("backlog", (2.0, 64.0)),
    ], sim=ADAPTIVE_SIM)
    metrics = ("sim_efficiency", "sim_bandwidth_gbs")
    flitsim.clear_compile_cache()
    us = time_us(lambda: space.evaluate(metrics=metrics)
                 ["sim_bandwidth_gbs"].values, warmup=1, iters=3)
    res = space.evaluate(metrics=metrics)
    stats = flitsim.compile_cache_stats()
    assert stats.misses == 2, (
        f"expected one compile per simulator family for the sim-phy "
        f"space, got {stats}")
    bw = res["sim_bandwidth_gbs"]
    winners = ";".join(
        f"{p.name}@bl64="
        + str(bw.sel(phy=p.name, backlog=64.0).argbest("protocol")
              .values[n // 2]) for p in phys[:2])
    peak = float(bw.sel(phy=UCIE_A_48G_45U.name).values.max())
    rows.append((f"sim_phy_frontier/{len(phys)}x2x{n}", us,
                 f"compiles={stats.misses};best@50R50W:{winners};"
                 f"peak_sim_gbs_48g={peak:.0f}"))


def run(rows: list):
    bench_table1(rows)
    bench_fig10(rows)
    bench_fig11(rows)
    bench_fig12(rows)
    bench_latency(rows)
    bench_cost(rows)
    bench_selector_grid(rows)
    bench_design_space(rows)
    bench_phy_axis(rows)
    bench_sim_phy_frontier(rows)
