"""Paper tables/figures reproduced from the core models.

  table1   — UCIe key metrics (Table 1)
  fig10    — BW density (linear/areal), UCIe-A approaches vs HBM4/LPDDR6
  fig11    — BW density, UCIe-S approaches vs HBM4/LPDDR6
  fig12    — power efficiency (pJ/b), UCIe-A and UCIe-S vs HBM4
  latency  — §IV.A round-trip latency comparison
  cost     — relative cost model ranking (§I/§V cost claims)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_us
from repro.core import (
    ALL_APPROACHES, HBM4, LPDDR6, MEASURED_FRONTEND_LATENCY_NS, PAPER_MIXES,
    UCIE_A_32G_55U, UCIE_S_32G, cost, latency_speedup, mixes_named, table1,
)


def bench_table1(rows):
    t1 = table1()
    for variant, metrics in t1.items():
        derived = (f"rate_max={max(metrics['data_rates_gtps'])}GT/s;"
                   f"width={metrics['width_per_direction']};"
                   f"latency={metrics['latency_roundtrip_ns']}ns")
        rows.append((f"table1/{variant}", 0.0, derived))


def _mix_table(phy, tag, rows):
    x, y, names = mixes_named(PAPER_MIXES)
    for key, proto in ALL_APPROACHES.items():
        lin_fn = jax.jit(lambda a, b, p=proto: p.bw_density_linear(a, b, phy))
        us = time_us(lin_fn, x, y)
        lin = lin_fn(x, y)
        areal = proto.bw_density_areal(x, y, phy)
        best = float(jnp.max(lin))
        vs_hbm4 = best / HBM4.linear_density_gbs_mm
        vs_lp6 = best / LPDDR6.linear_density_gbs_mm
        derived = (f"best_lin={best:.0f}GB/s/mm;x{vs_hbm4:.2f}_vs_HBM4;"
                   f"x{vs_lp6:.1f}_vs_LPDDR6;"
                   f"best_areal={float(jnp.max(areal)):.0f}")
        rows.append((f"{tag}/{key}", us, derived))
    rows.append((f"{tag}/baseline_HBM4", 0.0,
                 f"lin={HBM4.linear_density_gbs_mm:.1f};"
                 f"areal={HBM4.areal_density_gbs_mm2:.1f}"))
    rows.append((f"{tag}/baseline_LPDDR6", 0.0,
                 f"lin={LPDDR6.linear_density_gbs_mm:.1f};"
                 f"areal={LPDDR6.areal_density_gbs_mm2:.1f}"))


def bench_fig10(rows):
    _mix_table(UCIE_A_32G_55U, "fig10_ucie_a", rows)


def bench_fig11(rows):
    _mix_table(UCIE_S_32G, "fig11_ucie_s", rows)


def bench_fig12(rows):
    x, y, names = mixes_named(PAPER_MIXES)
    for phy, tag in ((UCIE_A_32G_55U, "A"), (UCIE_S_32G, "S")):
        for key, proto in ALL_APPROACHES.items():
            fn = jax.jit(lambda a, b, p=proto: p.power_pj_per_bit(a, b, phy))
            us = time_us(fn, x, y)
            pj = fn(x, y)
            derived = (f"min={float(jnp.min(pj)):.3f}pJ/b;"
                       f"max={float(jnp.max(pj)):.3f};"
                       f"HBM4=0.9;best_vs_HBM4=x"
                       f"{0.9 / float(jnp.min(pj)):.2f}")
            rows.append((f"fig12_{tag}/{key}", us, derived))


def bench_latency(rows):
    sp = latency_speedup()
    for name, ns in MEASURED_FRONTEND_LATENCY_NS.items():
        d = f"{ns}ns" + (f";speedup=x{sp[name]:.2f}"
                         if name in sp else ";(ours)")
        rows.append((f"latency/{name}", 0.0, d))


def bench_cost(rows):
    systems = cost.reference_systems()
    ranked = sorted(systems, key=lambda s: s.cost_per_gbs())
    for i, s in enumerate(ranked):
        rows.append((f"cost/{s.name}", 0.0,
                     f"rank={i};rel_cost={s.relative_cost():.1f};"
                     f"per_gbs={s.cost_per_gbs():.4f}"))


def run(rows: list):
    bench_table1(rows)
    bench_fig10(rows)
    bench_fig11(rows)
    bench_fig12(rows)
    bench_latency(rows)
    bench_cost(rows)
