"""Streaming sharded sweep-engine benchmarks.

The ``streaming/joint_1e7`` row is the tentpole demonstration: a
>= 10^7-cell joint [phy x mix x backlog x perturbation] space evaluated
under a FIXED per-chunk memory budget — per-cell tensors never
materialize; peak residency is ``chunk_cells x n_phys`` stacked-protocol
rows per dispatch, asserted every run.  Smoke mode swaps in a ~10^6-cell
space so the same assertions fire inside the CI budget, and the
``streaming/equality_goldens`` row re-proves the bit-identity contract
(streamed winner labels == materialized ``argbest``) on grids shaped
like the golden-covered ones.  The ``*_async`` rows time the PR 10
double-buffered dispatch loop (``prefetch=2``) against the sequential
``prefetch=1`` loop on the same warm executable and assert the winners,
win counts and running bests stay bit-identical.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import common

#: per-chunk cell budget the joint rows run under (and assert)
CHUNK_CELLS = 4096


def _joint_space(n_perts: int, n_backlogs: int, n_mixes: int):
    from repro.core import (
        DesignSpace, UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G,
        UCIE_S_48G_110U, axis,
    )
    perts = [{"g_slots": float(g)}
             for g in np.linspace(1.0, 4.0, n_perts)]
    return DesignSpace([
        axis("protocol_param", perts),
        axis("phy", [UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U,
                     UCIE_A_48G_45U]),
        axis("backlog", list(np.linspace(2.0, 128.0, n_backlogs))),
        axis("read_fraction", list(np.linspace(0.0, 1.0, n_mixes))),
    ], n_flits=64, n_accesses=64)


def _equality_row(rows: list) -> None:
    """Streamed winners == materialized winners on golden-shaped grids."""
    from repro.core import DesignSpace, StreamConfig, axis

    t0 = time.perf_counter()
    checked = 0
    # simulated grid: protocol frontier over (backlog x read_fraction),
    # the joint_frontier cell shape
    sim_space = DesignSpace([
        axis("backlog", [2.0, 8.0, 64.0, 256.0]),
        axis("read_fraction", list(np.linspace(0.0, 1.0, 9))),
    ], n_flits=64, n_accesses=64)
    ref = sim_space.evaluate(metrics=("sim_efficiency",))[
        "sim_efficiency"].argbest("protocol")
    sr = sim_space.evaluate(metrics=("sim_efficiency",),
                            stream=StreamConfig(chunk_cells=8, devices=1))
    assert np.array_equal(np.asarray(sr.winners.values, dtype=object),
                          np.asarray(ref.values, dtype=object))
    checked += sr.n_cells
    # analytic grid: system frontier over (read_fraction x shoreline),
    # the workload/shoreline_frontier cell shape
    cat_space = DesignSpace([
        axis("read_fraction", list(np.linspace(0.0, 1.0, 9))),
        axis("shoreline_mm", [4.0, 8.0, 16.0]),
    ])
    cref = cat_space.evaluate(metrics=("bandwidth_gbs",)).frontier(
        "bandwidth_gbs")
    csr = cat_space.evaluate(metrics=("bandwidth_gbs",),
                             stream=StreamConfig(chunk_cells=5, devices=1))
    assert np.array_equal(np.asarray(csr.winners.values, dtype=object),
                          np.asarray(cref.values, dtype=object))
    checked += csr.n_cells
    dt_us = (time.perf_counter() - t0) * 1e6
    rows.append(("streaming/equality_goldens", dt_us,
                 f"cells_checked={checked};bit_identical=True;"
                 f"compiles={sr.compiles + csr.compiles}"))


def _joint_row(rows: list, name: str, n_perts: int, n_backlogs: int,
               n_mixes: int, min_cells: int) -> None:
    from repro.core import StreamConfig

    space = _joint_space(n_perts, n_backlogs, n_mixes)
    t0 = time.perf_counter()
    sr = space.evaluate(metrics=("sim_bandwidth_gbs",),
                        stream=StreamConfig(chunk_cells=CHUNK_CELLS))
    dt = time.perf_counter() - t0
    assert sr.n_cells >= min_cells, (sr.n_cells, min_cells)
    # the memory contract: peak on-device residency per dispatch stays at
    # chunk_cells x n_phys stacked rows no matter how large the space is
    assert sr.peak_cells_per_chunk <= CHUNK_CELLS * 4, \
        sr.peak_cells_per_chunk
    assert sr.compiles <= 2, sr.compiles
    top = max(sr.win_counts, key=sr.win_counts.get)
    rows.append((name, dt * 1e6,
                 f"n_cells={sr.n_cells};dispatches={sr.n_dispatches};"
                 f"compiles={sr.compiles};"
                 f"peak_cells_per_chunk={sr.peak_cells_per_chunk};"
                 f"devices={sr.devices};cells_per_s={sr.n_cells / dt:.3g};"
                 f"top_winner={top}"))


def _async_row(rows: list, name: str, n_perts: int, n_backlogs: int,
               n_mixes: int, min_speedup: float = 0.0) -> None:
    """Async double-buffered dispatch (PR 10) vs the sequential loop on
    the SAME warm executable: prefetch=1 retires every chunk before the
    next marshal (the PR 9 behaviour); prefetch=2 overlaps host index
    marshalling with the in-flight device chunk.  Winners, win counts
    and running bests must stay bit-identical at every depth."""
    from repro.core import StreamConfig, flitsim

    space = _joint_space(n_perts, n_backlogs, n_mixes)

    def _eval(prefetch: int):
        t0 = time.perf_counter()
        sr = space.evaluate(metrics=("sim_bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=CHUNK_CELLS,
                                                prefetch=prefetch))
        return sr, time.perf_counter() - t0

    _eval(1)                                  # compile warm-up
    seq, dt_seq = _eval(1)
    for prefetch in (2, 4):
        sr, dt = _eval(prefetch)
        assert np.array_equal(
            np.asarray(sr.winners.values, dtype=object),
            np.asarray(seq.winners.values, dtype=object)), prefetch
        assert sr.win_counts == seq.win_counts, prefetch
        assert sr.best_by_label == seq.best_by_label, prefetch
        if prefetch == 2:
            dt_async = dt
    speedup = dt_seq / dt_async
    # the async win is host/device CONCURRENCY: on a single-core host
    # the overlapped marshal just time-slices against the device thread
    # and the loop legitimately degenerates to sequential speed, so the
    # wall-clock floor only binds where there is a spare core to run on
    cores = os.cpu_count() or 1
    if min_speedup and cores > 1:
        assert speedup >= min_speedup, (
            f"async dispatch only x{speedup:.2f} vs sequential on the "
            f"{seq.n_cells}-cell joint row (expected >= x{min_speedup} "
            f"on a {cores}-core host)")
    info = flitsim.last_run_info()["stream.sim"]
    rows.append((name, dt_async * 1e6,
                 f"n_cells={seq.n_cells};sequential_us={dt_seq * 1e6:.0f};"
                 f"speedup_vs_sequential=x{speedup:.2f};"
                 f"overlap_frac={info['overlap_frac']:.2f};"
                 f"cores={cores};prefetch=2;bit_identical=True"))


def run(rows: list):
    _equality_row(rows)
    if common.SMOKE:
        # ~10^6 cells: 250 perts x 4 phys x 25 backlogs x 41 mixes
        _joint_row(rows, "streaming/joint_1e6_smoke", 250, 25, 41,
                   min_cells=10 ** 6)
        _async_row(rows, "streaming/joint_1e6_async_smoke", 250, 25, 41)
        return
    # >= 10^7 cells: 2500 perts x 4 phys x 25 backlogs x 41 mixes
    _joint_row(rows, "streaming/joint_1e7", 2500, 25, 41,
               min_cells=10 ** 7)
    _async_row(rows, "streaming/joint_1e7_async", 2500, 25, 41,
               min_speedup=1.3)
