"""repro-lint wall-clock: the static pass gates CI ahead of the test
matrix, so it must stay fast — the row records the full-tree runtime and
the suite asserts the ~5 s budget from the lint README."""
from __future__ import annotations

import time

#: CI budget for the full-tree static pass (seconds); the gate runs
#: before every matrix leg, so regressions here tax every push
LINT_BUDGET_S = 5.0


def run(rows):
    from repro.lint import run_lint   # stdlib-only import

    t0 = time.perf_counter()
    report = run_lint()
    elapsed = time.perf_counter() - t0
    rows.append(("lint/full_tree", elapsed * 1e6,
                 f"files={report.files};checks={len(report.checks)};"
                 f"unsuppressed={len(report.unsuppressed)};"
                 f"suppressed={len(report.suppressed)}"))
    assert not report.unsuppressed, \
        [f.format() for f in report.unsuppressed]
    assert elapsed < LINT_BUDGET_S, \
        f"repro-lint took {elapsed:.2f}s over the {LINT_BUDGET_S}s budget"
