"""End-to-end train-step wall time on CPU (reduced configs) — the
framework-integration benchmark (data pipeline + train step + optimizer)."""
from __future__ import annotations

import jax

from benchmarks.common import time_us
from repro.configs import get
from repro.configs.shapes import ShapeSpec
from repro.models import ShardingCtx, build
from repro.train import (
    AdamW, SyntheticLM, constant_schedule, init_state, make_train_step,
)


def run(rows: list):
    ctx = ShardingCtx()
    for arch in ("smollm-360m", "mamba2-2.7b", "olmoe-1b-7b"):
        cfg = get(arch).reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(1e-3))
        state = init_state(model, jax.random.PRNGKey(0), opt)
        step = jax.jit(make_train_step(model, opt, ctx, num_microbatches=2))
        src = SyntheticLM(cfg, ShapeSpec("bench", 64, 8, "train"))
        batch = src.place(src.batch_for_step(0), ctx)
        us = time_us(lambda s, b: step(s, b)[1]["loss"], state, batch,
                     warmup=1, iters=3)
        tok_s = 8 * 64 / (us * 1e-6)
        rows.append((f"train_loop/{arch}-reduced", us,
                     f"tokens_per_s={tok_s:.0f}(cpu)"))
