"""Serving benchmarks: trace-tied memory capacity + engine throughput.

The ``serving/trace_capacity_*`` rows close the paper's loop between the
serving workload and the memory system: a synthetic serving trace (config
shapes only — these rows run in smoke mode with no weights) is evaluated
through the design space's ``trace`` axis, and the winning protocol's
delivered ``sim_bandwidth_gbs`` on the UCIe-A PHY is converted into the
decode tokens/sec it can sustain for that model's bytes-per-token.

The ``serving/continuous_batching`` row is the live-engine throughput
measurement (reduced model; skipped in smoke — it builds a model).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common

#: model configs the capacity rows sweep: a dense decoder and a MoE
CAPACITY_MODELS = ("smollm-360m", "olmoe-1b-7b")
_QPS, _SLOTS, _PROMPT, _DECODE = 2.0, 32, 512, 128


def run(rows: list):
    from repro.core import UCIE_A_32G_55U
    from repro.core.space import DesignSpace, SimConfig, axis
    from repro.traces import ModelTrafficSpec, synthetic_serving_trace

    sim = SimConfig(trace_cycles=512)
    for name in CAPACITY_MODELS:
        spec = ModelTrafficSpec.from_name(name)
        t0 = time.perf_counter()
        tr = synthetic_serving_trace(
            spec, qps=_QPS, n_ticks=192, n_phases=6, batch_slots=_SLOTS,
            prompt_len=_PROMPT, decode_len=_DECODE)
        bw = DesignSpace([axis("trace", [tr])], phy=UCIE_A_32G_55U,
                         sim=sim).evaluate(
            metrics=("trace_bandwidth_gbs",))["trace_bandwidth_gbs"]
        dt_us = (time.perf_counter() - t0) * 1e6
        winner = str(bw.argbest("protocol").values[0])
        gbs = float(bw.best("protocol").values[0])
        # a decode token's memory bill at the run's mean context, weight
        # streaming amortized over the decode batch
        r, w = spec.decode_bytes(_PROMPT + _DECODE // 2)
        per_tok = r + w + spec.weight_stream_bytes / _SLOTS
        tok_s = gbs * 1e9 / per_tok
        rows.append((f"serving/trace_capacity_{name}", dt_us,
                     f"winner={winner};sim_bandwidth_gbs={gbs:.1f};"
                     f"bytes_per_token={per_tok:.3g};"
                     f"mem_tok_per_s={tok_s:.3g}"))
    if common.SMOKE:
        return

    import jax

    from repro.configs import get
    from repro.models import ShardingCtx, build
    from repro.serve import Request, ServingEngine

    ctx = ShardingCtx()
    cfg = get("smollm-360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ctx, batch_slots=4, max_len=96)
    n_req, new_tok = 8, 12
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=np.arange(5 + i % 3) % 50,
                           max_new_tokens=new_tok))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    rows.append(("serving/continuous_batching",
                 dt / max(total_tokens, 1) * 1e6,
                 f"requests={n_req};tokens={total_tokens};"
                 f"tok_per_s={total_tokens / dt:.1f}(cpu)"))
