"""Serving engine throughput benchmark (reduced model, CPU)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get
from repro.models import ShardingCtx, build
from repro.serve import Request, ServingEngine


def run(rows: list):
    ctx = ShardingCtx()
    cfg = get("smollm-360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ctx, batch_slots=4, max_len=96)
    n_req, new_tok = 8, 12
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=np.arange(5 + i % 3) % 50,
                           max_new_tokens=new_tok))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    rows.append(("serving/continuous_batching",
                 dt / max(total_tokens, 1) * 1e6,
                 f"requests={n_req};tokens={total_tokens};"
                 f"tok_per_s={total_tokens / dt:.1f}(cpu)"))
