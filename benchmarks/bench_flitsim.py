"""Flit-level simulator vs analytic closed forms (Appendix Fig 13 +
validation of eqs 3/14/20)."""
from __future__ import annotations

from benchmarks.common import time_us
from repro.core.flitsim import (
    ANALYTIC, SIMULATORS, simulate_lpddr6_pipelining,
)


def run(rows: list):
    for key, sim in SIMULATORS.items():
        worst = 0.0
        for (x, y) in [(1, 0), (2, 1), (1, 1), (1, 2), (0, 1)]:
            a = float(ANALYTIC[key].bw_eff(x, y))
            s = sim(x, y)
            worst = max(worst, abs(a - s) / a)
        us = time_us(lambda: sim(2, 1), iters=3)
        rows.append((f"flitsim/{key}", us,
                     f"worst_err_vs_analytic={worst:.4%}"))
    for k in (1, 2, 3, 4):
        u = simulate_lpddr6_pipelining(k)
        rows.append((f"flitsim/lpddr6_pipelining_k{k}", 0.0,
                     f"link_utilization={u:.3f}"))
