"""Flit-level simulator vs analytic closed forms (Appendix Fig 13 +
validation of eqs 3/14/20), via the batched sweep engine.

The validation sweep (all 5 protocols x 5 canonical mixes) runs as ONE
compiled program per simulator family; a speedup row compares the batched
path against the legacy per-point loop on a 125-point grid.  Adaptive
rows run the same 125-point sweep under the convergence-adaptive chunked
engine (``ADAPTIVE_SIM``) and report the wall-clock and sequential-depth
cuts vs the fixed-horizon engine, the fixed-vs-adaptive max deviation
(asserted <= 1e-3), and the per-family cycles-to-convergence histograms.
Sensitivity rows perturb protocol parameters (slot counts, credit limits,
the write-buffer depth) through the ``protocol_param`` design-space axis,
and a joint-pipelining row sweeps (k, ucie_line_ui, device_line_ui) —
faster DRAM generations behind the logic die — in one compiled call.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import time_us
from repro.core import flitsim, mix_grid
from repro.core.flitsim import (
    ADAPTIVE_SIM, ANALYTIC, SIMULATORS, SYMMETRIC_PARAMS, sweep,
    sweep_perturbed, sweep_pipelining,
)


def _per_point_grid(mixes):
    """The pre-batching path: one scalar simulator call per grid point."""
    out = []
    for key in SIMULATORS:
        for (x, y) in mixes:
            out.append(SIMULATORS[key](x, y))
    return out


def run(rows: list):
    flitsim.clear_compile_cache()

    # -- validation sweep: 5 protocols x 5 mixes, one compile per family ----
    res = sweep()
    stats = flitsim.compile_cache_stats()
    assert stats.misses == 2, (
        f"expected exactly one compile per simulator family, got {stats}")
    for i, key in enumerate(res.protocols):
        worst = 0.0
        for j, (x, y) in enumerate(res.mixes):
            a = float(ANALYTIC[key].bw_eff(x, y))
            s = float(res.efficiency[i, j])
            worst = max(worst, abs(a - s) / a)
        rows.append((f"flitsim/{key}", 0.0,
                     f"worst_err_vs_analytic={worst:.4%}"))
    rows.append(("flitsim/sweep_compiles", 0.0,
                 f"families_compiled={stats.misses};cache_hits={stats.hits}"))

    # -- batched vs per-point wall clock on a 125-point grid ----------------
    gx, gy = mix_grid(25)
    mixes = list(zip(np.asarray(gx).tolist(), np.asarray(gy).tolist()))
    n_points = len(SIMULATORS) * len(mixes)
    us_batched = time_us(lambda: sweep(mixes=mixes).efficiency,
                         warmup=1, iters=5)
    us_scalar = time_us(lambda: _per_point_grid(mixes), warmup=1, iters=3)
    speedup = us_scalar / us_batched
    rows.append((f"flitsim/sweep_batched_{n_points}pt", us_batched,
                 f"per_point_us={us_scalar:.0f};speedup=x{speedup:.1f}"))

    # -- convergence-adaptive vs fixed on the same 125-point grid -----------
    eff_fixed = np.asarray(sweep(mixes=mixes).efficiency)
    eff_adapt = np.asarray(sweep(mixes=mixes, sim=ADAPTIVE_SIM).efficiency)
    max_dev = float(np.max(np.abs(eff_fixed - eff_adapt)))
    assert max_dev <= 1e-3, (
        f"adaptive engine deviates {max_dev:.2e} > 1e-3 from the fixed "
        f"engine on the {n_points}-pt sweep")
    us_adapt = time_us(
        lambda: np.asarray(sweep(mixes=mixes, sim=ADAPTIVE_SIM).efficiency),
        warmup=1, iters=5)
    info = flitsim.last_run_info()
    depth = {fam.split(".")[1]: f"{v['cycles_run']}/{v['horizon']}"
             for fam, v in sorted(info.items())}
    # sequential_depth counts a straggler-escalation pass as full-horizon
    depth_cut = min(v["horizon"] / max(v["sequential_depth"], 1)
                    for v in info.values())
    rows.append((f"flitsim/sweep_adaptive_{n_points}pt", us_adapt,
                 f"fixed_us={us_batched:.0f};"
                 f"wall_speedup=x{us_batched / us_adapt:.2f};"
                 f"depth_cut_min=x{depth_cut:.1f};"
                 f"cycles={';'.join(f'{k}={v}' for k, v in depth.items())};"
                 f"max_dev_vs_fixed={max_dev:.1e};"
                 f"per_point_us={us_scalar:.0f};"
                 f"speedup_vs_per_point=x{us_scalar / us_adapt:.1f}"))
    for fam, v in sorted(info.items()):
        hist = ">".join(f"{c}:{n}" for c, n in sorted(
            v["converged_cycles"].items(),
            key=lambda kv: (kv[0] == "horizon",
                            int(kv[0]) if kv[0] != "horizon" else 0)))
        rows.append((f"flitsim/convergence_hist/{fam.split('.')[1]}", 0.0,
                     f"cells={v['cells']};stragglers={v['stragglers']};"
                     f"cycles_to_convergence={hist}"))

    # -- backlog-sensitivity grid (symmetric family only) -------------------
    bl = sweep(protocols=tuple(SYMMETRIC_PARAMS), mixes=[(2, 1)],
               backlogs=[1, 2, 4, 8, 64])
    for i, key in enumerate(bl.protocols):
        e = np.asarray(bl.efficiency[i, :, 0])
        rows.append((f"flitsim/backlog_sensitivity/{key}", 0.0,
                     f"eff@bl1={e[0]:.3f};eff@bl64={e[-1]:.3f}"))

    # -- protocol-parameter sensitivity via the perturbation axis -----------
    # write_buffer_lines rides along: the write-buffer depth is its own
    # perturbable field now (it used to silently alias the read credit)
    perts = [{}, {"credit_lines": 0.1}, {"g_slots": 0.8},
             {"reqs_per_g": 0.5, "resps_per_g": 0.5},
             {"write_buffer_lines": 0.1}]
    sens = sweep_perturbed(perts, protocols=tuple(SYMMETRIC_PARAMS),
                           mixes=[(2, 1)], backlogs=[4.0, 64.0])
    eff = sens["sim_efficiency"]        # [pert, protocol, backlog, mix]
    base = eff.sel(protocol_param="baseline")
    for q, label in enumerate(eff.coord("protocol_param")):
        if label == "baseline":
            continue
        for i, key in enumerate(eff.coord("protocol")):
            d4 = float(eff.values[q, i, 0, 0] - base.values[i, 0, 0])
            d64 = float(eff.values[q, i, 1, 0] - base.values[i, 1, 0])
            rows.append((f"flitsim/sensitivity/{key}/{label}", 0.0,
                         f"d_eff@bl4={d4:+.3f};d_eff@bl64={d64:+.3f}"))

    # -- Fig 13: pipelining, batched over k in one call ---------------------
    ks = (1, 2, 3, 4)
    util = np.asarray(sweep_pipelining(ks))
    for k, u in zip(ks, util):
        rows.append((f"flitsim/lpddr6_pipelining_k{k}", 0.0,
                     f"link_utilization={u:.3f}"))

    # -- joint (k x ucie_line_ui x device_line_ui) pipelining sweep ---------
    # smaller device_line_ui models faster DRAM generations; the derived
    # column reports the smallest k that saturates the link per column
    us_axis, ds_axis = (8.0, 16.0), (16.0, 32.0, 64.0)
    joint = np.asarray(sweep_pipelining((1, 2, 3, 4, 6),
                                        ucie_line_ui=us_axis,
                                        device_line_ui=ds_axis))
    for ui, u_line in zip(us_axis, joint.transpose(1, 0, 2)):
        k_sat = []
        for d, col in zip(ds_axis, u_line.T):
            sat = np.nonzero(col >= 0.99)[0]
            k_sat.append(f"dev{d:g}ui:k="
                         f"{(1, 2, 3, 4, 6)[sat[0]] if sat.size else '>6'}")
        rows.append((f"flitsim/pipelining_joint_ucie{ui:g}ui", 0.0,
                     "saturating_" + ";".join(k_sat)))
