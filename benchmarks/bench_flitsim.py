"""Flit-level simulator vs analytic closed forms (Appendix Fig 13 +
validation of eqs 3/14/20), via the batched sweep engine.

The validation sweep (all 5 protocols x 5 canonical mixes) runs as ONE
compiled program per simulator family; a speedup row compares the batched
path against the legacy per-point loop on a 125-point grid.  Adaptive
rows run the same 125-point sweep under the convergence-adaptive chunked
engine (``ADAPTIVE_SIM``) and report the wall-clock and sequential-depth
cuts vs the fixed-horizon engine, the fixed-vs-adaptive max deviation
(asserted <= 1e-3), and the per-family cycles-to-convergence histograms.
Sensitivity rows perturb protocol parameters (slot counts, credit limits,
the write-buffer depth) through the ``protocol_param`` design-space axis,
and a joint-pipelining row sweeps (k, ucie_line_ui, device_line_ui) —
faster DRAM generations behind the logic die — in one compiled call.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import time_us
from repro.core import flitsim, mix_grid
from repro.core.flitsim import (
    ADAPTIVE_SIM, ANALYTIC, PALLAS_SIM, SIMULATORS, SYMMETRIC_PARAMS,
    simulate_grid, sweep_perturbed,
)
from repro.core.flitsim import _sweep_impl as sweep
from repro.core.flitsim import _sweep_pipelining_impl as sweep_pipelining


def _per_point_grid(mixes):
    """The pre-batching path: one scalar simulator call per grid point."""
    out = []
    for key in SIMULATORS:
        for (x, y) in mixes:
            out.append(SIMULATORS[key](x, y))
    return out


def run(rows: list):
    flitsim.clear_compile_cache()

    # -- validation sweep: 5 protocols x 5 mixes, one compile per family ----
    res = sweep()
    stats = flitsim.compile_cache_stats()
    assert stats.misses == 2, (
        f"expected exactly one compile per simulator family, got {stats}")
    for i, key in enumerate(res.protocols):
        worst = 0.0
        for j, (x, y) in enumerate(res.mixes):
            a = float(ANALYTIC[key].bw_eff(x, y))
            s = float(res.efficiency[i, j])
            worst = max(worst, abs(a - s) / a)
        # scalar-call steady-state cost; auto-scaled so the sub-resolution
        # per-point dispatch still yields a real fractional-us figure
        us_scalar_pt = time_us(SIMULATORS[key], 2.0, 1.0,
                               warmup=1, iters=5, min_total_us=10_000.0)
        rows.append((f"flitsim/{key}", us_scalar_pt,
                     f"worst_err_vs_analytic={worst:.4%}"))
    rows.append(("flitsim/sweep_compiles", 0.0,
                 f"families_compiled={stats.misses};cache_hits={stats.hits}"))

    # -- batched vs per-point wall clock on a 125-point grid ----------------
    gx, gy = mix_grid(25)
    mixes = list(zip(np.asarray(gx).tolist(), np.asarray(gy).tolist()))
    n_points = len(SIMULATORS) * len(mixes)
    us_batched = time_us(lambda: sweep(mixes=mixes).efficiency,
                         warmup=1, iters=5)
    us_scalar = time_us(lambda: _per_point_grid(mixes), warmup=1, iters=3)
    speedup = us_scalar / us_batched
    rows.append((f"flitsim/sweep_batched_{n_points}pt", us_batched,
                 f"per_point_us={us_scalar:.0f};speedup=x{speedup:.1f}"))

    # -- convergence-adaptive vs fixed on the same 125-point grid -----------
    eff_fixed = np.asarray(sweep(mixes=mixes).efficiency)
    eff_adapt = np.asarray(sweep(mixes=mixes, sim=ADAPTIVE_SIM).efficiency)
    max_dev = float(np.max(np.abs(eff_fixed - eff_adapt)))
    assert max_dev <= 1e-3, (
        f"adaptive engine deviates {max_dev:.2e} > 1e-3 from the fixed "
        f"engine on the {n_points}-pt sweep")
    us_adapt = time_us(
        lambda: np.asarray(sweep(mixes=mixes, sim=ADAPTIVE_SIM).efficiency),
        warmup=1, iters=5)
    info = flitsim.last_run_info()
    depth = {fam.split(".")[1]: f"{v['cycles_run']}/{v['horizon']}"
             for fam, v in sorted(info.items())}
    # sequential_depth counts a straggler-escalation pass as full-horizon
    depth_cut = min(v["horizon"] / max(v["sequential_depth"], 1)
                    for v in info.values())
    rows.append((f"flitsim/sweep_adaptive_{n_points}pt", us_adapt,
                 f"fixed_us={us_batched:.0f};"
                 f"wall_speedup=x{us_batched / us_adapt:.2f};"
                 f"depth_cut_min=x{depth_cut:.1f};"
                 f"cycles={';'.join(f'{k}={v}' for k, v in depth.items())};"
                 f"max_dev_vs_fixed={max_dev:.1e};"
                 f"per_point_us={us_scalar:.0f};"
                 f"speedup_vs_per_point=x{us_scalar / us_adapt:.1f}"))
    for fam, v in sorted(info.items()):
        hist = ">".join(f"{c}:{n}" for c, n in sorted(
            v["converged_cycles"].items(),
            key=lambda kv: (kv[0] == "horizon",
                            int(kv[0]) if kv[0] != "horizon" else 0)))
        rows.append((f"flitsim/convergence_hist/{fam.split('.')[1]}", 0.0,
                     f"cells={v['cells']};stragglers={v['stragglers']};"
                     f"cycles_to_convergence={hist}"))

    # -- fused-kernel engine (SimConfig engine="pallas") on the same grid ---
    # interpret-mode on CPU (the kernel bodies trace to XLA); the row pins
    # numerical agreement and IDENTICAL design-space winners vs the fixed
    # engine, plus the per-launch telemetry the TPU path reports
    eff_pallas = np.asarray(sweep(mixes=mixes, sim=PALLAS_SIM).efficiency)
    max_dev_p = float(np.max(np.abs(eff_fixed - eff_pallas)))
    assert max_dev_p <= 1e-3, (
        f"pallas engine deviates {max_dev_p:.2e} > 1e-3 from the fixed "
        f"engine on the {n_points}-pt sweep")
    assert (eff_fixed.argmax(axis=0) == eff_pallas.argmax(axis=0)).all(), (
        "pallas engine flips a per-mix protocol winner vs the fixed engine")
    us_pallas = time_us(
        lambda: np.asarray(sweep(mixes=mixes, sim=PALLAS_SIM).efficiency),
        warmup=1, iters=5)
    rows.append((f"flitsim/sweep_pallas_{n_points}pt", us_pallas,
                 f"fixed_us={us_batched:.0f};"
                 f"adaptive_xla_us={us_adapt:.0f};"
                 f"max_dev_vs_fixed={max_dev_p:.1e};winners=identical"))
    for fam, v in sorted(flitsim.last_run_info().items()):
        if v.get("mode") != "adaptive":
            continue
        rows.append((f"flitsim/pallas_{fam.split('.')[1]}", 0.0,
                     f"engine={v['engine']};launches={v['launches']};"
                     f"cycles_run={v['cycles_run']};"
                     f"cycles_per_sec_per_cell="
                     f"{v.get('cycles_per_sec_per_cell', 0.0):.0f}"))

    # -- period-exact asymmetric cut: dense perturbation grid ---------------
    # [31 lane-count scales x 2 asym protocols x 41 mixes]; every mix has a
    # small credit denominator, so the detector closes the warm window at
    # PERIOD_OBS steps instead of the 4096-access horizon — this is where
    # the adaptive depth cut becomes a wall-clock cut
    gx41, gy41 = mix_grid(41)
    asym_keys = ("lpddr6_asym", "hbm_asym")
    perts_dense = [{}] + [{"total_lanes": round(0.6 + 0.03 * q, 4)}
                          for q in range(30)]
    dense_cells = len(perts_dense) * len(asym_keys) * 41

    def _dense(sim=None):
        return np.asarray(simulate_grid(asym_keys, gx41, gy41, [64.0],
                                        perturbations=perts_dense, sim=sim))

    eff_fixed_d, eff_pallas_d = _dense(), _dense(PALLAS_SIM)
    max_dev_d = float(np.max(np.abs(eff_fixed_d - eff_pallas_d)))
    assert max_dev_d <= 1e-3, (
        f"period-exact engine deviates {max_dev_d:.2e} > 1e-3 on the "
        f"dense asymmetric grid")
    assert (eff_fixed_d.argmax(axis=1)
            == eff_pallas_d.argmax(axis=1)).all(), (
        "period-exact engine flips a protocol winner on the dense grid")
    us_fixed_d = time_us(_dense, warmup=1, iters=3)
    us_pallas_d = time_us(lambda: _dense(PALLAS_SIM), warmup=1, iters=3)
    speedup_d = us_fixed_d / us_pallas_d
    if not common.SMOKE:
        assert speedup_d >= 2.5, (
            f"period-exact asymmetric cut only x{speedup_d:.2f} vs fixed "
            f"XLA on the {dense_cells}-cell grid (expected >= x2.5)")
    vi = flitsim.last_run_info()["flitsim.asymmetric"]
    rows.append((f"flitsim/pallas_dense_asym_{dense_cells}pt", us_pallas_d,
                 f"fixed_us={us_fixed_d:.0f};wall_speedup=x{speedup_d:.2f};"
                 f"max_dev_vs_fixed={max_dev_d:.1e};"
                 f"cycles_run={vi['cycles_run']}/{vi['horizon']};"
                 f"stragglers={vi['stragglers']};"
                 f"n_periods={len(vi.get('periods', {}))}"))

    # -- period-exact symmetric cut: dense drained-backlog grid -------------
    # [3 symmetric protocols x 3 drained backlogs x 33 mixes]; drained
    # credit pools settle into an exactly-repeating f32 core state, so the
    # symmetric detector certifies the period inside its SYM_PERIOD_OBS
    # observation window and extrapolates the warm-window delivery sum
    # BITWISE to the 2048-flit horizon — agreement is exact, not approx
    gx33, gy33 = mix_grid(33)
    sym_mixes = list(zip(gx33.tolist(), gy33.tolist()))
    sym_bls = [1.0, 1.5, 2.0]
    sym_cells = len(SYMMETRIC_PARAMS) * len(sym_bls) * 33

    def _dense_sym(sim=None):
        return np.asarray(sweep(protocols=tuple(SYMMETRIC_PARAMS),
                                mixes=sym_mixes, backlogs=sym_bls,
                                sim=sim).efficiency)

    eff_fixed_s, eff_pallas_s = _dense_sym(), _dense_sym(PALLAS_SIM)
    dev_s = float(np.max(np.abs(eff_fixed_s - eff_pallas_s)))
    assert dev_s == 0.0, (
        f"symmetric period-exact engine deviates {dev_s:.2e} from the "
        f"fixed engine on the drained dense grid (expected BITWISE)")
    assert (eff_fixed_s.argmax(axis=0)
            == eff_pallas_s.argmax(axis=0)).all(), (
        "symmetric period-exact engine flips a protocol winner")
    us_fixed_s = time_us(_dense_sym, warmup=1, iters=3)
    us_pallas_s = time_us(lambda: _dense_sym(PALLAS_SIM), warmup=1, iters=3)
    speedup_s = us_fixed_s / us_pallas_s
    if not common.SMOKE:
        assert speedup_s >= 2.0, (
            f"symmetric period-exact cut only x{speedup_s:.2f} vs fixed "
            f"XLA on the {sym_cells}-cell grid (expected >= x2.0)")
    vs = flitsim.last_run_info()["flitsim.symmetric"]
    rows.append(("flitsim/pallas_dense_sym_periodic", us_pallas_s,
                 f"cells={sym_cells};fixed_us={us_fixed_s:.0f};"
                 f"wall_speedup=x{speedup_s:.2f};"
                 f"max_dev_vs_fixed={dev_s:.1e};"
                 f"cycles_run={vs['cycles_run']}/{vs['horizon']};"
                 f"stragglers={vs['stragglers']};"
                 f"n_periods={len(vs.get('periods', {}))}"))

    # -- million-cell asymmetric grid: cycles/sec/cell per engine -----------
    # the fixed engine is rate-measured at a reduced 256-access horizon
    # (full 4096 x 1e6 cells is minutes of CPU); adaptive engines run the
    # real 4096-access problem and report their own retired-cycle rate
    if not common.SMOKE:
        m_mixes = 41
        m_q = 1_000_000 // (len(asym_keys) * m_mixes) + 1   # -> 1,000,072
        perts_m = [{}] + [{"total_lanes": round(0.5 + 1.0 * q / m_q, 6)}
                          for q in range(1, m_q)]
        m_cells = m_q * len(asym_keys) * m_mixes

        def _million(sim=None, n_accesses=4096):
            return np.asarray(simulate_grid(
                asym_keys, gx41, gy41, [64.0], perturbations=perts_m,
                n_accesses=n_accesses, sim=sim))

        us_fixed_m = time_us(lambda: _million(n_accesses=256),
                             warmup=1, iters=1)
        rate_fixed = 256 / (us_fixed_m * 1e-6)
        parts = [f"cells={m_cells}",
                 f"xla_fixed_256acc={rate_fixed:.0f}c/s/cell"]
        eng_eff = {}
        for label, s in (("xla_adaptive", ADAPTIVE_SIM),
                         ("pallas", PALLAS_SIM)):
            us_m = time_us(lambda s=s: _million(sim=s), warmup=1, iters=1)
            vm = flitsim.last_run_info()["flitsim.asymmetric"]
            eng_eff[label] = _million(sim=s)
            parts.append(
                f"{label}={vm['cycles_run'] / (us_m * 1e-6):.0f}c/s/cell"
                f"(launches={vm['launches']},stragglers={vm['stragglers']})")
            last_us = us_m
        dev_m = float(np.max(np.abs(eng_eff["xla_adaptive"]
                                    - eng_eff["pallas"])))
        parts.append(f"xla_vs_pallas_dev={dev_m:.1e}")
        rows.append((f"flitsim/million_cell_asym_{m_cells}", last_us,
                     ";".join(parts)))

    # -- backlog-sensitivity grid (symmetric family only) -------------------
    bl = sweep(protocols=tuple(SYMMETRIC_PARAMS), mixes=[(2, 1)],
               backlogs=[1, 2, 4, 8, 64])
    for i, key in enumerate(bl.protocols):
        e = np.asarray(bl.efficiency[i, :, 0])
        rows.append((f"flitsim/backlog_sensitivity/{key}", 0.0,
                     f"eff@bl1={e[0]:.3f};eff@bl64={e[-1]:.3f}"))

    # -- protocol-parameter sensitivity via the perturbation axis -----------
    # write_buffer_lines rides along: the write-buffer depth is its own
    # perturbable field now (it used to silently alias the read credit)
    perts = [{}, {"credit_lines": 0.1}, {"g_slots": 0.8},
             {"reqs_per_g": 0.5, "resps_per_g": 0.5},
             {"write_buffer_lines": 0.1}]
    sens = sweep_perturbed(perts, protocols=tuple(SYMMETRIC_PARAMS),
                           mixes=[(2, 1)], backlogs=[4.0, 64.0])
    eff = sens["sim_efficiency"]        # [pert, protocol, backlog, mix]
    base = eff.sel(protocol_param="baseline")
    for q, label in enumerate(eff.coord("protocol_param")):
        if label == "baseline":
            continue
        for i, key in enumerate(eff.coord("protocol")):
            d4 = float(eff.values[q, i, 0, 0] - base.values[i, 0, 0])
            d64 = float(eff.values[q, i, 1, 0] - base.values[i, 1, 0])
            rows.append((f"flitsim/sensitivity/{key}/{label}", 0.0,
                         f"d_eff@bl4={d4:+.3f};d_eff@bl64={d64:+.3f}"))

    # -- Fig 13: pipelining, batched over k in one call ---------------------
    ks = (1, 2, 3, 4)
    util = np.asarray(sweep_pipelining(ks))
    for k, u in zip(ks, util):
        rows.append((f"flitsim/lpddr6_pipelining_k{k}", 0.0,
                     f"link_utilization={u:.3f}"))

    # -- joint (k x ucie_line_ui x device_line_ui) pipelining sweep ---------
    # smaller device_line_ui models faster DRAM generations; the derived
    # column reports the smallest k that saturates the link per column
    us_axis, ds_axis = (8.0, 16.0), (16.0, 32.0, 64.0)
    joint = np.asarray(sweep_pipelining((1, 2, 3, 4, 6),
                                        ucie_line_ui=us_axis,
                                        device_line_ui=ds_axis))
    for ui, u_line in zip(us_axis, joint.transpose(1, 0, 2)):
        k_sat = []
        for d, col in zip(ds_axis, u_line.T):
            sat = np.nonzero(col >= 0.99)[0]
            k_sat.append(f"dev{d:g}ui:k="
                         f"{(1, 2, 3, 4, 6)[sat[0]] if sat.size else '>6'}")
        rows.append((f"flitsim/pipelining_joint_ucie{ui:g}ui", 0.0,
                     "saturating_" + ";".join(k_sat)))
