"""Roofline + memsys tables from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape × mesh) cell: the three roofline terms,
the dominant bottleneck, and the paper bridge — the best UCIe-Memory
system for the cell's traffic mix vs the HBM baseline.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(rows: list):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        rows.append(("roofline/none", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        r = d["roofline"]
        cell = f"{d['arch']}__{d['shape']}__{d['mesh']}"
        # best UCIe system for this workload's mix
        br = d.get("memsys_bridge", {})
        best_key, best = None, None
        for key, sysd in br.get("systems", {}).items():
            if "UCIe" not in key and key not in ("HBM4", "LPDDR6"):
                continue
            if "/" not in key:
                continue
            if best is None or sysd["memory_term_s"] < best:
                best, best_key = sysd["memory_term_s"], key
        hbm_t = br.get("hbm_baseline_memory_s", r["memory_s"])
        derived = (f"compute={r['compute_s']*1e3:.1f}ms;"
                   f"memory={r['memory_s']*1e3:.1f}ms;"
                   f"collective={r['collective_s']*1e3:.1f}ms;"
                   f"dominant={r['dominant']};"
                   f"useful={r['useful_flops_ratio']:.2f};"
                   f"mix={br.get('mix', '?')}")
        if best_key is not None and hbm_t:
            derived += (f";best_memsys={best_key}"
                        f";memsys_gain=x{hbm_t / best:.2f}")
        rows.append((f"roofline/{cell}", float(d.get("compile_s", 0)) * 1e6,
                     derived))
