"""Roofline + memsys tables from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape × mesh) cell: the three roofline terms,
the dominant bottleneck, and the paper bridge — the best UCIe-Memory
system for the cell's traffic mix vs the HBM baseline.

A bridge row times the batched workload->design-space evaluation
(``bridge_design_space``: one compiled [configs x catalog x mixes x
shorelines] call) against the equivalent per-workload scalar-bridge loop.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _synthetic_report(name: str, read_frac: float, hlo_bytes: float):
    """Synthetic memory-bound workload cell with a chosen read fraction."""
    from repro.roofline.analysis import RooflineReport
    return RooflineReport(
        arch=name, shape="-", mesh="-", chips=256,
        hlo_flops_per_chip=1e12, hlo_bytes_per_chip=hlo_bytes,
        collective_bytes_per_chip=1e9, compute_s=5e-3,
        memory_s=hlo_bytes / 8.192e11, collective_s=2e-2,
        dominant="memory", model_flops=2e14, useful_flops_ratio=0.8,
        read_bytes_per_chip=hlo_bytes * read_frac,
        write_bytes_per_chip=hlo_bytes * (1 - read_frac))


def _bench_bridge(rows: list, n_workloads: int = 8, n_fracs: int = 41,
                  shorelines=(2.0, 4.0, 8.0, 16.0)):
    """Batched design-space bridge vs a per-workload scalar-bridge loop."""
    from benchmarks.common import time_us
    from repro.core.memsys import (
        clear_grid_cache, grid_cache_stats, standard_catalog)
    from repro.roofline.analysis import bridge_design_space, memsys_bridge

    reports = {
        f"w{i}": _synthetic_report(
            f"w{i}", 0.55 + 0.4 * i / max(n_workloads - 1, 1),
            1e10 * (1 + i))
        for i in range(n_workloads)}

    clear_grid_cache()
    us_batched = time_us(
        lambda: bridge_design_space(reports, n_fracs=n_fracs,
                                    shorelines=shorelines),
        warmup=1, iters=5)
    stats = grid_cache_stats()
    assert stats.misses == 1, (
        f"expected one compile for the design-space grid, got {stats}")
    us_scalar = time_us(
        lambda: [memsys_bridge(r) for r in reports.values()],
        warmup=1, iters=5)
    n_pts = (n_workloads * len(standard_catalog()) * (n_fracs + 1)
             * len(shorelines))
    rows.append((f"roofline/bridge_design_space_{n_pts}pt", us_batched,
                 f"workloads={n_workloads};compiles={stats.misses};"
                 f"scalar_bridge_own_mix_only_us={us_scalar:.0f}"))


def _bench_knee_bridge(rows: list, budget: float = 4.0, n_fracs: int = 11):
    """Per-mix backlog-knee budget: each workload's OWN HLO-derived mix —
    not the canonical-mix envelope — decides which simulated protocols
    survive the queue-depth constraint along the configs axis."""
    from repro.core.selector import SelectionConstraints
    from repro.roofline.analysis import bridge_design_space

    reports = {name: _synthetic_report(name, read_frac, 1e10)
               for name, read_frac in (("decode_pure_read", 1.0),
                                       ("train_67r33w", 0.67),
                                       ("balanced_50r50w", 0.5))}
    ds = bridge_design_space(
        reports, n_fracs=n_fracs,
        constraints=SelectionConstraints(max_backlog_knee=budget))
    bests = ";".join(f"{name}={w['best']}"
                     for name, w in ds["workloads"].items())
    rows.append((f"roofline/bridge_knee_budget{budget:g}", 0.0, bests))


def _bench_feasible_frontier(rows: list, n_fracs: int = 21):
    """First-class feasibility masks: one boolean SpaceArray composed
    through ``frontier(..., where=mask)`` replaces the grid_ranking
    valid-mask plumbing — winner labels per constraint set on one warm
    evaluation."""
    import numpy as np

    from repro.core.selector import SelectionConstraints
    from repro.core.space import DesignSpace, axis

    res = DesignSpace([
        axis("read_fraction", np.linspace(0.0, 1.0, n_fracs)),
        axis("shoreline_mm", (4.0, 8.0)),
    ]).evaluate()
    mid = n_fracs // 2
    bests = []
    for tag, cons in (
            ("any", SelectionConstraints()),
            ("ucie_s", SelectionConstraints(packaging="UCIe-S")),
            ("cheap", SelectionConstraints(max_relative_bit_cost=2.0)),
            ("shallow_q", SelectionConstraints(max_backlog_knee=2.0))):
        mask = res.feasible(cons)
        front = res.frontier("bandwidth_gbs", where=mask)
        bests.append(f"{tag}={front.values[mid, 1]}")
    rows.append((f"roofline/feasible_frontier_{n_fracs}pt", 0.0,
                 ";".join(bests)))


def _bench_joint_frontier_adaptive(rows: list):
    """Measured speedup on the joint analytic-vs-simulated frontier path:
    the flit-simulated grid inside ``joint_frontier`` runs fixed-horizon
    vs convergence-adaptive; winner labels must agree (the adaptive
    engine only moves efficiencies by <= ~1e-3)."""
    import numpy as np

    from benchmarks.common import time_us
    from repro.core import ADAPTIVE_SIM, flitsim
    from repro.core.space import joint_frontier

    jf_fixed = joint_frontier()
    jf_adapt = joint_frontier(sim=ADAPTIVE_SIM)
    assert jf_fixed["simulated_best"] == jf_adapt["simulated_best"], \
        "adaptive engine changed a joint-frontier winner label"
    us_fixed = time_us(lambda: joint_frontier(), warmup=1, iters=3)
    us_adapt = time_us(lambda: joint_frontier(sim=ADAPTIVE_SIM),
                       warmup=1, iters=3)
    info = flitsim.last_run_info()
    cycles = ";".join(
        f"{fam.split('.')[1]}={v['cycles_run']}/{v['horizon']}"
        for fam, v in sorted(info.items())
        if v.get("mode") == "adaptive")
    n_pts = (len(jf_fixed["read_fractions"]) * len(jf_fixed["backlogs"])
             * len(jf_fixed["shorelines"]))
    rows.append((f"roofline/joint_frontier_adaptive_{n_pts}pt", us_adapt,
                 f"fixed_us={us_fixed:.0f};"
                 f"speedup=x{us_fixed / us_adapt:.2f};{cycles};"
                 f"disagreement_fraction="
                 f"{jf_adapt['disagreement_fraction']:.2f}"))


def run(rows: list):
    _bench_bridge(rows)
    _bench_knee_bridge(rows)
    _bench_feasible_frontier(rows)
    _bench_joint_frontier_adaptive(rows)
    # skip anything that is not a per-cell workload artifact (the
    # aggregate design-space report, axes-first exports carrying phy /
    # catalog_param dimensions) — different schema than this loop consumes
    from repro.roofline.analysis import is_cell_artifact
    cells = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if is_cell_artifact(d):
            cells.append(d)
    if not cells:
        rows.append(("roofline/none", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
        return
    for d in cells:
        r = d["roofline"]
        cell = f"{d['arch']}__{d['shape']}__{d['mesh']}"
        # best UCIe system for this workload's mix
        br = d.get("memsys_bridge", {})
        best_key, best = None, None
        for key, sysd in br.get("systems", {}).items():
            if "UCIe" not in key and key not in ("HBM4", "LPDDR6"):
                continue
            if "/" not in key:
                continue
            if best is None or sysd["memory_term_s"] < best:
                best, best_key = sysd["memory_term_s"], key
        hbm_t = br.get("hbm_baseline_memory_s", r["memory_s"])
        derived = (f"compute={r['compute_s']*1e3:.1f}ms;"
                   f"memory={r['memory_s']*1e3:.1f}ms;"
                   f"collective={r['collective_s']*1e3:.1f}ms;"
                   f"dominant={r['dominant']};"
                   f"useful={r['useful_flops_ratio']:.2f};"
                   f"mix={br.get('mix', '?')}")
        if best_key is not None and hbm_t:
            derived += (f";best_memsys={best_key}"
                        f";memsys_gain=x{hbm_t / best:.2f}")
        rows.append((f"roofline/{cell}", float(d.get("compile_s", 0)) * 1e6,
                     derived))
