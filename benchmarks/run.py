# One function per paper table/figure + framework benchmarks.
# Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit


def main() -> None:
    rows = []
    from benchmarks import (
        bench_flitsim, bench_kernels, bench_paper_figures, bench_roofline,
        bench_serving, bench_train_loop,
    )
    suites = [
        ("paper_figures", bench_paper_figures.run),
        ("flitsim", bench_flitsim.run),
        ("kernels", bench_kernels.run),
        ("train_loop", bench_train_loop.run),
        ("serving", bench_serving.run),
        ("roofline", bench_roofline.run),
    ]
    failed = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        try:
            fn(rows)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    emit(rows)
    if failed:
        print(f"FAILED_SUITES: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
