# One function per paper table/figure + framework benchmarks.
# Prints ``name,us_per_call,derived`` CSV.
#
# ``--smoke`` (CI fast mode) clamps every timing loop to one warmup + one
# iteration and skips the model-building suites (kernels, train_loop,
# serving) — the paper-model suites still run end-to-end, so the
# compile-once assertions and derived columns are exercised on every push.
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import common
from benchmarks.common import emit


def main() -> None:
    ap = argparse.ArgumentParser(description="benchmark runner")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: 1 warmup + 1 iter per timing, "
                         "paper-model suites only")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON (the weekly CI "
                         "trend artifact) plus the repo-root "
                         "BENCH_flitsim.json flit-simulation trend file")
    args = ap.parse_args()
    common.SMOKE = args.smoke

    rows = []
    from benchmarks import (
        bench_flitsim, bench_kernels, bench_lint, bench_paper_figures,
        bench_roofline, bench_serving, bench_streaming, bench_train_loop,
    )
    suites = [
        # lint first: the same pass gates CI, and the row keeps its
        # wall-clock on the trend (budget: bench_lint.LINT_BUDGET_S)
        ("lint", bench_lint.run),
        ("paper_figures", bench_paper_figures.run),
        ("flitsim", bench_flitsim.run),
        ("streaming", bench_streaming.run),
        ("kernels", bench_kernels.run),
        ("train_loop", bench_train_loop.run),
        ("serving", bench_serving.run),
        ("roofline", bench_roofline.run),
    ]
    if args.smoke:
        # serving stays: its trace-capacity rows need no model build
        # (bench_serving skips the live-engine row itself under smoke)
        skipped = {"kernels", "train_loop"}
        suites = [(n, fn) for n, fn in suites if n not in skipped]
        print(f"# smoke mode: skipping {sorted(skipped)}", file=sys.stderr)
    failed = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        try:
            fn(rows)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    emit(rows)
    if args.json:
        out_dir = os.path.dirname(os.path.abspath(args.json))
        os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke,
                       "failed_suites": failed,
                       "rows": [{"name": n, "us_per_call": us,
                                 "derived": d} for n, us, d in rows]},
                      f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
        # repo-root flit-simulation trend file: batched-sweep us, the
        # adaptive-vs-fixed speedup, the cycles-to-convergence
        # histograms, the streaming sharded-sweep rows (async prefetch
        # speedup + overlap fraction), and the serving trace-capacity
        # rows (tokens/sec tied to sim_bandwidth_gbs) — the perf
        # trajectory tracked in-repo (and uploaded per CI matrix cell)
        flit_rows = [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows
                     if n.startswith(("flitsim/", "streaming/",
                                      "serving/"))]
        if flit_rows:
            trend = os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_flitsim.json")
            with open(trend, "w") as f:
                json.dump({"smoke": args.smoke, "rows": flit_rows},
                          f, indent=1)
            print(f"# wrote {trend}", file=sys.stderr)
    if failed:
        print(f"FAILED_SUITES: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
