"""Serving-trace subsystem tests: trace compilation, arrival processes,
the ``trace`` axis, trace-scan numerics (state carry + bit-identity),
compile-cache behavior, telemetry, and the serving frontier."""
import jax
import numpy as np
import pytest

from repro.core import flitsim
from repro.core.space import (AXIS_ORDER, FIXED_SIM, AxisSet, DesignSpace,
                              SimConfig, axis)
from repro.lint.runtime import no_retrace
from repro.traces import (MIN_BACKLOG, ModelTrafficSpec, TraceRecorder,
                          TrafficTrace, bursty_arrivals, diurnal_arrivals,
                          pad_traces, poisson_arrivals, serving_frontier,
                          synthetic_serving_trace)

#: small horizons keep every trace-scan test in the milliseconds
FAST = dict(n_flits=128, n_accesses=128)
FAST_TRACE = SimConfig(trace_cycles=128)


class TestTrafficTrace:
    def test_phase_validation(self):
        with pytest.raises(ValueError, match="length"):
            TrafficTrace("t", (1.0, 1.0), (0.5,), (4.0, 4.0))
        with pytest.raises(ValueError, match="positive sum"):
            TrafficTrace("t", (0.0,), (0.5,), (4.0,))
        with pytest.raises(ValueError, match=r"outside \[0, 1\]"):
            TrafficTrace("t", (1.0,), (1.5,), (4.0,))
        with pytest.raises(ValueError, match="backlog"):
            TrafficTrace("t", (1.0,), (0.5,), (0.0,))

    def test_padded_preserves_aggregate_weighting(self):
        t = TrafficTrace("t", (3.0, 1.0), (0.8, 0.2), (4.0, 32.0))
        p = t.padded(5)
        assert p.n_phases == 5
        assert p.durations == (3.0, 1.0, 0.0, 0.0, 0.0)
        assert p.read_fractions[2:] == (0.2,) * 3
        assert t.padded(2) is t
        with pytest.raises(ValueError, match="cannot pad"):
            t.padded(1)

    def test_from_ticks_compiles_byte_weighted_phases(self):
        # 4 ticks -> 2 phases: all-read then all-write, backlog ramps
        tr = TrafficTrace.from_ticks(
            "t", read_bytes=[10, 10, 0, 0], write_bytes=[0, 0, 10, 10],
            backlogs=[2, 4, 6, 8], n_phases=2)
        assert tr.durations == (2.0, 2.0)
        assert tr.read_fractions == (1.0, 0.0)
        assert tr.backlogs == (3.0, 7.0)

    def test_from_ticks_idle_segment_inherits_global_share(self):
        tr = TrafficTrace.from_ticks(
            "t", read_bytes=[30, 0], write_bytes=[10, 0],
            backlogs=[4, 0], n_phases=2)
        assert tr.read_fractions[1] == pytest.approx(0.75)
        assert tr.backlogs[1] == MIN_BACKLOG
        with pytest.raises(ValueError, match="no bytes"):
            TrafficTrace.from_ticks("t", [0.0], [0.0], [1.0])

    def test_pad_traces_to_common_phase_count(self):
        a = TrafficTrace.steady("a", 0.5, 4.0)
        b = TrafficTrace("b", (1.0, 1.0, 1.0), (0.9, 0.5, 0.1),
                         (2.0, 8.0, 32.0))
        pa, pb = pad_traces([a, b])
        assert pa.n_phases == pb.n_phases == 3
        assert pb is b

    def test_trace_is_a_pytree(self):
        t = TrafficTrace("t", (1.0, 2.0), (0.5, 0.25), (4.0, 8.0))
        leaves, treedef = jax.tree_util.tree_flatten(t)
        assert len(leaves) == 6
        assert jax.tree_util.tree_unflatten(treedef, leaves) == t


class TestArrivals:
    def test_processes_are_deterministic_in_seed(self):
        for fn in (poisson_arrivals, diurnal_arrivals, bursty_arrivals):
            a = fn(2.0, 64, seed=3)
            b = fn(2.0, 64, seed=3)
            c = fn(2.0, 64, seed=4)
            assert a.shape == (64,) and a.dtype == np.int64
            assert np.array_equal(a, b)
            assert not np.array_equal(a, c)

    def test_rates_track_the_mean(self):
        n = 20_000
        for fn in (poisson_arrivals, diurnal_arrivals):
            assert fn(3.0, n, seed=0).mean() == pytest.approx(3.0,
                                                              rel=0.1)

    def test_bursty_is_overdispersed(self):
        a = bursty_arrivals(2.0, 20_000, seed=0)
        p = poisson_arrivals(a.mean(), 20_000, seed=0)
        assert a.var() > 2.0 * p.var()


class TestModelTraffic:
    def test_decode_is_read_heavy_and_context_dependent(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        r1, w1 = spec.decode_bytes(128)
        r2, w2 = spec.decode_bytes(1024)
        assert r2 > r1                      # KV reads grow with context
        assert w2 == w1                     # one token's writes do not
        assert r1 > w1

    def test_prefill_is_write_balanced(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        r, w = spec.prefill_bytes(256)
        assert r == w > 0

    def test_moe_and_ssm_specs_diverge(self):
        moe = ModelTrafficSpec.from_name("olmoe-1b-7b")
        ssm = ModelTrafficSpec.from_name("mamba2-2.7b")
        assert moe.moe_shuffle_bytes_per_token > 0
        assert ssm.moe_shuffle_bytes_per_token == 0
        assert ssm.state_bytes_per_token > 0
        # SSM state is context-independent: decode reads are flat
        assert ssm.decode_bytes(64)[0] == ssm.decode_bytes(4096)[0]


class TestSyntheticTrace:
    def test_backlog_grows_with_qps(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        lo = synthetic_serving_trace(spec, qps=0.1, n_ticks=128,
                                     batch_slots=4)
        hi = synthetic_serving_trace(spec, qps=8.0, n_ticks=128,
                                     batch_slots=4)
        assert max(hi.backlogs) > 4.0 * max(lo.backlogs)

    def test_arrival_and_qps_validation(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        with pytest.raises(ValueError, match="arrival"):
            synthetic_serving_trace(spec, qps=1.0, arrival="nope")
        with pytest.raises(ValueError, match="qps"):
            synthetic_serving_trace(spec, qps=-1.0)

    def test_deterministic_and_named(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        a = synthetic_serving_trace(spec, qps=1.0, n_ticks=64, seed=5)
        b = synthetic_serving_trace(spec, qps=1.0, n_ticks=64, seed=5)
        assert a == b
        assert a.name == "smollm-360m@qps1-diurnal"


class TestTraceAxis:
    def test_axis_order_and_normalization(self):
        assert "trace" in AXIS_ORDER
        ax = axis("trace", [TrafficTrace.steady("a", 0.5, 4.0),
                            TrafficTrace("b", (1.0, 1.0), (0.9, 0.1),
                                         (2.0, 32.0))])
        assert ax.labels == ("a", "b")
        # padded to a common phase count at axis build time
        assert all(t.n_phases == 2 for t in ax.values)
        assert ax.index("b") == 1

    def test_axis_rejects_non_traces_and_duplicates(self):
        with pytest.raises(ValueError, match="TrafficTrace"):
            axis("trace", [0.5])
        t = TrafficTrace.steady("a", 0.5, 4.0)
        with pytest.raises(ValueError, match="duplicate"):
            axis("trace", [t, TrafficTrace.steady("a", 0.9, 8.0)])

    def test_trace_excludes_mix_and_backlog_axes(self):
        t = axis("trace", [TrafficTrace.steady("a", 0.5, 4.0)])
        for other in (axis("backlog", [4.0]),
                      axis("read_fraction", [0.5]),
                      axis("mix", [(2, 1)])):
            with pytest.raises(ValueError, match="exclusive"):
                AxisSet([t, other])

    def test_sim_config_trace_cycles_key(self):
        # the default keys — and every golden pinned on them — unchanged
        assert FIXED_SIM.key() == ("fixed",)
        assert SimConfig(trace_cycles=128).key() == ("fixed", 128)
        adaptive = SimConfig(mode="adaptive", trace_cycles=128).key()
        assert adaptive[0] == "adaptive" and adaptive[-1] == 128
        with pytest.raises(ValueError, match="trace_cycles"):
            SimConfig(trace_cycles=4)


class TestTraceScanNumerics:
    def test_single_phase_bit_identical_to_static_cell(self):
        """A steady trace IS the static cell: same kernel, same cycle
        count, same warm-up — bitwise, for every protocol family."""
        ds_t = DesignSpace([axis("trace",
                                 [TrafficTrace.steady("s", 0.7, 16.0)])],
                           sim=FAST_TRACE, **FAST)
        eff_t = ds_t.evaluate(metrics=("trace_efficiency",))
        ds_s = DesignSpace([axis("read_fraction", [0.7]),
                            axis("backlog", [16.0])], **FAST)
        eff_s = ds_s.evaluate(metrics=("sim_efficiency",))
        np.testing.assert_array_equal(
            eff_t["trace_efficiency"].values[:, 0],
            eff_s["sim_efficiency"].values[:, 0, 0])

    def test_state_carries_across_phase_boundaries(self):
        """Phase 2 of a burst->drain trace must differ from the same
        phase started cold: the carried queue state is the point."""
        burst = TrafficTrace("burst", (1.0, 1.0), (0.1, 0.9),
                             (64.0, 2.0))
        cold = TrafficTrace.steady("cold", 0.9, 2.0)
        res = DesignSpace([axis("trace", [burst, cold])],
                          sim=FAST_TRACE, **FAST).evaluate(
            metrics=("trace_phase_efficiency",))
        phase = res["trace_phase_efficiency"]
        assert phase.dims[-1] == "phase"
        carried = phase.values[:, 0, 1]     # burst trace, phase 2
        fresh = phase.values[:, 1, 0]       # cold steady state
        sym = [i for i, k in enumerate(phase.coord("protocol"))
               if k in flitsim.SYMMETRIC_PARAMS]
        assert not np.allclose(carried[sym], fresh[sym])

    def test_duration_weighting(self):
        """The aggregate is the duration-weighted mean of phase cells."""
        t = TrafficTrace("t", (3.0, 1.0), (0.9, 0.2), (4.0, 32.0))
        res = DesignSpace([axis("trace", [t])], sim=FAST_TRACE,
                          **FAST).evaluate(
            metrics=("trace_efficiency", "trace_phase_efficiency"))
        per = res["trace_phase_efficiency"].values[:, 0].astype(np.float64)
        agg = res["trace_efficiency"].values[:, 0]
        np.testing.assert_allclose(agg, (0.75 * per[:, 0]
                                         + 0.25 * per[:, 1]).astype(
                                             np.float32), rtol=1e-6)

    def test_trace_bandwidth_threads_the_phy(self):
        from repro.core import UCIE_A_32G_55U
        t = TrafficTrace.steady("s", 0.7, 16.0)
        res = DesignSpace([axis("trace", [t])], phy=UCIE_A_32G_55U,
                          sim=FAST_TRACE, **FAST).evaluate()
        bw = res["trace_bandwidth_gbs"]
        eff = res["trace_efficiency"]
        np.testing.assert_allclose(
            bw.values, eff.values * UCIE_A_32G_55U.raw_bandwidth_gbs,
            rtol=1e-6)
        with pytest.raises(ValueError, match="phy"):
            DesignSpace([axis("trace", [t])], **FAST).evaluate(
                metrics=("trace_bandwidth_gbs",))

    def test_protocol_param_perturbations_on_trace_axis(self):
        t = TrafficTrace.steady("s", 0.6, 8.0)
        res = DesignSpace(
            [axis("protocol_param", [{}, {"flit_bits": 2.0}]),
             axis("protocol", ["cxl_opt", "chi"]),
             axis("trace", [t])],
            sim=FAST_TRACE, **FAST).evaluate(
            metrics=("trace_efficiency",))
        eff = res["trace_efficiency"]
        assert eff.dims == ("protocol_param", "protocol", "trace")
        assert not np.allclose(eff.values[0], eff.values[1])


class TestTraceCompileCaching:
    def test_alternating_trace_shapes_do_not_retrace(self):
        """Two different trace SETS of one shape share the executables;
        alternating evaluate() calls must hit the warm cache."""
        t_a = TrafficTrace("a", (1.0, 2.0), (0.9, 0.5), (4.0, 64.0))
        t_b = TrafficTrace("b", (2.0, 1.0), (0.3, 0.8), (32.0, 8.0))
        t_c = TrafficTrace("c", (1.0, 1.0), (0.6, 0.6), (16.0, 16.0))
        ds1 = DesignSpace([axis("trace", [t_a, t_b])], sim=FAST_TRACE,
                          **FAST)
        ds2 = DesignSpace([axis("trace", [t_b, t_c])], sim=FAST_TRACE,
                          **FAST)
        ds1.evaluate(metrics=("trace_efficiency",))         # warm both
        ds2.evaluate(metrics=("trace_efficiency",))
        with no_retrace():
            for _ in range(3):
                r1 = ds1.evaluate(metrics=("trace_efficiency",))
                r2 = ds2.evaluate(metrics=("trace_efficiency",))
        # the shared trace rides in both sets at different positions
        np.testing.assert_array_equal(
            r1["trace_efficiency"].sel(trace="b").values,
            r2["trace_efficiency"].sel(trace="b").values)

    def test_trace_and_static_keys_do_not_collide(self):
        t = TrafficTrace.steady("s", 0.5, 8.0)
        ds = DesignSpace([axis("trace", [t])], sim=FAST_TRACE, **FAST)
        ds.evaluate(metrics=("trace_efficiency",))
        st = DesignSpace([axis("read_fraction", [0.5]),
                          axis("backlog", [8.0])], **FAST)
        st.evaluate(metrics=("sim_efficiency",))
        with no_retrace():      # both executables stay warm side by side
            ds.evaluate(metrics=("trace_efficiency",))
            st.evaluate(metrics=("sim_efficiency",))

    def test_telemetry_reports_trace_mode(self):
        t = TrafficTrace("t", (1.0, 1.0, 1.0), (0.9, 0.5, 0.1),
                         (2.0, 8.0, 32.0))
        DesignSpace([axis("trace", [t])], sim=FAST_TRACE,
                    **FAST).evaluate(metrics=("trace_efficiency",))
        info = flitsim.last_run_info()
        for fam in ("flitsim.symmetric.trace", "flitsim.asymmetric.trace"):
            d = info[fam]
            assert d["mode"] == "trace"
            assert d["phases"] == 3
            assert d["cycles_per_phase"] == 128
            assert d["cycles_run"] == 384
            assert d["state_carry_depth"] == 256
            assert d["trace_cells"] > 0


class TestServingFrontier:
    def test_frontier_report_shape_and_vocabulary(self):
        from repro.core.selector import SIM_APPROACH_KEYS
        rep = serving_frontier(
            models=("smollm-360m", "mamba2-2.7b"), qps_points=(0.25, 4.0),
            n_ticks=96, n_phases=4, sim=SimConfig(trace_cycles=256))
        assert rep["models"] == ["smollm-360m", "mamba2-2.7b"]
        labels = set(SIM_APPROACH_KEYS.values())
        for m in rep["models"]:
            assert set(rep["winner_by_model_qps"][m]) == {"0.25", "4"}
            assert set(rep["winner_by_model_qps"][m].values()) <= labels
            for v in rep["winner_gbs_by_model_qps"][m].values():
                assert v > 0.0
        assert set(rep["telemetry"]) == {"flitsim.symmetric.trace",
                                         "flitsim.asymmetric.trace"}
        assert rep["compiles"] >= 0

    def test_design_space_entry_point(self):
        rep = DesignSpace.serving_frontier(
            models=("smollm-360m",), qps_points=(1.0,), n_ticks=48,
            n_phases=3, sim=SimConfig(trace_cycles=128))
        assert rep["trace_names"] == ["smollm-360m@q1"]
        assert rep["n_phases"] == 3


class TestTraceRecorder:
    def test_recorder_prices_ticks(self):
        spec = ModelTrafficSpec.from_name("smollm-360m")
        rec = TraceRecorder(spec)
        rec.on_prefill(8)
        rec.on_decode([8, 4])
        rec.on_tick(queue_depth=3, active=2)
        rec.on_decode([9, 5])
        rec.on_tick(queue_depth=0, active=2)
        assert rec.n_ticks == 2
        assert rec.prefill_tokens_per_tick == [8, 0]
        assert rec.decode_tokens_per_tick == [2, 2]
        tr = rec.trace(n_phases=2, name="r")
        assert tr.n_phases == 2
        assert tr.backlogs == (5.0, 2.0)
        with pytest.raises(ValueError, match="no ticks"):
            TraceRecorder(spec).trace()

    def test_recorded_engine_run_compiles_to_a_trace(self):
        """End to end: a live ServingEngine run through the recorder
        yields a trace the design space can evaluate."""
        from repro.configs import get
        from repro.models import ShardingCtx, build
        from repro.serve import Request, ServingEngine
        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rec = TraceRecorder.for_model(cfg)
        eng = ServingEngine(model, params, ShardingCtx(), batch_slots=2,
                            max_len=32, recorder=rec)
        for i in range(4):
            eng.submit(Request(rid=i, prompt=np.arange(3 + i) % 50,
                               max_new_tokens=4))
        eng.run_until_drained()
        assert rec.n_ticks > 0
        assert sum(rec.prefill_tokens_per_tick) == 3 + 4 + 5 + 6
        assert sum(rec.decode_tokens_per_tick) > 0
        tr = rec.trace(n_phases=4)
        res = DesignSpace([axis("trace", [tr])], sim=FAST_TRACE,
                          **FAST).evaluate(metrics=("trace_efficiency",))
        assert np.all(res["trace_efficiency"].values > 0.0)
