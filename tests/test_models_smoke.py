"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For each assigned arch: instantiate a reduced same-family config, run one
forward/train step asserting output shapes + no NaNs, take one gradient
step, and check prefill+decode consistency against the full forward.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get
from repro.models import ShardingCtx, build

CTX = ShardingCtx()
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, t=24, key=jax.random.PRNGKey(1)):
    tokens = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    if cfg.is_encdec:
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (b, 8, cfg.d_model)).astype(jnp.bfloat16)
        return {"frames": frames, "tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision":
        p = cfg.frontend_tokens
        pe = jax.random.normal(
            jax.random.PRNGKey(2), (b, p, cfg.d_model)).astype(jnp.bfloat16)
        return {"tokens": tokens, "patch_embeds": pe, "labels": tokens}
    return {"tokens": tokens, "labels": tokens}


@pytest.fixture(scope="module", params=arch_ids())
def arch_setup(request):
    arch = request.param
    cfg = get(arch).reduced()
    if cfg.is_moe:
        # avoid capacity drops so decode-vs-train consistency is exact
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build(cfg)
    params = model.init(KEY)
    return arch, cfg, model, params


class TestSmoke:
    def test_forward_shapes_and_no_nan(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = make_batch(cfg)
        logits, _, aux = model._forward(params, batch, CTX, mode="train")
        b, t = batch["tokens"].shape
        expect_t = t + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
        assert logits.shape == (b, expect_t, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
        assert not bool(jnp.isnan(aux))

    def test_train_step_reduces_loss(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = make_batch(cfg)

        def loss_fn(p):
            loss, _ = model.loss(p, batch, CTX)
            return loss

        l0, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(l0))
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0
        params2 = jax.tree.map(lambda p, g: p - 0.5 * g / (gnorm + 1e-6),
                               params, grads)
        l1 = loss_fn(params2)
        assert float(l1) < float(l0), (arch, float(l0), float(l1))

    def test_prefill_decode_matches_full_forward(self, arch_setup):
        arch, cfg, model, params = arch_setup
        b, t, t0 = 2, 24, 16
        batch = make_batch(cfg, b, t)
        full_logits, _, _ = model._forward(params, batch, CTX, mode="train")
        prefix = dict(batch)
        prefix.pop("labels")
        prefix["tokens"] = batch["tokens"][:, :t0]
        offset = 0
        if cfg.frontend == "vision":
            offset = cfg.frontend_tokens
            full_logits = full_logits[:, offset:]
        logits, caches = model.prefill(params, prefix, CTX,
                                       pad_cache_to=offset + t)
        errs = [float(jnp.max(jnp.abs(
            logits.astype(jnp.float32)
            - full_logits[:, t0 - 1].astype(jnp.float32))))]
        pos = offset + t0
        for step in range(t0, t):
            logits, caches = model.decode_step(
                params, batch["tokens"][:, step:step + 1], caches,
                jnp.full((b, 1), pos, jnp.int32), CTX)
            errs.append(float(jnp.max(jnp.abs(
                logits.astype(jnp.float32)
                - full_logits[:, step].astype(jnp.float32)))))
            pos += 1
        # bf16 PV matmuls: streaming (prefill) vs full-softmax (decode)
        # attention differ at bf16 epsilon; cache bugs give O(1) errors
        assert max(errs) < 0.05, (arch, errs)

    def test_param_count_matches_analytic(self, arch_setup):
        arch, cfg, model, params = arch_setup
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert actual == model.param_count()


class TestFullConfigs:
    """Full (non-reduced) configs are instantiated abstractly only."""

    @pytest.mark.parametrize("arch", arch_ids())
    def test_abstract_instantiation(self, arch):
        cfg = get(arch)
        model = build(cfg)
        ap = model.abstract_params()
        n = model.param_count()
        assert n > 0
        # rough magnitude sanity vs the arch's nameplate size
        nameplate = {
            "seamless-m4t-large-v2": 2.3e9, "recurrentgemma-2b": 2.7e9,
            "smollm-360m": 0.36e9, "starcoder2-15b": 15e9,
            "qwen1.5-110b": 111e9, "mistral-large-123b": 123e9,
            "mamba2-2.7b": 2.7e9, "llama4-scout-17b-a16e": 100e9,
            "olmoe-1b-7b": 6.9e9, "internvl2-1b": 0.6e9,
        }[arch]
        assert 0.4 * nameplate < n < 2.1 * nameplate, (arch, n, nameplate)

    @pytest.mark.parametrize("arch", arch_ids())
    def test_analytic_count_matches_schema(self, arch):
        cfg = get(arch)
        model = build(cfg)
        if cfg.is_encdec:
            pytest.skip("encdec analytic count covered by schema count")
        analytic = cfg.param_count()
        schema_n = model.param_count()
        assert abs(analytic - schema_n) / schema_n < 0.02, (
            arch, analytic, schema_n)
