"""Unit tests for the logical-axis sharding rules (no devices needed —
specs are pure metadata until applied to a mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.models import build
from repro.models.sharding import ShardingCtx, from_mesh


@pytest.fixture(scope="module")
def ctx():
    # a mesh over 1 real device is enough to build specs (abstract)
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))

    class FakeCtx(ShardingCtx):
        pass
    c = from_mesh(mesh)
    # pretend the production sizes for divisibility checks
    object.__setattr__(c, "_sizes", {"data": 16, "model": 16, "pod": 2})
    return c


class TestSpecBuilding:
    def test_divisibility_guard_drops_axis(self):
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = jax.sharding.Mesh(devs, ("data", "model"))
        c = from_mesh(mesh)
        # size-1 axes always divide; use explicit rule resolution instead
        spec = c.spec(("vocab", "embed"), (100, 64))
        assert isinstance(spec, P)

    def test_duplicate_mesh_axis_dropped(self):
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = jax.sharding.Mesh(devs, ("data", "model"))
        c = from_mesh(mesh, sequence_parallel=True)
        # both "seq" (SP) and "kv_heads" map to model: second one drops
        spec = c.spec(("batch", "seq", "kv_heads", None), (8, 16, 4, 32))
        flat = [s for s in spec if s is not None]
        names = []
        for s in flat:
            names.extend(s if isinstance(s, tuple) else (s,))
        assert len(names) == len(set(names))

    def test_disabled_ctx_constrain_is_identity(self):
        import jax.numpy as jnp
        c = ShardingCtx()
        x = jnp.ones((4, 4))
        assert c.constrain(x, "batch", None) is x


class TestSchemaSpecs:
    @pytest.mark.parametrize("arch", ["qwen1.5-110b", "olmoe-1b-7b",
                                      "mamba2-2.7b"])
    def test_param_specs_structure_matches_params(self, arch):
        cfg = get(arch)
        model = build(cfg)
        specs = model.param_specs(ShardingCtx())
        ap = model.abstract_params()
        assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(
            x, P)) == jax.tree.structure(ap)

    def test_padded_vocab_shards(self):
        cfg = get("mamba2-2.7b")
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        model = build(cfg)
        ap = model.abstract_params()
        assert ap["embedding"]["embed"].shape[0] == cfg.padded_vocab
