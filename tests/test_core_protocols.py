"""Unit tests for the paper's closed-form protocol models (eqs 1-23)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    APPROACH_A, APPROACH_A_NATIVE, APPROACH_B, APPROACH_C, APPROACH_D,
    APPROACH_E, HBM4, LPDDR5, LPDDR6, UCIE_A_32G_55U, UCIE_S_32G,
    IDLE_POWER_FRACTION,
)

P = IDLE_POWER_FRACTION


def f(v):
    return float(np.asarray(v))


class TestApproachA:
    """LPDDR6 on asymmetric UCIe — eqs (1)-(10)."""

    def test_transfer_times_eq1(self):
        # 576/36 = 16 UI per read, 576/24 = 24 UI per write
        assert f(APPROACH_A.read_ui(1)) == 16
        assert f(APPROACH_A.write_ui(1)) == 24

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1), (5, 3)])
    def test_t_xryw_eq2(self, x, y):
        assert f(APPROACH_A.t_xryw(x, y)) == 8 * max(2 * x, 3 * y)

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1), (7, 2)])
    def test_bw_eff_eq3(self, x, y):
        expect = 32 * (x + y) / (37 * max(2 * x, 3 * y))
        assert f(APPROACH_A.bw_eff(x, y)) == pytest.approx(expect, rel=1e-6)

    def test_power_eqs5to9_hand_computed(self):
        # 1R1W: t = 24. eq5: 26*(24 + 0) = 624; eq6: 192 + (240-192)*.15=199.2
        # eq7: max(24, 19.2)*.85 + 24*.15 = 20.4+3.6 = 24
        # eq8: 37*(16*.85 + 24*.15) = 37*17.2 = 636.4 ; total = 1483.6
        # p_data = 1024/1483.6
        expect = 1024.0 / (624.0 + 199.2 + 24.0 + 636.4)
        assert f(APPROACH_A.p_data(1, 1)) == pytest.approx(expect, rel=1e-5)

    def test_lane_accounting(self):
        # 26 + 10 + 1 (S2M) + 36 + 1 (M2S) = 74
        a = APPROACH_A
        assert (a.write_lanes + a.wmask_lanes + a.cmd_lanes + 1
                + a.read_lanes + 1) == a.total_lanes

    def test_reads_only_matches_lpddr6_at_same_frequency(self):
        # paper: "For 100% reads our approach with UCIe has the same
        # bandwidth as LPDDR6" — 36 read lanes (module) vs LPDDR6's
        # equivalent DQ at the same frequency; we check the native-PHY
        # variant: 24 read lanes == 24 bidirectional LPDDR6 wires.
        n = APPROACH_A_NATIVE
        assert n.read_lanes == 24
        # and 100% writes yield half of 24 bidirectional wires
        assert f(n.write_ui(1)) == pytest.approx(2 * 576 / 24)


class TestApproachB:
    """HBM3/4 on asymmetric UCIe — derived equations (DESIGN.md §6.1)."""

    def test_lane_accounting_fig5b(self):
        b = APPROACH_B
        s2m = b.cmd_lanes + b.write_lanes + b.wmask_lanes + 1   # 65
        m2s = b.read_lanes + 1                                   # 73
        assert s2m == 65 and m2s == 73
        assert s2m + m2s == b.total_lanes == 138

    def test_transfer_times_fig5b(self):
        # "Cache transfer (UI): 16 S2M / 8 M2S"
        assert f(APPROACH_B.write_ui(1)) == 16
        assert f(APPROACH_B.read_ui(1)) == 8

    def test_read_write_ratio_2to1(self):
        assert APPROACH_B.read_lanes == 2 * APPROACH_B.write_lanes

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (0, 1)])
    def test_bw_eff(self, x, y):
        expect = 512 * (x + y) / (138 * max(8 * x, 16 * y))
        assert f(APPROACH_B.bw_eff(x, y)) == pytest.approx(expect, rel=1e-6)


class TestApproachD:
    """CXL.Mem unoptimized — eqs (11)-(16)."""

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1), (3, 5)])
    def test_slots_eqs11_12(self, x, y):
        assert f(APPROACH_D.slots_s2m(x, y)) == pytest.approx(x + 5 * y)
        assert f(APPROACH_D.slots_m2s(x, y)) == pytest.approx((9 * x + y) / 2)

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1)])
    def test_bw_eff_eq14(self, x, y):
        smax = max(x + 5 * y, (9 * x + y) / 2)
        expect = (15 / 16) * 4 * (x + y) / (2 * smax)
        assert f(APPROACH_D.bw_eff(x, y)) == pytest.approx(expect, rel=1e-6)

    def test_command_fields_table2(self):
        # 74-bit request -> 1/slot (128b); 26-bit response -> 2/slot
        assert APPROACH_D.requests_per_slot == 1
        assert APPROACH_D.responses_per_slot == 2


class TestApproachE:
    """CXL.Mem optimized — eqs (17)-(23)."""

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1), (3, 5)])
    def test_slots_eqs17_18(self, x, y):
        s2m = (16 / 15) * 4 * y + max((x + y) - 4 * y / 15, 0)
        m2s = (16 / 15) * 4 * x + max((x + y) / 4 - 4 * x / 15, 0)
        assert f(APPROACH_E.slots_s2m(x, y)) == pytest.approx(s2m, rel=1e-6)
        assert f(APPROACH_E.slots_m2s(x, y)) == pytest.approx(m2s, rel=1e-6)

    def test_no_flit_overhead_eq20(self):
        # E has no 15/16 factor (CRC/Hdr live in the 16th slot's 6 B)
        x, y = 1, 1
        smax = f(APPROACH_E.slots_max(x, y))
        assert f(APPROACH_E.bw_eff(x, y)) == pytest.approx(
            4 * (x + y) / (2 * smax), rel=1e-6)

    def test_improves_on_unopt_by_6_to_10pct(self):
        # §IV.C: "achieving 6-10% improvement over CXL.Mem (without
        # optimization)" — holds on read-dominated mixes where the extra
        # G-slot and 4-per-slot responses bite.
        for x, y in [(1, 0), (4, 1), (2, 1)]:
            gain = f(APPROACH_E.bw_eff(x, y)) / f(APPROACH_D.bw_eff(x, y))
            assert 1.05 < gain < 1.35, (x, y, gain)

    def test_command_fields_table2_opt(self):
        assert APPROACH_E.requests_per_hs == 1      # 62-bit req per 10 B HS
        assert APPROACH_E.responses_per_slot == 4   # 16-bit responses


class TestApproachC:
    """CHI on symmetric UCIe — modeled per DESIGN.md §6.2."""

    def test_granule_geometry(self):
        assert APPROACH_C.granules_per_flit == 12
        assert APPROACH_C.granule_bytes == 20
        assert APPROACH_C.capacity_fraction == pytest.approx(15 / 16)
        assert APPROACH_C.payload_efficiency == pytest.approx(4 / 5)

    @pytest.mark.parametrize("x,y", [(1, 0), (2, 1), (1, 1), (0, 1)])
    def test_chi_below_cxl(self, x, y):
        # the paper's stated ordering: CHI < CXL-unopt < CXL-opt (reads);
        # CHI always below both CXL variants
        c = f(APPROACH_C.bw_eff(x, y))
        assert c < f(APPROACH_D.bw_eff(x, y))
        assert c < f(APPROACH_E.bw_eff(x, y))


class TestBaselines:
    def test_lpddr5_published_densities(self):
        assert LPDDR5.linear_density_gbs_mm == pytest.approx(26.5, abs=0.1)
        assert LPDDR5.areal_density_gbs_mm2 == pytest.approx(15.1, abs=0.1)

    def test_lpddr6_published_densities(self):
        assert LPDDR6.linear_density_gbs_mm == pytest.approx(35.3, abs=0.1)
        assert LPDDR6.areal_density_gbs_mm2 == pytest.approx(20.2, abs=0.1)

    def test_hbm4_published_densities(self):
        assert HBM4.linear_density_gbs_mm == pytest.approx(204.8, abs=0.1)
        assert HBM4.areal_density_gbs_mm2 == pytest.approx(81.9, abs=0.1)

    def test_optimistic_bus_model(self):
        assert f(HBM4.bw_eff(3, 1)) == 1.0
        assert f(LPDDR6.p_data(0, 1)) == 1.0


class TestUCIePhy:
    def test_raw_bandwidths_section4b(self):
        # doubly-stacked UCIe-S x32 @32G = 256 GB/s; UCIe-A pair = 1024
        assert UCIE_S_32G.raw_bandwidth_gbs == 256.0
        assert UCIE_S_32G.linear_density_gbs_mm == 224.0
        assert UCIE_S_32G.areal_density_gbs_mm2 == pytest.approx(145.44)
        assert UCIE_A_32G_55U.linear_density_gbs_mm == pytest.approx(658.44)
        assert UCIE_A_32G_55U.areal_density_gbs_mm2 == pytest.approx(416.27)

    def test_frequency_scaling(self):
        s16 = UCIE_S_32G.scaled(16.0)
        assert s16.linear_density_gbs_mm == pytest.approx(112.0)
