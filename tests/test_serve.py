"""Serving engine tests: continuous batching correctness."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.models import ShardingCtx, build
from repro.serve import Request, ServingEngine

CTX = ShardingCtx()


@pytest.fixture(scope="module")
def setup():
    cfg = get("smollm-360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestServingEngine:
    def test_drains_all_requests(self, setup):
        cfg, model, params = setup
        eng = ServingEngine(model, params, CTX, batch_slots=3, max_len=64)
        for i in range(7):
            eng.submit(Request(rid=i, prompt=np.arange(3 + i) % 50,
                               max_new_tokens=5))
        done = eng.run_until_drained()
        assert sorted(r.rid for r in done) == list(range(7))
        assert all(len(r.generated) == 5 for r in done)

    def test_batched_matches_single_request(self, setup):
        """Continuous batching must not change any request's tokens."""
        cfg, model, params = setup
        prompts = [np.arange(4) % 50, (np.arange(6) * 3) % 50,
                   (np.arange(5) * 7) % 50]

        ref_gens = []
        for i, p in enumerate(prompts):
            eng = ServingEngine(model, params, CTX, batch_slots=1,
                                max_len=64)
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
            ref_gens.append(eng.run_until_drained()[0].generated)

        eng = ServingEngine(model, params, CTX, batch_slots=3, max_len=64)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        done = {r.rid: r.generated for r in eng.run_until_drained()}
        for i in range(3):
            assert done[i] == ref_gens[i], (i, done[i], ref_gens[i])

    def test_eos_frees_slot_early(self, setup):
        cfg, model, params = setup
        eng = ServingEngine(model, params, CTX, batch_slots=1, max_len=64)
        # pick eos = the first generated token of a probe run
        probe = ServingEngine(model, params, CTX, batch_slots=1, max_len=64)
        probe.submit(Request(rid=0, prompt=np.arange(4) % 50,
                             max_new_tokens=3))
        first = probe.run_until_drained()[0].generated[1]
        eng.submit(Request(rid=1, prompt=np.arange(4) % 50,
                           max_new_tokens=50, eos_id=int(first)))
        done = eng.run_until_drained()
        assert len(done[0].generated) < 50

    def test_rejects_prompt_longer_than_max_len(self, setup):
        """A prompt that cannot fit the packed KV slot must be rejected
        at submit() with a clear error, not silently corrupt the slot."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, CTX, batch_slots=2, max_len=16)
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(Request(rid=0, prompt=np.arange(16) % 50,
                               max_new_tokens=2))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(Request(rid=1, prompt=np.arange(40) % 50,
                               max_new_tokens=2))
        # the rejected requests never entered the queue; the engine still
        # serves in-range work untouched
        assert not eng.queue
        eng.submit(Request(rid=2, prompt=np.arange(8) % 50,
                           max_new_tokens=3))
        assert [r.rid for r in eng.run_until_drained()] == [2]

    def test_freed_slot_state_fully_reset(self, setup):
        """Freeing a slot must clear its position and last token — reuse
        of a slot must not inherit the previous occupant's state, and a
        recycled slot must decode exactly what a fresh engine decodes."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, CTX, batch_slots=1, max_len=64)
        eng.submit(Request(rid=0, prompt=(np.arange(9) * 5) % 50,
                           max_new_tokens=7))
        eng.run_until_drained()
        assert eng.positions[0] == 0
        assert eng.last_token[0] == 0

        probe = np.arange(4) % 50
        ref = ServingEngine(model, params, CTX, batch_slots=1, max_len=64)
        ref.submit(Request(rid=1, prompt=probe, max_new_tokens=6))
        expect = ref.run_until_drained()[0].generated
        eng.submit(Request(rid=2, prompt=probe, max_new_tokens=6))
        assert eng.run_until_drained()[-1].generated == expect

    def test_ssm_engine_round(self):
        cfg = get("mamba2-2.7b").reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, CTX, batch_slots=2, max_len=32)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=np.arange(4 + i) % 50,
                               max_new_tokens=4))
        done = eng.run_until_drained()
        assert len(done) == 3
