"""Checkpoint save/restore/elastic-reshard + fault-tolerant driver tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get
from repro.configs.shapes import ShapeSpec
from repro.models import ShardingCtx, build
from repro.runtime import DriverConfig, SimulatedFailure, StragglerMonitor, run
from repro.train import (
    AdamW, SyntheticLM, constant_schedule, init_state, make_train_step,
)

CTX = ShardingCtx()


def small_state():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": jnp.zeros((), jnp.int32)},
    }


class TestCkpt:
    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            state = small_state()
            ckpt.save(state, 3, d)
            restored, step = ckpt.restore(d, target=jax.eval_shape(
                lambda: state))
            assert step == 3
            for x, y in zip(jax.tree.leaves(state),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(x, np.float32),
                                              np.asarray(y, np.float32))

    def test_latest_and_commit_marker(self):
        with tempfile.TemporaryDirectory() as d:
            state = small_state()
            ckpt.save(state, 1, d)
            ckpt.save(state, 5, d)
            assert ckpt.latest_step(d) == 5
            # uncommitted checkpoints are ignored
            os.remove(os.path.join(d, "step_00000005", "_COMMITTED"))
            assert ckpt.latest_step(d) == 1

    def test_async_save_then_wait(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(small_state(), 0, d, asynchronous=True)
            ckpt.wait()
            assert ckpt.latest_step(d) == 0

    def test_restore_missing_raises(self):
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(FileNotFoundError):
                ckpt.restore(d, target=small_state())


class TestFaultTolerantDriver:
    def _setup(self):
        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(3e-3))
        state = init_state(model, jax.random.PRNGKey(0), opt)
        step = jax.jit(make_train_step(model, opt, CTX))
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 8, "train"))
        return state, step, lambda s: src.place(src.batch_for_step(s), CTX)

    def test_failure_restart_replays_exactly(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d:
            cfg = DriverConfig(total_steps=10, ckpt_every=3, ckpt_dir=d,
                               fail_at_steps=(5,), async_ckpt=False)
            losses = {}

            def on_step(s, m):
                if s in losses:
                    # replayed step must reproduce the identical loss
                    assert losses[s] == pytest.approx(
                        float(m["loss"]), abs=0.0)
                losses[s] = float(m["loss"])

            rep = run(step_fn, state, batch_fn, cfg, on_step=on_step)
            assert rep.restarts == 1
            assert rep.restored_steps == [2]
            # steps 3,4 replayed after restoring step 2
            assert rep.steps_run == 12

    def test_exceeding_max_restarts_raises(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d:
            cfg = DriverConfig(total_steps=6, ckpt_every=100, ckpt_dir=d,
                               fail_at_steps=(1,), max_restarts=0,
                               async_ckpt=False)
            with pytest.raises(SimulatedFailure):
                run(step_fn, state, batch_fn, cfg)

    def test_resume_from_existing_checkpoint_dir(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d:
            cfg1 = DriverConfig(total_steps=4, ckpt_every=2, ckpt_dir=d,
                                async_ckpt=False)
            run(step_fn, state, batch_fn, cfg1)
            cfg2 = DriverConfig(total_steps=8, ckpt_every=2, ckpt_dir=d,
                                async_ckpt=False)
            rep = run(step_fn, state, batch_fn, cfg2)
            assert rep.restored_steps == [3]
            assert rep.steps_run == 4          # only steps 4..7


class TestStragglerMonitor:
    def test_flags_slow_steps_and_remaps(self):
        remaps = []
        mon = StragglerMonitor(threshold=2.0, evict_after=2,
                               on_remap=remaps.append)
        for s in range(10):
            mon.observe(s, 0.1)
        assert not mon.events
        assert mon.observe(10, 0.5)
        assert mon.observe(11, 0.5)
        assert remaps == [11]
        # recovery resets the consecutive counter
        mon.observe(12, 0.1)
        assert mon.consecutive == 0

    def test_baseline_not_polluted_by_stragglers(self):
        mon = StragglerMonitor(threshold=2.0)
        for s in range(20):
            mon.observe(s, 0.1)
        mon.observe(20, 10.0)
        assert mon.ewma == pytest.approx(0.1, rel=1e-6)
