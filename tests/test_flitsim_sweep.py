"""Regression tests for the batched flit-simulation sweep engine.

The batched [P protocols, B backlogs, M mixes] grid must reproduce the
scalar simulator outputs, and identically-shaped sweeps must reuse the warm
compiled executable (no retrace).  No hypothesis dependency — these run
everywhere the bare tier-1 environment does.
"""
import numpy as np
import pytest

from repro.core import flitsim
from repro.core.flitsim import (
    ANALYTIC, ASYMMETRIC_PARAMS, CANONICAL_MIXES, SIMULATORS,
    SYMMETRIC_PARAMS, AsymmetricLaneParams, SymmetricFlitParams,
    simulate_asymmetric, simulate_lpddr6_pipelining, simulate_symmetric,
)
from repro.core.flitsim import _sweep_impl as sweep
from repro.core.flitsim import _sweep_pipelining_impl as sweep_pipelining


# Golden outputs of the SEED (pre-batching) scalar simulators at the five
# canonical mixes, captured by executing the original implementation
# (git c31bfce^..) on CPU.  The batched engine reproduces them bit-for-bit;
# the 1e-6 bound allows for backend-dependent float reassociation only.
SEED_GOLDEN = {
    "cxl_unopt": (0.41666749, 0.59208971, 0.62499517, 0.51138824,
                  0.37500000),
    "cxl_opt": (0.46875000, 0.68565327, 0.66666937, 0.54544550,
                0.40000045),
    "chi": (0.33333740, 0.47367275, 0.50005633, 0.40905342, 0.29999578),
    "lpddr6_asym": (0.43243244, 0.64880705, 0.57657659, 0.43237966,
                    0.28828830),
    "hbm_asym": (0.46376812, 0.69531268, 0.46376812, 0.34778363,
                 0.23188406),
}
SEED_GOLDEN_PIPELINING = {1: 0.25036675, 2: 0.50097847, 3: 0.75073314,
                          4: 1.0, 6: 1.0}


class TestSeedGoldenRegression:
    """The batched sweep reproduces the ORIGINAL scalar implementation's
    outputs — a true old-vs-new check, not new-vs-new."""

    def test_sweep_matches_seed_goldens(self):
        res = sweep()
        assert res.mixes == CANONICAL_MIXES
        for i, key in enumerate(res.protocols):
            np.testing.assert_allclose(
                np.asarray(res.efficiency[i]), SEED_GOLDEN[key],
                atol=1e-6, err_msg=key)

    def test_pipelining_matches_seed_goldens(self):
        ks = sorted(SEED_GOLDEN_PIPELINING)
        util = np.asarray(sweep_pipelining(ks))
        np.testing.assert_allclose(
            util, [SEED_GOLDEN_PIPELINING[k] for k in ks], atol=1e-6)


class TestBatchedMatchesScalar:
    """The batched sweep and the scalar wrappers stay consistent."""

    def test_all_protocols_all_canonical_mixes(self):
        res = sweep()       # all five SIMULATORS x five canonical mixes
        assert res.efficiency.shape == (len(SIMULATORS),
                                        len(CANONICAL_MIXES))
        assert tuple(res.protocols) == tuple(SIMULATORS)
        for i, key in enumerate(res.protocols):
            for j, (x, y) in enumerate(res.mixes):
                batched = float(res.efficiency[i, j])
                scalar = SIMULATORS[key](x, y)
                assert batched == pytest.approx(scalar, abs=1e-6), \
                    (key, x, y)

    def test_symmetric_backlog_axis(self):
        res = sweep(protocols=tuple(SYMMETRIC_PARAMS), mixes=[(2, 1)],
                    backlogs=[4, 64])
        assert res.efficiency.shape == (len(SYMMETRIC_PARAMS), 2, 1)
        for i, key in enumerate(res.protocols):
            for b, backlog in enumerate(res.backlogs):
                scalar = simulate_symmetric(SYMMETRIC_PARAMS[key], 2, 1,
                                            backlog=backlog)
                assert float(res.efficiency[i, b, 0]) == pytest.approx(
                    scalar, abs=1e-6), (key, backlog)

    def test_asymmetric_rows_backlog_invariant(self):
        res = sweep(protocols=tuple(ASYMMETRIC_PARAMS), mixes=[(1, 1)],
                    backlogs=[4, 64])
        e = np.asarray(res.efficiency)
        np.testing.assert_allclose(e[:, 0, :], e[:, 1, :], atol=0)

    def test_pipelining_batched_matches_scalar(self):
        util = np.asarray(sweep_pipelining([1, 2, 3, 4, 6]))
        for k, u in zip([1, 2, 3, 4, 6], util):
            assert float(u) == pytest.approx(
                simulate_lpddr6_pipelining(k), abs=1e-6), k

    def test_analytic_agreement(self):
        """The batched sweep stays within 2% of every closed form (the same
        bound the hypothesis property tests assert point-wise)."""
        res = sweep()
        for i, key in enumerate(res.protocols):
            for j, (x, y) in enumerate(res.mixes):
                a = float(ANALYTIC[key].bw_eff(x, y))
                assert abs(a - float(res.efficiency[i, j])) / a < 0.02, \
                    (key, x, y)


class TestCompileCache:
    def test_one_compile_per_family_and_no_retrace(self):
        flitsim.clear_compile_cache()
        sweep()
        first = flitsim.compile_cache_stats()
        assert first.misses == 2     # one symmetric + one asymmetric
        sweep()                      # identical shape -> warm executable
        second = flitsim.compile_cache_stats()
        assert second.misses == first.misses
        assert second.hits > first.hits

    def test_new_shape_compiles_once_then_caches(self):
        flitsim.clear_compile_cache()
        mixes = [(1, 0), (1, 1)]
        sweep(mixes=mixes)
        sweep(mixes=mixes)
        stats = flitsim.compile_cache_stats()
        assert stats.misses == 2 and stats.hits == 2

    def test_scalar_wrappers_share_cache(self):
        flitsim.clear_compile_cache()
        simulate_symmetric(SymmetricFlitParams.cxl_opt(), 2, 1)
        simulate_symmetric(SymmetricFlitParams.chi(), 1, 1)
        simulate_asymmetric(AsymmetricLaneParams.hbm(), 1, 0)
        simulate_asymmetric(AsymmetricLaneParams.lpddr6(), 0, 1)
        stats = flitsim.compile_cache_stats()
        assert stats.misses == 2 and stats.hits == 2


class TestSweepAPI:
    def test_traffic_mix_objects_accepted(self):
        from repro.core import TrafficMix
        res = sweep(protocols=["cxl_opt"],
                    mixes=[TrafficMix(2, 1), (1, 1)])
        assert res.efficiency.shape == (1, 2)
        assert res.mixes == ((2.0, 1.0), (1.0, 1.0))

    def test_for_protocol(self):
        res = sweep(protocols=["chi", "hbm_asym"])
        np.testing.assert_array_equal(np.asarray(res.for_protocol("chi")),
                                      np.asarray(res.efficiency[0]))

    def test_unknown_protocol_rejected(self):
        with pytest.raises(ValueError, match="unknown protocol"):
            sweep(protocols=["nope"])

    def test_empty_inputs_rejected(self):
        with pytest.raises(ValueError, match="at least one protocol"):
            sweep(protocols=[])
        with pytest.raises(ValueError, match="at least one traffic mix"):
            sweep(mixes=[])

    def test_numpy_backlogs_accepted(self):
        res = sweep(protocols=["chi"], mixes=[(1, 1)],
                    backlogs=np.array([8.0, 64.0]))
        assert res.efficiency.shape == (1, 2, 1)
        assert res.backlogs == (8.0, 64.0)

    def test_degenerate_mix_rejected(self):
        with pytest.raises(ValueError, match="invalid traffic mix"):
            sweep(mixes=[(0, 0)])
        with pytest.raises(ValueError, match="invalid traffic mix"):
            simulate_symmetric(SymmetricFlitParams.chi(), 0, 0)
        with pytest.raises(ValueError, match="invalid traffic mix"):
            simulate_asymmetric(AsymmetricLaneParams.hbm(), -1, 2)

    def test_param_stacking_roundtrip(self):
        stack = SymmetricFlitParams.stack(
            [SymmetricFlitParams.cxl_unopt(), SymmetricFlitParams.chi()])
        assert stack.g_slots.shape == (2,)
        assert float(stack.g_slots[1]) == 12.0
