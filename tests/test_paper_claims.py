"""End-to-end checks of the paper's headline claims (§Abstract, §IV.C)."""
import numpy as np
import pytest

from repro.core import (
    ALL_APPROACHES, APPROACH_A, APPROACH_B, APPROACH_D, APPROACH_E, HBM4,
    LPDDR6, PAPER_MIXES, TrafficMix, UCIE_A_32G_55U, UCIE_S_32G,
    latency_speedup, rank, best, SelectionConstraints,
)


def f(v):
    return float(np.asarray(v))


class TestHeadlines:
    def test_up_to_10x_bandwidth_density(self):
        """Abstract: 'significantly higher bandwidth density (up to 10x)'.

        Best UCIe-A approach vs LPDDR6 across mixes exceeds 10x linear.
        """
        gains = []
        for m in PAPER_MIXES:
            e = f(APPROACH_E.bw_density_linear(m.x, m.y, UCIE_A_32G_55U))
            gains.append(e / LPDDR6.linear_density_gbs_mm)
        assert max(gains) > 10.0

    def test_up_to_3x_latency(self):
        sp = latency_speedup()
        assert max(sp.values()) == pytest.approx(2.5)   # "up to 3x"
        assert min(sp.values()) >= 2.0

    def test_up_to_3x_power(self):
        """Abstract: 'lower power (up to 3x)' vs HBM4's 0.9 pJ/b."""
        ratios = []
        for m in PAPER_MIXES:
            pj = f(APPROACH_E.power_pj_per_bit(m.x, m.y, UCIE_A_32G_55U))
            ratios.append(HBM4.pj_per_bit / pj)
        assert max(ratios) > 2.4
        assert max(ratios) < 4.0   # sane upper bound

    def test_ucie_a_beats_hbm4_all_metrics_fig10(self):
        """§IV.C: UCIe-A approaches 'substantially outperform HBM4 with the
        same bump-pitch (55u), across all three metrics'."""
        for m in PAPER_MIXES:
            if m.x == 0:   # 100%W is the known asym-approach worst case;
                continue   # figures sweep read-bearing mixes
            e_lin = f(APPROACH_E.bw_density_linear(m.x, m.y, UCIE_A_32G_55U))
            e_areal = f(APPROACH_E.bw_density_areal(m.x, m.y, UCIE_A_32G_55U))
            e_pj = f(APPROACH_E.power_pj_per_bit(m.x, m.y, UCIE_A_32G_55U))
            assert e_lin > HBM4.linear_density_gbs_mm, m.name
            assert e_areal > HBM4.areal_density_gbs_mm2, m.name
            assert e_pj < HBM4.pj_per_bit, m.name

    def test_ucie_s_beats_lpddr6_all_mixes_fig11(self):
        """§IV.C: UCIe-S 'outperform LPDDR6 across all metrics and traffic
        mixes'."""
        for m in PAPER_MIXES:
            for key, proto in ALL_APPROACHES.items():
                lin = f(proto.bw_density_linear(m.x, m.y, UCIE_S_32G))
                assert lin > LPDDR6.linear_density_gbs_mm, (key, m.name)

    def test_ucie_s_power_within_10_to_20pct_of_hbm4(self):
        """§IV.C: UCIe-S optimized CXL power comes 'close to HBM4 across all
        workloads (e.g., 10-20%)'."""
        worst = 0.0
        for m in PAPER_MIXES:
            pj = f(APPROACH_E.power_pj_per_bit(m.x, m.y, UCIE_S_32G))
            worst = max(worst, pj / HBM4.pj_per_bit)
        # read-bearing mixes stay within ~1.2x; pure-write is the outlier
        mids = [m for m in PAPER_MIXES if m.x > 0]
        for m in mids:
            pj = f(APPROACH_E.power_pj_per_bit(m.x, m.y, UCIE_S_32G))
            assert pj < 1.35 * HBM4.pj_per_bit, m.name

    def test_asym_power_converges_to_sym_as_reads_increase(self):
        """§IV.C claims asym mappings edge out optimized CXL.Mem on power as
        read percentage increases (fine-grained lane-group gating).  With
        our derived Approach-B command-power assumptions the asym mappings
        come within ~3% but do not strictly cross (DESIGN.md §6.10); we
        assert the paper's *mechanism*: the gap narrows monotonically with
        read fraction and stays small at the read-heavy end."""
        mixes = [TrafficMix(1, 1), TrafficMix(2, 1), TrafficMix(4, 1),
                 TrafficMix(9, 1)]
        ratios = []
        for m in mixes:
            pj_asym = f(APPROACH_B.power_pj_per_bit(m.x, m.y, UCIE_A_32G_55U))
            pj_sym = f(APPROACH_E.power_pj_per_bit(m.x, m.y, UCIE_A_32G_55U))
            ratios.append(pj_asym / pj_sym)
        assert all(a >= b - 1e-6 for a, b in zip(ratios, ratios[1:])), ratios
        assert ratios[-1] < 1.05, ratios

    def test_best_overall_is_cxl_opt_fig_conclusion(self):
        """§IV.C: 'CXL.Mem with optimization on symmetric UCIe offers the
        best power-efficient performance' among the symmetric/logic-die
        approaches — and the best raw bandwidth density of all of them on
        the canonical mixes."""
        for m in [TrafficMix(1, 1), TrafficMix(1, 2), TrafficMix(0, 1)]:
            effs = {k: f(p.bw_eff(m.x, m.y)) for k, p in ALL_APPROACHES.items()}
            assert max(effs, key=effs.get) == "E:cxl-mem-opt", (m.name, effs)

    def test_selector_prefers_ucie_over_incumbents(self):
        r = best(TrafficMix(2, 1), objective="bandwidth")
        assert "UCIe" in r.key or ":" in r.key
        ranked = rank(TrafficMix(2, 1), objective="bandwidth")
        names = [x.key for x in ranked]
        assert names.index("HBM4") > 0          # some UCIe approach wins
        # every UCIe-A approach out-ranks LPDDR6
        lp = names.index("LPDDR6")
        for key in ALL_APPROACHES:
            assert names.index(f"{key}/UCIe-A") < lp

    def test_selector_constraints(self):
        c = SelectionConstraints(packaging="UCIe-S",
                                 max_relative_bit_cost=2.0)
        r = best(TrafficMix(2, 1), constraints=c, objective="gbs_per_watt")
        assert "UCIe-S" in r.key
        assert r.relative_bit_cost <= 2.0
