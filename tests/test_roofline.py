"""Tests for the loop-weighted HLO cost model and the memsys bridge."""
import subprocess
import sys
import os
import textwrap

import numpy as np
import pytest

from repro.core import TrafficMix
from repro.roofline.analysis import RooflineReport, memsys_bridge
from repro.roofline.hlo_parse import HloCostModel

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lower_hlo(body: str, devices: int = 8) -> str:
    """Compile a small sharded program in a subprocess; return HLO text."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr
    return out.stdout


class TestHloCostModel:
    @pytest.fixture(scope="class")
    def scan_hlo(self):
        return _lower_hlo("""
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        lowered = jax.jit(f, in_shardings=(
            jax.NamedSharding(mesh, P("data", None)),
            jax.NamedSharding(mesh, P(None, "model")))).lower(xs, ws)
        print(lowered.compile().as_text())
        """)

    def test_loop_weighted_flops(self, scan_hlo):
        m = HloCostModel(scan_hlo)
        met = m.metrics()
        # per device: 7 iterations x 2*32*256*64 (batch/2, out 256/4)
        expect = 7 * 2 * 32 * 256 * 64
        assert met.flops == pytest.approx(expect, rel=0.01), met.flops

    def test_loop_weighted_collectives(self, scan_hlo):
        m = HloCostModel(scan_hlo)
        met = m.metrics()
        # all-gather of x shard [32, 64] f32 over model, once per iteration
        expect = 7 * 32 * 64 * 4
        assert met.collective_bytes == pytest.approx(expect, rel=0.25), \
            met.collective_bytes

    def test_bytes_reasonable(self, scan_hlo):
        m = HloCostModel(scan_hlo)
        met = m.metrics()
        # weights read (256*64 f32) + act read/write per iteration, x7;
        # must be within a small factor of the analytic expectation
        analytic = 7 * (256 * 64 + 2 * 32 * 64 + 32 * 256) * 4
        assert analytic * 0.3 < met.bytes_accessed < analytic * 6, (
            met.bytes_accessed, analytic)

    def test_trip_count_parsing(self, scan_hlo):
        m = HloCostModel(scan_hlo)
        trips = [i.trip for comp in m.comps.values() for i in comp
                 if i.opcode == "while"]
        assert 7 in trips


class TestMemsysBridge:
    def test_bridge_structure_and_ordering(self):
        rep = RooflineReport(
            arch="x", shape="train_4k", mesh="16x16", chips=256,
            hlo_flops_per_chip=1e12, hlo_bytes_per_chip=1e10,
            collective_bytes_per_chip=1e9, compute_s=5e-3, memory_s=1.2e-2,
            collective_s=2e-2, dominant="collective", model_flops=2e14,
            useful_flops_ratio=0.8, read_bytes_per_chip=7e9,
            write_bytes_per_chip=3e9)
        br = memsys_bridge(rep)
        assert 0 < br["read_fraction"] < 1
        systems = br["systems"]
        assert any("E:cxl-mem-opt" in k for k in systems)
        # UCIe-A systems must beat the LPDDR6 bus on memory term
        lp = systems["LPDDR6"]["memory_term_s"]
        e_a = systems["E:cxl-mem-opt/UCIe-A"]["memory_term_s"]
        assert e_a < lp

    def test_mix_from_byte_counts(self):
        m = TrafficMix.from_bytes(700e9, 300e9)
        assert m.read_fraction == pytest.approx(0.7)
        assert m.x + m.y == pytest.approx(100.0)
