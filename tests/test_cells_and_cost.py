"""Coverage for the cell machinery (arch × shape matrix) and the cost
model — no compilation, pure metadata."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ALL_SHAPES, SHAPES, arch_ids, applicable, get, microbatches_for,
)
from repro.core import TrafficMix, cost
from repro.core.selector import SelectionConstraints, best, rank
from repro.models import ShardingCtx, build

CTX = ShardingCtx()


class TestCellMatrix:
    def test_40_cells_accounted(self):
        runnable, skipped = 0, 0
        for arch in arch_ids():
            cfg = get(arch)
            for shape in ALL_SHAPES:
                ok, why = applicable(cfg, shape)
                if ok:
                    runnable += 1
                else:
                    skipped += 1
                    assert shape.name == "long_500k"
                    assert "sub-quadratic" in why
        assert runnable == 32 and skipped == 8
        assert runnable + skipped == 40

    def test_long_500k_runs_only_for_subquadratic(self):
        ok_archs = [a for a in arch_ids()
                    if applicable(get(a), SHAPES["long_500k"])[0]]
        assert sorted(ok_archs) == ["mamba2-2.7b", "recurrentgemma-2b"]

    @pytest.mark.parametrize("arch", arch_ids())
    def test_input_specs_shapes(self, arch):
        cfg = get(arch)
        model = build(cfg)
        for shape in ALL_SHAPES:
            if not applicable(cfg, shape)[0]:
                continue
            specs = model.input_specs(shape)
            if shape.kind == "train":
                assert "labels" in specs
                total = specs["tokens"].shape[1]
                if cfg.frontend == "vision":
                    total += specs["patch_embeds"].shape[1]
                if not cfg.is_encdec:
                    assert total == shape.seq_len
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
                assert "caches" in specs
                leaves = jax.tree.leaves(specs["caches"])
                assert leaves, arch

    @pytest.mark.parametrize("arch", arch_ids())
    def test_decode_cache_budget(self, arch):
        """Decode caches fit the HBM budget once sharded over 256 chips."""
        cfg = get(arch)
        model = build(cfg)
        specs = model.input_specs(SHAPES["decode_32k"])
        total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree.leaves(specs["caches"]))
        per_chip = total / 256
        assert per_chip < 12e9, (arch, per_chip / 1e9)

    def test_microbatch_defaults(self):
        tr = SHAPES["train_4k"]
        assert microbatches_for(get("mistral-large-123b"), tr, 16) == 16
        assert microbatches_for(get("smollm-360m"), tr, 16) == 4
        assert microbatches_for(get("smollm-360m"), SHAPES["decode_32k"],
                                16) == 1

    def test_active_params_moe(self):
        cfg = get("olmoe-1b-7b")
        assert cfg.active_param_count() < cfg.param_count() * 0.35

    def test_paper_flops_scale(self):
        # mistral train_4k: 6 N D ~ 7.7e17 global
        cfg = get("mistral-large-123b")
        d = SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
        assert 6.0 * cfg.active_param_count() * d == pytest.approx(
            7.7e17, rel=0.05)


class TestCostModelAndSelector:
    def test_reference_systems_ranking(self):
        systems = {s.name: s for s in cost.reference_systems()}
        # wire-bonded LPDDR6 over UCIe-S is the cheapest per GB/s;
        # native HBM4 is the most expensive per GB
        per_gb = {k: s.cost_per_gb() for k, s in systems.items()}
        assert per_gb["HBM4(native)"] == max(per_gb.values())
        assert per_gb["LPDDR6(native)"] < per_gb["HBM4(native)"] / 4

    def test_cost_param_sensitivity(self):
        p_cheap_hbm = cost.CostParams(hbm_bit_cost=5.0)
        p_dear_hbm = cost.CostParams(hbm_bit_cost=10.0)
        s = cost.reference_systems()[0]         # HBM4 native
        assert s.relative_cost(p_dear_hbm) > s.relative_cost(p_cheap_hbm)

    def test_rank_objectives_consistent(self):
        mix = TrafficMix(2, 1)
        by_bw = rank(mix, objective="bandwidth")
        by_pw = rank(mix, objective="power")
        assert by_bw[0].bandwidth_gbs == max(r.bandwidth_gbs for r in by_bw)
        assert by_pw[0].pj_per_bit == min(r.pj_per_bit for r in by_pw)

    def test_power_cap_constraint(self):
        mix = TrafficMix(2, 1)
        unc = best(mix, objective="bandwidth")
        capped = best(mix, constraints=SelectionConstraints(
            max_power_w=unc.power_w * 0.5), objective="bandwidth")
        assert capped.power_w <= unc.power_w * 0.5
        assert capped.bandwidth_gbs <= unc.bandwidth_gbs

    def test_latency_objective_prefers_ucie(self):
        r = best(TrafficMix(1, 1), objective="latency")
        assert r.latency_ns == 3.0


class TestRankGrid:
    """Batched whole-catalog ranking over dense mix grids."""

    def test_matches_scalar_rank_per_point(self):
        from repro.core.selector import _rank_grid_impl as rank_grid
        from repro.core.traffic import mix_grid
        x, y = mix_grid(11)
        g = rank_grid(x, y, objective="bandwidth")
        keys = g.best_keys()
        for j in range(11):
            scalar_best = rank(TrafficMix(float(x[j]), float(y[j])),
                               objective="bandwidth")[0].key
            assert keys[j] == scalar_best, j

    def test_infeasible_points_marked_not_misreported(self):
        from repro.core.selector import _rank_grid_impl as rank_grid
        from repro.core.traffic import mix_grid
        x, y = mix_grid(5)
        g = rank_grid(x, y, SelectionConstraints(
            required_bandwidth_gbs=1e12))
        assert not bool(jnp.any(g.valid))
        assert np.all(np.asarray(g.best_index) == -1)
        assert set(g.best_keys().tolist()) == {"(none)"}
