"""Fused flit-simulator kernels (repro.kernels.flit_sim) + the
``SimConfig(engine="pallas")`` execution path and the period-exact
asymmetric convergence detector.

Contracts:

  * the Pallas kernels (interpret mode on CPU — the exact kernel bodies
    traced to XLA) agree with the jnp reference computes bit-for-bit,
    and the reference computes are what the XLA engine itself runs.
  * ``engine="pallas"`` tracks the XLA adaptive engine to float-noise
    and the fixed engine within the adaptive 1e-3 contract, for all
    three simulator families, with identical design-space winners.
  * the period detector finds a period that DIVIDES the true rational
    credit period ``(x + y) / gcd(x, y)``, and its ~2-period
    extrapolated report matches the full-horizon fixed engine to 1e-6.
  * the symmetric period detector (PR 10) certifies an exact f32
    pool-state period over a short observation window and extrapolates
    the warm-window delivery sum BITWISE to the fixed horizon; grids it
    cannot certify (saturated backlogs) fall back to the chunked core.
  * ``last_run_info()`` reports the engine, launch count and retired
    cycle rate; the periodic run adds the detected-period histogram.

Everything here is deterministic — the hypothesis property test at the
bottom is skipped (not the module) when hypothesis is missing, so this
coverage exists in the bare container unlike the flash/ssd kernel suite.
"""
from fractions import Fraction

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import flitsim
from repro.core.flitsim import (
    ADAPTIVE_SIM, ASYMMETRIC_PARAMS, FIXED_SIM, PALLAS_SIM,
    SYMMETRIC_PARAMS, AsymmetricLaneParams, SimConfig,
    SymmetricFlitParams,
)
from repro.core.flitsim import _sweep_impl as sweep
from repro.core.flitsim import _sweep_pipelining_impl as sweep_pipelining
from repro.core.traffic import mix_grid
from repro.kernels.flit_sim import kernel as fs_kernel
from repro.kernels.flit_sim import ops as fs_ops
from repro.kernels.flit_sim import ref as fs_ref


def _dense_mixes(n=13):
    fr = np.linspace(0.0, 1.0, n)
    return list(zip((100.0 * fr).tolist(), (100.0 - 100.0 * fr).tolist()))


class TestEngineConfig:
    def test_engine_validation(self):
        with pytest.raises(ValueError, match="engine"):
            SimConfig(engine="cuda")
        with pytest.raises(ValueError, match="adaptive"):
            SimConfig(mode="fixed", engine="pallas")

    def test_engine_in_cache_key(self):
        assert PALLAS_SIM.key() != ADAPTIVE_SIM.key()
        assert "pallas" in PALLAS_SIM.key()
        # fixed keys stay pinned — the goldens' cache entries survive
        assert FIXED_SIM.key() == ("fixed",)

    def test_engines_do_not_evict_each_other(self):
        flitsim.clear_compile_cache()
        mixes = [(3, 2), (1, 1)]
        sweep(mixes=mixes, sim=ADAPTIVE_SIM)
        sweep(mixes=mixes, sim=PALLAS_SIM)
        misses = flitsim.compile_cache_stats().misses
        sweep(mixes=mixes, sim=ADAPTIVE_SIM)
        sweep(mixes=mixes, sim=PALLAS_SIM)
        assert flitsim.compile_cache_stats().misses == misses


class TestKernelMatchesRef:
    """interpret=True pallas_call vs the shared jnp compute — the
    BlockSpec/grid plumbing must be value-neutral."""

    def _asym_rows(self, n_mixes=25):
        gx, gy = mix_grid(n_mixes)
        pstack = AsymmetricLaneParams.stack(
            [ASYMMETRIC_PARAMS[k] for k in ("lpddr6_asym", "hbm_asym")])
        rows = flitsim._asym_param_rows(pstack, jnp.asarray(gx),
                                        jnp.asarray(gy))
        return rows, 2 * n_mixes

    def test_asymmetric_periodic_bit_exact(self):
        rows, cells = self._asym_rows()
        tile, cpad = fs_ops.tile_for(cells)
        padded = fs_ops.pad_cells(rows, cpad)
        out_k = fs_kernel.asymmetric_periodic(padded, n_accesses=4096,
                                              tile=tile, interpret=True)
        out_r = fs_ref.asymmetric_periodic_compute(padded, n_accesses=4096)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def _sym_rows(self, backlogs=(1.0, 1.5, 2.0), n_mixes=9):
        gx, gy = mix_grid(n_mixes)
        pstack = SymmetricFlitParams.stack(
            [SYMMETRIC_PARAMS[k] for k in ("cxl_opt", "chi")])
        rows = flitsim._sym_param_rows(
            pstack, jnp.asarray(gx), jnp.asarray(gy),
            jnp.asarray(backlogs, jnp.float32))
        return rows, 2 * len(backlogs) * n_mixes

    def test_symmetric_periodic_bit_exact(self):
        rows, cells = self._sym_rows()
        tile, cpad = fs_ops.tile_for(cells, fs_ops.SYM_PERIODIC_MAX_TILE)
        padded = fs_ops.pad_cells(rows, cpad)
        out_k = fs_kernel.symmetric_periodic(padded, n_flits=2048,
                                             tile=tile, interpret=True)
        out_r = fs_ref.symmetric_periodic_compute(padded, n_flits=2048)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_pad_cells_replicates_cell_zero(self):
        rows = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        padded = fs_ops.pad_cells(rows, 6)
        assert padded.shape == (2, 6)
        np.testing.assert_array_equal(np.asarray(padded[:, 4:]),
                                      np.asarray(rows[:, :1]).repeat(2, 1))

    def test_tile_for_lane_aligned(self):
        for cells in (1, 127, 128, 129, 50, 9000, 1_000_072):
            tile, pad = fs_ops.tile_for(cells)
            assert pad >= cells and pad % tile == 0
            assert tile % fs_kernel.LANE == 0 or tile == pad


class TestPallasEngineMatches:
    def test_symmetric_family(self):
        mixes = _dense_mixes()
        kw = dict(protocols=("cxl_unopt", "cxl_opt", "chi"), mixes=mixes,
                  backlogs=[2.0, 8.0, 64.0])
        f = np.asarray(sweep(**kw).efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM, **kw).efficiency)
        p = np.asarray(sweep(sim=PALLAS_SIM, **kw).efficiency)
        assert float(np.max(np.abs(p - f))) <= 1e-3
        # the engines share the report math — only op scheduling differs
        assert float(np.max(np.abs(p - a))) <= 1e-5
        info = flitsim.last_run_info()["flitsim.symmetric"]
        assert info["engine"] == "pallas"
        assert info["launches"] >= info["cycles_run"] // info["chunk"]

    def test_asymmetric_family_period_exact(self):
        mixes = _dense_mixes(25)
        kw = dict(protocols=("lpddr6_asym", "hbm_asym"), mixes=mixes)
        f = np.asarray(sweep(**kw).efficiency)
        for sim in (ADAPTIVE_SIM, PALLAS_SIM):
            a = np.asarray(sweep(sim=sim, **kw).efficiency)
            # rational mixes: the periodic extrapolation is EXACT, not
            # merely within the adaptive tolerance
            np.testing.assert_allclose(a, f, atol=1e-6)
            info = flitsim.last_run_info()["flitsim.asymmetric"]
            assert info["engine"] == sim.engine
            assert info["cycles_run"] == fs_ref.PERIOD_OBS
            assert info["periods"]

    def test_pipelining_family(self):
        kw = dict(ucie_line_ui=(8.0, 16.0), device_line_ui=(32.0, 64.0))
        f = np.asarray(sweep_pipelining((1, 2, 3, 4), **kw))
        a = np.asarray(sweep_pipelining((1, 2, 3, 4), sim=ADAPTIVE_SIM,
                                        **kw))
        p = np.asarray(sweep_pipelining((1, 2, 3, 4), sim=PALLAS_SIM,
                                        **kw))
        assert float(np.max(np.abs(p - f))) <= 1e-3
        np.testing.assert_array_equal(p, a)

    def test_identical_winner_labels(self):
        mixes = _dense_mixes(21)
        f = np.asarray(sweep(mixes=mixes).efficiency)
        p = np.asarray(sweep(mixes=mixes, sim=PALLAS_SIM).efficiency)
        np.testing.assert_array_equal(f.argmax(axis=0), p.argmax(axis=0))

    def test_run_info_telemetry_fields(self):
        sweep(mixes=[(2, 1), (1, 1)], sim=PALLAS_SIM)
        for fam, v in flitsim.last_run_info().items():
            if v.get("mode") != "adaptive":    # trace-scan runs ride along
                continue
            assert v["engine"] == "pallas", fam
            assert v["launches"] >= 1
            assert v["elapsed_s"] > 0.0
            assert v["cycles_per_sec_per_cell"] > 0.0


def _true_period(x, y):
    """Exact credit period: the reduced denominator of x / (x + y)."""
    if x + y == 0:
        return 1
    return Fraction(x / (x + y)).limit_denominator(4096).denominator


class TestPeriodDetector:
    def test_detected_period_divides_true_period(self):
        gx, gy = mix_grid(41)          # denominators divide 40 < PERIOD_MAX
        rows, cells = TestKernelMatchesRef()._asym_rows(41)
        out = np.asarray(
            fs_ref.asymmetric_periodic_compute(rows, n_accesses=4096))
        assert (out[1, :cells] > 0.5).all(), "i/40 grid must fully detect"
        periods = out[2, :cells].astype(int).reshape(2, -1)
        for j, (x, y) in enumerate(zip(np.asarray(gx), np.asarray(gy))):
            t = _true_period(float(x), float(y))
            for prot_row in periods:
                assert t % int(prot_row[j]) == 0, (x, y, t, prot_row[j])

    def test_two_period_report_matches_full_horizon(self):
        mixes = _dense_mixes(25)
        kw = dict(protocols=("lpddr6_asym", "hbm_asym"), mixes=mixes,
                  n_accesses=4096)
        full = np.asarray(sweep(**kw).efficiency)
        peri = np.asarray(sweep(sim=ADAPTIVE_SIM, **kw).efficiency)
        np.testing.assert_allclose(peri, full, atol=1e-6)
        info = flitsim.last_run_info()["flitsim.asymmetric"]
        assert info["stragglers"] == 0          # i/24 grid fully detects
        assert info["cycles_run"] == fs_ref.PERIOD_OBS

    def test_aperiodic_grid_falls_back_to_chunked_core(self):
        # irrational-ish mixes (large prime ratios): periods exceed
        # PERIOD_MAX for most cells -> the periodic cut must decline and
        # the chunked adaptive core must still honor the 1e-3 contract
        mixes = [(97, 31), (89, 53), (83, 71), (101, 97), (67, 61)]
        kw = dict(protocols=("lpddr6_asym", "hbm_asym"), mixes=mixes)
        f = np.asarray(sweep(**kw).efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM, **kw).efficiency)
        assert float(np.max(np.abs(a - f))) <= 1e-3
        info = flitsim.last_run_info()["flitsim.asymmetric"]
        assert "periods" not in info     # chunked core, not the detector

    def test_partial_detection_escalates_exactly(self):
        # small-denominator mixes (detected) mixed with prime-ratio ones
        # (undetected, below the fall-back fraction): the undetected
        # cells re-run the exact fixed path, so the whole grid is exact
        mixes = ([(i, 40 - i) for i in range(0, 36, 4)]
                 + [(97, 31), (89, 53)])
        kw = dict(protocols=("lpddr6_asym", "hbm_asym"), mixes=mixes)
        f = np.asarray(sweep(**kw).efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM, **kw).efficiency)
        info = flitsim.last_run_info()["flitsim.asymmetric"]
        if "periods" in info and info["stragglers"]:
            np.testing.assert_allclose(a, f, atol=1e-6)
            assert info["launches"] == 2
        else:       # chunked fall-back still honors the engine contract
            assert float(np.max(np.abs(a - f))) <= 1e-3


class TestSymmetricPeriodicDetector:
    """PR 10: exact-state symmetric period certificate + bitwise
    warm-window extrapolation, with chunked-core fall-back."""

    LOW = dict(protocols=tuple(SYMMETRIC_PARAMS),
               mixes=_dense_mixes(9), backlogs=[1.0, 1.5, 2.0])

    def test_low_backlog_grid_bitwise_vs_fixed(self):
        f = np.asarray(sweep(**self.LOW).efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM, **self.LOW).efficiency)
        np.testing.assert_array_equal(a, f)     # BITWISE, not approx
        info = flitsim.last_run_info()["flitsim.symmetric"]
        assert info["cycles_run"] == fs_ref.SYM_PERIOD_OBS
        assert "periods" in info
        assert sum(info["periods"].values()) + info["stragglers"] == \
            3 * 3 * 9

    def test_pallas_engine_bitwise_vs_fixed(self):
        f = np.asarray(sweep(**self.LOW).efficiency)
        p = np.asarray(sweep(sim=PALLAS_SIM, **self.LOW).efficiency)
        np.testing.assert_array_equal(p, f)
        info = flitsim.last_run_info()["flitsim.symmetric"]
        assert info["engine"] == "pallas"
        assert info["cycles_run"] == fs_ref.SYM_PERIOD_OBS

    def test_saturated_grid_falls_back_to_chunked_core(self):
        # saturated pools re-round the proportional split every cycle,
        # so the exact-state certificate cannot fire; the detector must
        # decline and the chunked core must honor its 1e-3 contract
        kw = dict(protocols=tuple(SYMMETRIC_PARAMS),
                  mixes=_dense_mixes(9), backlogs=[8.0, 64.0])
        f = np.asarray(sweep(**kw).efficiency)
        for sim in (ADAPTIVE_SIM, PALLAS_SIM):
            a = np.asarray(sweep(sim=sim, **kw).efficiency)
            assert float(np.max(np.abs(a - f))) <= 1e-3
            info = flitsim.last_run_info()["flitsim.symmetric"]
            assert "periods" not in info    # chunked core, not detector
            assert info["cycles_run"] > fs_ref.SYM_PERIOD_OBS

    def test_short_horizon_skips_detector(self):
        # the observation window must fit inside the pre-warm quarter of
        # the horizon: 96 // 4 < SYM_PERIOD_OBS, so the gate declines
        kw = dict(self.LOW, n_flits=96)
        f = np.asarray(sweep(**kw).efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM, **kw).efficiency)
        assert float(np.max(np.abs(a - f))) <= 1e-3
        assert "periods" not in flitsim.last_run_info()["flitsim.symmetric"]


class TestPeriodDetectorHypothesis:
    """Property form of the divides-true-period law (needs hypothesis;
    the deterministic 41-mix grid above covers the bare container)."""

    def test_random_rational_mixes(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=25, deadline=None)
        @given(x=st.integers(0, 24), y=st.integers(0, 24))
        def inner(x, y):
            if x + y == 0 or _true_period(x, y) > fs_ref.PERIOD_MAX:
                return
            pstack = AsymmetricLaneParams.stack(
                [AsymmetricLaneParams.lpddr6()])
            rows = flitsim._asym_param_rows(
                pstack, jnp.asarray([float(x)]), jnp.asarray([float(y)]))
            out = np.asarray(fs_ref.asymmetric_periodic_compute(
                rows, n_accesses=4096))
            assert out[1, 0] > 0.5, (x, y)
            assert _true_period(x, y) % int(out[2, 0]) == 0, (x, y)

        inner()
