"""Tests for the axes-first DesignSpace API and its shared compile cache.

Four contracts:

  * ONE evaluation covering [configs x catalog x mixes x backlogs x
    shorelines] compiles exactly once per engine family (shared-cache
    counters), and the retired front-ends' ``_*_impl`` engines
    (``_sweep_impl``, ``_catalog_grid_impl``, ``_rank_grid_impl``) run
    WARM against a space-primed cache.
  * The unified API reproduces the pinned seed goldens <= 1e-6 and is
    bit-identical to the ``_*_impl`` engines (same executables).
  * The new capabilities work: per-mix backlog knees along the bridge's
    configs axis, the joint (k x ucie_line_ui x device_line_ui)
    pipelining sweep, protocol-parameter perturbations, and the joint
    analytic-vs-simulated frontier with its disagreement report.
  * Named-axis queries (sel / isel / argbest / frontier) behave.
"""
import numpy as np
import pytest

from repro.core import flitsim
from repro.core import space as space_mod
from repro.core.flitsim import CANONICAL_MIXES
from repro.core.flitsim import _sweep_impl as sweep
from repro.core.flitsim import _sweep_pipelining_impl as sweep_pipelining
from repro.core.memsys import _catalog_grid_impl as catalog_grid
from repro.core.selector import SelectionConstraints
from repro.core.selector import _rank_grid_impl as rank_grid
from repro.core.space import (
    OWN_MIX, AxisSet, DesignSpace, axis, joint_frontier, regimes,
)
from repro.core.traffic import TrafficMix
from repro.roofline.analysis import RooflineReport, bridge_design_space


# Spot rows of the SEED (pre-batching) scalar-simulator goldens at the
# canonical mixes — the full pinned set lives in tests/test_flitsim_sweep.py;
# the axes-first path must reproduce the same numbers <= 1e-6.
SEED_GOLDEN_SPOT = {
    "cxl_opt": (0.46875000, 0.68565327, 0.66666937, 0.54544550, 0.40000045),
    "lpddr6_asym": (0.43243244, 0.64880705, 0.57657659, 0.43237966,
                    0.28828830),
}


def _report(read, write, hlo_bytes=1e10):
    return RooflineReport(
        arch="w", shape="s", mesh="16x16", chips=256,
        hlo_flops_per_chip=1e12, hlo_bytes_per_chip=hlo_bytes,
        collective_bytes_per_chip=1e9, compute_s=5e-3, memory_s=1.2e-2,
        collective_s=2e-2, dominant="memory", model_flops=2e14,
        useful_flops_ratio=0.8, read_bytes_per_chip=read,
        write_bytes_per_chip=write)


class TestAxes:
    def test_mix_axis_normalization_and_labels(self):
        ax = axis("mix", [TrafficMix(2, 1), (1, 1), OWN_MIX])
        assert ax.values == ((2.0, 1.0), (1.0, 1.0), OWN_MIX)
        assert ax.labels == ("2R1W", "1R1W", OWN_MIX)
        assert ax.index((2, 1)) == 0 and ax.index("1R1W") == 1

    def test_invalid_mix_rejected(self):
        with pytest.raises(ValueError, match="invalid traffic mix"):
            axis("mix", [(0, 0)])

    def test_read_fraction_bounds(self):
        with pytest.raises(ValueError, match="outside"):
            axis("read_fraction", [1.5])

    def test_unknown_axis_name(self):
        with pytest.raises(ValueError, match="unknown axis name"):
            axis("nope", [1])

    def test_empty_axis(self):
        with pytest.raises(ValueError, match="at least one value"):
            axis("backlog", [])

    def test_duplicate_and_exclusive_axes(self):
        with pytest.raises(ValueError, match="duplicate"):
            AxisSet(axis("backlog", [1]), axis("backlog", [2]))
        with pytest.raises(ValueError, match="mutually"):
            AxisSet(axis("mix", [(1, 1)]), axis("read_fraction", [0.5]))

    def test_axisset_canonical_order(self):
        s = AxisSet(axis("shoreline_mm", [8.0]), axis("backlog", [4]),
                    axis("mix", [(1, 1)]))
        assert s.names == ("backlog", "mix", "shoreline_mm")

    def test_own_mix_requires_configs(self):
        with pytest.raises(ValueError, match="workload_config"):
            DesignSpace([axis("mix", [OWN_MIX])])

    def test_workload_config_from_report(self):
        ax = axis("workload_config", {"w": _report(7e9, 3e9)})
        assert ax.labels == ("w",)
        assert ax.values[0][1].read_fraction == pytest.approx(0.7)


class TestJointSpaceCompileOnce:
    """Acceptance: [configs x catalog x mixes x backlogs x shorelines] in
    one evaluation, exactly one compile per engine family."""

    def _space(self):
        return DesignSpace([
            axis("workload_config", {"train": TrafficMix(67, 33),
                                     "decode": TrafficMix(95, 5)}),
            axis("mix", [OWN_MIX, (2, 1), (1, 1)]),
            axis("backlog", [4.0, 64.0]),
            axis("shoreline_mm", [4.0, 8.0]),
        ], n_flits=512, n_accesses=512)

    def test_compiles_once_per_family_then_warm(self):
        space_mod.clear_cache()
        res = self._space().evaluate()
        assert space_mod.cache_stats(("memsys.catalog",)).misses == 1
        assert space_mod.cache_stats(("flitsim.symmetric",)).misses == 1
        assert space_mod.cache_stats(("flitsim.asymmetric",)).misses == 1
        assert space_mod.cache_stats().misses == 3
        # full dims over the joint space
        assert res["bandwidth_gbs"].dims == (
            "system", "workload_config", "mix", "shoreline_mm")
        assert res["sim_efficiency"].dims == (
            "protocol", "backlog", "workload_config", "mix")
        first = space_mod.cache_stats()
        # identical shapes -> warm: the runtime sanitizer turns any
        # compile event (not just cached_program misses) into a failure
        from repro.lint import runtime
        with runtime.no_retrace():
            self._space().evaluate()
        second = space_mod.cache_stats()
        assert second.misses == first.misses
        assert second.hits > first.hits

    def test_own_mix_column_resolves_per_config(self):
        res = self._space().evaluate(metrics=("bandwidth_gbs",))
        bw = res["bandwidth_gbs"]
        own_train = bw.sel(workload_config="train", mix=OWN_MIX,
                           shoreline_mm=8.0)
        direct = catalog_grid(67.0, 33.0, 8.0)
        np.testing.assert_allclose(own_train.values,
                                   np.asarray(direct.bandwidth_gbs),
                                   rtol=1e-6)


class TestSharedCacheAcrossFrontends:
    """Warming the space through the axes-first API warms every legacy
    front-end (and vice versa) — one cache, many doors."""

    def test_legacy_wrappers_run_warm_after_designspace(self):
        space_mod.clear_cache()
        DesignSpace([axis("mix", CANONICAL_MIXES)]).evaluate(
            metrics=("bandwidth_gbs", "sim_efficiency"))
        primed = space_mod.cache_stats()
        assert primed.misses == 3       # catalog + symmetric + asymmetric
        sweep()                          # default canonical sweep
        catalog_grid(np.asarray([m[0] for m in CANONICAL_MIXES]),
                     np.asarray([m[1] for m in CANONICAL_MIXES]))
        after = space_mod.cache_stats()
        assert after.misses == primed.misses, \
            "legacy front-ends retraced a space-primed executable"
        assert after.hits > primed.hits

    def test_rank_grid_shares_catalog_program(self):
        space_mod.clear_cache()
        x = np.asarray([100.0, 50.0, 0.0])
        y = 100.0 - x
        DesignSpace([axis("mix", list(zip(x, y)))]).evaluate(
            metrics=("bandwidth_gbs",))
        before = space_mod.cache_stats(("memsys.catalog",))
        rank_grid(x, y)
        after = space_mod.cache_stats(("memsys.catalog",))
        assert after.misses == before.misses
        assert after.hits > before.hits


class TestCompatNumerics:
    def test_designspace_matches_seed_goldens(self):
        res = DesignSpace([axis("mix", CANONICAL_MIXES)]).evaluate(
            metrics=("sim_efficiency",))
        eff = res["sim_efficiency"]
        for key, golden in SEED_GOLDEN_SPOT.items():
            got = eff.values[eff.coord("protocol").index(key)]
            np.testing.assert_allclose(got, golden, atol=1e-6, err_msg=key)

    def test_designspace_bit_identical_to_sweep(self):
        mixes = [(3, 1), (1, 1), (1, 4)]
        res = DesignSpace([axis("mix", mixes),
                           axis("backlog", [8.0, 64.0])]).evaluate(
            metrics=("sim_efficiency",))
        legacy = sweep(mixes=mixes, backlogs=[8.0, 64.0])
        # [P, B, M] both ways, same executable -> bit-for-bit
        np.testing.assert_array_equal(res["sim_efficiency"].values,
                                      np.asarray(legacy.efficiency))

    def test_designspace_bit_identical_to_catalog_grid(self):
        x = np.asarray([80.0, 20.0], np.float32)
        y = 100.0 - x
        res = DesignSpace(
            [axis("mix", list(zip(x, y))),
             axis("shoreline_mm", [4.0, 8.0])]).evaluate(
            metrics=("bandwidth_gbs", "pj_per_bit"))
        legacy = catalog_grid(x[:, None], y[:, None],
                              np.asarray([4.0, 8.0]))
        np.testing.assert_array_equal(res["bandwidth_gbs"].values,
                                      np.asarray(legacy.bandwidth_gbs))
        np.testing.assert_array_equal(res["pj_per_bit"].values,
                                      np.asarray(legacy.pj_per_bit))


class TestPerMixKnees:
    def test_envelope_is_max_over_per_mix(self):
        per = flitsim.backlog_knees(per_mix=True)
        env = flitsim.backlog_knees()
        for key, arr in per.items():
            assert float(np.max(arr)) == env[key], key
            assert arr.shape == (len(CANONICAL_MIXES),)

    def test_knees_vary_by_mix(self):
        per = flitsim.backlog_knees(per_mix=True)
        # at least one symmetric protocol needs a deeper queue on some
        # mixes than others — the whole point of the per-mix refinement
        assert any(np.min(per[k]) < np.max(per[k])
                   for k in flitsim.SYMMETRIC_PARAMS)

    def test_bridge_knee_budget_follows_configs_axis(self):
        """A queue-depth budget below a protocol's canonical-mix envelope
        but above its knee at a workload's OWN mix keeps that protocol in
        the workload's frontier — per-config masking, not the envelope."""
        per = flitsim.backlog_knees(
            mixes=[(100.0, 0.0), (50.0, 50.0)], per_mix=True)
        budget = float(per["cxl_opt"][0])          # pure-read knee
        assert per["cxl_opt"][1] > budget, \
            "fixture mixes no longer separate the knees; pick new mixes"
        reports = {"pure_read": _report(1e10, 0.0),
                   "balanced": _report(5e9, 5e9)}
        ds = bridge_design_space(
            reports, n_fracs=5,
            constraints=SelectionConstraints(max_backlog_knee=budget))
        pure = {c["best"] for c in
                ds["workloads"]["pure_read"]["crossovers"]}
        bal = {c["best"] for c in
               ds["workloads"]["balanced"]["crossovers"]}
        # the pure-read config keeps CXL-opt in its frontier...
        assert any(k.startswith("E:") for k in pure), pure
        # ...the balanced config loses every deep-queue symmetric protocol
        # (under the old envelope semantics BOTH rows would lose them)
        assert not any(k.startswith(("C:", "D:", "E:")) for k in bal), bal

    def test_generous_budget_changes_nothing(self):
        reports = {"w": _report(7e9, 3e9)}
        base = bridge_design_space(reports, n_fracs=5)
        roomy = bridge_design_space(
            reports, n_fracs=5,
            constraints=SelectionConstraints(
                max_backlog_knee=max(flitsim.KNEE_BACKLOGS)))
        assert base["workloads"]["w"]["best"] == \
            roomy["workloads"]["w"]["best"]
        assert base["workloads"]["w"]["crossovers"] == \
            roomy["workloads"]["w"]["crossovers"]


class TestJointPipelining:
    def test_joint_grid_matches_scalar_calls(self):
        ks, us, ds_ = (1, 2, 4), (8.0, 16.0), (32.0, 64.0)
        joint = np.asarray(sweep_pipelining(ks, ucie_line_ui=us,
                                            device_line_ui=ds_))
        assert joint.shape == (3, 2, 2)
        for i, k in enumerate(ks):
            for j, u in enumerate(us):
                for l, d in enumerate(ds_):
                    scalar = float(np.asarray(sweep_pipelining(
                        [k], ucie_line_ui=u, device_line_ui=d))[0])
                    assert joint[i, j, l] == pytest.approx(
                        scalar, abs=1e-6), (k, u, d)

    def test_faster_devices_saturate_with_fewer(self):
        # halving device_line_ui (a faster DRAM generation) at fixed link
        # speed needs half the devices for full utilization
        joint = np.asarray(sweep_pipelining(
            (1, 2, 3, 4), ucie_line_ui=(16.0,),
            device_line_ui=(32.0, 64.0)))[:, 0, :]
        k_sat_fast = int(np.argmax(joint[:, 0] >= 0.99)) + 1
        k_sat_slow = int(np.argmax(joint[:, 1] >= 0.99)) + 1
        assert k_sat_fast == 2 and k_sat_slow == 4

    def test_designspace_pipelining_axes(self):
        res = DesignSpace([
            axis("k", [1, 2, 4]),
            axis("ucie_line_ui", [8.0, 16.0]),
            axis("device_line_ui", [32.0, 64.0]),
        ]).evaluate()
        u = res["utilization"]
        assert u.dims == ("k", "ucie_line_ui", "device_line_ui")
        assert u.shape == (3, 2, 2)
        # fixed link: utilization never decreases with more devices
        assert (np.diff(u.values, axis=0) >= -1e-6).all()

    def test_legacy_scalar_form_unchanged(self):
        util = np.asarray(sweep_pipelining([1, 2, 3, 4]))
        assert util.shape == (4,)
        assert util[-1] == pytest.approx(1.0, abs=1e-3)


class TestPerturbations:
    def test_baseline_row_bit_identical_to_sweep(self):
        res = flitsim.sweep_perturbed(
            [{}, {"g_slots": 0.8}], protocols=("cxl_opt", "hbm_asym"),
            mixes=[(2, 1)])
        legacy = sweep(protocols=("cxl_opt", "hbm_asym"), mixes=[(2, 1)])
        np.testing.assert_array_equal(
            res["sim_efficiency"].sel(protocol_param="baseline").values,
            np.asarray(legacy.efficiency))

    def test_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="unknown perturbation"):
            flitsim.sweep_perturbed([{"warp_drive": 2.0}])

    def test_inapplicable_perturbation_rejected(self):
        # total_lanes exists only on the asymmetric family: applying it
        # to a symmetric-only sweep would silently yield a baseline row
        # labeled as perturbed
        with pytest.raises(ValueError, match="applies to no parameter"):
            flitsim.sweep_perturbed([{}, {"total_lanes": 0.5}],
                                    protocols=("cxl_opt",),
                                    mixes=[(2, 1)])

    def test_slot_count_perturbation_binds_symmetric_only(self):
        res = flitsim.sweep_perturbed(
            [{}, {"g_slots": 0.8}],
            protocols=("cxl_opt", "lpddr6_asym"), mixes=[(2, 1)])
        eff = res["sim_efficiency"].values        # [2 pert, 2 proto, 1 mix]
        assert eff[1, 0, 0] < eff[0, 0, 0]        # fewer slots hurt cxl_opt
        assert eff[1, 1, 0] == eff[0, 1, 0]       # asym has no g_slots

    def test_credit_limit_perturbation_binds(self):
        res = flitsim.sweep_perturbed(
            [{}, {"credit_lines": 0.1}], protocols=("cxl_opt",),
            mixes=[(2, 1)])
        eff = res["sim_efficiency"].values
        assert eff[1, 0, 0] < eff[0, 0, 0] - 0.01

    def test_labels(self):
        res = flitsim.sweep_perturbed(
            [{}, ("tight_credit", {"credit_lines": 0.1})],
            protocols=("chi",), mixes=[(1, 1)])
        assert res["sim_efficiency"].coord("protocol_param") == (
            "baseline", "tight_credit")


class TestJointFrontier:
    @pytest.fixture(scope="class")
    def jf(self):
        return joint_frontier(n_fracs=9, backlogs=(2.0, 64.0),
                              shorelines=(8.0,), n_flits=1024)

    def test_structure(self, jf):
        assert len(jf["read_fractions"]) == 9
        assert len(jf["analytic_best"]) == 9          # [M][L]
        assert len(jf["simulated_best"]) == 2         # [B][M][L]
        assert 0.0 <= jf["disagreement_fraction"] <= 1.0
        for r in jf["disagreement_regions"]:
            assert r["analytic_best"] != r["simulated_best"]
            assert 0.0 <= r["read_fraction_lo"] < r["read_fraction_hi"] \
                <= 1.0
            assert r["backlog"] in jf["backlogs"]

    def test_shallow_queues_disagree_more(self, jf):
        sim_best = np.asarray(jf["simulated_best"], dtype=object)
        ana_best = np.asarray(jf["analytic_best"], dtype=object)
        dis_shallow = float((sim_best[0] != ana_best).mean())   # backlog 2
        dis_deep = float((sim_best[1] != ana_best).mean())      # backlog 64
        assert dis_shallow > dis_deep
        # at saturation the simulation backs the closed forms almost
        # everywhere, so disagreement exists only at shallow queues
        assert any(r["backlog"] == 2.0
                   for r in jf["disagreement_regions"])

    def test_asymmetric_protocols_match_closed_forms(self, jf):
        # backlog-independent lane simulators track eq (3) tightly
        assert jf["protocol_rel_err"]["lpddr6_asym"] < 0.01
        assert jf["protocol_rel_err"]["hbm_asym"] < 0.01


class TestSpaceQueries:
    def test_sel_isel_argbest(self):
        res = DesignSpace([axis("read_fraction", [0.0, 0.5, 1.0]),
                           axis("shoreline_mm", [4.0, 8.0])]).evaluate(
            metrics=("bandwidth_gbs",))
        bw = res["bandwidth_gbs"]
        assert bw.dims == ("system", "read_fraction", "shoreline_mm")
        one = bw.sel(read_fraction=0.5, shoreline_mm=8.0)
        assert one.dims == ("system",)
        np.testing.assert_array_equal(one.values, bw.values[:, 1, 1])
        assert bw.isel(shoreline_mm=0).dims == ("system", "read_fraction")
        labels = bw.argbest("system")
        assert labels.shape == (3, 2)
        with pytest.raises(KeyError):
            bw.sel(read_fraction=0.25)

    def test_frontier_matches_rank_grid(self):
        fracs = np.linspace(0.0, 1.0, 11)
        res = DesignSpace([axis("read_fraction", fracs)]).evaluate(
            metrics=("bandwidth_gbs",))
        front = res.frontier("bandwidth_gbs")
        g = rank_grid(100.0 * fracs, 100.0 - 100.0 * fracs)
        np.testing.assert_array_equal(front.values, g.best_keys())

    def test_result_sel_applies_across_arrays(self):
        res = DesignSpace([axis("mix", [(2, 1), (1, 1)]),
                           axis("backlog", [4.0, 64.0])]).evaluate()
        narrowed = res.sel(backlog=64.0)
        assert "backlog" not in narrowed["sim_efficiency"].dims
        # arrays without the dim pass through untouched
        assert narrowed["latency_ns"].dims == ("system",)
        # ...but a dim on NO array (typo) must not silently no-op
        with pytest.raises(KeyError, match="not present on any array"):
            res.sel(backlogs=64.0)

    def test_regimes_tile_unit_interval(self):
        labs = ["a", "a", "b", "b", "b", "c"]
        fr = np.linspace(0.0, 1.0, 6)
        regs = regimes(labs, fr)
        assert regs[0][0] == 0.0 and regs[-1][1] == 1.0
        for (lo, hi, _), (lo2, hi2, _) in zip(regs, regs[1:]):
            assert hi == lo2
        assert [r[2] for r in regs] == ["a", "b", "c"]
