"""Multi-device distribution tests (8 host CPU devices via subprocess —
the device count must be set before jax initializes, so each test body
runs in a fresh interpreter)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 900) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestShardedTraining:
    def test_sharded_train_step_matches_single_device(self):
        # Requires layout-invariant RNG (jax_threefry_partitionable, enabled
        # by repro.compat): with legacy threefry, init under sharded
        # out_shardings draws different embedding values than single-device
        # init from the same key (0.09 max abs diff BEFORE any train step).
        run_sub("""
        from repro.configs import get
        from repro.configs.shapes import ShapeSpec
        from repro.models import build, ShardingCtx, from_mesh
        from repro.train import (AdamW, constant_schedule, init_state,
                                 make_train_step, state_shardings,
                                 SyntheticLM)

        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(1e-3))
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 8, "train"))

        # single device reference
        ctx0 = ShardingCtx()
        state0 = init_state(model, jax.random.PRNGKey(0), opt)
        step0 = jax.jit(make_train_step(model, opt, ctx0))
        s_ref, m_ref = step0(state0, src.place(src.batch_for_step(0), ctx0))

        # sharded (4 data x 2 model)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = from_mesh(mesh)
        st_sh = state_shardings(model, ctx)
        state1 = jax.jit(lambda k: init_state(model, k, opt),
                         out_shardings=st_sh)(jax.random.PRNGKey(0))
        step1 = jax.jit(make_train_step(model, opt, ctx),
                        in_shardings=(st_sh, None), out_shardings=(st_sh, None))
        s_sh, m_sh = step1(state1, src.place(src.batch_for_step(0), ctx))

        l0, l1 = float(m_ref["loss"]), float(m_sh["loss"])
        assert abs(l0 - l1) / l0 < 2e-2, (l0, l1)
        d = max(float(jnp.max(jnp.abs(np.asarray(a, np.float32)
                                      - np.asarray(b, np.float32))))
                for a, b in zip(jax.tree.leaves(s_ref.params),
                                jax.tree.leaves(s_sh.params)))
        assert d < 0.05, d
        print("OK sharded-vs-single", l0, l1, d)
        """)

    def test_moe_shard_map_matches_single_device(self):
        """The MoE *block* on bit-identical inputs: the shard_map
        expert-parallel path must route identically and combine to the
        same outputs as the single-device path.  (Full-model comparisons
        flip router ties through upstream bf16 reduction-order noise —
        inherent to discrete top-k, not a distribution bug.)"""
        run_sub("""
        import dataclasses
        from repro.configs import get
        from repro.models import build, ShardingCtx, from_mesh
        from repro.models.moe import moe_block, moe_schema
        from repro.models.schema import init_params
        cfg = dataclasses.replace(get("olmoe-1b-7b").reduced(),
                                  moe_capacity_factor=8.0)
        params = init_params(moe_schema(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, 16, cfg.d_model)).astype(jnp.bfloat16)

        ctx0 = ShardingCtx()
        out0, aux0 = moe_block(params, x, cfg, ctx0)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        ctx = from_mesh(mesh)
        out1, aux1 = jax.jit(
            lambda p, xx: moe_block(p, xx, cfg, ctx))(params, x)
        err = float(jnp.max(jnp.abs(np.asarray(out0, np.float32)
                                    - np.asarray(out1, np.float32))))
        assert err < 0.02, err
        # per-shard aux averaging differs from global by at most Jensen gap
        assert abs(float(aux0) - float(aux1)) < 0.25
        print("OK moe shard_map", err, float(aux0), float(aux1))
        """)

    def test_elastic_checkpoint_reshard(self):
        run_sub("""
        import tempfile
        from repro.checkpoint import ckpt
        from repro.configs import get
        from repro.models import build, from_mesh, ShardingCtx
        from repro.train import (AdamW, constant_schedule, init_state,
                                 state_shardings)

        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(1e-3))

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        ctx_a = from_mesh(mesh_a)
        st_sh_a = state_shardings(model, ctx_a)
        state = jax.jit(lambda k: init_state(model, k, opt),
                        out_shardings=st_sh_a)(jax.random.PRNGKey(0))

        with tempfile.TemporaryDirectory() as d:
            ckpt.save(state, 0, d)
            # restore onto a DIFFERENT mesh (2x2, elastic shrink)
            mesh_b = jax.make_mesh((2, 2), ("data", "model"))
            ctx_b = from_mesh(mesh_b)
            st_sh_b = state_shardings(model, ctx_b)
            restored, step = ckpt.restore(
                d, target=jax.eval_shape(lambda: state),
                shardings=st_sh_b)
            for x, y in zip(jax.tree.leaves(state),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(
                    np.asarray(x, np.float32), np.asarray(y, np.float32))
        print("OK elastic reshard")
        """)

    def test_compressed_psum_int8(self):
        run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.train.grad_compress import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def f(xb):
            return compressed_psum(xb, "pod")

        y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod", None),
                              out_specs=P("pod", None)))(x)
        ref = jnp.broadcast_to(x.sum(0), (8, 64))
        rel = float(jnp.max(jnp.abs(np.asarray(y)[0] - np.asarray(ref)[0]))
                    / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert rel < 0.05, rel
        print("OK compressed psum", rel)
        """)


class TestMeshConstruction:
    def test_production_mesh_shapes(self):
        run_sub("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh()
        assert dict(m.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK mesh")
        """, devices=512)
