"""Regression tests for the batched workload->design-space bridge.

The rebuilt ``memsys_bridge`` (one stacked ``_catalog_grid_impl`` call) must
reproduce the pre-refactor scalar per-system Python loop, the batched
``bridge_design_space`` configs-axis path must compile exactly once per
grid shape, and the selector's packaging / backlog-knee constraints must
actually exclude what they claim to.
"""
import numpy as np
import pytest

from repro.core import flitsim
from repro.core.memsys import (
    clear_grid_cache, grid_cache_stats, standard_catalog,
)
from repro.core.selector import SelectionConstraints, rank
from repro.core.selector import _rank_grid_impl as rank_grid
from repro.core.traffic import TrafficMix, mix_grid
from repro.roofline.analysis import (
    RooflineReport, bridge_design_space, memsys_bridge,
)


def _report(read, write, hlo_bytes):
    return RooflineReport(
        arch="golden", shape="s", mesh="16x16", chips=256,
        hlo_flops_per_chip=1e12, hlo_bytes_per_chip=hlo_bytes,
        collective_bytes_per_chip=1e9, compute_s=5e-3, memory_s=1.2e-2,
        collective_s=2e-2, dominant="collective", model_flops=2e14,
        useful_flops_ratio=0.8, read_bytes_per_chip=read,
        write_bytes_per_chip=write)


# Golden outputs of the SEED (pre-batching) scalar per-system loop in
# memsys_bridge, captured by executing the original implementation
# (git 57b9da2) on CPU.  The batched catalog-grid path must reproduce them
# to <= 1e-6 relative (float reassociation inside the fused program only).
SEED_GOLDEN_70R30W_8MM = {      # read=7e9 write=3e9 hlo_bytes=1e10
    "E:cxl-mem-opt/UCIe-A": {
        "bandwidth_gbs": 3454.111083984375,
        "pj_per_bit": 0.3360937535762787,
        "memory_term_s": 0.002895100868749372,
        "interconnect_energy_j_per_step": 0.026887500286102293,
    },
    "A2:lpddr6-native/UCIe-A": {
        "bandwidth_gbs": 3733.34765625,
        "pj_per_bit": 0.33082032203674316,
        "memory_term_s": 0.0026785611522835255,
    },
    "C:chi-sym/UCIe-S": {
        "bandwidth_gbs": 814.5454711914062,
        "interconnect_energy_j_per_step": 0.07553333282470703,
    },
    "HBM4": {"bandwidth_gbs": 1638.4000244140625,
             "memory_term_s": 0.0061035155340505316,
             "pj_per_bit": 0.8999999761581421},
    "LPDDR6": {"bandwidth_gbs": 282.4827575683594,
               "memory_term_s": 0.03540039075687673},
}
SEED_GOLDEN_95R5W_4MM = {       # read=1.9e10 write=1e9 hlo_bytes=2e10
    "E:cxl-mem-opt/UCIe-A": {
        "bandwidth_gbs": 1299.5526123046875,
        "pj_per_bit": 0.3550833761692047,
        "memory_term_s": 0.015389911736263653,
    },
    "B:hbm-asym/UCIe-S": {"bandwidth_gbs": 437.40655517578125},
    "HBM3": {"bandwidth_gbs": 409.6000061035156,
             "memory_term_s": 0.04882812427240425},
}


class TestBridgeSeedGolden:
    """The batched bridge reproduces the ORIGINAL per-system loop."""

    @pytest.mark.parametrize("golden,args,shoreline", [
        (SEED_GOLDEN_70R30W_8MM, (7e9, 3e9, 1e10), 8.0),
        (SEED_GOLDEN_95R5W_4MM, (19e9, 1e9, 2e10), 4.0),
    ])
    def test_matches_scalar_loop_goldens(self, golden, args, shoreline):
        br = memsys_bridge(_report(*args), shoreline_mm=shoreline)
        assert set(br["systems"]) == set(standard_catalog())
        for key, metrics in golden.items():
            for m, v in metrics.items():
                assert br["systems"][key][m] == pytest.approx(
                    v, rel=1e-6), (key, m)

    def test_mix_metadata(self):
        br = memsys_bridge(_report(7e9, 3e9, 1e10))
        assert br["mix"] == "70R30W"
        assert br["read_fraction"] == pytest.approx(0.7)
        assert br["hbm_baseline_memory_s"] == pytest.approx(1.2e-2)

    def test_every_system_has_full_metric_set(self):
        br = memsys_bridge(_report(1e10, 1e10, 1e10))
        for key, s in br["systems"].items():
            assert set(s) == {"bandwidth_gbs", "pj_per_bit",
                              "memory_term_s",
                              "interconnect_energy_j_per_step",
                              "latency_ns"}, key
            assert s["memory_term_s"] > 0


class TestDesignSpaceBridge:
    REPORTS = {
        "train": _report(6.7e9, 3.3e9, 1e10),
        "prefill": _report(1.7e10, 3e9, 1.5e10),
        "decode": _report(1.9e10, 1e9, 2e10),
    }

    def test_own_mix_column_matches_scalar_bridge(self):
        """Column 0 of the configs axis is each workload's own mix — its
        per-system metrics must bit-match the scalar-path memsys_bridge."""
        ds = bridge_design_space(self.REPORTS, shorelines=(4.0, 8.0))
        for name, rep in self.REPORTS.items():
            br = memsys_bridge(rep, shoreline_mm=8.0)
            w = ds["workloads"][name]
            assert w["mix"] == br["mix"]
            for key, s in br["systems"].items():
                for m, v in s.items():
                    assert w["systems"][key][m] == pytest.approx(
                        v, rel=1e-6), (name, key, m)

    def test_configs_axis_compiles_once_per_grid_shape(self):
        clear_grid_cache()
        bridge_design_space(self.REPORTS)
        first = grid_cache_stats()
        assert first.misses == 1, first
        bridge_design_space(self.REPORTS)      # same shape -> warm
        second = grid_cache_stats()
        assert second.misses == first.misses
        assert second.hits > first.hits
        # a different grid shape compiles once more, then caches again
        bridge_design_space(self.REPORTS, n_fracs=11)
        bridge_design_space(self.REPORTS, n_fracs=11)
        third = grid_cache_stats()
        assert third.misses == 2

    def test_frontier_structure(self):
        ds = bridge_design_space(self.REPORTS, n_fracs=21)
        assert len(ds["read_fractions"]) == 21
        for name, w in ds["workloads"].items():
            assert w["feasible"]
            assert w["best"] in ds["keys"]
            # crossover regimes tile [0, 1] without gaps: every read
            # fraction falls in exactly one regime
            cs = w["crossovers"]
            assert cs[0]["read_fraction_lo"] == 0.0
            assert cs[-1]["read_fraction_hi"] == 1.0
            for a, b in zip(cs, cs[1:]):
                assert b["read_fraction_lo"] == a["read_fraction_hi"]
                assert b["read_fraction_lo"] < b["read_fraction_hi"]
            assert set(w["shoreline_frontier"]) == \
                {f"{s:g}mm" for s in ds["shorelines"]}

    def test_reference_shoreline_never_snapped(self):
        """A shoreline list missing the constraints' reference budget gets
        it appended — `best`/`systems` are evaluated at the requested
        shoreline exactly, not a nearest neighbor."""
        ds = bridge_design_space(self.REPORTS, n_fracs=5,
                                 shorelines=(2.0, 5.0))
        assert ds["reference_shoreline_mm"] == 8.0
        assert ds["shorelines"] == [2.0, 5.0, 8.0]
        for name, rep in self.REPORTS.items():
            br = memsys_bridge(rep, shoreline_mm=8.0)
            w = ds["workloads"][name]
            for key, s in br["systems"].items():
                assert w["systems"][key]["bandwidth_gbs"] == pytest.approx(
                    s["bandwidth_gbs"], rel=1e-6)

    def test_constraints_flow_through(self):
        ds = bridge_design_space(
            self.REPORTS, n_fracs=11,
            constraints=SelectionConstraints(packaging="UCIe-S"))
        for w in ds["workloads"].values():
            assert w["best"].endswith("UCIe-S")
            for c in w["crossovers"]:
                assert c["best"].endswith("UCIe-S")


class TestRankGrid2D:
    def test_shoreline_axis_shapes_and_consistency(self):
        x, y = mix_grid(9)
        x = np.asarray(x)[:, None]
        y = np.asarray(y)[:, None]
        sl = np.array([4.0, 8.0])
        g = rank_grid(x, y, shoreline_mm=sl)
        assert g.best_index.shape == (9, 2)
        assert g.grid.bandwidth_gbs.shape == (len(g.keys), 9, 2)
        # doubling the shoreline doubles bandwidth, leaves pJ/b unchanged
        bw = np.asarray(g.grid.bandwidth_gbs)
        np.testing.assert_allclose(bw[:, :, 1], 2.0 * bw[:, :, 0],
                                   rtol=1e-6)
        pj = np.asarray(g.grid.pj_per_bit)
        np.testing.assert_allclose(pj[:, :, 1], pj[:, :, 0], atol=0)


class TestPackagingConstraint:
    def test_rank_excludes_bus_baselines(self):
        mix = TrafficMix(2, 1)
        for pkg in ("UCIe-A", "UCIe-S"):
            ranked = rank(mix, constraints=SelectionConstraints(
                packaging=pkg))
            assert ranked, pkg
            for r in ranked:
                assert pkg in r.key, (pkg, r.key)

    def test_rank_grid_excludes_bus_baselines(self):
        x, y = mix_grid(5)
        g = rank_grid(x, y, constraints=SelectionConstraints(
            packaging="UCIe-A"))
        valid = np.asarray(g.valid)
        for i, key in enumerate(g.keys):
            if "UCIe-A" in key:
                assert valid[i].all(), key
            else:
                assert not valid[i].any(), key

    def test_unconstrained_still_admits_baselines(self):
        ranked = rank(TrafficMix(1, 1))
        assert any(r.key in ("HBM4", "LPDDR6") for r in ranked)


class TestBacklogKneeConstraint:
    def test_knees_shape_and_families(self):
        knees = flitsim.backlog_knees()
        assert set(knees) == set(flitsim.SIMULATORS)
        # asymmetric protocols are backlog-independent: knee at the floor
        assert knees["lpddr6_asym"] == min(flitsim.KNEE_BACKLOGS)
        assert knees["hbm_asym"] == min(flitsim.KNEE_BACKLOGS)
        # symmetric protocols need a real queue to saturate
        assert all(knees[k] > min(flitsim.KNEE_BACKLOGS)
                   for k in flitsim.SYMMETRIC_PARAMS)

    def test_selector_enforces_knee_budget(self):
        mix = TrafficMix(2, 1)
        knees = flitsim.backlog_knees()
        budget = min(knees[k] for k in flitsim.SYMMETRIC_PARAMS) - 1.0
        ranked = rank(mix, constraints=SelectionConstraints(
            max_backlog_knee=budget))
        keys = [r.key for r in ranked]
        # every symmetric-protocol system is excluded...
        assert not any(k.startswith(("C:", "D:", "E:")) for k in keys)
        # ...asymmetric UCIe systems and (un-simulated) baselines remain
        assert any(k.startswith("A") for k in keys)
        assert any(k in ("HBM4", "LPDDR6") for k in keys)

    def test_generous_budget_excludes_nothing(self):
        mix = TrafficMix(2, 1)
        base = {r.key for r in rank(mix)}
        roomy = {r.key for r in rank(mix, constraints=SelectionConstraints(
            max_backlog_knee=max(flitsim.KNEE_BACKLOGS)))}
        assert roomy == base
