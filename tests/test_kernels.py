"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle, plus hypothesis property tests for the flit-pack data path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flit_pack.kernel import pack_flits
from repro.kernels.flit_pack.ref import (
    flits_needed, pack_flits_ref, unpack_flits_ref,
)
from repro.kernels.rglru_scan.kernel import rglru_scan
from repro.kernels.rglru_scan.ref import lru_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("b,k,g,sq,skv,hd,bq,bk", [
        (2, 2, 3, 128, 128, 64, 64, 64),
        (1, 1, 1, 256, 256, 128, 128, 128),
        (2, 2, 2, 96, 96, 64, 64, 64),         # non-multiple of block
        (1, 1, 2, 64, 192, 64, 64, 64),        # Sq != Skv
    ])
    def test_causal_matches_ref(self, b, k, g, sq, skv, hd, bq, bk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, k, g, sq, hd), jnp.float32)
        kk = jax.random.normal(ks[1], (b, k, skv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, k, skv, hd), jnp.float32)
        off = skv - sq
        out = flash_attention_fwd(q, kk, v, causal=True, q_offset=off,
                                  block_q=bq, block_kv=bk, interpret=True)
        ref = attention_ref(q, kk, v, causal=True, q_offset=off)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-4)

    @pytest.mark.parametrize("window", [16, 32, 64])
    def test_local_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 2, 128, 64))
        kk = jax.random.normal(ks[1], (1, 2, 128, 64))
        v = jax.random.normal(ks[2], (1, 2, 128, 64))
        out = flash_attention_fwd(q, kk, v, causal=True, window=window,
                                  block_q=32, block_kv=32, interpret=True)
        ref = attention_ref(q, kk, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-4)

    def test_non_causal_cross(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 1, 1, 64, 64))
        kk = jax.random.normal(ks[1], (2, 1, 160, 64))
        v = jax.random.normal(ks[2], (2, 1, 160, 64))
        out = flash_attention_fwd(q, kk, v, causal=False, block_q=64,
                                  block_kv=64, interpret=True)
        ref = attention_ref(q, kk, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 1, 2, 64, 64)).astype(dtype)
        kk = jax.random.normal(ks[1], (1, 1, 64, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 1, 64, 64)).astype(dtype)
        out = flash_attention_fwd(q, kk, v, block_q=32, block_kv=32,
                                  interpret=True)
        ref = attention_ref(q, kk, v)
        assert out.dtype == dtype
        tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol)


class TestSSDScan:
    @pytest.mark.parametrize("bsz,s,h,p,n,chunk", [
        (2, 64, 4, 16, 8, 16),
        (1, 128, 2, 32, 16, 32),
        (2, 96, 3, 16, 8, 32),
        (1, 64, 1, 64, 32, 64),      # single chunk
    ])
    def test_matches_sequential_ref(self, bsz, s, h, p, n, chunk):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (bsz, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
        b = jax.random.normal(ks[2], (bsz, s, n)) * 0.5
        c = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
        a_log = jax.random.normal(ks[4], (h,)) * 0.3
        y, fs = ssd_scan(x, dt, b, c, a_log, chunk=chunk, interpret=True)
        yr, fsr = ssd_ref(x, dt, b, c, a_log)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=5e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                                   atol=5e-5, rtol=1e-4)

    def test_model_chunked_form_matches_ref(self):
        """The model's closed-form chunked SSD == sequential recurrence."""
        from repro.models.ssm import ssd_chunked
        ks = jax.random.split(KEY, 5)
        bsz, s, h, p, n = 2, 64, 4, 16, 8
        x = jax.random.normal(ks[0], (bsz, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
        b = jax.random.normal(ks[2], (bsz, s, n)) * 0.5
        c = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
        a_log = jax.random.normal(ks[4], (h,)) * 0.3
        y, fs = ssd_chunked(x, dt, b[:, :, None], c[:, :, None], a_log, 16)
        yr, fsr = ssd_ref(x, dt, b, c, a_log)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=5e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                                   atol=5e-5, rtol=1e-4)


class TestRGLRUScan:
    @pytest.mark.parametrize("bsz,s,c,q,bc", [
        (2, 64, 32, 16, 16),
        (1, 128, 64, 32, 32),
        (2, 32, 16, 32, 16),         # single seq block
    ])
    def test_matches_sequential_ref(self, bsz, s, c, q, bc):
        ks = jax.random.split(KEY, 2)
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (bsz, s, c)))
        b = jax.random.normal(ks[1], (bsz, s, c))
        h = rglru_scan(log_a, b, block_seq=q, block_ch=bc, interpret=True)
        hr = lru_ref(log_a, b)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   atol=1e-5, rtol=1e-4)

    def test_model_assoc_scan_matches_ref(self):
        from repro.models.rglru import lru_scan
        ks = jax.random.split(KEY, 2)
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (2, 64, 32)))
        b = jax.random.normal(ks[1], (2, 64, 32))
        np.testing.assert_allclose(np.asarray(lru_scan(log_a, b)),
                                   np.asarray(lru_ref(log_a, b)),
                                   atol=1e-5, rtol=1e-4)


class TestFlitPack:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 120))
    def test_roundtrip_and_checksum(self, n):
        f = flits_needed(n)
        lines = jax.random.randint(jax.random.PRNGKey(n), (n, 64), 0, 256)
        hdrs = jax.random.randint(jax.random.PRNGKey(n + 1), (f, 10), 0, 256)
        meta = jax.random.randint(jax.random.PRNGKey(n + 2), (f, 4), 0, 256)
        out = pack_flits(lines, hdrs, meta, interpret=True)
        ref = pack_flits_ref(lines, hdrs, meta)
        assert jnp.array_equal(out, ref)
        l2, h2, m2, ok = unpack_flits_ref(out, n)
        assert jnp.array_equal(l2, lines)
        assert jnp.array_equal(h2, hdrs)
        assert bool(ok.all())

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 60), st.integers(0, 253))
    def test_corruption_detected(self, n, byte):
        f = flits_needed(n)
        lines = jax.random.randint(jax.random.PRNGKey(n), (n, 64), 0, 256)
        hdrs = jnp.zeros((f, 10), jnp.int32)
        meta = jnp.zeros((f, 4), jnp.int32)
        out = pack_flits_ref(lines, hdrs, meta)
        bad = out.at[0, byte].set((out[0, byte] + 1) % 256)
        _, _, _, ok = unpack_flits_ref(bad, n)
        assert not bool(ok[0])

    def test_slot_efficiency_matches_approach_e(self):
        """4N data slots over ceil(4N/15) flits -> the 15/16-free packing
        the paper's eq (20) assumes."""
        n = 15 * 10
        f = flits_needed(n)
        assert f == 4 * n // 15
        # every byte of the data region is payload
        assert f * 240 == n * 64
