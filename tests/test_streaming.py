"""Streaming sharded sweep engine + unified report API (PR 9).

Bit-identity contract: streamed winner labels equal the materialized
``argbest`` on every grid — same dims, same coords, same labels — for
simulated and analytic metrics, with and without constraints, for any
chunk size / axis order — and, since PR 10, at any async ``prefetch``
depth (the double-buffered dispatch loop overlaps host marshalling with
in-flight device execution; the fold order is FIFO, so the running
reductions are bit-identical to the sequential loop).  Plus: chunk-size
edge cases, compile-cache accounting, ``cache_stats`` family validation,
the retired positional front-ends, and the ``report(spec)``
byte-identity guarantees.
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import (
    ADAPTIVE_SIM, DesignSpace, FIXED_SIM, ReportSpec, SelectionConstraints,
    StreamConfig, axis, build_report, cache_stats, clear_cache, flitsim,
    joint_frontier,
)
from repro.core.space import STREAM_FAMILIES
from repro.core.traffic import TrafficMix
from repro.core.ucie import UCIE_A_32G_55U, UCIE_S_32G

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: cheap fixed horizons — bit-identity holds at ANY horizon, so the
#: equality tests shrink the scan instead of the grid
FAST = dict(n_flits=96, n_accesses=96)


def assert_same_winners(stream_res, materialized):
    assert stream_res.winners.dims == materialized.dims
    assert stream_res.winners.coords == materialized.coords
    np.testing.assert_array_equal(
        np.asarray(stream_res.winners.values, dtype=object),
        np.asarray(materialized.values, dtype=object))


class TestStreamingSimEquality:
    def _space(self, **kw):
        base = dict(FAST)
        base.update(kw)
        return DesignSpace([
            axis("protocol_param", [{}, {"g_slots": 2.0}]),
            axis("phy", [UCIE_S_32G, UCIE_A_32G_55U]),
            axis("backlog", [2.0, 64.0]),
            axis("read_fraction", np.linspace(0.0, 1.0, 5)),
        ], **base)

    def test_sim_bandwidth_bit_equal(self):
        space = self._space()
        res = space.evaluate(metrics=("sim_bandwidth_gbs",))
        sr = space.evaluate(metrics=("sim_bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=3, devices=1))
        assert_same_winners(sr, res["sim_bandwidth_gbs"].argbest("protocol"))
        # dispatch accounting: 2 perts x 2 backlogs x 5 mixes = 20
        # streamed cells, x 2 phys broadcast in-kernel
        assert sr.n_stream_cells == 20 and sr.n_cells == 40
        assert sr.chunk_cells == 3 and sr.peak_cells_per_chunk == 6
        assert sr.n_dispatches == 7
        assert sum(sr.win_counts.values()) == sr.n_cells

    def test_chunk_larger_than_space(self):
        space = self._space()
        res = space.evaluate(metrics=("sim_efficiency",))
        sr = space.evaluate(metrics=("sim_efficiency",),
                            stream=StreamConfig(chunk_cells=10 ** 6,
                                                devices=1))
        assert_same_winners(sr, res["sim_efficiency"].argbest("protocol"))
        assert sr.n_dispatches == 1 and sr.chunk_cells == 20

    def test_non_divisor_chunk(self):
        space = self._space()
        res = space.evaluate(metrics=("sim_efficiency",))
        for chunk in (1, 3, 7, 19):
            sr = space.evaluate(metrics=("sim_efficiency",),
                                stream=StreamConfig(chunk_cells=chunk,
                                                    devices=1))
            assert_same_winners(sr,
                                res["sim_efficiency"].argbest("protocol"))

    def test_axis_order_invariance(self):
        space = self._space()
        ref = space.evaluate(metrics=("sim_efficiency",),
                             stream=StreamConfig(chunk_cells=4, devices=1))
        per = space.evaluate(metrics=("sim_efficiency",), stream=StreamConfig(
            chunk_cells=4, devices=1,
            axis_order=("read_fraction", "backlog", "protocol_param")))
        assert_same_winners(per, ref.winners)
        assert per.win_counts == ref.win_counts

    def test_bad_axis_order_raises(self):
        with pytest.raises(ValueError, match="permutation"):
            self._space().evaluate(
                metrics=("sim_efficiency",),
                stream=StreamConfig(chunk_cells=4, devices=1,
                                    axis_order=("backlog", "bogus")))

    def test_adaptive_sim_rejected(self):
        with pytest.raises(ValueError, match="fixed-horizon"):
            self._space(sim=ADAPTIVE_SIM).evaluate(
                metrics=("sim_efficiency",), stream=StreamConfig(devices=1))

    def test_constraints_rejected_for_sim_metrics(self):
        with pytest.raises(ValueError, match="analytic metrics only"):
            self._space().evaluate(
                metrics=("sim_efficiency",),
                stream=StreamConfig(
                    devices=1,
                    constraints=SelectionConstraints(max_power_w=5.0)))

    def test_single_metric_contract(self):
        with pytest.raises(ValueError, match="ONE metric"):
            self._space().evaluate(metrics=None, stream=StreamConfig())
        with pytest.raises(ValueError, match="ONE metric"):
            self._space().evaluate(
                metrics=("sim_efficiency", "sim_bandwidth_gbs"),
                stream=StreamConfig())
        with pytest.raises(ValueError, match="not streamable"):
            self._space().evaluate(metrics=("latency_ns",),
                                   stream=StreamConfig(devices=1))

    def test_uncovered_axis_raises(self):
        with pytest.raises(ValueError, match="'k' axis"):
            DesignSpace([axis("k", [1, 2, 4])]).evaluate(
                metrics=("utilization",), stream=StreamConfig(devices=1))


class TestStreamingCatalogEquality:
    def _space(self):
        return DesignSpace([
            axis("read_fraction", np.linspace(0.0, 1.0, 7)),
            axis("shoreline_mm", [4.0, 8.0, 16.0]),
        ])

    def test_bandwidth_bit_equal(self):
        space = self._space()
        res = space.evaluate(metrics=("bandwidth_gbs",))
        sr = space.evaluate(metrics=("bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=5, devices=1))
        assert_same_winners(sr, res.frontier("bandwidth_gbs"))
        assert sr.mode == "max" and sr.reduce_dim == "system"

    def test_min_mode_metric(self):
        space = self._space()
        res = space.evaluate(metrics=("power_w",))
        sr = space.evaluate(metrics=("power_w",),
                            stream=StreamConfig(chunk_cells=4, devices=1))
        assert sr.mode == "min"
        assert_same_winners(sr, res.frontier("power_w", mode="min"))

    @pytest.mark.parametrize("cons", [
        SelectionConstraints(packaging="UCIe-A", max_backlog_knee=32.0,
                             max_power_w=40.0),
        SelectionConstraints(max_relative_bit_cost=1.5,
                             required_bandwidth_gbs=200.0),
    ])
    def test_constrained_bit_equal(self, cons):
        space = self._space()
        res = space.evaluate(metrics=("bandwidth_gbs", "power_w"))
        ref = res.frontier("bandwidth_gbs", where=res.feasible(cons))
        sr = space.evaluate(metrics=("bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=4, devices=1,
                                                constraints=cons))
        assert_same_winners(sr, ref)

    def test_none_cells_counted(self):
        cons = SelectionConstraints(packaging="UCIe-S", max_power_w=1e-3)
        space = self._space()
        res = space.evaluate(metrics=("bandwidth_gbs", "power_w"))
        ref = res.frontier("bandwidth_gbs", where=res.feasible(cons))
        sr = space.evaluate(metrics=("bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=6, devices=1,
                                                constraints=cons))
        assert_same_winners(sr, ref)
        n_none = int(np.sum(np.asarray(ref.values, dtype=object)
                            == "(none)"))
        assert n_none > 0 and sr.win_counts["(none)"] == n_none
        assert sum(sr.win_counts.values()) == sr.n_cells
        # labels the constraints never admit report NaN bests
        assert any(np.isnan(v) for v in sr.best_by_label.values())

    def test_phy_axis_routed_to_materialized(self):
        with pytest.raises(ValueError, match="materialized"):
            DesignSpace([
                axis("phy", [UCIE_S_32G]),
                axis("read_fraction", [0.5]),
            ]).evaluate(metrics=("bandwidth_gbs",),
                        stream=StreamConfig(devices=1))


class TestStreamingCompileCache:
    def test_one_compile_per_shape_then_warm(self):
        clear_cache(STREAM_FAMILIES)
        space = DesignSpace([
            axis("read_fraction", np.linspace(0.0, 1.0, 9)),
            axis("shoreline_mm", [4.0, 8.0]),
        ])
        sr = space.evaluate(metrics=("bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=4, devices=1))
        assert sr.compiles == 1 and sr.n_dispatches > 1
        warm = space.evaluate(metrics=("bandwidth_gbs",),
                              stream=StreamConfig(chunk_cells=4, devices=1))
        assert warm.compiles == 0
        assert cache_stats(STREAM_FAMILIES).misses == 1

    def test_cache_stats_unknown_family_raises(self):
        with pytest.raises(KeyError, match="choose from"):
            cache_stats(("stream.bogus",))
        with pytest.raises(KeyError, match="flitsim.symmetric"):
            cache_stats(("flitsim.symetric",))


class TestAsyncDispatch:
    """PR 10 async double-buffered dispatch: winners, win counts and
    running bests stay bit-identical at EVERY in-flight depth, and the
    ``stream.*`` telemetry reports the overlap accounting."""

    def _space(self, n_fracs=5):
        return DesignSpace([
            axis("protocol_param", [{}, {"g_slots": 2.0}]),
            axis("phy", [UCIE_S_32G, UCIE_A_32G_55U]),
            axis("backlog", [2.0, 64.0]),
            axis("read_fraction", np.linspace(0.0, 1.0, n_fracs)),
        ], **FAST)

    def _eval(self, space, **kw):
        return space.evaluate(metrics=("sim_efficiency",),
                              stream=StreamConfig(devices=1, **kw))

    def test_prefetch_depths_bit_identical(self):
        space = self._space()
        seq = self._eval(space, chunk_cells=3, prefetch=1)
        for prefetch in (2, 3, 8):
            sr = self._eval(space, chunk_cells=3, prefetch=prefetch)
            assert_same_winners(sr, seq.winners)
            assert sr.win_counts == seq.win_counts
            assert sr.best_by_label == seq.best_by_label

    def test_prefetch_one_is_sequential(self):
        # depth 1 retires each dispatch before the next marshal starts:
        # the FIFO never holds a chunk across a marshal, so no overlap
        space = self._space()
        self._eval(space, chunk_cells=3, prefetch=1)
        info = flitsim.last_run_info()["stream.sim"]
        assert info["mode"] == "stream" and info["prefetch"] == 1
        assert info["overlap_frac"] == 0.0

    def test_stream_telemetry_contents(self):
        space = self._space()
        sr = self._eval(space, chunk_cells=3, prefetch=2)
        info = flitsim.last_run_info()["stream.sim"]
        assert info["dispatches"] == sr.n_dispatches == 7
        assert info["prefetch"] == 2
        assert info["pad_cells"] == 7 * 3 - 20 and info["cells"] == 20
        assert 0.0 <= info["overlap_frac"] <= 1.0
        assert info["elapsed_s"] > 0.0
        assert 0.0 <= info["marshal_s"] <= info["elapsed_s"]

    def test_single_chunk_smaller_than_space(self):
        # n_cells < chunk_cells: ONE dispatch; the drain loop (not the
        # bounded-depth gate) retires it
        space = self._space()
        ref = space.evaluate(metrics=("sim_efficiency",))
        sr = self._eval(space, chunk_cells=10 ** 6, prefetch=4)
        assert sr.n_dispatches == 1
        assert_same_winners(sr, ref["sim_efficiency"].argbest("protocol"))

    def test_non_divisor_tails_under_prefetch(self):
        space = self._space()
        ref = space.evaluate(metrics=("sim_efficiency",))
        for chunk in (1, 3, 7, 19):
            sr = self._eval(space, chunk_cells=chunk, prefetch=3)
            assert_same_winners(sr,
                                ref["sim_efficiency"].argbest("protocol"))

    def test_catalog_engine_prefetch_bit_identical(self):
        space = DesignSpace([
            axis("read_fraction", np.linspace(0.0, 1.0, 9)),
            axis("shoreline_mm", [4.0, 8.0]),
        ])
        seq = space.evaluate(metrics=("bandwidth_gbs",),
                             stream=StreamConfig(chunk_cells=4, devices=1,
                                                 prefetch=1))
        for prefetch in (2, 5):
            sr = space.evaluate(metrics=("bandwidth_gbs",),
                                stream=StreamConfig(chunk_cells=4,
                                                    devices=1,
                                                    prefetch=prefetch))
            assert_same_winners(sr, seq.winners)
            assert sr.win_counts == seq.win_counts
        info = flitsim.last_run_info()["stream.catalog"]
        assert info["mode"] == "stream" and info["prefetch"] == 5

    def test_prefetch_validated(self):
        with pytest.raises(ValueError, match="prefetch"):
            StreamConfig(prefetch=0)

    def test_prefetch_participates_in_stream_key(self):
        assert StreamConfig(prefetch=1).key() != \
            StreamConfig(prefetch=2).key()
        # the constraints slot stays LAST (the catalog engine peels it)
        assert StreamConfig(prefetch=2).key()[-1] == \
            StreamConfig(chunk_cells=4, prefetch=3).key()[-1]


class TestRetiredFrontEnds:
    def test_positional_front_ends_are_gone(self):
        """PR 10 retired the deprecated positional wrappers; only the
        private ``_*_impl`` engines remain (axes-first API on top)."""
        from repro.core import memsys, selector
        for mod, gone, kept in [
            (flitsim, "sweep", "_sweep_impl"),
            (flitsim, "sweep_pipelining", "_sweep_pipelining_impl"),
            (memsys, "catalog_grid", "_catalog_grid_impl"),
            (selector, "rank_grid", "_rank_grid_impl"),
        ]:
            assert not hasattr(mod, gone), gone
            assert callable(getattr(mod, kept)), kept
        import repro.core as core
        assert not hasattr(core, "catalog_grid")
        assert not hasattr(core, "rank_grid")

    def test_internal_paths_warning_free(self):
        from repro.core import rank
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            flitsim.backlog_knees(mixes=[(50.0, 50.0)], n_flits=64)
            rank(TrafficMix(70.0, 30.0))
            DesignSpace([axis("k", [1, 2, 4])]).evaluate(
                metrics=("utilization",))
        ours = [w for w in caught
                if issubclass(w.category, DeprecationWarning)
                and "front-end" in str(w.message)]
        assert not ours, [str(w.message) for w in ours]


class TestUnifiedReportAPI:
    JOINT_OPTS = dict(n_fracs=5, backlogs=(2.0, 64.0), shorelines=(8.0,),
                      n_flits=96)

    def test_joint_section_byte_identical(self):
        legacy = joint_frontier(**self.JOINT_OPTS)
        rep = build_report(ReportSpec(
            sections=("joint",), options={"joint": self.JOINT_OPTS}))
        assert json.dumps(legacy, sort_keys=True) == \
            json.dumps(rep["joint"].payload, sort_keys=True)

    def test_joint_frontier_folds_sim_bandwidth(self):
        jf = joint_frontier(**self.JOINT_OPTS)
        sbs = jf["sim_bandwidth_gbs"]
        assert sbs["phys"] == ["UCIe-S-32G-110u", "UCIe-A-32G-55u",
                               "UCIe-S-48G-110u", "UCIe-A-48G-45u"]
        assert set(sbs["best_protocol_by_phy"]) == set(sbs["phys"])
        for phy, by_bl in sbs["regimes_by_phy_backlog"].items():
            assert set(by_bl) == {"2", "64"}
            for regs in by_bl.values():
                assert all(r["approach"].split(":")[0] in "ABCDE"
                           for r in regs)

    def test_frontier_section_materialized_vs_streaming(self):
        space = DesignSpace([
            axis("read_fraction", np.linspace(0.0, 1.0, 7)),
            axis("shoreline_mm", [4.0, 8.0]),
        ])
        rep = space.report(ReportSpec(sections=("frontier",)))
        pay = rep["frontier"].payload
        assert pay["engine"] == "materialized"
        ref = space.evaluate(metrics=("bandwidth_gbs",)) \
            .frontier("bandwidth_gbs")
        assert pay["winners"] == np.asarray(ref.values,
                                            dtype=object).tolist()
        srep = space.report(ReportSpec(sections=("frontier",), options={
            "frontier": {"stream": StreamConfig(chunk_cells=4,
                                                devices=1)}}))
        spay = srep["frontier"].payload
        assert spay["engine"] == "streaming"
        assert spay["winners"] == pay["winners"]
        assert spay["peak_cells_per_chunk"] == 4

    def test_report_validation(self):
        with pytest.raises(ValueError, match="unknown report sections"):
            build_report(ReportSpec(sections=("bogus",)))
        with pytest.raises(ValueError, match="DesignSpace instance"):
            build_report(ReportSpec(sections=("frontier",)))


class TestStreamingDistributed:
    """8 virtual CPU devices (set before jax initializes — subprocess)."""

    def _run(self, body: str, devices: int = 8, timeout: int = 900) -> str:
        prog = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count={devices}"
            import numpy as np
        """) + textwrap.dedent(body)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True,
                             timeout=timeout, env=env)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        return out.stdout

    def test_eight_device_sharding_bit_equal(self):
        self._run("""
        from repro.core import DesignSpace, StreamConfig, axis
        from repro.core.space import STREAM_FAMILIES, cache_stats

        space = DesignSpace([
            axis("protocol_param", [{}, {"g_slots": 2.0}, {}]),
            axis("backlog", [2.0, 8.0, 64.0, 128.0]),
            axis("read_fraction", np.linspace(0.0, 1.0, 11)),
        ], n_flits=96, n_accesses=96)
        res = space.evaluate(metrics=("sim_efficiency",))
        ref = res["sim_efficiency"].argbest("protocol")
        sr = space.evaluate(metrics=("sim_efficiency",),
                            stream=StreamConfig(chunk_cells=7, devices=8))
        assert sr.devices == 8 and sr.chunk_cells == 7
        assert sr.winners.dims == ref.dims
        np.testing.assert_array_equal(
            np.asarray(sr.winners.values, dtype=object),
            np.asarray(ref.values, dtype=object))
        assert sum(sr.win_counts.values()) == sr.n_cells == 132
        assert cache_stats(STREAM_FAMILIES).misses == 1
        warm = space.evaluate(metrics=("sim_efficiency",),
                              stream=StreamConfig(chunk_cells=7,
                                                  devices=8))
        assert warm.compiles == 0
        print("OK 8-device sim streaming")
        """)

    def test_eight_device_catalog_constrained(self):
        self._run("""
        from repro.core import (DesignSpace, SelectionConstraints,
                                StreamConfig, axis)

        cons = SelectionConstraints(packaging="UCIe-A",
                                    max_relative_bit_cost=2.0)
        space = DesignSpace([
            axis("read_fraction", np.linspace(0.0, 1.0, 21)),
            axis("shoreline_mm", [4.0, 8.0, 16.0]),
        ])
        res = space.evaluate(metrics=("bandwidth_gbs",))
        ref = res.frontier("bandwidth_gbs", where=res.feasible(cons))
        sr = space.evaluate(metrics=("bandwidth_gbs",),
                            stream=StreamConfig(chunk_cells=4, devices=8,
                                                constraints=cons))
        np.testing.assert_array_equal(
            np.asarray(sr.winners.values, dtype=object),
            np.asarray(ref.values, dtype=object))
        print("OK 8-device catalog streaming")
        """)

    def test_devices_exceeding_local_raises(self):
        space = DesignSpace([axis("read_fraction", [0.0, 1.0])])
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            space.evaluate(metrics=("bandwidth_gbs",),
                           stream=StreamConfig(devices=4096))
