"""Convergence-adaptive flit-simulation engine (SimConfig) tests.

Contracts:

  * ``mode="fixed"`` (the default) is the exact pre-config engine — the
    pinned seed goldens in test_flitsim_sweep.py keep covering it, and the
    explicit ``sim=FIXED_SIM`` spelling is bit-identical to the default.
  * ``mode="adaptive"`` tracks the fixed engine within 1e-3 across mixes,
    backlogs, perturbations and all five protocols (property-based when
    hypothesis is available), while running fewer sequential cycles.
  * switching SimConfig never invalidates other configs' warm cache
    entries (the config participates in the shared cache key).
  * the PHY-absolute ``sim_bandwidth_gbs`` metric threads UCIePhy raw
    bandwidth into the simulated efficiency (phy axis or phy=).
  * the ``write_buffer_lines`` bugfix field: default preserves numerics
    bit-for-bit, and the write path is now independently perturbable.
"""
import numpy as np
import pytest

from repro.core import flitsim
from repro.core import space as space_mod
from repro.core.flitsim import (
    ADAPTIVE_SIM, FIXED_SIM, SYMMETRIC_PARAMS, SimConfig,
    SymmetricFlitParams, simulate_symmetric,
)
from repro.core.flitsim import _sweep_impl as sweep
from repro.core.flitsim import _sweep_pipelining_impl as sweep_pipelining
from repro.core.space import DesignSpace, axis
from repro.core.ucie import UCIE_A_48G_45U, UCIE_S_32G

DENSE_BACKLOGS = (1.0, 2.0, 8.0, 64.0)


def _dense_mixes(n=13):
    fr = np.linspace(0.0, 1.0, n)
    return list(zip((100.0 * fr).tolist(), (100.0 - 100.0 * fr).tolist()))


class TestSimConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="mode"):
            SimConfig(mode="turbo")
        with pytest.raises(ValueError, match="chunk"):
            SimConfig(chunk=1)
        with pytest.raises(ValueError, match="tol"):
            SimConfig(tol=0.0)
        with pytest.raises(ValueError, match="unroll"):
            SimConfig(unroll=0)
        with pytest.raises(ValueError, match="max_cycles"):
            SimConfig(max_cycles=0)

    def test_cache_keys_distinguish_configs(self):
        assert FIXED_SIM.key() == ("fixed",)
        assert ADAPTIVE_SIM.key() != FIXED_SIM.key()
        assert SimConfig(mode="adaptive", tol=1e-4).key() != \
            ADAPTIVE_SIM.key()

    def test_horizon_override(self):
        assert FIXED_SIM.horizon(2048) == 2048
        assert SimConfig(max_cycles=512).horizon(2048) == 512

    def test_divisor_chunk_lands_on_horizon(self):
        for horizon in (2048, 4096, 512, 1000):
            c = flitsim._divisor_chunk(horizon, 128)
            assert horizon % c == 0
            assert horizon // c >= 8

    def test_divisor_chunk_prefers_warm_window_alignment(self):
        # a chunk count divisible by 4 makes the reconstructed warm
        # window start exactly at horizon // 4
        for horizon in (2048, 4096, 512, 1024, 1100):
            c = flitsim._divisor_chunk(horizon, 128)
            assert (horizon // c) % 4 == 0, (horizon, c)

    def test_prime_horizon_falls_back_to_fixed(self):
        # 1021 is prime: no usable chunk divisor — adaptive must degrade
        # to the fixed engine at that horizon, not to per-cycle chunking
        assert flitsim._divisor_chunk(1021, 128) < 8
        cfg = SimConfig(mode="adaptive", max_cycles=1021)
        a = sweep(protocols=["chi"], mixes=[(1, 1)], sim=cfg)
        f = sweep(protocols=["chi"], mixes=[(1, 1)], n_flits=1021)
        np.testing.assert_array_equal(np.asarray(a.efficiency),
                                      np.asarray(f.efficiency))

    def test_chunk_larger_than_horizon(self):
        # configured chunk above the horizon: the cap clamps to
        # horizon // 8, so short horizons still get >= 8 checks
        for horizon in (64, 128, 256):
            c = flitsim._divisor_chunk(horizon, 1024)
            assert horizon % c == 0 and horizon // c >= 8, (horizon, c)

    def test_divisor_poor_small_horizon_bit_identical_to_fixed(self):
        # 2 * 31: the only divisors <= horizon // 8 are 1 and 2 — below
        # the usable-chunk floor, so the runner must hand the run to the
        # fixed engine verbatim (bit-identity, not merely within tol)
        assert flitsim._divisor_chunk(62, 128) < 8
        for engine in ("xla", "pallas"):
            cfg = SimConfig(mode="adaptive", max_cycles=62, engine=engine)
            a = sweep(protocols=["cxl_opt"], mixes=[(2, 1), (0, 1)],
                      sim=cfg)
            f = sweep(protocols=["cxl_opt"], mixes=[(2, 1), (0, 1)],
                      n_flits=62)
            np.testing.assert_array_equal(np.asarray(a.efficiency),
                                          np.asarray(f.efficiency))

    def test_chunk_count_not_divisible_by_4_still_lands(self):
        # 162 = 2 * 81 carries a single factor of 2, so NO divisor can
        # make the chunk count a multiple of 4 — _divisor_chunk must
        # still take the best usable divisor (18 -> 9 chunks) rather
        # than fall back to the fixed engine
        c162 = flitsim._divisor_chunk(162, 128)
        assert c162 == 18 and (162 // c162) % 4 != 0
        cfg = SimConfig(mode="adaptive", max_cycles=162)
        a = sweep(protocols=["chi"], mixes=[(1, 1)], sim=cfg)
        f = sweep(protocols=["chi"], mixes=[(1, 1)], n_flits=162)
        # a usable divisor exists, so this runs the ADAPTIVE engine
        # (within tol), not the fixed fall-back
        assert float(np.max(np.abs(np.asarray(a.efficiency)
                                   - np.asarray(f.efficiency)))) <= 1e-3
        assert flitsim.last_run_info()["flitsim.symmetric"]["chunk"] == c162


class TestFixedModeUnchanged:
    def test_default_is_fixed_and_bit_identical(self):
        base = sweep(mixes=[(2, 1), (1, 1)])
        explicit = sweep(mixes=[(2, 1), (1, 1)], sim=FIXED_SIM)
        np.testing.assert_array_equal(np.asarray(base.efficiency),
                                      np.asarray(explicit.efficiency))

    def test_fixed_warm_after_adaptive_run(self):
        """Alternating configs must not invalidate each other's entries —
        enforced both by the shared-cache counters and by the runtime
        retrace sanitizer (zero compile events on the warm replay)."""
        from repro.lint import runtime

        flitsim.clear_compile_cache()
        mixes = [(3, 2), (1, 1)]
        sweep(mixes=mixes)                      # fixed: 2 compiles
        sweep(mixes=mixes, sim=ADAPTIVE_SIM)    # adaptive: 2 more
        after_both = flitsim.compile_cache_stats()
        assert after_both.misses == 4
        with runtime.no_retrace():              # any compile -> RetraceError
            sweep(mixes=mixes)                  # fixed again: warm
            sweep(mixes=mixes, sim=ADAPTIVE_SIM)  # adaptive again: warm
        final = flitsim.compile_cache_stats()
        assert final.misses == after_both.misses, \
            "switching SimConfig invalidated a warm cache entry"
        assert final.hits > after_both.hits


class TestAdaptiveMatchesFixed:
    def test_canonical_sweep(self):
        f = np.asarray(sweep().efficiency)
        a = np.asarray(sweep(sim=ADAPTIVE_SIM).efficiency)
        assert float(np.max(np.abs(f - a))) <= 1e-3

    def test_dense_mix_backlog_grid(self):
        mixes = _dense_mixes()
        f = np.asarray(sweep(mixes=mixes,
                             backlogs=list(DENSE_BACKLOGS)).efficiency)
        a = np.asarray(sweep(mixes=mixes, backlogs=list(DENSE_BACKLOGS),
                             sim=ADAPTIVE_SIM).efficiency)
        assert float(np.max(np.abs(f - a))) <= 1e-3

    def test_adaptive_runs_fewer_cycles(self):
        sweep(sim=ADAPTIVE_SIM)
        info = flitsim.last_run_info()
        assert set(info) >= {"flitsim.symmetric", "flitsim.asymmetric"}
        # scope to the families THIS sweep ran — other tests may leave
        # run info (e.g. a pipelining grid that legitimately hit horizon)
        for fam in ("flitsim.symmetric", "flitsim.asymmetric"):
            v = info[fam]
            assert v["cycles_run"] < v["horizon"], (fam, v)
            assert sum(v["converged_cycles"].values()) == v["cells"]

    def test_pipelining_adaptive(self):
        ks = [1, 2, 3, 4, 6]
        f = np.asarray(sweep_pipelining(ks))
        a = np.asarray(sweep_pipelining(ks, sim=ADAPTIVE_SIM))
        assert float(np.max(np.abs(f - a))) <= 1e-3
        # the k=4 saturation claim survives the adaptive engine
        assert a[3] == pytest.approx(1.0, abs=2e-3)

    def test_joint_pipelining_adaptive(self):
        f = np.asarray(sweep_pipelining((1, 2, 4), ucie_line_ui=(8.0, 16.0),
                                        device_line_ui=(32.0, 64.0)))
        a = np.asarray(sweep_pipelining((1, 2, 4), ucie_line_ui=(8.0, 16.0),
                                        device_line_ui=(32.0, 64.0),
                                        sim=ADAPTIVE_SIM))
        assert float(np.max(np.abs(f - a))) <= 1e-3

    def test_straggler_escalation_on_large_grid(self):
        """A grid above the escalation floor may strand stragglers; they
        must be re-simulated exactly (match the fixed engine ~exactly,
        not just within tol)."""
        mixes = _dense_mixes(41)
        backlogs = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0]
        f = np.asarray(sweep(protocols=tuple(SYMMETRIC_PARAMS),
                             mixes=mixes, backlogs=backlogs).efficiency)
        a = np.asarray(sweep(protocols=tuple(SYMMETRIC_PARAMS),
                             mixes=mixes, backlogs=backlogs,
                             sim=ADAPTIVE_SIM).efficiency)
        info = flitsim.last_run_info()["flitsim.symmetric"]
        assert info["cells"] == 3 * len(backlogs) * len(mixes)
        assert float(np.max(np.abs(f - a))) <= 1e-3
        if info["stragglers"]:
            # straggler cells ran the full fixed horizon — their rows in
            # the histogram count under "horizon"
            assert info["converged_cycles"].get("horizon", 0) >= \
                info["stragglers"]

    def test_perturbations_adaptive(self):
        perts = [{}, {"credit_lines": 0.5}, {"g_slots": 0.8}]
        f = flitsim.sweep_perturbed(perts, protocols=("cxl_opt", "chi"),
                                    mixes=[(2, 1), (1, 1)])
        a = flitsim.sweep_perturbed(perts, protocols=("cxl_opt", "chi"),
                                    mixes=[(2, 1), (1, 1)],
                                    sim=ADAPTIVE_SIM)
        dev = np.max(np.abs(f["sim_efficiency"].values
                            - a["sim_efficiency"].values))
        assert float(dev) <= 1e-3


@pytest.mark.parametrize("protocol", sorted(flitsim.SIMULATORS))
def test_adaptive_property_per_protocol(protocol):
    """Deterministic per-protocol spot check (the hypothesis sweep below
    covers random combinations)."""
    mixes = [(1, 0), (5, 3), (1, 1), (2, 7), (0, 1)]
    f = np.asarray(sweep(protocols=[protocol], mixes=mixes,
                         backlogs=[2.0, 64.0]).efficiency)
    a = np.asarray(sweep(protocols=[protocol], mixes=mixes,
                         backlogs=[2.0, 64.0],
                         sim=ADAPTIVE_SIM).efficiency)
    assert float(np.max(np.abs(f - a))) <= 1e-3, protocol


class TestAdaptiveHypothesis:
    """Property-based fixed-vs-adaptive agreement (needs hypothesis)."""

    @classmethod
    def setup_class(cls):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis; the deterministic "
                   "grids above cover the bare environment")

    def test_random_mixes_backlogs_perturbations(self):
        from hypothesis import given, settings, strategies as st

        mix = st.tuples(st.integers(0, 8), st.integers(0, 8)).filter(
            lambda t: t[0] + t[1] > 0)
        backlog = st.sampled_from([1.0, 2.0, 4.0, 16.0, 64.0, 128.0])
        pert = st.sampled_from([{}, {"credit_lines": 0.5},
                                {"write_buffer_lines": 0.5},
                                {"g_slots": 0.8}, {"read_lanes": 0.8},
                                {"total_lanes": 1.2}])

        @settings(max_examples=10, deadline=None)
        @given(mix=mix, bl=backlog, pert=pert)
        def inner(mix, bl, pert):
            perts = [{}, pert] if pert else [{}]
            kw = dict(mixes=[mix], backlogs=[bl])
            f = flitsim.sweep_perturbed(perts, **kw)
            a = flitsim.sweep_perturbed(perts, sim=ADAPTIVE_SIM, **kw)
            dev = np.max(np.abs(f["sim_efficiency"].values
                                - a["sim_efficiency"].values))
            assert float(dev) <= 1e-3, (mix, bl, pert)

        inner()


class TestDesignSpaceSimThreading:
    def test_space_and_evaluate_override(self):
        axes = [axis("mix", [(2, 1), (1, 1)]), axis("backlog", [4.0, 64.0])]
        fixed = DesignSpace(axes).evaluate(metrics=("sim_efficiency",))
        adapt = DesignSpace(axes, sim=ADAPTIVE_SIM).evaluate(
            metrics=("sim_efficiency",))
        override = DesignSpace(axes).evaluate(
            metrics=("sim_efficiency",), sim=ADAPTIVE_SIM)
        assert fixed.sim.mode == "fixed"
        assert adapt.sim.mode == "adaptive"
        dev = np.max(np.abs(fixed["sim_efficiency"].values
                            - adapt["sim_efficiency"].values))
        assert float(dev) <= 1e-3
        np.testing.assert_array_equal(adapt["sim_efficiency"].values,
                                      override["sim_efficiency"].values)

    def test_bridge_accepts_sim(self):
        from repro.roofline.analysis import (
            RooflineReport, bridge_design_space,
        )
        rep = RooflineReport(
            arch="w", shape="s", mesh="m", chips=16,
            hlo_flops_per_chip=1e12, hlo_bytes_per_chip=1e10,
            collective_bytes_per_chip=1e9, compute_s=1e-3, memory_s=1e-2,
            collective_s=1e-2, dominant="memory", model_flops=1e13,
            useful_flops_ratio=0.5, read_bytes_per_chip=7e9,
            write_bytes_per_chip=3e9)
        base = bridge_design_space({"w": rep}, n_fracs=5)
        adap = bridge_design_space({"w": rep}, n_fracs=5,
                                   sim=ADAPTIVE_SIM)
        # analytic closed forms are sim-independent -> identical report
        assert base["workloads"]["w"]["best"] == \
            adap["workloads"]["w"]["best"]

    def test_joint_frontier_accepts_sim(self):
        f = space_mod.joint_frontier(n_fracs=5, backlogs=(2.0, 64.0),
                                     shorelines=(8.0,), n_flits=1024)
        a = space_mod.joint_frontier(n_fracs=5, backlogs=(2.0, 64.0),
                                     shorelines=(8.0,), n_flits=1024,
                                     sim=SimConfig(mode="adaptive",
                                                   max_cycles=1024))
        assert f["keys"] == a["keys"]


class TestSimPhyMetric:
    def test_values_and_dims(self):
        phys = [UCIE_S_32G, UCIE_A_48G_45U]
        res = DesignSpace([
            axis("phy", phys),
            axis("read_fraction", [0.0, 0.5, 1.0]),
            axis("backlog", [64.0]),
        ]).evaluate(metrics=("sim_efficiency", "sim_bandwidth_gbs"))
        eff = res["sim_efficiency"]
        bw = res["sim_bandwidth_gbs"]
        assert bw.dims == ("protocol", "phy", "backlog", "read_fraction")
        assert bw.coord("phy") == tuple(p.name for p in phys)
        for i, p in enumerate(phys):
            np.testing.assert_allclose(
                bw.values[:, i], eff.values * p.raw_bandwidth_gbs,
                rtol=1e-6)

    def test_phy_kwarg_drops_dim(self):
        res = DesignSpace([axis("read_fraction", [0.5]),
                           axis("backlog", [64.0])],
                          phy=UCIE_S_32G).evaluate(
            metrics=("sim_efficiency", "sim_bandwidth_gbs"))
        assert "phy" not in res["sim_bandwidth_gbs"].dims

    def test_requires_phy(self):
        with pytest.raises(ValueError, match="phy"):
            DesignSpace([axis("read_fraction", [0.5])]).evaluate(
                metrics=("sim_bandwidth_gbs",))

    def test_default_metrics_include_sim_phy(self):
        space = DesignSpace([axis("phy", [UCIE_S_32G]),
                             axis("read_fraction", [0.5]),
                             axis("backlog", [64.0])])
        assert "sim_bandwidth_gbs" in space._default_metrics()

    def test_48g_scales_simulated_bandwidth(self):
        res = DesignSpace([
            axis("phy", [UCIE_S_32G, UCIE_A_48G_45U]),
            axis("read_fraction", [0.7]),
            axis("backlog", [64.0]),
        ]).evaluate(metrics=("sim_bandwidth_gbs",))
        bw = res["sim_bandwidth_gbs"]
        g32 = bw.sel(phy=UCIE_S_32G.name).values
        g48 = bw.sel(phy=UCIE_A_48G_45U.name).values
        # 48G advanced package carries more absolute GB/s at identical
        # simulated efficiency
        assert (g48 > g32).all()


class TestWriteBufferLines:
    def test_default_aliases_credit_lines(self):
        p = SymmetricFlitParams.cxl_opt()
        assert float(p.write_buffer_lines) == float(p.credit_lines)
        deep = SymmetricFlitParams.cxl_opt()
        import dataclasses
        custom = dataclasses.replace(deep, credit_lines=4.0,
                                     write_buffer_lines=None)
        assert float(custom.write_buffer_lines) == 4.0

    def test_default_numerics_preserved(self):
        """The split field must not change the engine's outputs — the
        pinned seed goldens in test_flitsim_sweep.py double-cover this."""
        eff = simulate_symmetric(SymmetricFlitParams.cxl_opt(), 2, 1)
        assert eff == pytest.approx(0.68565327, abs=1e-6)

    def test_field_is_perturbable(self):
        assert "write_buffer_lines" in flitsim.PERTURBABLE_FIELDS
        res = flitsim.sweep_perturbed(
            [{}, {"write_buffer_lines": 0.05}], protocols=("cxl_opt",),
            mixes=[(0, 1), (1, 0)], backlogs=[64.0])
        eff = res["sim_efficiency"].values      # [pert, proto, bl, mix]
        # squeezing the write buffer throttles the write-heavy mix...
        assert eff[1, 0, 0, 0] < eff[0, 0, 0, 0] - 0.01
        # ...and leaves the pure-read mix untouched
        assert eff[1, 0, 0, 1] == pytest.approx(eff[0, 0, 0, 1], abs=1e-6)

    def test_credit_perturbation_no_longer_moves_write_path(self):
        """Pre-fix, credit_lines doubled as the write-buffer bound; now a
        pure-write mix is insensitive to it."""
        res = flitsim.sweep_perturbed(
            [{}, {"credit_lines": 0.05}], protocols=("cxl_opt",),
            mixes=[(0, 1)], backlogs=[64.0])
        eff = res["sim_efficiency"].values
        assert eff[1, 0, 0, 0] == pytest.approx(eff[0, 0, 0, 0], abs=1e-6)
