"""repro-lint: fixture goldens per check, suppression semantics, the
clean-tree CI gate, and the runtime tracer-safety sanitizer.

Contracts:

  * each check RL001–RL005 fires on its known-bad fixture and stays
    silent on the known-good twin;
  * ``# repro-lint: disable=RLxxx`` keeps the finding in the report
    (suppressed) without failing the run;
  * ``python -m repro.lint --json`` over the real ``src/`` tree exits 0
    with zero unsuppressed findings — the CI lint gate;
  * adding a numerics-affecting field to a ``key()``-carrying dataclass
    without extending the key is caught (the PR 5/6 incident class);
  * the runtime sanitizer detects a fresh compile inside a
    ``no_retrace`` section, passes warm sections, and arms
    ``jax.transfer_guard``.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.lint import run_lint
from repro.lint.engine import LintError

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"
SRC = REPO / "src"


def _ids(report):
    return sorted({f.check for f in report.unsuppressed})


class TestFixtures:
    @pytest.mark.parametrize("check,good,bad", [
        ("RL001", "rl001_good.py", "rl001_bad.py"),
        ("RL002", "rl002_good", "rl002_bad"),
        ("RL003", "rl003_good.py", "rl003_bad.py"),
        ("RL004", "rl004_good.py", "rl004_bad.py"),
        ("RL005", "rl005_good.py", "rl005_bad.py"),
    ])
    def test_good_bad_pair(self, check, good, bad):
        assert check not in _ids(run_lint(FIXTURES / good)), \
            f"{check} false positive on {good}"
        assert check in _ids(run_lint(FIXTURES / bad)), \
            f"{check} missed the seeded defect in {bad}"

    def test_simconfig_style_key_omission_names_the_field(self):
        """Acceptance: a numerics-affecting field added without extending
        the compile-cache key fails the lint gate, by name."""
        msgs = [f.message for f in run_lint(FIXTURES / "rl001_bad.py")
                .unsuppressed if f.check == "RL001"]
        assert any("staleness" in m and "key()" in m for m in msgs), msgs

    def test_rl001_catches_row_count_drift(self):
        msgs = [f.message for f in run_lint(FIXTURES / "rl001_bad.py")
                .unsuppressed if f.check == "RL001"]
        assert any("RowParams" in m and "2 positional rows" in m
                   for m in msgs), msgs

    def test_rl002_reports_all_three_contracts(self):
        msgs = [f.message for f in run_lint(FIXTURES / "rl002_bad")
                .unsuppressed if f.check == "RL002"]
        assert any("never imports" in m for m in msgs)
        assert any("re-defines 'demo_compute'" in m for m in msgs)
        assert any("row-stacked with cells LAST" in m for m in msgs)

    def test_rl004_reports_each_sync_point(self):
        msgs = " | ".join(f.message
                          for f in run_lint(FIXTURES / "rl004_bad.py")
                          .unsuppressed if f.check == "RL004")
        assert "Python `if`" in msgs
        assert "Python `while`" in msgs
        assert "stray numpy" in msgs
        assert "float() on a traced value" in msgs
        assert "in-flight device value" in msgs

    def test_rl004_good_fixture_retire_sync_is_audited(self):
        """The bounded-FIFO retire sync in the good fixture is reported
        suppressed — audited, not invisible."""
        rep = run_lint(FIXTURES / "rl004_good.py")
        sup = [f for f in rep.suppressed if f.check == "RL004"]
        assert len(sup) == 1 and "in-flight device value" in sup[0].message

    def test_suppression_keeps_finding_in_report(self):
        rep = run_lint(FIXTURES / "rl_suppressed.py")
        assert not rep.unsuppressed
        assert [(f.check, f.suppressed) for f in rep.findings] == \
            [("RL003", True)]

    def test_unknown_check_id_rejected(self):
        with pytest.raises(LintError, match="RL999"):
            run_lint(FIXTURES / "rl001_good.py", select=["RL999"])

    def test_select_runs_only_requested_checks(self):
        rep = run_lint(FIXTURES / "rl004_bad.py", select=["RL003"])
        assert rep.checks == ("RL003",)
        assert not rep.findings


class TestRealTree:
    def test_src_tree_clean_in_process(self):
        rep = run_lint(SRC)
        assert rep.files > 50
        assert not rep.unsuppressed, \
            "\n".join(f.format() for f in rep.unsuppressed)
        # the three pre-PR-6 kernels carry audited RL002 suppressions;
        # the two streaming retire paths carry audited RL004 ones
        assert {f.path for f in rep.suppressed} == {
            "repro/kernels/flash_attention/kernel.py",
            "repro/kernels/rglru_scan/kernel.py",
            "repro/kernels/ssd_scan/kernel.py",
            "repro/core/streaming.py",
        }

    def test_cli_json_exit_zero(self):
        """The CI gate: ``python -m repro.lint --json`` exits 0 on the
        real tree and reports all five checks."""
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH",
                                                            "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.lint", "--json"],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        payload = json.loads(proc.stdout)
        assert payload["checks"] == ["RL001", "RL002", "RL003", "RL004",
                                     "RL005"]
        assert payload["counts"]["unsuppressed"] == 0
        assert payload["counts"]["suppressed"] == 5
        assert payload["files"] > 50

    def test_cli_fails_on_bad_fixture(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH",
                                                            "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.lint",
             str(FIXTURES / "rl001_bad.py")],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert proc.returncode == 1
        assert "RL001" in proc.stdout


class TestRuntimeSanitizer:
    def test_fresh_compile_detected(self):
        import jax
        import jax.numpy as jnp
        from repro.lint import runtime

        @jax.jit
        def fresh(x):
            return x * 2.0 + 1.0

        with pytest.raises(runtime.RetraceError, match="compile event"):
            with runtime.no_retrace():
                fresh(jnp.arange(7.0)).block_until_ready()

    def test_warm_section_passes(self):
        import jax
        import jax.numpy as jnp
        from repro.lint import runtime

        @jax.jit
        def warm(x):
            return x - 3.0

        x = jnp.arange(5.0)
        warm(x).block_until_ready()
        with runtime.no_retrace() as log:
            warm(x).block_until_ready()
        assert log.count == 0

    def test_transfer_guard_wiring(self):
        import jax.numpy as jnp
        import numpy as np
        from repro.lint import runtime

        x = jnp.ones(3)
        x.block_until_ready()
        with pytest.raises(Exception, match="[Dd]isallow"):
            with runtime.no_retrace(max_compiles=100, transfer="disallow"):
                (x + np.arange(3.0)).block_until_ready()  # implicit h2d
