"""Property tests: the discrete-event flit simulator validates every
closed-form bandwidth-efficiency expression (hypothesis over traffic mixes),
plus invariant properties of the analytic models themselves."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; the batched "
                           "sweep regressions in test_flitsim_sweep.py "
                           "cover the bare environment")
from hypothesis import given, settings, strategies as st

from repro.core import ALL_APPROACHES, PAPER_MIXES
from repro.core.flitsim import (
    ANALYTIC, SIMULATORS, simulate_lpddr6_pipelining,
)

MIX = st.tuples(st.integers(0, 8), st.integers(0, 8)).filter(
    lambda t: t[0] + t[1] > 0)


def f(v):
    return float(np.asarray(v))


class TestSimulatorMatchesAnalytic:
    @settings(max_examples=20, deadline=None)
    @given(MIX)
    def test_cxl_unopt(self, mix):
        x, y = mix
        a, s = f(ANALYTIC["cxl_unopt"].bw_eff(x, y)), SIMULATORS["cxl_unopt"](x, y)
        assert abs(a - s) / a < 0.02

    @settings(max_examples=20, deadline=None)
    @given(MIX)
    def test_cxl_opt(self, mix):
        x, y = mix
        a, s = f(ANALYTIC["cxl_opt"].bw_eff(x, y)), SIMULATORS["cxl_opt"](x, y)
        assert abs(a - s) / a < 0.02

    @settings(max_examples=20, deadline=None)
    @given(MIX)
    def test_chi(self, mix):
        x, y = mix
        a, s = f(ANALYTIC["chi"].bw_eff(x, y)), SIMULATORS["chi"](x, y)
        assert abs(a - s) / a < 0.02

    @settings(max_examples=20, deadline=None)
    @given(MIX)
    def test_lpddr6_asym(self, mix):
        x, y = mix
        a = f(ANALYTIC["lpddr6_asym"].bw_eff(x, y))
        s = SIMULATORS["lpddr6_asym"](x, y)
        assert abs(a - s) / a < 0.02

    @settings(max_examples=20, deadline=None)
    @given(MIX)
    def test_hbm_asym(self, mix):
        x, y = mix
        a = f(ANALYTIC["hbm_asym"].bw_eff(x, y))
        s = SIMULATORS["hbm_asym"](x, y)
        assert abs(a - s) / a < 0.02


class TestAnalyticInvariants:
    """Properties every protocol model must satisfy, for any mix."""

    @settings(max_examples=50, deadline=None)
    @given(MIX)
    def test_efficiency_bounded(self, mix):
        x, y = mix
        for key, proto in ALL_APPROACHES.items():
            e = f(proto.bw_eff(x, y))
            assert 0.0 < e <= 1.0, (key, x, y, e)

    @settings(max_examples=50, deadline=None)
    @given(MIX)
    def test_power_ratio_bounded(self, mix):
        x, y = mix
        for key, proto in ALL_APPROACHES.items():
            pd = f(proto.p_data(x, y))
            assert 0.0 < pd <= 1.0, (key, x, y, pd)

    @settings(max_examples=50, deadline=None)
    @given(MIX, st.integers(1, 7))
    def test_scale_invariance(self, mix, k):
        """xRyW and kx R ky W are the same mix — all metrics identical."""
        x, y = mix
        for key, proto in ALL_APPROACHES.items():
            assert f(proto.bw_eff(x, y)) == pytest.approx(
                f(proto.bw_eff(k * x, k * y)), rel=1e-5), key
            assert f(proto.p_data(x, y)) == pytest.approx(
                f(proto.p_data(k * x, k * y)), rel=1e-5), key

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 8))
    def test_read_monotone_toward_optimum_asym(self, x):
        """For the 2:1-provisioned asymmetric HBM mapping, adding reads up
        to the provisioned ratio only helps; beyond it only hurts."""
        proto = ALL_APPROACHES["B:hbm-asym"]
        e_balanced = f(proto.bw_eff(2, 1))          # provisioned ratio
        assert f(proto.bw_eff(x, 1)) <= e_balanced + 1e-6

    def test_power_gating_helps_idle_direction(self):
        """Read-only traffic should cost less energy/bit than 50/50 on the
        asymmetric mappings (write lanes gated)."""
        proto = ALL_APPROACHES["A:lpddr6-asym"]
        assert f(proto.p_data(1, 0)) > f(proto.p_data(1, 4))


class TestLPDDR6Pipelining:
    """Appendix Fig 13: four x12 LPDDR6 devices saturate the UCIe link."""

    def test_four_devices_saturate(self):
        assert simulate_lpddr6_pipelining(4) == pytest.approx(1.0, abs=1e-3)

    @pytest.mark.parametrize("k", [1, 2, 3])
    def test_fewer_devices_proportional(self, k):
        u = simulate_lpddr6_pipelining(k)
        assert u == pytest.approx(k / 4, abs=0.01)

    def test_more_devices_no_overdrive(self):
        assert simulate_lpddr6_pipelining(6) <= 1.0 + 1e-6
