"""Tests for optimizer, gradient compression, data pipeline, train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get
from repro.configs.shapes import ShapeSpec
from repro.models import ShardingCtx, build
from repro.train import (
    AdamW, SyntheticLM, constant_schedule, cosine_schedule, global_norm,
    grad_compress, init_state, make_train_step,
)

CTX = ShardingCtx()


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(learning_rate=constant_schedule(0.1), weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_grad_clipping(self):
        opt = AdamW(learning_rate=constant_schedule(0.1), grad_clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        _, _, metrics = opt.update({"w": jnp.full((4,), 100.0)}, state,
                                   params)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(jnp.array(0))) == 0.0
        assert float(lr(jnp.array(10))) == pytest.approx(1.0)
        assert float(lr(jnp.array(110))) == pytest.approx(0.1, abs=1e-3)

    def test_weight_decay_shrinks(self):
        opt = AdamW(learning_rate=constant_schedule(0.1), weight_decay=0.5,
                    grad_clip_norm=None)
        params = {"w": jnp.full((2,), 10.0)}
        state = opt.init(params)
        p2, _, _ = opt.update({"w": jnp.zeros(2)}, state, params)
        assert float(p2["w"][0]) < 10.0


class TestGradCompression:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 1000))
    def test_roundtrip_error_bounded(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (256,))
        e0 = jnp.zeros((256,))
        deq, err = grad_compress.compress_tree({"g": g}, {"g": e0})
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(deq["g"] - g))) <= scale * 0.51 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        """With EF, the *accumulated* applied gradient tracks the true sum."""
        key = jax.random.PRNGKey(0)
        true_sum = jnp.zeros((64,))
        applied_sum = jnp.zeros((64,))
        err = {"g": jnp.zeros((64,))}
        for i in range(50):
            g = jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.1
            true_sum = true_sum + g
            deq, err_new = grad_compress.compress_tree({"g": g}, err)
            err = err_new
            applied_sum = applied_sum + deq["g"]
        resid = float(jnp.max(jnp.abs(true_sum - (applied_sum + err["g"]))))
        assert resid < 1e-4      # sum(applied) + residual == sum(true)

    def test_compression_ratio(self):
        assert grad_compress.compression_ratio() == 0.25


class TestDataPipeline:
    def test_deterministic_per_step(self):
        cfg = get("smollm-360m").reduced()
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 4, "train"))
        b1 = src.batch_for_step(7)
        b2 = src.batch_for_step(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = src.batch_for_step(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_family_specific_inputs(self):
        cfg = get("seamless-m4t-large-v2").reduced()
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 4, "train"))
        b = src.batch_for_step(0)
        assert set(b) == {"frames", "tokens", "labels"}
        cfg = get("internvl2-1b").reduced()
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 4, "train"))
        b = src.batch_for_step(0)
        assert set(b) == {"tokens", "patch_embeds", "labels"}
        assert b["tokens"].shape[1] == 16 - cfg.frontend_tokens


class TestTrainStep:
    def test_microbatched_equals_full_batch(self):
        """Gradient accumulation over microbatches == one big batch."""
        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(1e-2),
                    weight_decay=0.0, grad_clip_norm=None)
        state0 = init_state(model, jax.random.PRNGKey(0), opt)
        src = SyntheticLM(cfg, ShapeSpec("t", 8, 16, "train"))
        batch = src.place(src.batch_for_step(0), CTX)

        s1, m1 = make_train_step(model, opt, CTX, num_microbatches=1)(
            state0, batch)
        s4, m4 = make_train_step(model, opt, CTX, num_microbatches=4)(
            state0, batch)
        l1, l4 = float(m1["loss"]), float(m4["loss"])
        assert l1 == pytest.approx(l4, rel=2e-2)
        d = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(s1.params),
                            jax.tree.leaves(s4.params)))
        # adam's first-step normalization amplifies bf16 grad noise on
        # near-zero second moments; 5e-2 still catches real accumulation bugs
        assert d < 5e-2

    def test_compressed_training_still_converges(self):
        cfg = get("smollm-360m").reduced()
        model = build(cfg)
        opt = AdamW(learning_rate=constant_schedule(3e-3))
        state = init_state(model, jax.random.PRNGKey(0), opt, compress=True)
        step = jax.jit(make_train_step(model, opt, CTX, compress=True))
        src = SyntheticLM(cfg, ShapeSpec("t", 16, 8, "train"))
        losses = []
        for i in range(8):
            state, metrics = step(state, src.place(src.batch_for_step(i),
                                                   CTX))
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]
