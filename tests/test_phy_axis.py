"""Tests for the first-class ``phy`` axis, analytic ``catalog_param``
perturbations, and the constraint-aware ``feasible()`` / ``where=`` masks.

Acceptance contracts (ISSUE 4):

  * the full [phy x mix x shoreline] catalog evaluation compiles exactly
    once per engine family (shared-cache counters);
  * UCIe-A / UCIe-S rows of the PHY-stacked space are BIT-identical to the
    pre-axis flat catalog (``_catalog_grid_impl`` keys ``.../UCIe-A``);
  * ``SpaceResult.frontier(..., where=mask)`` reproduces the
    ``selector._rank_grid_impl`` feasible-set winners on the bridge layout;
  * UCIe-2.0 / 48G entries scale density linearly at constant pJ/b;
  * per-cell artifact consumers SKIP (not crash on) artifacts carrying the
    new ``phy`` / ``catalog_param`` dimensions.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core import space as space_mod
from repro.core.memsys import (
    approach_catalog_items, approach_grid, default_catalog_items,
)
from repro.core.memsys import _catalog_grid_impl as catalog_grid
from repro.core.selector import (
    SelectionConstraints, grid_ranking, system_mask,
)
from repro.core.selector import _rank_grid_impl as rank_grid
from repro.core.space import DesignSpace, OWN_MIX, axis
from repro.core.traffic import TrafficMix
from repro.core.ucie import (
    PERTURBABLE_PHY_FIELDS, UCIE_A_32G_55U, UCIE_A_48G_45U, UCIE_S_32G,
    UCIE_S_48G_110U,
)

PHYS = (UCIE_S_32G, UCIE_A_32G_55U, UCIE_S_48G_110U, UCIE_A_48G_45U)

#: flat-catalog key suffix -> canonical phy label on the axis
TAG_TO_PHY = {"UCIe-A": UCIE_A_32G_55U.name, "UCIe-S": UCIE_S_32G.name}


class TestUcie2Entries:
    """UCIe 2.0 / 48G data points: §V bump-limited scaling — density grows
    linearly with data rate at constant power efficiency."""

    @pytest.mark.parametrize("g48,g32,lin_gain", [
        (UCIE_S_48G_110U, UCIE_S_32G, 1.5),
        # the 48G advanced point rides the 45um pitch: 1.5x rate on top of
        # the (55/45) linear pitch gain over the published 55um numbers
        (UCIE_A_48G_45U, UCIE_A_32G_55U, 1.5 * 55.0 / 45.0),
    ])
    def test_density_scales_at_constant_power(self, g48, g32, lin_gain):
        assert g48.data_rate_gtps == 48.0
        assert g48.linear_density_gbs_mm == pytest.approx(
            g32.linear_density_gbs_mm * lin_gain)
        assert g48.power_pj_per_bit == g32.power_pj_per_bit
        assert g48.lanes_per_direction == g32.lanes_per_direction
        assert g48.raw_bandwidth_gbs == pytest.approx(
            g32.raw_bandwidth_gbs * 1.5)

    def test_s48_exact_values(self):
        assert UCIE_S_48G_110U.linear_density_gbs_mm == pytest.approx(
            224.0 * 1.5)
        assert UCIE_S_48G_110U.areal_density_gbs_mm2 == pytest.approx(
            145.44 * 1.5)

    def test_catalog_monotone_in_data_rate(self):
        """Every approach's deliverable bandwidth is monotonically better
        on the 48G generation at every mix — the paper's §V claim."""
        fracs = np.linspace(0.0, 1.0, 9)
        res = DesignSpace([
            axis("phy", [UCIE_S_32G, UCIE_S_48G_110U]),
            axis("read_fraction", fracs),
        ]).evaluate(metrics=("bandwidth_gbs", "pj_per_bit"))
        bw = res["bandwidth_gbs"]
        assert (bw.sel(phy=UCIE_S_48G_110U.name).values
                >= bw.sel(phy=UCIE_S_32G.name).values).all()
        pj = res["pj_per_bit"]
        np.testing.assert_array_equal(
            pj.sel(phy=UCIE_S_48G_110U.name).values,
            pj.sel(phy=UCIE_S_32G.name).values)

    def test_phy_perturbed_validates_fields(self):
        with pytest.raises(ValueError, match="unknown catalog perturbation"):
            UCIE_S_32G.perturbed({"warp_drive": 2.0})
        p = UCIE_S_32G.perturbed({"power_pj_per_bit": 2.0})
        assert p.power_pj_per_bit == pytest.approx(1.0)
        assert p.linear_density_gbs_mm == UCIE_S_32G.linear_density_gbs_mm


class TestPhyAxisCompileOnce:
    """Acceptance: the full [phy x mix x shoreline] space compiles exactly
    once per engine family, then runs warm."""

    def _space(self):
        return DesignSpace([
            axis("phy", list(PHYS)),
            axis("read_fraction", np.linspace(0.0, 1.0, 5)),
            axis("shoreline_mm", [4.0, 8.0]),
        ])

    def test_one_compile_per_family(self):
        space_mod.clear_cache()
        res = self._space().evaluate()
        assert space_mod.cache_stats(("memsys.catalog",)).misses == 1
        assert space_mod.cache_stats(("memsys.approach",)).misses == 1
        assert res["bandwidth_gbs"].dims == (
            "system", "phy", "read_fraction", "shoreline_mm")
        assert res["linear_density_gbs_mm"].dims == (
            "approach", "phy", "read_fraction")
        assert res["bandwidth_gbs"].coord("phy") == tuple(
            p.name for p in PHYS)
        first = space_mod.cache_stats()
        self._space().evaluate()
        second = space_mod.cache_stats()
        assert second.misses == first.misses
        assert second.hits > first.hits

    def test_phy_axis_excludes_bus_baselines(self):
        res = self._space().evaluate(metrics=("bandwidth_gbs",))
        keys = res["bandwidth_gbs"].coord("system")
        assert keys == tuple(k for k, _ in approach_catalog_items())
        assert not any("/" in k or k in ("HBM4", "LPDDR6") for k in keys)

    def test_phy_axis_conflicts_rejected(self):
        with pytest.raises(ValueError, match="not both"):
            DesignSpace([axis("phy", [UCIE_S_32G]),
                         axis("read_fraction", [0.5])],
                        phy=UCIE_A_32G_55U)
        with pytest.raises(ValueError, match="custom catalog"):
            DesignSpace([axis("phy", [UCIE_S_32G]),
                         axis("read_fraction", [0.5])],
                        catalog=dict(default_catalog_items()))
        with pytest.raises(ValueError, match="UCIePhy"):
            axis("phy", ["UCIe-A"])
        with pytest.raises(ValueError, match="duplicate phy"):
            axis("phy", [UCIE_S_32G, UCIE_S_32G])


class TestPhyAxisBitIdentity:
    """Acceptance: UCIe-A / UCIe-S rows of the PHY-stacked space are
    bit-identical to the pre-axis flat catalog and approach grids."""

    FRACS = np.linspace(0.0, 1.0, 7)

    def test_catalog_rows_match_flat_catalog(self):
        res = DesignSpace([
            axis("phy", list(PHYS)),
            axis("read_fraction", self.FRACS),
            axis("shoreline_mm", [4.0, 8.0]),
        ]).evaluate(metrics=("bandwidth_gbs", "pj_per_bit", "power_w"))
        x = (100.0 * self.FRACS)[:, None]
        flat = catalog_grid(x, 100.0 - x, np.asarray([4.0, 8.0]))
        sys_keys = res["bandwidth_gbs"].coord("system")
        checked = 0
        for i, key in enumerate(flat.keys):
            if "/" not in key:
                continue            # bus baselines have no phy
            app, tag = key.split("/")
            sub = res.sel(phy=TAG_TO_PHY[tag])
            s = sys_keys.index(app)
            for metric, legacy in (("bandwidth_gbs", flat.bandwidth_gbs),
                                   ("pj_per_bit", flat.pj_per_bit),
                                   ("power_w", flat.power_w)):
                np.testing.assert_array_equal(
                    sub[metric].values[s], np.asarray(legacy)[i],
                    err_msg=f"{key}/{metric}")
            checked += 1
        assert checked == 12        # 6 approaches x 2 packages

    def test_approach_rows_match_approach_grid(self):
        res = DesignSpace([
            axis("phy", list(PHYS)),
            axis("read_fraction", self.FRACS),
        ]).evaluate(metrics=("linear_density_gbs_mm",
                             "areal_density_gbs_mm2",
                             "approach_pj_per_bit"))
        x = 100.0 * self.FRACS
        for p in PHYS:
            ag = approach_grid(p, x, 100.0 - x)
            sub = res.sel(phy=p)            # UCIePhy selects by name
            np.testing.assert_array_equal(
                sub["linear_density_gbs_mm"].values, np.asarray(ag.linear))
            np.testing.assert_array_equal(
                sub["areal_density_gbs_mm2"].values, np.asarray(ag.areal))
            np.testing.assert_array_equal(
                sub["approach_pj_per_bit"].values,
                np.asarray(ag.pj_per_bit))

    def test_single_phy_axis_matches_phy_kwarg(self):
        """A one-entry phy axis and the legacy DesignSpace(phy=...) are the
        same program (same cache key), so bit-identical."""
        res_axis = DesignSpace([
            axis("phy", [UCIE_A_32G_55U]),
            axis("read_fraction", self.FRACS),
        ]).evaluate(metrics=("linear_density_gbs_mm",))
        res_kw = DesignSpace([axis("read_fraction", self.FRACS)],
                             phy=UCIE_A_32G_55U).evaluate(
            metrics=("linear_density_gbs_mm",))
        np.testing.assert_array_equal(
            res_axis["linear_density_gbs_mm"].sel(
                phy=UCIE_A_32G_55U.name).values,
            res_kw["linear_density_gbs_mm"].values)


class TestCatalogParam:
    """Analytic perturbation axis mirroring flitsim's protocol_param."""

    def test_baseline_row_identical_to_unperturbed(self):
        res = DesignSpace([
            axis("catalog_param", [{}, {"power_pj_per_bit": 2.0}]),
            axis("read_fraction", [0.25, 0.75]),
        ]).evaluate(metrics=("bandwidth_gbs", "pj_per_bit"))
        plain = DesignSpace([axis("read_fraction", [0.25, 0.75])]).evaluate(
            metrics=("bandwidth_gbs",))
        assert res["bandwidth_gbs"].dims == (
            "catalog_param", "system", "read_fraction")
        assert res["bandwidth_gbs"].coord("catalog_param")[0] == "baseline"
        np.testing.assert_array_equal(
            res["bandwidth_gbs"].sel(catalog_param="baseline").values,
            plain["bandwidth_gbs"].values)

    def test_perturbations_bind_ucie_only(self):
        """Scaling PHY pJ/b or shoreline density perturbs every UCIe
        system and leaves the (phy-less) bus baselines untouched."""
        res = DesignSpace([
            axis("catalog_param", [{}, {"power_pj_per_bit": 2.0},
                                   {"linear_density_gbs_mm": 0.5}]),
            axis("read_fraction", [0.5]),
        ]).evaluate(metrics=("bandwidth_gbs", "pj_per_bit"))
        keys = res["bandwidth_gbs"].coord("system")
        pj = res["pj_per_bit"].values
        bw = res["bandwidth_gbs"].values
        for s, key in enumerate(keys):
            if "/" in key:          # UCIe-attached
                assert pj[1, s, 0] == pytest.approx(2.0 * pj[0, s, 0]), key
                assert bw[2, s, 0] == pytest.approx(0.5 * bw[0, s, 0]), key
            else:                   # bus baseline: no PHY to perturb
                assert pj[1, s, 0] == pj[0, s, 0], key
                assert bw[2, s, 0] == bw[0, s, 0], key

    def test_composes_with_phy_axis(self):
        res = DesignSpace([
            axis("catalog_param", [{}, ("half_density",
                                        {"linear_density_gbs_mm": 0.5})]),
            axis("phy", [UCIE_S_32G, UCIE_A_32G_55U]),
            axis("read_fraction", [0.5]),
        ]).evaluate(metrics=("bandwidth_gbs",))
        bw = res["bandwidth_gbs"]
        assert bw.dims == ("catalog_param", "system", "phy",
                           "read_fraction")
        assert bw.coord("catalog_param") == ("baseline", "half_density")
        np.testing.assert_allclose(
            bw.sel(catalog_param="half_density").values,
            0.5 * bw.sel(catalog_param="baseline").values, rtol=1e-6)

    def test_unknown_field_rejected_at_axis_build(self):
        with pytest.raises(ValueError, match="unknown catalog perturbation"):
            axis("catalog_param", [{"g_slots": 0.5}])

    def test_compile_once_with_catalog_param(self):
        space_mod.clear_cache()
        DesignSpace([
            axis("catalog_param", [{}, {"power_pj_per_bit": 1.5}]),
            axis("read_fraction", [0.0, 0.5, 1.0]),
        ]).evaluate(metrics=("bandwidth_gbs",))
        assert space_mod.cache_stats(("memsys.catalog",)).misses == 1


class TestFeasibleWhere:
    """First-class feasibility: boolean SpaceArray masks composable with
    arbitrary axes via where=."""

    FRACS = np.linspace(0.0, 1.0, 11)

    @pytest.fixture(scope="class")
    def res(self):
        return DesignSpace([
            axis("read_fraction", self.FRACS),
            axis("shoreline_mm", [4.0, 8.0]),
        ]).evaluate()

    def test_static_mask_composition(self, res):
        """packaging + bit-cost masks equal the legacy selector
        system_mask (ex-_static_mask), broadcast over the grid."""
        cons = SelectionConstraints(packaging="UCIe-A",
                                    max_relative_bit_cost=2.0)
        m = res.feasible(cons)
        assert m.dims == res["bandwidth_gbs"].dims
        static = system_mask(default_catalog_items(), cons)
        np.testing.assert_array_equal(
            m.values, np.broadcast_to(static[:, None, None], m.shape))

    @pytest.mark.parametrize("cons", [
        SelectionConstraints(),
        SelectionConstraints(packaging="UCIe-S"),
        SelectionConstraints(max_relative_bit_cost=2.0),
        SelectionConstraints(max_power_w=5.0),
        SelectionConstraints(required_bandwidth_gbs=500.0),
    ])
    def test_frontier_where_matches_rank_grid(self, res, cons):
        front = res.frontier("bandwidth_gbs", where=res.feasible(cons))
        g = rank_grid((100.0 * self.FRACS)[:, None],
                      (100.0 - 100.0 * self.FRACS)[:, None],
                      constraints=cons,
                      shoreline_mm=np.asarray([4.0, 8.0]))
        np.testing.assert_array_equal(front.values, g.best_keys())

    def test_none_sentinel_matches_rank_grid(self, res):
        cons = SelectionConstraints(required_bandwidth_gbs=1e9)
        front = res.frontier("bandwidth_gbs", where=res.feasible(cons))
        assert (front.values == "(none)").all()

    def test_where_broadcasts_extra_dims(self, res):
        """A grid-shaped mask applied to the per-system latency column
        broadcasts the frontier over the mask's extra dims."""
        mask = res.feasible(SelectionConstraints(packaging="UCIe-S"))
        front = res.frontier("latency_ns", mode="min", where=mask)
        assert front.dims == ("read_fraction", "shoreline_mm")
        assert all("UCIe-S" in k for k in front.values.ravel())

    def test_sel_where_masks_to_nan(self, res):
        mask = res.feasible(SelectionConstraints(packaging="UCIe-A"))
        bw = res["bandwidth_gbs"].sel(where=mask, shoreline_mm=8.0)
        keys = res["bandwidth_gbs"].coord("system")
        for s, key in enumerate(keys):
            if "UCIe-A" in key:
                assert np.isfinite(bw.values[s]).all(), key
            else:
                assert np.isnan(bw.values[s]).all(), key

    def test_knee_budget_is_per_mix_on_a_mix_axis(self, res):
        """On a dense mix axis the knee budget follows each mix POINT —
        a strict refinement of rank_grid's canonical-mix envelope."""
        from repro.core import flitsim
        per = flitsim.backlog_knees(
            mixes=[(100.0 * r, 100.0 - 100.0 * r) for r in self.FRACS],
            per_mix=True)
        budget = float(np.min(per["cxl_opt"]))
        mask = res.feasible(SelectionConstraints(max_backlog_knee=budget))
        keys = res["bandwidth_gbs"].coord("system")
        e_row = mask.values[keys.index("E:cxl-mem-opt/UCIe-A")]
        np.testing.assert_array_equal(
            e_row[:, 0], per["cxl_opt"] <= budget)
        # the envelope (rank_grid semantics) would exclude E everywhere
        assert system_mask(
            default_catalog_items(),
            SelectionConstraints(max_backlog_knee=budget))[
            keys.index("E:cxl-mem-opt/UCIe-A")] == (
            float(np.max(per["cxl_opt"])) <= budget)

    def test_bridge_layout_matches_legacy_grid_ranking(self):
        """Acceptance: frontier(where=feasible) reproduces the legacy
        grid_ranking + valid_mask plumbing on the bridge layout
        [workload_config x mix(OWN+grid) x shoreline]."""
        from repro.core import flitsim
        from repro.core.memsys import CatalogGrid
        from repro.core.selector import sim_key_for
        configs = {"pure_read": TrafficMix(100, 0),
                   "balanced": TrafficMix(50, 50)}
        fracs = np.linspace(0.0, 1.0, 5)
        space = DesignSpace([
            axis("workload_config", configs),
            axis("mix", [OWN_MIX] + [(100.0 * r, 100.0 - 100.0 * r)
                                     for r in fracs]),
            axis("shoreline_mm", [4.0, 8.0]),
        ])
        res = space.evaluate()
        per = flitsim.backlog_knees(
            mixes=[(m.x, m.y) for m in configs.values()], per_mix=True)
        budget = float(per["cxl_opt"][0])
        cons = SelectionConstraints(max_backlog_knee=budget)
        front = res.frontier("bandwidth_gbs", where=res.feasible(cons))

        # legacy path: grid_ranking with the hand-built [S, C, 1, 1] mask
        items = default_catalog_items()
        grid = CatalogGrid(
            keys=res["bandwidth_gbs"].coord("system"),
            bandwidth_gbs=res["bandwidth_gbs"].values,
            pj_per_bit=res["pj_per_bit"].values,
            power_w=res["power_w"].values,
            gbs_per_watt=res["gbs_per_watt"].values,
            latency_ns=res["latency_ns"].values,
            relative_bit_cost=res["relative_bit_cost"].values)
        valid = np.ones((len(items), len(configs), 1, 1), dtype=bool)
        for i, (key, _) in enumerate(items):
            sim = sim_key_for(key)
            if sim is not None:
                valid[i, :, 0, 0] = per[sim] <= budget
        g = grid_ranking(items, grid, SelectionConstraints(),
                         objective="bandwidth", valid_mask=valid)
        np.testing.assert_array_equal(front.values, g.best_keys())

    def test_typo_dim_still_rejected(self, res):
        with pytest.raises(KeyError, match="not present on any array"):
            res.sel(backlogs=64.0)


def _load_module(path, name):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestArtifactConsumersSkipNewDims:
    """Per-cell artifact consumers must SKIP aggregate/axes-first exports
    (phy / catalog_param dimensions) instead of crashing."""

    CELL = {"arch": "a", "shape": "s", "mesh": "16x16", "chips": 256,
            "compile_s": 1.0, "num_microbatches": 4,
            "memory_analysis": {"argument_size_in_bytes": 1e9,
                                "temp_size_in_bytes": 1e9},
            "roofline": {"hlo_flops_per_chip": 1e12, "compute_s": 1.0,
                         "memory_s": 2.0, "collective_s": 0.5,
                         "dominant": "memory", "useful_flops_ratio": 0.5},
            "memsys_bridge": {"mix": "70R30W", "read_fraction": 0.7,
                              "hbm_baseline_memory_s": 2.0, "systems": {}}}
    PHY_EXPORT = {"arch": "x", "shape": "s", "mesh": "m",
                  "roofline": {}, "axes": ["phy", "read_fraction"]}
    AGGREGATE = {"keys": [], "workloads": {}}

    def test_is_cell_artifact_predicate(self):
        from repro.roofline.analysis import is_cell_artifact
        assert is_cell_artifact(self.CELL)
        assert not is_cell_artifact(self.PHY_EXPORT)
        assert not is_cell_artifact(self.AGGREGATE)
        assert not is_cell_artifact(
            {**self.CELL, "axes": ["catalog_param"]})
        assert not is_cell_artifact([1, 2, 3])

    def _write_artifacts(self, d):
        os.makedirs(d, exist_ok=True)
        for fname, payload in (("cell.json", self.CELL),
                               ("phy_export.json", self.PHY_EXPORT),
                               ("design_space.json", self.AGGREGATE),
                               ("broken.json", None)):
            with open(os.path.join(d, fname), "w") as f:
                if payload is None:
                    f.write("{not json")
                else:
                    json.dump(payload, f)

    def test_make_experiments_tables_skips(self, tmp_path, monkeypatch):
        mod = _load_module(
            os.path.join(REPO, "tools", "make_experiments_tables.py"),
            "make_experiments_tables")
        self._write_artifacts(str(tmp_path / "experiments" / "dryrun"))
        monkeypatch.setattr(mod, "ROOT", str(tmp_path))
        cells = mod.load("dryrun")
        assert list(cells) == [("a", "s", "16x16")]
        # and the table renders from the surviving cell without crashing
        assert "| a | s |" in mod.dryrun_table(cells, "16x16")

    def test_explorer_cell_files_skip(self, tmp_path, monkeypatch):
        mod = _load_module(
            os.path.join(REPO, "examples", "memsys_explorer.py"),
            "memsys_explorer")
        self._write_artifacts(str(tmp_path))
        monkeypatch.setattr(mod, "DRYRUN", str(tmp_path))
        files = mod._cell_files()
        assert [os.path.basename(f) for f in files] == ["cell.json"]


class TestSummaryTool:
    def test_summary_is_drift_stable_fields_only(self):
        mod = _load_module(
            os.path.join(REPO, "tools", "design_space_summary.py"),
            "design_space_summary")
        ds = {"keys": ["A", "B"], "objective": "bandwidth",
              "shorelines": [4.0, 8.0],
              "workloads": {"w": {
                  "mix": "70R30W", "best": "A", "feasible": True,
                  "crossovers": [
                      {"read_fraction_lo": 0.0, "read_fraction_hi": 0.6,
                       "best": "A"},
                      {"read_fraction_lo": 0.6, "read_fraction_hi": 1.0,
                       "best": "B"}],
                  "shoreline_frontier": {"4mm": "A", "8mm": "A"},
                  "shoreline_sensitive": False}},
              "joint_frontier": {
                  "keys": ["A", "B"],
                  "disagreement_regions": [
                      {"backlog": 2.0, "analytic_best": "A",
                       "simulated_best": "B"}]},
              "phy_frontier": {
                  "phys": ["P1"], "best_approach_by_phy": {"P1": "A"},
                  "regimes_by_phy": {"P1": [{"best": "A"}]}}}
        out = mod.summarize(ds)
        w = out["workloads"]["w"]
        assert w["crossover_winners"] == ["A", "B"]
        assert w["crossover_count"] == 2
        assert out["joint_frontier"]["disagreement_region_count"] == 1
        assert out["joint_frontier"]["disagreeing_backlogs"] == [2.0]
        assert out["phy_frontier"]["regime_winners_by_phy"] == {"P1": ["A"]}
        # no floating-point METRICS leak into the gate (grid coordinates
        # like shorelines/backlogs are exact, version-independent inputs)
        assert "read_fractions" not in out
        assert "disagreement_fraction" not in str(out)
