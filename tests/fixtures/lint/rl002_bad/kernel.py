"""RL002 bad fixture: no ref import, a re-implemented compute body, and
a BlockSpec that puts the row dimension after the cell dimension."""
from jax.experimental import pallas as pl

DEMO_ROWS = 4


def demo_compute(params, state):
    # drifted re-implementation of the ref body
    return params + state + 0.0


def _kernel(p_ref, s_ref, o_ref):
    o_ref[...] = demo_compute(p_ref[...], s_ref[...])


def launch(p, s, tile=128):
    return pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec((tile, DEMO_ROWS), lambda i: (i, 0))],
    )(p, s)
