"""RL002 bad fixture: same oracle as the good twin."""
DEMO_ROWS = 4


def demo_compute(params, state):
    return params + state
