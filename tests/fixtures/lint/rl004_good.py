"""RL004 good: traced step uses jnp.where; branches only on static
keyword-only parameters and shapes; the streaming dispatch loop syncs
only through the audited bounded-FIFO retire path."""
import collections

import jax
import jax.numpy as jnp
import numpy as np


def step(carry, x, *, saturate=True):
    if saturate:                      # static kwonly — exempt
        carry = jnp.minimum(carry + x, 1.0)
    if carry.shape[0] > 1:            # shape read — static, exempt
        carry = carry[:1]
    carry = jnp.where(carry > 0, carry + x, carry)
    return carry, carry


def run(xs):
    return jax.lax.scan(step, jnp.zeros(1), xs)


def cached_program(family, key, fn, args):
    return fn


def stream(chunks, prefetch=2):
    prog = cached_program("demo.sim", (), run, chunks[0])
    inflight = collections.deque()    # FIFO of in-flight dispatches
    out = []

    def retire():
        # repro-lint: disable=RL004  (audited FIFO retire sync)
        out.append(np.asarray(inflight.popleft()))

    for chunk in chunks:
        inflight.append(prog(chunk))  # async dispatch, bounded depth
        while len(inflight) >= prefetch:
            retire()
    while inflight:
        retire()
    return out
