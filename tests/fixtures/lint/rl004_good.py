"""RL004 good: traced step uses jnp.where; branches only on static
keyword-only parameters and shapes."""
import jax
import jax.numpy as jnp


def step(carry, x, *, saturate=True):
    if saturate:                      # static kwonly — exempt
        carry = jnp.minimum(carry + x, 1.0)
    if carry.shape[0] > 1:            # shape read — static, exempt
        carry = carry[:1]
    carry = jnp.where(carry > 0, carry + x, carry)
    return carry, carry


def run(xs):
    return jax.lax.scan(step, jnp.zeros(1), xs)
