"""RL003 good: kernel-scope constants and horizons stay within 2**24."""
from jax.experimental import pallas as pl  # noqa: F401  (kernel scope)

HORIZON = 4096
PERIOD_OBS = 128


def run(x, n_flits=4096, *, chunk=128):
    return x
