"""RL003 bad: a 2**25 horizon constant and a 50M default both overflow
the exact-integer range of the f32-encoded cycle counters."""
from jax.experimental import pallas as pl  # noqa: F401  (kernel scope)

HORIZON = 1 << 25


def run(x, n_flits=50_000_000):
    return x
