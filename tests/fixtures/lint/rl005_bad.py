"""RL005 bad: the registry names a field that does not exist on the
dataclass (renamed/typo drift) and is not sorted."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DemoPhy:
    linear_density_gbs_mm: float = 880.0
    power_pj_per_bit: float = 0.5


PERTURBABLE_DEMO_FIELDS = ("power_pj_per_bit", "linear_density_gbs_mm2")

#: derived without sorted()/fields(): nondeterministic, does not track
DERIVED_DEMO_FIELDS = tuple(vars(DemoPhy))
