"""Suppression fixture: a real RL003 violation silenced inline — it must
surface in the JSON report as suppressed but not fail the run."""
from jax.experimental import pallas as pl  # noqa: F401  (kernel scope)

# deliberate overflow, suppressed with an explanation as the syntax
# requires
BIG_HORIZON = 1 << 25  # repro-lint: disable=RL003
