"""RL004 bad: Python control flow, host syncs and stray numpy on traced
values inside a lax.scan step; a host sync on an in-flight device value
inside a streaming dispatch loop."""
import jax
import numpy as np


def step(carry, x):
    gain = carry + x
    if gain > 0:                      # Python branch on a traced value
        carry = gain
    while carry < 0:                  # Python loop on a traced value
        carry = carry + 1.0
    level = np.log1p(gain)            # stray numpy on a traced value
    return carry, float(level)        # host sync on a traced value


def run(xs):
    return jax.lax.scan(step, 0.0, xs)


def cached_program(family, key, fn, args):
    return fn


def stream(chunks):
    prog = cached_program("demo.sim", (), run, chunks[0])
    out = []
    for chunk in chunks:
        res = prog(chunk)
        out.append(np.asarray(res))   # sync inside the dispatch loop:
        # the host blocks on chunk t before marshalling chunk t+1
    return out
