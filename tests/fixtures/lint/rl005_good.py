"""RL005 good: the registry names real dataclass fields, sorted."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DemoPhy:
    linear_density_gbs_mm: float = 880.0
    power_pj_per_bit: float = 0.5


PERTURBABLE_DEMO_FIELDS = ("linear_density_gbs_mm", "power_pj_per_bit")

#: derived registries must go through sorted(dataclasses.fields(...))
DERIVED_DEMO_FIELDS = tuple(sorted(
    f.name for f in dataclasses.fields(DemoPhy)))
