"""RL001 good: every numerics-affecting field participates in key()."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GoodSimConfig:
    mode: str = "fixed"
    chunk: int = 128
    staleness: float = 1e-3

    def key(self):
        if self.mode == "fixed":
            return ("fixed",)
        return ("adaptive", int(self.chunk), float(self.staleness))
