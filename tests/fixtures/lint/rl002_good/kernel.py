"""RL002 good fixture: kernel imports the ref body, rows-leading specs."""
from jax.experimental import pallas as pl

from .ref import DEMO_ROWS, demo_compute


def _kernel(p_ref, s_ref, o_ref):
    o_ref[...] = demo_compute(p_ref[...], s_ref[...])


def launch(p, s, tile=128):
    return pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec((DEMO_ROWS, tile), lambda i: (0, i))],
    )(p, s)
