"""RL002 good fixture: the reference oracle owns the compute body."""
DEMO_ROWS = 4


def demo_compute(params, state):
    return params + state
