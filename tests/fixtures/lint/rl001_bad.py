"""RL001 bad: ``staleness`` changes numerics but never reaches key(), so
two configs differing only in staleness share one compiled executable —
the exact PR 5/6 incident class this check exists for."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BadSimConfig:
    mode: str = "fixed"
    chunk: int = 128
    staleness: float = 1e-3

    def key(self):
        if self.mode == "fixed":
            return ("fixed",)
        return ("adaptive", int(self.chunk))


@dataclasses.dataclass(frozen=True)
class RowParams:
    alpha: float = 1.0
    beta: float = 2.0
    gamma: float = 3.0


def rebuild(rows):
    # three-field dataclass rebuilt from only two rows: layout drift
    return RowParams(*[rows[i] for i in range(2)])
