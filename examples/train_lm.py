"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
CPU with the full production stack — microbatched train step, AdamW,
deterministic data pipeline, async checkpoints, a mid-run injected
failure (auto-restart), and the straggler monitor.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(~100M params: smollm-360m geometry narrowed to d_model=512/16L —
`--full` trains the real 362M config if you have the time.)
"""
import argparse
import dataclasses
import os
import tempfile
import time

import jax

from repro.configs import get
from repro.configs.shapes import ShapeSpec
from repro.models import ShardingCtx, build
from repro.runtime import DriverConfig, StragglerMonitor, run
from repro.train import (
    AdamW, SyntheticLM, cosine_schedule, init_state, make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get("smollm-360m")
    if not args.full:
        cfg = dataclasses.replace(
            cfg, name="smollm-100m", num_layers=16, d_model=512,
            num_heads=8, num_kv_heads=4, head_dim=64, d_ff=1536,
            vocab_size=32768)
    model = build(cfg)
    ctx = ShardingCtx()
    print(f"training {cfg.name}: {model.param_count():,} params, "
          f"{args.steps} steps, batch {args.global_batch}x{args.seq_len}")

    opt = AdamW(learning_rate=cosine_schedule(3e-3, warmup=20,
                                              total=args.steps))
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_train_step(model, opt, ctx, num_microbatches=2))
    src = SyntheticLM(cfg, ShapeSpec("ex", args.seq_len, args.global_batch,
                                     "train"))
    mon = StragglerMonitor()
    t_last = [time.perf_counter()]

    def on_step(step, metrics):
        now = time.perf_counter()
        mon.observe(step, now - t_last[0])
        t_last[0] = now
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({now - t_last[0] + (now - t_last[0]):.0f})")

    with tempfile.TemporaryDirectory() as d:
        dcfg = DriverConfig(
            total_steps=args.steps, ckpt_every=50, ckpt_dir=d,
            heartbeat_path=os.path.join(d, "heartbeat"),
            fail_at_steps=(args.steps // 2,))     # injected mid-run failure
        rep = run(step_fn, state, lambda s: src.place(src.batch_for_step(s),
                                                      ctx),
                  dcfg, on_step=on_step)
    print(f"\nfinished: {rep.steps_run} steps run "
          f"({rep.restarts} restart from step {rep.restored_steps}), "
          f"loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}")
    assert rep.losses[-1] < rep.losses[0]


if __name__ == "__main__":
    main()
