"""Batched serving example: continuous batching over mixed-length
requests, with per-request correctness vs single-request decoding.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get
from repro.models import ShardingCtx, build
from repro.serve import Request, ServingEngine


def main():
    cfg = get("smollm-360m").reduced()
    model = build(cfg)
    ctx = ShardingCtx()
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({model.param_count():,} params)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
               .astype(np.int32) for _ in range(10)]

    eng = ServingEngine(model, params, ctx, batch_slots=4, max_len=96)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {total} tokens, {total / dt:.1f} tok/s")

    # correctness: batched output == single-request output
    ref = ServingEngine(model, params, ctx, batch_slots=1, max_len=96)
    ref.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=12))
    r0 = ref.run_until_drained()[0]
    b0 = [r for r in done if r.rid == 0][0]
    assert r0.generated == b0.generated, "continuous batching changed output"
    print("continuous-batching correctness check passed")


if __name__ == "__main__":
    main()
