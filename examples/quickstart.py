"""Quickstart: the paper's models in five minutes.

Evaluates every UCIe-Memory approach (A-E) against the HBM4/LPDDR6
incumbents across traffic mixes, validates the closed forms against the
flit-level simulator, and picks the best memory system for a workload —
the paper's §IV in one script.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (
    ALL_APPROACHES, HBM4, LPDDR6, PAPER_MIXES, TrafficMix, UCIE_A_32G_55U,
    UCIE_S_32G, best, latency_speedup, rank,
)
from repro.core.flitsim import ANALYTIC, SIMULATORS


def main():
    print("=" * 72)
    print("UCIe-Memory (approaches A-E) vs HBM4 / LPDDR6 — paper Figs 10-12")
    print("=" * 72)
    hdr = f"{'approach':26s} " + " ".join(f"{m.name:>8s}" for m in PAPER_MIXES)
    print("\nLinear bandwidth density (GB/s/mm), UCIe-A @55um:")
    print(hdr)
    for key, proto in ALL_APPROACHES.items():
        vals = [float(proto.bw_density_linear(m.x, m.y, UCIE_A_32G_55U))
                for m in PAPER_MIXES]
        print(f"{key:26s} " + " ".join(f"{v:8.0f}" for v in vals))
    print(f"{'HBM4 (optimistic bus)':26s} " + " ".join(
        f"{HBM4.linear_density_gbs_mm:8.0f}" for _ in PAPER_MIXES))
    print(f"{'LPDDR6 (optimistic bus)':26s} " + " ".join(
        f"{LPDDR6.linear_density_gbs_mm:8.0f}" for _ in PAPER_MIXES))

    print("\nPower efficiency (pJ/b), UCIe-S vs HBM4=0.9:")
    print(hdr)
    for key, proto in ALL_APPROACHES.items():
        vals = [float(proto.power_pj_per_bit(m.x, m.y, UCIE_S_32G))
                for m in PAPER_MIXES]
        print(f"{key:26s} " + " ".join(f"{v:8.3f}" for v in vals))

    print("\nLatency speedups vs incumbents:", latency_speedup())

    print("\nFlit-level simulator vs closed forms (2R1W):")
    for key, sim in SIMULATORS.items():
        a = float(ANALYTIC[key].bw_eff(2, 1))
        s = sim(2, 1)
        print(f"  {key:14s} analytic={a:.4f} simulated={s:.4f} "
              f"err={abs(a - s) / a:.3%}")

    print("\nBest memory system for a 2R1W workload, 8mm shoreline:")
    for r in rank(TrafficMix(2, 1))[:5]:
        print(f"  {r.key:32s} {r.bandwidth_gbs:8.0f} GB/s  "
              f"{r.pj_per_bit:.3f} pJ/b  {r.latency_ns:.0f} ns")
    b = best(TrafficMix(2, 1), objective="gbs_per_watt")
    print(f"\npaper conclusion check — best power-efficient performance: "
          f"{b.key} ({b.gbs_per_watt:.1f} GB/s per W)")


if __name__ == "__main__":
    main()
