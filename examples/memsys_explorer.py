"""Memory-system explorer: the paper bridge end-to-end.

Four modes:

  * artifact mode (default) — takes a compiled workload cell from the
    dry-run artifacts (or computes a fresh one for a reduced config),
    derives its xRyW traffic mix from the HLO byte counts, and reports what
    every UCIe-Memory approach would deliver for that workload — bandwidth,
    power, latency — vs today's HBM.

        PYTHONPATH=src python examples/memsys_explorer.py [cell.json]

  * sweep mode — full design-space exploration over a dense 2-D
    (read-fraction x backlog) grid: the batched flit-simulation sweep
    engine evaluates every simulated protocol over hundreds of grid points
    in one compiled call per simulator family, and the batched selector
    ranks the whole catalog across the read-fraction axis in one more.

        PYTHONPATH=src python examples/memsys_explorer.py --sweep

  * bridge mode — the batched workload->design-space bridge: every
    workload's HLO-derived traffic mix (from dry-run artifacts when
    present, representative train/prefill/decode workloads otherwise)
    is stacked as a workload_config axis on top of the dense mix grid and
    a shoreline axis (the axes-first DesignSpace API), and the whole
    [configs x catalog x mixes x shorelines] space resolves through ONE
    compiled catalog evaluation.  Each workload reports its frontier:
    best system, read-fraction crossovers, shoreline sensitivity.  The
    mode then runs the joint (mix x backlog x shoreline)
    analytic-vs-flit-simulated frontier and flags the regions where the
    cycle-level simulation disagrees with the closed forms about the best
    memory system, evaluates the PHY-stacked frontier (UCIe-A/S at 32G
    plus the forward-looking 48G points, via the first-class ``phy``
    axis) plus its cycle-level counterpart (``sim_phy_frontier``: the
    simulated efficiency threaded onto each PHY's raw link bandwidth, per
    queue depth), and writes the whole report to
    experiments/dryrun/design_space.json (the CI artifact — a checked-in
    summary of its winner labels gates CI against drift).  The
    flit-simulated parts run the convergence-adaptive engine
    (``ADAPTIVE_SIM``) — the chunked cores early-exit once every grid
    cell's estimate converges, deviating <= ~1e-3 from the fixed engine.

        PYTHONPATH=src python examples/memsys_explorer.py --bridge

  * serving mode — the serving-trace frontier: synthetic serving traces
    (per-model memory traffic under Poisson/diurnal/bursty arrival
    processes, no weights needed) evaluated through the design space's
    ``trace`` axis, with queue/credit state carried across phase
    boundaries inside the flit simulators.  Reports which memory
    approach wins at which (model, QPS) point plus the trace-scan
    telemetry.  Bridge mode embeds the same report as the
    ``serving_frontier`` section of design_space.json.

        PYTHONPATH=src python examples/memsys_explorer.py --serving
"""
import glob
import json
import os
import sys
import time

import numpy as np

from repro.core import TrafficMix, rank, SelectionConstraints

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")

def _cell_artifacts():
    """Decoded per-cell artifacts as (path, dict) pairs.

    The aggregate design-space report (and any axes-first export carrying
    phy / catalog_param dimensions) lives next to the per-cell artifacts
    but has a different schema — per-cell consumers must SKIP anything
    that is not a workload cell, not crash on missing keys.
    """
    from repro.roofline.analysis import is_cell_artifact
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        try:
            with open(f) as fh:
                d = json.load(fh)
        except (OSError, ValueError):
            continue
        if is_cell_artifact(d):
            out.append((f, d))
    return out


def _cell_files():
    """Paths of the per-cell artifacts (see :func:`_cell_artifacts`)."""
    return [f for f, _ in _cell_artifacts()]


def explore(d: dict):
    r = d["roofline"]
    br = d["memsys_bridge"]
    print(f"cell: {d['arch']} × {d['shape']} × {d['mesh']} "
          f"({d['chips']} chips)")
    print(f"  traffic mix (from HLO bytes): {br['mix']} "
          f"(read fraction {br['read_fraction']:.2f})")
    print(f"  roofline: compute {r['compute_s']*1e3:.1f} ms | "
          f"memory {r['memory_s']*1e3:.1f} ms | "
          f"collective {r['collective_s']*1e3:.1f} ms  "
          f"-> {r['dominant']}-bound")
    print(f"\n  memory systems for this workload "
          f"(8 mm shoreline; HBM-baseline memory term "
          f"{br['hbm_baseline_memory_s']*1e3:.1f} ms):")
    rows = sorted(br["systems"].items(),
                  key=lambda kv: kv[1]["memory_term_s"])
    for key, s in rows:
        print(f"    {key:32s} {s['bandwidth_gbs']:8.0f} GB/s  "
              f"{s['pj_per_bit']:.3f} pJ/b  {s['latency_ns']:4.1f} ns  "
              f"memory term {s['memory_term_s']*1e3:8.2f} ms  "
              f"{s['interconnect_energy_j_per_step']:.2f} J/step")


def sweep_mode(n_fracs: int = 41, backlogs=(1, 2, 4, 8, 16, 32, 64, 128)):
    """Dense design-space sweep: read-fraction x backlog x protocol.

    Runs the convergence-adaptive engine (``ADAPTIVE_SIM``): the chunked
    cores early-exit as soon as the slowest grid cell converges, with the
    few non-converging straggler cells re-simulated exactly.
    """
    from repro.core import ADAPTIVE_SIM, flitsim, mix_grid
    from repro.core.space import DesignSpace, axis

    x, y = mix_grid(n_fracs)
    fracs = np.asarray(x) / 100.0

    t0 = time.perf_counter()
    res = DesignSpace([
        axis("backlog", list(backlogs)),
        axis("read_fraction", fracs),
    ], sim=ADAPTIVE_SIM).evaluate(metrics=("sim_efficiency",))
    sa = res["sim_efficiency"]
    protocols = list(sa.coord("protocol"))
    eff = np.asarray(sa.values)                   # [P, B, M]
    t_sim = time.perf_counter() - t0
    n_pts = eff.size
    stats = flitsim.compile_cache_stats()
    print(f"flit-simulated {n_pts} grid points "
          f"({len(protocols)} protocols x {len(backlogs)} backlogs x "
          f"{n_fracs} read fractions) in {t_sim:.2f}s "
          f"[{stats.misses} compiles, {stats.hits} cache hits]")
    for fam, info in sorted(flitsim.last_run_info().items()):
        if info.get("mode") != "adaptive":
            continue
        print(f"    {fam.split('.')[1]:10s} adaptive: "
              f"{info['cycles_run']}/{info['horizon']} cycles "
              f"({info['stragglers']} stragglers re-simulated exactly)")

    bl_ref = list(backlogs).index(64) if 64 in backlogs else len(backlogs) - 1
    print(f"\nsimulated data efficiency at backlog={backlogs[bl_ref]} "
          f"(read fraction 0 / 0.5 / 1):")
    mid = n_fracs // 2
    for i, key in enumerate(protocols):
        e = eff[i, bl_ref]
        sens = float(np.max(eff[i, :, mid]) - np.min(eff[i, :, mid]))
        print(f"    {key:12s} {e[0]:.3f} / {e[mid]:.3f} / {e[-1]:.3f}   "
              f"backlog sensitivity @50/50: {sens:.3f}")

    print("\nbest simulated protocol per read-fraction regime "
          f"(backlog={backlogs[bl_ref]}):")
    best = np.argmax(eff[:, bl_ref, :], axis=0)
    start = 0
    for j in range(1, n_fracs + 1):
        if j == n_fracs or best[j] != best[start]:
            key = protocols[best[start]]
            print(f"    read fraction {fracs[start]:.2f}-"
                  f"{fracs[j - 1]:.2f}: {key}")
            start = j

    # catalog ranking over the same read-fraction axis, one compiled call
    t0 = time.perf_counter()
    cres = DesignSpace([axis("read_fraction", fracs)]).evaluate(
        metrics=("bandwidth_gbs",))
    keys = cres.frontier("bandwidth_gbs").values
    n_sys = len(cres["bandwidth_gbs"].coord("system"))
    t_rank = time.perf_counter() - t0
    print(f"\ncatalog ranking over {n_fracs} read fractions "
          f"({n_sys} systems) in {t_rank*1e3:.1f} ms:")
    start = 0
    for j in range(1, n_fracs + 1):
        if j == n_fracs or keys[j] != keys[start]:
            print(f"    read fraction {fracs[start]:.2f}-"
                  f"{fracs[j - 1]:.2f}: {keys[start]}")
            start = j


#: Fallback workloads (per-chip bytes) when no dry-run artifacts exist:
#: training reads weights+activations and writes gradients; prefill is
#: read-heavy; decode is nearly pure weight streaming.
REPRESENTATIVE_WORKLOADS = {
    "train_67R33W": (6.7e9, 3.3e9, 1.0e10),
    "prefill_85R15W": (1.27e10, 2.3e9, 1.5e10),
    "decode_95R5W": (1.9e10, 1.0e9, 2.0e10),
}


def phy_frontier_report(n_fracs: int = 21, shorelines=(4.0, 8.0, 16.0)):
    """First-class ``phy`` axis: the catalog across UCIe-A/UCIe-S at 32G
    plus the forward-looking 48G (UCIe 2.0 scaling) points, in ONE
    PHY-stacked evaluation.  Thin wrapper over the unified report API
    (:func:`repro.core.report.build_report`, section ``"phy"``); returns
    the JSON-able report section for the CI design-space artifact."""
    from repro.core.report import ReportSpec, build_report

    spec = ReportSpec(sections=("phy",), verbose=True,
                      options={"phy": {"n_fracs": n_fracs,
                                       "shorelines": shorelines}})
    return build_report(spec)["phy"].payload


def sim_phy_frontier_report(n_fracs: int = 21, backlogs=(2.0, 64.0)):
    """Simulation-corrected PHY-absolute frontier: the flit simulators'
    data efficiency threaded onto each PHY generation's raw link bandwidth
    (``sim_bandwidth_gbs`` = sim efficiency x ``UCIePhy.raw_bandwidth_gbs``)
    — the cycle-level counterpart of the analytic ``phy_frontier``.  Thin
    wrapper over the unified report API (section ``"sim_phy"``); runs the
    convergence-adaptive engine and returns the JSON-able report section
    for the CI design-space artifact."""
    from repro.core.report import ReportSpec, build_report

    spec = ReportSpec(sections=("sim_phy",), verbose=True,
                      options={"sim_phy": {"n_fracs": n_fracs,
                                           "backlogs": backlogs}})
    return build_report(spec)["sim_phy"].payload


def serving_frontier_report(models=None, qps_points=None, **kwargs):
    """Serving-trace frontier: which memory approach wins at which
    (model, QPS) point.  Synthetic serving traces (config shapes only, no
    weights) are evaluated through the design space's ``trace`` axis —
    queue/credit state carried across phase boundaries — and each
    (model, QPS) cell's winning protocol on the UCIe-A PHY is mapped to
    its catalog memory approach.  Prints the frontier plus the trace-scan
    telemetry; returns the JSON-able ``serving_frontier`` artifact
    section (sourced through the unified report API, section
    ``"serving"``)."""
    from repro.core.report import ReportSpec, build_report

    t0 = time.perf_counter()
    opts = dict(kwargs, models=models, qps_points=qps_points)
    spec = ReportSpec(sections=("serving",), options={"serving": opts})
    rep = build_report(spec)["serving"].payload
    dt = time.perf_counter() - t0
    n_cells = len(rep["models"]) * len(rep["qps_points"])
    print(f"serving frontier: {len(rep['models'])} models x "
          f"{len(rep['qps_points'])} QPS points x "
          f"{len(rep['protocols'])} protocols ({rep['n_phases']} phases "
          f"per trace, {rep['arrival']} arrivals) in {dt:.2f}s "
          f"[{rep['compiles']} compiles on {rep['phy']}]")
    for fam, tele in sorted(rep["telemetry"].items()):
        print(f"    {fam.split('.')[1]:10s} trace-scan: "
              f"{tele['phases']} phases x {tele['cycles_per_phase']} "
              f"cycles ({tele['trace_cells']} cells, state carried "
              f"across {tele['state_carry_depth']} cycles)")
    for m in rep["models"]:
        wins = rep["winner_by_model_qps"][m]
        gbs = rep["winner_gbs_by_model_qps"][m]
        pts = "  ".join(
            f"qps={q}: {wins[q]} ({gbs[q]:.0f} GB/s)" for q in wins)
        tag = "QPS-SENSITIVE" if rep["qps_sensitive"][m] else \
            "qps-insensitive"
        print(f"    {m:14s} {pts}  [{tag}]")
    if n_cells and not any(rep["qps_sensitive"].values()):
        print("    (one approach serves every load point on this PHY)")
    return rep


def serving_mode():
    """``--serving``: print the serving-trace frontier standalone."""
    rep = serving_frontier_report()
    traces = rep["traces"]
    print(f"\n{len(traces)} synthetic traces "
          f"({rep['n_ticks']} engine ticks each):")
    for name in rep["trace_names"]:
        t = traces[name]
        rf = "/".join(f"{r:.2f}" for r in t["read_fractions"])
        bl = "/".join(f"{b:.0f}" for b in t["backlogs"])
        print(f"    {name:22s} read fraction {rf}  backlog {bl}")


def bridge_mode(n_fracs: int = 41, shorelines=(2.0, 4.0, 8.0, 16.0)):
    """Batched workload->design-space bridge over all available cells."""
    from repro.core.memsys import grid_cache_stats
    from repro.roofline.analysis import RooflineReport, bridge_design_space

    reports = {}
    for _, d in _cell_artifacts():
        reports[f"{d['arch']}__{d['shape']}__{d['mesh']}"] = RooflineReport(
            **d["roofline"])
    if reports:
        print(f"{len(reports)} workload cells from dry-run artifacts")
    else:
        print("no dry-run artifacts; using representative workloads")
        for name, (r, w, hb) in REPRESENTATIVE_WORKLOADS.items():
            reports[name] = RooflineReport(
                arch=name, shape="-", mesh="-", chips=256,
                hlo_flops_per_chip=0.0, hlo_bytes_per_chip=hb,
                collective_bytes_per_chip=0.0, compute_s=0.0,
                memory_s=hb / 8.192e11, collective_s=0.0,
                dominant="memory", model_flops=0.0, useful_flops_ratio=0.0,
                read_bytes_per_chip=r, write_bytes_per_chip=w)

    t0 = time.perf_counter()
    ds = bridge_design_space(reports, n_fracs=n_fracs,
                             shorelines=shorelines)
    dt = time.perf_counter() - t0
    stats = grid_cache_stats()
    n_pts = (len(reports) * len(ds["keys"]) * (n_fracs + 1)
             * len(shorelines))
    print(f"design space: {len(reports)} workloads x {len(ds['keys'])} "
          f"systems x {n_fracs + 1} mixes x {len(shorelines)} shorelines "
          f"= {n_pts} points in {dt:.2f}s "
          f"[{stats.misses} compiles, {stats.hits} cache hits]\n")
    for name, w in ds["workloads"].items():
        hbm_t = w["hbm_baseline_memory_s"]
        best_t = w["systems"][w["best"]]["memory_term_s"]
        print(f"{name}  ({w['mix']}, read fraction "
              f"{w['read_fraction']:.2f})")
        print(f"    best @ {ds['reference_shoreline_mm']:g} mm: "
              f"{w['best']}  memory term {best_t*1e3:.2f} ms "
              f"(HBM baseline {hbm_t*1e3:.2f} ms, "
              f"x{hbm_t / best_t:.2f})")
        regimes = ", ".join(
            f"{c['read_fraction_lo']:.2f}-{c['read_fraction_hi']:.2f}:"
            f"{c['best']}" for c in w["crossovers"])
        print(f"    read-fraction frontier: {regimes}")
        if w["shoreline_sensitive"]:
            print(f"    shoreline-SENSITIVE: {w['shoreline_frontier']}")
        else:
            budgets = ", ".join(f"{s:g}" for s in ds["shorelines"])
            print(f"    shoreline-insensitive ({budgets} mm)")
        print()

    # joint (mix x backlog x shoreline) analytic-vs-simulated frontier:
    # where do the closed forms and the cycle-level simulation DISAGREE
    # about the best memory system?  Runs the convergence-adaptive engine
    # (canonical artifact grid; winner labels are gate-checked against
    # the fixed-mode golden summary).
    from repro.core import ADAPTIVE_SIM
    from repro.core.report import ReportSpec, build_report
    jf = build_report(ReportSpec(sections=("joint",), sim=ADAPTIVE_SIM,
                                 verbose=True))["joint"].payload
    errs = ", ".join(f"{k}={v:.1%}"
                     for k, v in jf["protocol_rel_err"].items())
    print(f"    worst simulated-vs-analytic efficiency error: {errs}")
    if jf["disagreement_regions"]:
        print("    disagreement regions (simulation overrules the closed "
              "forms):")
        for r in jf["disagreement_regions"][:8]:
            print(f"      backlog={r['backlog']:g} "
                  f"shoreline={r['shoreline_mm']:g}mm read fraction "
                  f"{r['read_fraction_lo']:.2f}-{r['read_fraction_hi']:.2f}"
                  f": analytic {r['analytic_best']} -> simulated "
                  f"{r['simulated_best']}")
        extra = len(jf["disagreement_regions"]) - 8
        if extra > 0:
            print(f"      ... and {extra} more regions")
    else:
        print("    no disagreement: the closed forms pick the simulated "
              "winner everywhere")

    # PHY as a first-class axis: UCIe-A/S at 32G + the 48G (UCIe 2.0
    # scaling) points, one PHY-stacked compiled evaluation
    print()
    pf = phy_frontier_report()

    # ...and its cycle-level counterpart: the flit-simulated efficiency
    # threaded onto each PHY's raw bandwidth (sim_bandwidth_gbs), per
    # queue depth
    print()
    spf = sim_phy_frontier_report()

    # ...and the serving-trace frontier: time-varying traffic from the
    # LLM serving workloads, winners per (model, QPS) point
    print()
    sf = serving_frontier_report()

    from repro.roofline.analysis import DESIGN_SPACE_JSON
    ds["joint_frontier"] = jf
    ds["phy_frontier"] = pf
    ds["sim_phy_frontier"] = spf
    ds["serving_frontier"] = sf
    os.makedirs(DRYRUN, exist_ok=True)
    out_path = os.path.join(DRYRUN, DESIGN_SPACE_JSON)
    with open(out_path, "w") as f:
        json.dump(ds, f, indent=1)
    print(f"\nwrote {os.path.relpath(out_path)}")


def main():
    args = [a for a in sys.argv[1:]]
    if "--sweep" in args:
        sweep_mode()
        return
    if "--bridge" in args:
        bridge_mode()
        return
    if "--serving" in args:
        serving_mode()
        return
    if args:
        with open(args[0]) as fh:
            cells = [json.load(fh)]
    else:
        cells = [d for _, d in _cell_artifacts()[:3]]
    if not cells:
        print("no dry-run artifacts; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first "
              "(or try `--sweep` for the design-space sweep, which needs "
              "no artifacts)")
        return
    for d in cells:
        explore(d)
        print()


if __name__ == "__main__":
    main()
