"""Memory-system explorer: the paper bridge end-to-end.

Takes a compiled workload cell from the dry-run artifacts (or computes a
fresh one for a reduced config), derives its xRyW traffic mix from the
HLO byte counts, and reports what every UCIe-Memory approach would
deliver for that workload — bandwidth, power, latency — vs today's HBM.

    PYTHONPATH=src python examples/memsys_explorer.py [cell.json]
"""
import glob
import json
import os
import sys

from repro.core import TrafficMix, rank, SelectionConstraints

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def explore(d: dict):
    r = d["roofline"]
    br = d["memsys_bridge"]
    print(f"cell: {d['arch']} × {d['shape']} × {d['mesh']} "
          f"({d['chips']} chips)")
    print(f"  traffic mix (from HLO bytes): {br['mix']} "
          f"(read fraction {br['read_fraction']:.2f})")
    print(f"  roofline: compute {r['compute_s']*1e3:.1f} ms | "
          f"memory {r['memory_s']*1e3:.1f} ms | "
          f"collective {r['collective_s']*1e3:.1f} ms  "
          f"-> {r['dominant']}-bound")
    print(f"\n  memory systems for this workload "
          f"(8 mm shoreline; HBM-baseline memory term "
          f"{br['hbm_baseline_memory_s']*1e3:.1f} ms):")
    rows = sorted(br["systems"].items(),
                  key=lambda kv: kv[1]["memory_term_s"])
    for key, s in rows:
        print(f"    {key:32s} {s['bandwidth_gbs']:8.0f} GB/s  "
              f"{s['pj_per_bit']:.3f} pJ/b  {s['latency_ns']:4.1f} ns  "
              f"memory term {s['memory_term_s']*1e3:8.2f} ms  "
              f"{s['interconnect_energy_j_per_step']:.2f} J/step")


def main():
    if len(sys.argv) > 1:
        files = [sys.argv[1]]
    else:
        files = sorted(glob.glob(os.path.join(DRYRUN, "*.json")))[:3]
    if not files:
        print("no dry-run artifacts; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return
    for f in files:
        with open(f) as fh:
            explore(json.load(fh))
        print()


if __name__ == "__main__":
    main()
