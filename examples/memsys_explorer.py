"""Memory-system explorer: the paper bridge end-to-end.

Two modes:

  * artifact mode (default) — takes a compiled workload cell from the
    dry-run artifacts (or computes a fresh one for a reduced config),
    derives its xRyW traffic mix from the HLO byte counts, and reports what
    every UCIe-Memory approach would deliver for that workload — bandwidth,
    power, latency — vs today's HBM.

        PYTHONPATH=src python examples/memsys_explorer.py [cell.json]

  * sweep mode — full design-space exploration over a dense 2-D
    (read-fraction x backlog) grid: the batched flit-simulation sweep
    engine evaluates every simulated protocol over hundreds of grid points
    in one compiled call per simulator family, and the batched selector
    ranks the whole catalog across the read-fraction axis in one more.

        PYTHONPATH=src python examples/memsys_explorer.py --sweep
"""
import glob
import json
import os
import sys
import time

import numpy as np

from repro.core import TrafficMix, rank, SelectionConstraints

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def explore(d: dict):
    r = d["roofline"]
    br = d["memsys_bridge"]
    print(f"cell: {d['arch']} × {d['shape']} × {d['mesh']} "
          f"({d['chips']} chips)")
    print(f"  traffic mix (from HLO bytes): {br['mix']} "
          f"(read fraction {br['read_fraction']:.2f})")
    print(f"  roofline: compute {r['compute_s']*1e3:.1f} ms | "
          f"memory {r['memory_s']*1e3:.1f} ms | "
          f"collective {r['collective_s']*1e3:.1f} ms  "
          f"-> {r['dominant']}-bound")
    print(f"\n  memory systems for this workload "
          f"(8 mm shoreline; HBM-baseline memory term "
          f"{br['hbm_baseline_memory_s']*1e3:.1f} ms):")
    rows = sorted(br["systems"].items(),
                  key=lambda kv: kv[1]["memory_term_s"])
    for key, s in rows:
        print(f"    {key:32s} {s['bandwidth_gbs']:8.0f} GB/s  "
              f"{s['pj_per_bit']:.3f} pJ/b  {s['latency_ns']:4.1f} ns  "
              f"memory term {s['memory_term_s']*1e3:8.2f} ms  "
              f"{s['interconnect_energy_j_per_step']:.2f} J/step")


def sweep_mode(n_fracs: int = 41, backlogs=(1, 2, 4, 8, 16, 32, 64, 128)):
    """Dense design-space sweep: read-fraction x backlog x protocol."""
    from repro.core import flitsim, mix_grid
    from repro.core.selector import rank_grid

    x, y = mix_grid(n_fracs)
    mixes = list(zip(np.asarray(x).tolist(), np.asarray(y).tolist()))
    fracs = np.asarray(x) / 100.0

    t0 = time.perf_counter()
    res = flitsim.sweep(mixes=mixes, backlogs=list(backlogs))
    eff = np.asarray(res.efficiency)              # [P, B, M]
    t_sim = time.perf_counter() - t0
    n_pts = eff.size
    stats = flitsim.compile_cache_stats()
    print(f"flit-simulated {n_pts} grid points "
          f"({len(res.protocols)} protocols x {len(backlogs)} backlogs x "
          f"{n_fracs} read fractions) in {t_sim:.2f}s "
          f"[{stats.misses} compiles, {stats.hits} cache hits]")

    bl_ref = list(backlogs).index(64) if 64 in backlogs else len(backlogs) - 1
    print(f"\nsimulated data efficiency at backlog={backlogs[bl_ref]} "
          f"(read fraction 0 / 0.5 / 1):")
    mid = n_fracs // 2
    for i, key in enumerate(res.protocols):
        e = eff[i, bl_ref]
        sens = float(np.max(eff[i, :, mid]) - np.min(eff[i, :, mid]))
        print(f"    {key:12s} {e[0]:.3f} / {e[mid]:.3f} / {e[-1]:.3f}   "
              f"backlog sensitivity @50/50: {sens:.3f}")

    print("\nbest simulated protocol per read-fraction regime "
          f"(backlog={backlogs[bl_ref]}):")
    best = np.argmax(eff[:, bl_ref, :], axis=0)
    start = 0
    for j in range(1, n_fracs + 1):
        if j == n_fracs or best[j] != best[start]:
            key = res.protocols[best[start]]
            print(f"    read fraction {fracs[start]:.2f}-"
                  f"{fracs[j - 1]:.2f}: {key}")
            start = j

    # catalog ranking over the same read-fraction axis, one compiled call
    t0 = time.perf_counter()
    g = rank_grid(x, y)
    keys = g.best_keys()
    t_rank = time.perf_counter() - t0
    print(f"\ncatalog ranking over {n_fracs} read fractions "
          f"({len(g.keys)} systems) in {t_rank*1e3:.1f} ms:")
    start = 0
    for j in range(1, n_fracs + 1):
        if j == n_fracs or keys[j] != keys[start]:
            print(f"    read fraction {fracs[start]:.2f}-"
                  f"{fracs[j - 1]:.2f}: {keys[start]}")
            start = j


def main():
    args = [a for a in sys.argv[1:]]
    if "--sweep" in args:
        sweep_mode()
        return
    if args:
        files = [args[0]]
    else:
        files = sorted(glob.glob(os.path.join(DRYRUN, "*.json")))[:3]
    if not files:
        print("no dry-run artifacts; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first "
              "(or try `--sweep` for the design-space sweep, which needs "
              "no artifacts)")
        return
    for f in files:
        with open(f) as fh:
            explore(json.load(fh))
        print()


if __name__ == "__main__":
    main()
