#!/usr/bin/env python
"""Thin wrapper so ``tools/lint.py`` works without PYTHONPATH setup:
inserts the repo's ``src/`` ahead of sys.path and runs ``repro.lint``
(the same entry as ``python -m repro.lint``)."""
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
