"""Generate the EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python tools/make_experiments_tables.py
"""
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(dirname):
    cells = {}
    for f in sorted(glob.glob(os.path.join(ROOT, "experiments", dirname,
                                           "*.json"))):
        # aggregate report (repro.roofline.analysis.DESIGN_SPACE_JSON),
        # not a per-cell artifact — literal kept: this tool runs standalone
        if os.path.basename(f) == "design_space.json":
            continue
        try:
            d = json.load(open(f))
        except ValueError:
            continue
        # skip (don't crash on) anything that is not a per-cell artifact:
        # axes-first exports carry phy / catalog_param dimensions and a
        # different schema (mirrors repro.roofline.analysis.is_cell_artifact,
        # inlined because this tool runs standalone)
        if not isinstance(d, dict) or not all(
                k in d for k in ("arch", "shape", "mesh", "roofline")):
            continue
        if any(a in (d.get("axes") or ()) for a in ("phy", "catalog_param")):
            continue
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def dryrun_table(cells, mesh):
    out = ["| arch | shape | chips | microbatches | state/args GB/chip | "
           "temp GB/chip | HLO GFLOP/chip | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        ma = d["memory_analysis"]
        args = ma.get("argument_size_in_bytes", 0) / 1e9
        temp = ma.get("temp_size_in_bytes", 0) / 1e9
        out.append(
            f"| {arch} | {shape} | {d['chips']} | "
            f"{d.get('num_microbatches', '-')} | {args:.2f} | {temp:.2f} | "
            f"{d['roofline']['hlo_flops_per_chip']/1e9:.0f} | "
            f"{d['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(cells, mesh="16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | useful FLOPs | mix | best UCIe memsys "
           "(mem-term gain) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        r = d["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        br = d["memsys_bridge"]
        best_k, best_v = None, None
        for k, s in br["systems"].items():
            if "/" not in k:
                continue
            if best_v is None or s["memory_term_s"] < best_v:
                best_k, best_v = k, s["memory_term_s"]
        gain = (br["hbm_baseline_memory_s"] / best_v) if best_v else 0.0
        rf = br.get("read_fraction")
        mix = (f"{100*rf:.0f}R{100*(1-rf):.0f}W" if rf is not None
               else br["mix"])
        out.append(
            f"| {arch} | {shape} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {frac:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {mix} | "
            f"{best_k} (x{gain:.1f}) |")
    return "\n".join(out)


def main():
    final = load("dryrun")
    base = load("dryrun_baseline")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run (single pod, 16x16 = 256 chips)\n")
        print(dryrun_table(final or base, "16x16"))
        print("\n### Dry-run (multi-pod, 2x16x16 = 512 chips)\n")
        print(dryrun_table(final or base, "2x16x16"))
    if which in ("all", "roofline"):
        print("\n### Roofline (single pod)\n")
        print(roofline_table(final or base))
    if which in ("all", "baseline"):
        print("\n### Baseline roofline (pre-hillclimb)\n")
        print(roofline_table(base))


if __name__ == "__main__":
    main()
